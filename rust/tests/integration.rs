//! Integration tests across the stack: python-goldens ↔ rust solver
//! parity, artifact loading, native execution, the serving coordinator,
//! and the circuit-vs-compiled cross-check.
//!
//! Tests that need `make artifacts` detect the missing directory through
//! the `artifacts()` helper and *skip with a message* instead of failing,
//! so `cargo test -q` stays green on a clean checkout.  The router /
//! coordinator tests construct their engines in memory and always run.

use std::collections::HashSet;
use std::path::PathBuf;
use std::time::Duration;

use sac::cells::multiplier::Multiplier;
use sac::cells::{Algorithmic, HProvider};
use sac::coordinator::{
    synthetic_engine, Engine, InferenceServer, RequestId, Router, RouterConfig,
};
use sac::data::{Dataset, TrainedNet};
use sac::nn::batch::{BatchKernel, GridConfig};
use sac::pdk::regime::Regime;
use sac::pdk::{CMOS180, FINFET7};
use sac::runtime::{Executable, ExecMode, Runtime};
use sac::sac::gmp::{solve_bisect, Shape, GMP_ITERS};
use sac::sac::TableModel;
use sac::util::json;

/// Artifact directory, or `None` (with an explanatory message) when the
/// artifacts have not been built — the caller returns early, skipping the
/// test body without failing the suite.
fn artifacts() -> Option<PathBuf> {
    let dir = sac::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: artifacts/ not built (run `make artifacts`, i.e. \
             python -m compile.aot from python/)"
        );
        None
    }
}

#[test]
fn rust_gmp_matches_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let j = json::parse_file(&dir.join("goldens_gmp.json")).unwrap();
    let cases = j.get("gmp").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let c = case.get("c").unwrap().as_f64().unwrap();
        let xs = case.get("x").unwrap().as_f64_mat().unwrap();
        let hs = case.get("h").unwrap().as_f64_vec().unwrap();
        for (row, &h_py) in xs.iter().zip(&hs) {
            let h_rs = solve_bisect(row, c, Shape::Relu, GMP_ITERS);
            assert!(
                (h_rs - h_py).abs() < 1e-5,
                "c={c} rust={h_rs} python={h_py}"
            );
        }
    }
}

#[test]
fn rust_cells_match_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let j = json::parse_file(&dir.join("goldens_gmp.json")).unwrap();
    let zs = j.get("z").unwrap().as_f64_vec().unwrap();
    let cells = j.get("cells").unwrap();
    let alg = Algorithmic::relu();
    let check = |name: &str, f: &dyn Fn(f64) -> f64| {
        let py = cells.get(name).unwrap().as_f64_vec().unwrap();
        for (&z, &y_py) in zs.iter().zip(&py) {
            let y_rs = f(z);
            assert!(
                (y_rs - y_py).abs() < 1e-4,
                "{name}(z={z}): rust={y_rs} python={y_py}"
            );
        }
    };
    check("proto_s1", &|z| sac::cells::proto_unit(&alg, z, 1, 1.0));
    check("proto_s3", &|z| sac::cells::proto_unit(&alg, z, 3, 1.0));
    check("relu", &|z| {
        sac::cells::activations::relu_cell(&alg, z, 0.05)
    });
    check("phi1", &|z| {
        sac::cells::activations::phi1_cell(&alg, z, 1.0, 3, 0.5)
    });
    check("cosh", &|z| {
        sac::cells::activations::cosh_cell(&alg, z, 3, 1.0)
    });
    check("sinh", &|z| {
        sac::cells::activations::sinh_cell(&alg, z, 3, 1.0)
    });
}

#[test]
fn native_gmp_kernel_matches_rust_solver() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("gmp_kernel").unwrap();
    let shape = &exe.spec.params[0].shape;
    let (b, m) = (shape[0], shape[1]);
    let c = exe.spec.meta.get("c").unwrap().as_f64().unwrap();
    // deterministic pseudo-random input
    let mut rng = sac::util::rng::Rng::new(99);
    let buf: Vec<f32> = (0..b * m)
        .map(|_| rng.uniform_in(-3.0, 3.0) as f32)
        .collect();
    let out = exe.run_f32(&[&buf]).unwrap();
    assert_eq!(out.len(), b);
    // spot-check rows against the rust bisection solver
    for row in (0..b).step_by(97) {
        let xs: Vec<f64> = (0..m).map(|j| buf[row * m + j] as f64).collect();
        let h_rs = solve_bisect(&xs, c, Shape::Relu, GMP_ITERS);
        assert!(
            (out[row] as f64 - h_rs).abs() < 1e-4,
            "row {row}: native={} rust={h_rs}",
            out[row]
        );
    }
}

#[test]
fn serving_accuracy_matches_training_record() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for task in ["xor", "arem"] {
        let mut server = InferenceServer::new(&rt, task).unwrap();
        let ds = Dataset::load_sacd(&dir.join(format!("{task}_test.bin"))).unwrap();
        for i in 0..ds.n {
            server.submit(ds.row(i).to_vec());
        }
        let results = server.drain().unwrap();
        assert_eq!(results.len(), ds.n, "padding leaked into results");
        let correct = results
            .iter()
            .filter(|&&(id, pred, _)| pred == ds.y[id as usize] as usize)
            .count();
        let acc = correct as f64 / ds.n as f64;
        // the exported graph runs the same math as training → accuracies
        // match up to the bisect-vs-exact solver difference
        let recorded = server.engine.net.acc_sac_algorithmic;
        assert!(
            (acc - recorded).abs() < 0.03,
            "{task}: served acc {acc:.3} vs recorded {recorded:.3}"
        );
    }
}

#[test]
fn table_tier_agrees_with_algorithmic_on_xor() {
    let Some(dir) = artifacts() else { return };
    let net = sac::nn::load_net(&dir, "xor").unwrap();
    let ds = Dataset::load_sacd(&dir.join("xor_test.bin")).unwrap();
    let alg =
        sac::nn::evaluate(&net, || Box::new(Algorithmic::relu()), &ds, 128, 4);
    let tm = sac::sac::TableModel::calibrate(
        &sac::pdk::CMOS180,
        sac::pdk::regime::Regime::WeakInversion,
        27.0,
    );
    let tab = sac::nn::evaluate(&net, || Box::new(tm.clone()), &ds, 128, 4);
    assert!(
        (alg.accuracy() - tab.accuracy()).abs() < 0.08,
        "alg={} table={}",
        alg.accuracy(),
        tab.accuracy()
    );
}

#[test]
fn manifest_lists_all_tasks() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for entry in ["gmp_kernel", "xor_mlp", "arem_mlp", "digits_mlp"] {
        assert!(
            rt.manifest.entries.contains_key(entry),
            "missing manifest entry {entry}"
        );
    }
}

#[test]
fn provider_backends_share_label_contract() {
    let alg = Algorithmic::relu();
    assert!(alg.label().contains("algorithmic"));
    let cc = sac::cells::CircuitCorner::new(
        &sac::pdk::CMOS180,
        sac::pdk::regime::Regime::WeakInversion,
    );
    assert!(cc.label().contains("cmos180"));
}

// ---------------------------------------------------------------------------
// Router: concurrent multi-task serving (artifact-free — always runs)
// ---------------------------------------------------------------------------

/// A hand-built net with f32-exact weights so the engine's f32 weight
/// buffers and the f64 golden path compute identical numbers.
fn toy_net(task: &str, seed: u64, sizes: &[usize]) -> TrainedNet {
    toy_net_act(task, seed, sizes, "phi1")
}

/// [`toy_net`] with an explicit hidden activation.
fn toy_net_act(task: &str, seed: u64, sizes: &[usize], activation: &str) -> TrainedNet {
    let mut rng = sac::util::rng::Rng::new(seed);
    let nl = sizes.len() - 1;
    let mut weights = Vec::with_capacity(nl);
    let mut biases = Vec::with_capacity(nl);
    // quantize to 1/64 so every weight is exactly representable in f32
    let mut q = |lo: f64, hi: f64| (rng.uniform_in(lo, hi) * 64.0).round() / 64.0;
    for li in 0..nl {
        weights.push((0..sizes[li] * sizes[li + 1]).map(|_| q(-0.9, 0.9)).collect());
        biases.push((0..sizes[li + 1]).map(|_| q(-0.2, 0.2)).collect());
    }
    TrainedNet {
        task: task.to_string(),
        sizes: sizes.to_vec(),
        activation: activation.to_string(),
        splines: 3,
        c: 1.0,
        acc_sw: 0.0,
        acc_sac_algorithmic: 0.0,
        weights,
        biases,
    }
}

fn toy_engine(net: &TrainedNet, batch: usize) -> Engine {
    let exe = Executable::native_mlp(net, batch).unwrap();
    Engine::from_parts(net.clone(), exe).unwrap()
}

/// Deterministic, f32-exact feature vector for (submitter, k).
fn toy_features(dim: usize, submitter: usize, k: usize) -> Vec<f32> {
    (0..dim)
        .map(|j| {
            let v = ((submitter * 7 + k * 3 + j * 5) % 33) as f32;
            (v - 16.0) / 16.0
        })
        .collect()
}

/// The tentpole acceptance test: many concurrent submitters against a
/// two-task router; every request id must be answered exactly once, and
/// every answer must match the golden circuit path (`nn::forward` on the
/// algorithmic tier with the same multiplier calibration).
#[test]
fn router_concurrent_serving_exactly_once_with_golden_outputs() {
    let nets = [
        toy_net("alpha", 21, &[3, 5, 2]),
        toy_net("beta", 22, &[2, 4, 3]),
    ];
    let router = Router::new(
        RouterConfig {
            workers: 4,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
            ..RouterConfig::default()
        },
        vec![
            ("alpha".into(), toy_engine(&nets[0], 4)),
            ("beta".into(), toy_engine(&nets[1], 3)),
        ],
    );

    let n_submitters = 6;
    let per_submitter = 25;
    // (request handle, task, features) per submitter
    let submitted: Vec<Vec<(RequestId, usize, Vec<f32>)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_submitters)
                .map(|s| {
                    let router = &router;
                    scope.spawn(move || {
                        (0..per_submitter)
                            .map(|k| {
                                let task = (s + k) % 2;
                                let dim = if task == 0 { 3 } else { 2 };
                                let feats = toy_features(dim, s, k);
                                let req =
                                    router.submit(task, feats.clone()).unwrap();
                                (req, task, feats)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

    router
        .drain(Duration::from_secs(30))
        .expect("router drained cleanly");

    // golden path: the circuit-tier forward with the identical calibration
    let provider = Algorithmic::relu();
    let mults: Vec<Multiplier> = nets
        .iter()
        .map(|n| Multiplier::calibrate(&provider, n.splines, n.c))
        .collect();

    let total = n_submitters * per_submitter;
    let mut seen: HashSet<(usize, u64)> = HashSet::new();
    for (req, task, feats) in submitted.into_iter().flatten() {
        let r = router
            .try_take(req)
            .expect("no engine failure")
            .unwrap_or_else(|| panic!("request {req:?} never answered"));
        assert!(
            seen.insert((task, r.id)),
            "request {req:?} answered more than once"
        );
        // exactly-once delivery: a second take must find nothing
        assert!(router.try_take(req).unwrap().is_none());

        let golden = sac::nn::forward(&nets[task], &provider, &mults[task], &feats);
        assert_eq!(r.logits.len(), golden.len());
        for (j, (&got, &want)) in r.logits.iter().zip(&golden).enumerate() {
            assert!(
                (got as f64 - want).abs() < 1e-4,
                "{req:?} logit {j}: served {got} vs golden {want}"
            );
        }
        let golden_pred = golden
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        assert_eq!(r.pred, golden_pred, "{req:?}: prediction diverged");
    }
    assert_eq!(seen.len(), total, "lost requests");
    assert_eq!(router.ready(), 0, "stray responses left behind");
    assert_eq!(router.pending(), 0, "stranded requests in a lane queue");
    assert_eq!(router.aggregate_metrics().total_requests(), total);
    assert!(router.failures().is_empty(), "{:?}", router.failures());
}

/// Partial batches must be executed by the deadline flusher even when no
/// one calls drain — tail requests are never stranded.
#[test]
fn router_deadline_flush_answers_tail_requests() {
    let net = toy_net("tail", 31, &[2, 3, 2]);
    let router = Router::new(
        RouterConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
            ..RouterConfig::default()
        },
        vec![("tail".into(), toy_engine(&net, 8))],
    );
    // a single request in a batch-of-8 lane
    let req = router.submit(0, vec![0.25, -0.5]).unwrap();
    let r = router
        .wait(req, Duration::from_secs(5))
        .expect("deadline flush delivered the tail request");
    assert_eq!(r.id, req.id);
    assert_eq!(r.logits.len(), 2);
}

// ---------------------------------------------------------------------------
// Batched columnar engine: equivalence with the scalar path
// (artifact-free — always runs)
// ---------------------------------------------------------------------------

/// Stated logit tolerance of the batched columnar engine against the
/// scalar per-row path at default grid resolution (DESIGN.md §7 error
/// budget: interpolation is exact on the piecewise-linear ReLU-shape
/// tier away from kink cells, so observed deviations sit well below
/// this bound).
const BATCH_TOL: f64 = 1e-2;

/// The (node, regime, temperature) corners the table tier exercises.
fn table_corners() -> Vec<TableModel> {
    [
        (&CMOS180, Regime::WeakInversion, 27.0),
        (&CMOS180, Regime::ModerateInversion, 27.0),
        (&FINFET7, Regime::WeakInversion, 27.0),
        (&FINFET7, Regime::ModerateInversion, 27.0),
        (&CMOS180, Regime::WeakInversion, 85.0),
    ]
    .into_iter()
    .map(|(node, regime, t_c)| TableModel::calibrate(node, regime, t_c))
    .collect()
}

/// For random toy nets across every (node, regime, temperature) corner,
/// the batched engine's logits must match the scalar `nn::forward` path
/// within `BATCH_TOL` — the ISSUE-2 equivalence acceptance.
#[test]
fn batched_engine_matches_scalar_forward_across_corners() {
    let nets = [
        toy_net_act("eqa", 41, &[3, 5, 2], "phi1"),
        toy_net_act("eqb", 42, &[2, 4, 3], "softplus"),
        toy_net_act("eqc", 43, &[4, 6, 2], "relu"),
    ];
    let tables = table_corners();
    let rows = 12;
    for ci in 0..=tables.len() {
        for net in &nets {
            let provider: Box<dyn HProvider + Send + Sync> = if ci == 0 {
                Box::new(Algorithmic::relu())
            } else {
                Box::new(tables[ci - 1].clone())
            };
            let label = provider.label();
            let kernel = BatchKernel::for_net(provider, net, &GridConfig::default()).unwrap();
            // golden scalar path with the *same* backend + calibration
            let scalar_p: Box<dyn HProvider> = if ci == 0 {
                Box::new(Algorithmic::relu())
            } else {
                Box::new(tables[ci - 1].clone())
            };
            let mult = Multiplier::calibrate(scalar_p.as_ref(), net.splines, net.c);
            let din = net.sizes[0];
            let k = *net.sizes.last().unwrap();
            let x: Vec<f32> = (0..rows)
                .flat_map(|r| toy_features(din, ci, r))
                .collect();
            let batched = kernel.forward_net(net, &x, rows);
            assert_eq!(batched.len(), rows * k);
            for r in 0..rows {
                let golden =
                    sac::nn::forward(net, scalar_p.as_ref(), &mult, &x[r * din..(r + 1) * din]);
                for (j, &want) in golden.iter().enumerate() {
                    let got = batched[r * k + j];
                    assert!(
                        (got - want).abs() < BATCH_TOL,
                        "corner {label} net {} row {r} logit {j}: \
                         batched {got} vs scalar {want}",
                        net.task
                    );
                }
            }
        }
    }
}

/// Features pushed deliberately beyond the multiplier grid's safety
/// margin must take the exact-cell fallback (never a clamp) and still
/// agree with the scalar path within `BATCH_TOL` — the out-of-grid
/// escape hatch of the batched engine.
#[test]
fn batched_out_of_grid_features_fall_back_to_exact_cells() {
    // a deliberately tight grid: proto_range 2.5 barely covers the
    // calibrated operating point ± weight, so |x| ≳ 1 fails the margin
    // check and routes through the exact multiplier; act_range 12 still
    // gets exceeded by the relu hidden layer on the large rows
    let cfg = GridConfig {
        proto_range: 2.5,
        proto_density: 256,
        act_range: 12.0,
        act_density: 256,
    };
    let net = toy_net_act("oog", 47, &[3, 5, 2], "relu");
    let provider: Box<dyn HProvider + Send + Sync> = Box::new(Algorithmic::relu());
    let kernel = BatchKernel::for_net(provider, &net, &cfg).unwrap();
    let scalar_p = Algorithmic::relu();
    let mult = Multiplier::calibrate(&scalar_p, net.splines, net.c);
    // mixed rows: comfortably in-grid next to far out-of-grid features
    let rows_f: Vec<Vec<f32>> = vec![
        vec![0.2, -0.4, 0.1],
        vec![3.5, -3.5, 2.75],
        vec![-4.0, 0.25, 3.875],
        vec![0.125, 4.5, -0.2],
    ];
    let rows = rows_f.len();
    let x: Vec<f32> = rows_f.iter().flatten().copied().collect();
    let k = *net.sizes.last().unwrap();
    let batched = kernel.forward_net(&net, &x, rows);
    assert_eq!(batched.len(), rows * k);
    for (r, row) in rows_f.iter().enumerate() {
        let golden = sac::nn::forward(&net, &scalar_p, &mult, row);
        for (j, &want) in golden.iter().enumerate() {
            let got = batched[r * k + j];
            assert!(
                got.is_finite(),
                "row {r} logit {j} not finite: {got}"
            );
            assert!(
                (got - want).abs() < BATCH_TOL,
                "row {r} logit {j}: batched {got} vs scalar {want} \
                 (out-of-grid fallback diverged)"
            );
        }
    }
}

/// Property test for the row-sharded kernel: at every table corner (and
/// on the algorithmic tier), randomized layer shapes / row counts /
/// weights must produce **bit-identical** logits whether the batch runs
/// serially or sharded across 2, 3, or 8 slab threads.  Equality is
/// `assert_eq!` on the raw f64s — no tolerance — because slab sharding
/// preserves each row's accumulation order exactly (DESIGN.md §10).
#[test]
fn parallel_kernel_is_bit_identical_across_corners() {
    // coarse grids keep corner calibration cheap: bit-identity between
    // thread counts holds at any resolution, so resolution is not under
    // test here (the corner-equivalence test above covers accuracy)
    let cfg = GridConfig {
        proto_range: 6.0,
        proto_density: 192,
        act_range: 16.0,
        act_density: 96,
    };
    let tables = table_corners();
    let mut rng = sac::util::rng::Rng::new(0xb17_1de2);
    for ci in 0..=tables.len() {
        let provider: Box<dyn HProvider + Send + Sync> = if ci == 0 {
            Box::new(Algorithmic::relu())
        } else {
            Box::new(tables[ci - 1].clone())
        };
        let label = provider.label();
        let kernel = BatchKernel::new(provider, sac::nn::Activation::Phi1, 3, 1.0, &cfg);
        // randomized shapes: 2–3 layers, widths 2..=6, rows 1..=48
        for case in 0..3 {
            let nl = 2 + (rng.next_u64() % 2) as usize;
            let sizes: Vec<usize> = (0..=nl).map(|_| 2 + (rng.next_u64() % 5) as usize).collect();
            let rows = 1 + (rng.next_u64() % 48) as usize;
            let mut weights = Vec::with_capacity(nl);
            let mut biases = Vec::with_capacity(nl);
            for li in 0..nl {
                weights.push(
                    (0..sizes[li] * sizes[li + 1])
                        .map(|_| rng.uniform_in(-0.9, 0.9))
                        .collect::<Vec<f64>>(),
                );
                biases.push(
                    (0..sizes[li + 1])
                        .map(|_| rng.uniform_in(-0.2, 0.2))
                        .collect::<Vec<f64>>(),
                );
            }
            let x: Vec<f32> = (0..rows * sizes[0])
                .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
                .collect();
            let serial = kernel.forward_batch_threads(&sizes, &weights, &biases, &x, rows, 1);
            for threads in [2usize, 3, 8] {
                let par =
                    kernel.forward_batch_threads(&sizes, &weights, &biases, &x, rows, threads);
                assert_eq!(
                    serial, par,
                    "corner {label} case {case} (sizes {sizes:?}, rows {rows}): \
                     logits diverged at {threads} threads"
                );
            }
        }
    }
}

/// The golden serving test on the batched engine: the full concurrent
/// router path with batched executables must reproduce the scalar golden
/// forward's logits within `BATCH_TOL` and its predicted labels exactly
/// (rows whose golden top-2 margin is inside the stated tolerance band
/// cannot meaningfully pin an argmax and are excluded; they must stay a
/// small minority).
#[test]
fn batched_router_serving_matches_scalar_golden() {
    let nets = [
        toy_net("balpha", 21, &[3, 5, 2]),
        toy_net("bbeta", 22, &[2, 4, 3]),
    ];
    let mk_engine = |net: &TrainedNet, batch: usize| -> Engine {
        let exe = Executable::native_mlp_with_mode(net, batch, ExecMode::Batched).unwrap();
        Engine::from_parts(net.clone(), exe).unwrap()
    };
    let router = Router::new(
        RouterConfig {
            workers: 4,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
            ..RouterConfig::default()
        },
        vec![
            ("balpha".into(), mk_engine(&nets[0], 4)),
            ("bbeta".into(), mk_engine(&nets[1], 3)),
        ],
    );

    let n_submitters = 4;
    let per_submitter = 20;
    let submitted: Vec<Vec<(RequestId, usize, Vec<f32>)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_submitters)
                .map(|s| {
                    let router = &router;
                    scope.spawn(move || {
                        (0..per_submitter)
                            .map(|k| {
                                let task = (s + k) % 2;
                                let dim = if task == 0 { 3 } else { 2 };
                                let feats = toy_features(dim, s, k);
                                let req = router.submit(task, feats.clone()).unwrap();
                                (req, task, feats)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

    router
        .drain(Duration::from_secs(30))
        .expect("router drained cleanly");

    let provider = Algorithmic::relu();
    let mults: Vec<Multiplier> = nets
        .iter()
        .map(|n| Multiplier::calibrate(&provider, n.splines, n.c))
        .collect();

    let total = n_submitters * per_submitter;
    let mut checked = 0usize;
    let mut margin_skipped = 0usize;
    for (req, task, feats) in submitted.into_iter().flatten() {
        let r = router
            .try_take(req)
            .expect("no engine failure")
            .unwrap_or_else(|| panic!("request {req:?} never answered"));
        let golden = sac::nn::forward(&nets[task], &provider, &mults[task], &feats);
        assert_eq!(r.logits.len(), golden.len());
        for (j, (&got, &want)) in r.logits.iter().zip(&golden).enumerate() {
            assert!(
                (got as f64 - want).abs() < BATCH_TOL,
                "{req:?} logit {j}: batched {got} vs golden {want}"
            );
        }
        // label check: argmax is only well-defined outside the tolerance
        // band around a tie
        let mut sorted = golden.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let margin = sorted[0] - sorted[1];
        if margin > 2.0 * BATCH_TOL {
            let golden_pred = golden
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            assert_eq!(
                r.pred, golden_pred,
                "{req:?}: batched label diverged from scalar golden"
            );
            checked += 1;
        } else {
            margin_skipped += 1;
        }
    }
    assert_eq!(checked + margin_skipped, total);
    assert!(
        margin_skipped * 5 <= total,
        "too many near-tie rows ({margin_skipped}/{total}) for the label \
         check to be meaningful"
    );
    assert!(router.failures().is_empty(), "{:?}", router.failures());
}

// ---------------------------------------------------------------------------
// Router edge cases (artifact-free — always runs)
// ---------------------------------------------------------------------------

/// Submitting after shutdown is a clean error; work accepted before
/// shutdown still completes and remains takeable.
#[test]
fn router_submit_after_shutdown_is_rejected() {
    let net = toy_net("shut", 51, &[2, 3, 2]);
    let router = Router::new(
        RouterConfig {
            workers: 2,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
            ..RouterConfig::default()
        },
        vec![("shut".into(), toy_engine(&net, 8))],
    );
    let req = router.submit(0, vec![0.5, -0.25]).unwrap();
    router.shutdown();
    assert!(router.is_shut_down());
    let err = router.submit(0, vec![0.1, 0.1]).unwrap_err();
    assert!(err.to_string().contains("shut down"), "{err}");
    // the accepted request is still served (manual flush substitutes for
    // the exited deadline flusher)
    router.flush();
    router.drain(Duration::from_secs(10)).unwrap();
    let r = router.try_take(req).unwrap().expect("accepted work answered");
    assert_eq!(r.id, req.id);
    assert_eq!(router.aggregate_metrics().total_requests(), 1);
}

/// Flush / drain with nothing pending are clean no-ops, and flush is
/// idempotent around real work.
#[test]
fn router_zero_pending_flush_is_noop() {
    let net = toy_net("idle", 52, &[2, 3, 2]);
    let router = Router::new(
        RouterConfig {
            workers: 1,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
            ..RouterConfig::default()
        },
        vec![("idle".into(), toy_engine(&net, 4))],
    );
    router.flush();
    router.flush();
    router.drain(Duration::from_secs(2)).unwrap();
    assert_eq!(router.pending(), 0);
    assert_eq!(router.ready(), 0);
    assert_eq!(router.aggregate_metrics().total_requests(), 0);
    assert_eq!(router.aggregate_metrics().total_batches, 0);
    // and a double flush around a real request changes nothing
    let req = router.submit(0, vec![0.2, 0.4]).unwrap();
    router.flush();
    router.flush();
    router.drain(Duration::from_secs(5)).unwrap();
    assert!(router.try_take(req).unwrap().is_some());
    assert_eq!(router.aggregate_metrics().total_requests(), 1);
}

/// Per-task metrics must aggregate exactly under concurrent submitters:
/// each lane counts precisely its own requests, the aggregate is their
/// sum, and batch counts are consistent.
#[test]
fn router_per_task_metrics_aggregate_under_concurrency() {
    let dims = [2usize, 3, 4];
    let engines: Vec<(String, Engine)> = dims
        .iter()
        .enumerate()
        .map(|(t, &d)| {
            (
                format!("m{t}"),
                synthetic_engine(70 + t as u64, &[d, 4, 2], 4).unwrap(),
            )
        })
        .collect();
    let router = Router::new(
        RouterConfig {
            workers: 4,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
            ..RouterConfig::default()
        },
        engines,
    );
    let n_threads = 6;
    let per_thread = 30;
    std::thread::scope(|scope| {
        for t in 0..n_threads {
            let router = &router;
            let dims = &dims;
            scope.spawn(move || {
                let task = t % dims.len();
                for k in 0..per_thread {
                    let feats = toy_features(dims[task], t, k);
                    router.submit(task, feats).unwrap();
                }
            });
        }
    });
    router.drain(Duration::from_secs(20)).unwrap();
    // 6 threads over 3 tasks → exactly 2 threads (60 requests) per task
    let per_task = 2 * per_thread;
    let mut batch_sum = 0;
    for t in 0..dims.len() {
        let m = router.metrics(t);
        assert_eq!(m.total_requests(), per_task, "task {t}");
        assert!(
            m.total_batches >= per_task / 4,
            "task {t}: {} batches for {per_task} requests of batch size 4",
            m.total_batches
        );
        batch_sum += m.total_batches;
    }
    let agg = router.aggregate_metrics();
    assert_eq!(agg.total_requests(), n_threads * per_thread);
    assert_eq!(agg.total_batches, batch_sum);
    assert!(router.failures().is_empty());
}
