//! Integration tests across the stack: python-goldens ↔ rust solver
//! parity, artifact loading, PJRT execution, serving coordinator, and the
//! circuit-vs-compiled cross-check.  These need `make artifacts` to have
//! run; each test skips (with a message) when artifacts are missing so
//! `cargo test` stays green on a fresh checkout.

use std::path::PathBuf;

use sac::cells::{Algorithmic, HProvider};
use sac::coordinator::InferenceServer;
use sac::data::Dataset;
use sac::runtime::Runtime;
use sac::sac::gmp::{solve_bisect, Shape, GMP_ITERS};
use sac::util::json;

fn artifacts() -> Option<PathBuf> {
    let dir = sac::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn rust_gmp_matches_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let j = json::parse_file(&dir.join("goldens_gmp.json")).unwrap();
    let cases = j.get("gmp").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let c = case.get("c").unwrap().as_f64().unwrap();
        let xs = case.get("x").unwrap().as_f64_mat().unwrap();
        let hs = case.get("h").unwrap().as_f64_vec().unwrap();
        for (row, &h_py) in xs.iter().zip(&hs) {
            let h_rs = solve_bisect(row, c, Shape::Relu, GMP_ITERS);
            assert!(
                (h_rs - h_py).abs() < 1e-5,
                "c={c} rust={h_rs} python={h_py}"
            );
        }
    }
}

#[test]
fn rust_cells_match_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let j = json::parse_file(&dir.join("goldens_gmp.json")).unwrap();
    let zs = j.get("z").unwrap().as_f64_vec().unwrap();
    let cells = j.get("cells").unwrap();
    let alg = Algorithmic::relu();
    let check = |name: &str, f: &dyn Fn(f64) -> f64| {
        let py = cells.get(name).unwrap().as_f64_vec().unwrap();
        for (&z, &y_py) in zs.iter().zip(&py) {
            let y_rs = f(z);
            assert!(
                (y_rs - y_py).abs() < 1e-4,
                "{name}(z={z}): rust={y_rs} python={y_py}"
            );
        }
    };
    check("proto_s1", &|z| sac::cells::proto_unit(&alg, z, 1, 1.0));
    check("proto_s3", &|z| sac::cells::proto_unit(&alg, z, 3, 1.0));
    check("relu", &|z| {
        sac::cells::activations::relu_cell(&alg, z, 0.05)
    });
    check("phi1", &|z| {
        sac::cells::activations::phi1_cell(&alg, z, 1.0, 3, 0.5)
    });
    check("cosh", &|z| {
        sac::cells::activations::cosh_cell(&alg, z, 3, 1.0)
    });
    check("sinh", &|z| {
        sac::cells::activations::sinh_cell(&alg, z, 3, 1.0)
    });
}

#[test]
fn pjrt_gmp_kernel_matches_rust_solver() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("gmp_kernel").unwrap();
    let shape = &exe.spec.params[0].shape;
    let (b, m) = (shape[0], shape[1]);
    let c = exe.spec.meta.get("c").unwrap().as_f64().unwrap();
    // deterministic pseudo-random input
    let mut rng = sac::util::rng::Rng::new(99);
    let buf: Vec<f32> = (0..b * m)
        .map(|_| rng.uniform_in(-3.0, 3.0) as f32)
        .collect();
    let out = exe.run_f32(&[&buf]).unwrap();
    assert_eq!(out.len(), b);
    // spot-check rows against the rust bisection solver
    for row in (0..b).step_by(97) {
        let xs: Vec<f64> = (0..m).map(|j| buf[row * m + j] as f64).collect();
        let h_rs = solve_bisect(&xs, c, Shape::Relu, GMP_ITERS);
        assert!(
            (out[row] as f64 - h_rs).abs() < 1e-4,
            "row {row}: pjrt={} rust={h_rs}",
            out[row]
        );
    }
}

#[test]
fn serving_accuracy_matches_training_record() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for task in ["xor", "arem"] {
        let mut server = InferenceServer::new(&rt, task).unwrap();
        let ds = Dataset::load_sacd(&dir.join(format!("{task}_test.bin"))).unwrap();
        for i in 0..ds.n {
            server.submit(ds.row(i).to_vec());
        }
        let results = server.drain().unwrap();
        assert_eq!(results.len(), ds.n, "padding leaked into results");
        let correct = results
            .iter()
            .filter(|&&(id, pred, _)| pred == ds.y[id as usize] as usize)
            .count();
        let acc = correct as f64 / ds.n as f64;
        // the AOT graph runs the same math as training → accuracies match
        // up to the bisect-vs-exact solver difference
        let recorded = server.net.acc_sac_algorithmic;
        assert!(
            (acc - recorded).abs() < 0.03,
            "{task}: served acc {acc:.3} vs recorded {recorded:.3}"
        );
    }
}

#[test]
fn table_tier_agrees_with_algorithmic_on_xor() {
    let Some(dir) = artifacts() else { return };
    let net = sac::nn::load_net(&dir, "xor").unwrap();
    let ds = Dataset::load_sacd(&dir.join("xor_test.bin")).unwrap();
    let alg =
        sac::nn::evaluate(&net, || Box::new(Algorithmic::relu()), &ds, 128, 4);
    let tm = sac::sac::TableModel::calibrate(
        &sac::pdk::CMOS180,
        sac::pdk::regime::Regime::WeakInversion,
        27.0,
    );
    let tab = sac::nn::evaluate(&net, || Box::new(tm.clone()), &ds, 128, 4);
    assert!(
        (alg.accuracy() - tab.accuracy()).abs() < 0.08,
        "alg={} table={}",
        alg.accuracy(),
        tab.accuracy()
    );
}

#[test]
fn manifest_lists_all_tasks() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for entry in ["gmp_kernel", "xor_mlp", "arem_mlp", "digits_mlp"] {
        assert!(
            rt.manifest.entries.contains_key(entry),
            "missing manifest entry {entry}"
        );
    }
}

#[test]
fn provider_backends_share_label_contract() {
    let alg = Algorithmic::relu();
    assert!(alg.label().contains("algorithmic"));
    let cc = sac::cells::CircuitCorner::new(
        &sac::pdk::CMOS180,
        sac::pdk::regime::Regime::WeakInversion,
    );
    assert!(cc.label().contains("cmos180"));
}
