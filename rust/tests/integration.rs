//! Integration tests across the stack: python-goldens ↔ rust solver
//! parity, artifact loading, native execution, the serving coordinator,
//! and the circuit-vs-compiled cross-check.
//!
//! Tests that need `make artifacts` detect the missing directory through
//! the `artifacts()` helper and *skip with a message* instead of failing,
//! so `cargo test -q` stays green on a clean checkout.  The router /
//! coordinator tests construct their engines in memory and always run.

use std::collections::HashSet;
use std::path::PathBuf;
use std::time::Duration;

use sac::cells::multiplier::Multiplier;
use sac::cells::{Algorithmic, HProvider};
use sac::coordinator::{Engine, InferenceServer, RequestId, Router, RouterConfig};
use sac::data::{Dataset, TrainedNet};
use sac::runtime::{Executable, Runtime};
use sac::sac::gmp::{solve_bisect, Shape, GMP_ITERS};
use sac::util::json;

/// Artifact directory, or `None` (with an explanatory message) when the
/// artifacts have not been built — the caller returns early, skipping the
/// test body without failing the suite.
fn artifacts() -> Option<PathBuf> {
    let dir = sac::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: artifacts/ not built (run `make artifacts`, i.e. \
             python -m compile.aot from python/)"
        );
        None
    }
}

#[test]
fn rust_gmp_matches_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let j = json::parse_file(&dir.join("goldens_gmp.json")).unwrap();
    let cases = j.get("gmp").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let c = case.get("c").unwrap().as_f64().unwrap();
        let xs = case.get("x").unwrap().as_f64_mat().unwrap();
        let hs = case.get("h").unwrap().as_f64_vec().unwrap();
        for (row, &h_py) in xs.iter().zip(&hs) {
            let h_rs = solve_bisect(row, c, Shape::Relu, GMP_ITERS);
            assert!(
                (h_rs - h_py).abs() < 1e-5,
                "c={c} rust={h_rs} python={h_py}"
            );
        }
    }
}

#[test]
fn rust_cells_match_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let j = json::parse_file(&dir.join("goldens_gmp.json")).unwrap();
    let zs = j.get("z").unwrap().as_f64_vec().unwrap();
    let cells = j.get("cells").unwrap();
    let alg = Algorithmic::relu();
    let check = |name: &str, f: &dyn Fn(f64) -> f64| {
        let py = cells.get(name).unwrap().as_f64_vec().unwrap();
        for (&z, &y_py) in zs.iter().zip(&py) {
            let y_rs = f(z);
            assert!(
                (y_rs - y_py).abs() < 1e-4,
                "{name}(z={z}): rust={y_rs} python={y_py}"
            );
        }
    };
    check("proto_s1", &|z| sac::cells::proto_unit(&alg, z, 1, 1.0));
    check("proto_s3", &|z| sac::cells::proto_unit(&alg, z, 3, 1.0));
    check("relu", &|z| {
        sac::cells::activations::relu_cell(&alg, z, 0.05)
    });
    check("phi1", &|z| {
        sac::cells::activations::phi1_cell(&alg, z, 1.0, 3, 0.5)
    });
    check("cosh", &|z| {
        sac::cells::activations::cosh_cell(&alg, z, 3, 1.0)
    });
    check("sinh", &|z| {
        sac::cells::activations::sinh_cell(&alg, z, 3, 1.0)
    });
}

#[test]
fn native_gmp_kernel_matches_rust_solver() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("gmp_kernel").unwrap();
    let shape = &exe.spec.params[0].shape;
    let (b, m) = (shape[0], shape[1]);
    let c = exe.spec.meta.get("c").unwrap().as_f64().unwrap();
    // deterministic pseudo-random input
    let mut rng = sac::util::rng::Rng::new(99);
    let buf: Vec<f32> = (0..b * m)
        .map(|_| rng.uniform_in(-3.0, 3.0) as f32)
        .collect();
    let out = exe.run_f32(&[&buf]).unwrap();
    assert_eq!(out.len(), b);
    // spot-check rows against the rust bisection solver
    for row in (0..b).step_by(97) {
        let xs: Vec<f64> = (0..m).map(|j| buf[row * m + j] as f64).collect();
        let h_rs = solve_bisect(&xs, c, Shape::Relu, GMP_ITERS);
        assert!(
            (out[row] as f64 - h_rs).abs() < 1e-4,
            "row {row}: native={} rust={h_rs}",
            out[row]
        );
    }
}

#[test]
fn serving_accuracy_matches_training_record() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for task in ["xor", "arem"] {
        let mut server = InferenceServer::new(&rt, task).unwrap();
        let ds = Dataset::load_sacd(&dir.join(format!("{task}_test.bin"))).unwrap();
        for i in 0..ds.n {
            server.submit(ds.row(i).to_vec());
        }
        let results = server.drain().unwrap();
        assert_eq!(results.len(), ds.n, "padding leaked into results");
        let correct = results
            .iter()
            .filter(|&&(id, pred, _)| pred == ds.y[id as usize] as usize)
            .count();
        let acc = correct as f64 / ds.n as f64;
        // the exported graph runs the same math as training → accuracies
        // match up to the bisect-vs-exact solver difference
        let recorded = server.engine.net.acc_sac_algorithmic;
        assert!(
            (acc - recorded).abs() < 0.03,
            "{task}: served acc {acc:.3} vs recorded {recorded:.3}"
        );
    }
}

#[test]
fn table_tier_agrees_with_algorithmic_on_xor() {
    let Some(dir) = artifacts() else { return };
    let net = sac::nn::load_net(&dir, "xor").unwrap();
    let ds = Dataset::load_sacd(&dir.join("xor_test.bin")).unwrap();
    let alg =
        sac::nn::evaluate(&net, || Box::new(Algorithmic::relu()), &ds, 128, 4);
    let tm = sac::sac::TableModel::calibrate(
        &sac::pdk::CMOS180,
        sac::pdk::regime::Regime::WeakInversion,
        27.0,
    );
    let tab = sac::nn::evaluate(&net, || Box::new(tm.clone()), &ds, 128, 4);
    assert!(
        (alg.accuracy() - tab.accuracy()).abs() < 0.08,
        "alg={} table={}",
        alg.accuracy(),
        tab.accuracy()
    );
}

#[test]
fn manifest_lists_all_tasks() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for entry in ["gmp_kernel", "xor_mlp", "arem_mlp", "digits_mlp"] {
        assert!(
            rt.manifest.entries.contains_key(entry),
            "missing manifest entry {entry}"
        );
    }
}

#[test]
fn provider_backends_share_label_contract() {
    let alg = Algorithmic::relu();
    assert!(alg.label().contains("algorithmic"));
    let cc = sac::cells::CircuitCorner::new(
        &sac::pdk::CMOS180,
        sac::pdk::regime::Regime::WeakInversion,
    );
    assert!(cc.label().contains("cmos180"));
}

// ---------------------------------------------------------------------------
// Router: concurrent multi-task serving (artifact-free — always runs)
// ---------------------------------------------------------------------------

/// A hand-built net with f32-exact weights so the engine's f32 weight
/// buffers and the f64 golden path compute identical numbers.
fn toy_net(task: &str, seed: u64, sizes: &[usize]) -> TrainedNet {
    let mut rng = sac::util::rng::Rng::new(seed);
    let nl = sizes.len() - 1;
    let mut weights = Vec::with_capacity(nl);
    let mut biases = Vec::with_capacity(nl);
    // quantize to 1/64 so every weight is exactly representable in f32
    let mut q = |lo: f64, hi: f64| (rng.uniform_in(lo, hi) * 64.0).round() / 64.0;
    for li in 0..nl {
        weights.push((0..sizes[li] * sizes[li + 1]).map(|_| q(-0.9, 0.9)).collect());
        biases.push((0..sizes[li + 1]).map(|_| q(-0.2, 0.2)).collect());
    }
    TrainedNet {
        task: task.to_string(),
        sizes: sizes.to_vec(),
        activation: "phi1".into(),
        splines: 3,
        c: 1.0,
        acc_sw: 0.0,
        acc_sac_algorithmic: 0.0,
        weights,
        biases,
    }
}

fn toy_engine(net: &TrainedNet, batch: usize) -> Engine {
    let exe = Executable::native_mlp(net, batch).unwrap();
    Engine::from_parts(net.clone(), exe).unwrap()
}

/// Deterministic, f32-exact feature vector for (submitter, k).
fn toy_features(dim: usize, submitter: usize, k: usize) -> Vec<f32> {
    (0..dim)
        .map(|j| {
            let v = ((submitter * 7 + k * 3 + j * 5) % 33) as f32;
            (v - 16.0) / 16.0
        })
        .collect()
}

/// The tentpole acceptance test: many concurrent submitters against a
/// two-task router; every request id must be answered exactly once, and
/// every answer must match the golden circuit path (`nn::forward` on the
/// algorithmic tier with the same multiplier calibration).
#[test]
fn router_concurrent_serving_exactly_once_with_golden_outputs() {
    let nets = [
        toy_net("alpha", 21, &[3, 5, 2]),
        toy_net("beta", 22, &[2, 4, 3]),
    ];
    let router = Router::new(
        RouterConfig {
            workers: 4,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
        },
        vec![
            ("alpha".into(), toy_engine(&nets[0], 4)),
            ("beta".into(), toy_engine(&nets[1], 3)),
        ],
    );

    let n_submitters = 6;
    let per_submitter = 25;
    // (request handle, task, features) per submitter
    let submitted: Vec<Vec<(RequestId, usize, Vec<f32>)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_submitters)
                .map(|s| {
                    let router = &router;
                    scope.spawn(move || {
                        (0..per_submitter)
                            .map(|k| {
                                let task = (s + k) % 2;
                                let dim = if task == 0 { 3 } else { 2 };
                                let feats = toy_features(dim, s, k);
                                let req =
                                    router.submit(task, feats.clone()).unwrap();
                                (req, task, feats)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

    router
        .drain(Duration::from_secs(30))
        .expect("router drained cleanly");

    // golden path: the circuit-tier forward with the identical calibration
    let provider = Algorithmic::relu();
    let mults: Vec<Multiplier> = nets
        .iter()
        .map(|n| Multiplier::calibrate(&provider, n.splines, n.c))
        .collect();

    let total = n_submitters * per_submitter;
    let mut seen: HashSet<(usize, u64)> = HashSet::new();
    for (req, task, feats) in submitted.into_iter().flatten() {
        let r = router
            .try_take(req)
            .expect("no engine failure")
            .unwrap_or_else(|| panic!("request {req:?} never answered"));
        assert!(
            seen.insert((task, r.id)),
            "request {req:?} answered more than once"
        );
        // exactly-once delivery: a second take must find nothing
        assert!(router.try_take(req).unwrap().is_none());

        let golden = sac::nn::forward(&nets[task], &provider, &mults[task], &feats);
        assert_eq!(r.logits.len(), golden.len());
        for (j, (&got, &want)) in r.logits.iter().zip(&golden).enumerate() {
            assert!(
                (got as f64 - want).abs() < 1e-4,
                "{req:?} logit {j}: served {got} vs golden {want}"
            );
        }
        let golden_pred = golden
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        assert_eq!(r.pred, golden_pred, "{req:?}: prediction diverged");
    }
    assert_eq!(seen.len(), total, "lost requests");
    assert_eq!(router.ready(), 0, "stray responses left behind");
    assert_eq!(router.pending(), 0, "stranded requests in a lane queue");
    assert_eq!(router.aggregate_metrics().total_requests(), total);
    assert!(router.failures().is_empty(), "{:?}", router.failures());
}

/// Partial batches must be executed by the deadline flusher even when no
/// one calls drain — tail requests are never stranded.
#[test]
fn router_deadline_flush_answers_tail_requests() {
    let net = toy_net("tail", 31, &[2, 3, 2]);
    let router = Router::new(
        RouterConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            flush_tick: Duration::from_micros(200),
        },
        vec![("tail".into(), toy_engine(&net, 8))],
    );
    // a single request in a batch-of-8 lane
    let req = router.submit(0, vec![0.25, -0.5]).unwrap();
    let r = router
        .wait(req, Duration::from_secs(5))
        .expect("deadline flush delivered the tail request");
    assert_eq!(r.id, req.id);
    assert_eq!(r.logits.len(), 2);
}
