//! Chaos integration suite: seeded fault plans replayed end to end
//! against the serving stack (ISSUE 6 acceptance).
//!
//! What is pinned here:
//!  * graceful degradation — synthetic-task agreement under
//!    paper-calibrated mismatch + temperature drift + stuck cells stays
//!    inside the documented envelope at both paper corners;
//!  * router liveness — exactly-once delivery, no stranded waiters and a
//!    bounded drain despite injected panics, latency and submit storms;
//!  * determinism — identical-seed replays produce bit-identical
//!    canonical reports, different seeds measurably different ones.

use sac::faults::{
    run_chaos, run_infra, AnalogFault, ChaosConfig, DriftKind, FaultPlan, InfraFault,
    MEAN_DEGRADATION_ENVELOPE, WORST_DEGRADATION_ENVELOPE,
};

fn small_cfg(trials: usize) -> ChaosConfig {
    ChaosConfig {
        trials,
        workers: 3,
        eval_rows: 24,
        kernel_threads: None,
    }
}

#[test]
fn chaos_plan_json_roundtrip() {
    let plan = FaultPlan::default_plan(77);
    let text = plan.to_json().to_string();
    let back = FaultPlan::parse(&text).unwrap();
    assert_eq!(back, plan);
    // canonical serialization is stable across a round trip
    assert_eq!(back.to_json().to_string(), text);
    // and the schema is strict, not lossy
    assert!(FaultPlan::parse("{\"seed\": 1}").is_err());
}

#[test]
fn chaos_default_plan_passes_invariants_and_envelope() {
    let plan = FaultPlan::default_plan(20260808);
    let cfg = small_cfg(6);
    let report = run_chaos(&plan, &cfg).unwrap();

    assert_eq!(report.corners.len(), 2, "both paper corners must run");
    for c in &report.corners {
        assert_eq!(c.trial_agreement.len(), cfg.trials);
        // the drift ramp is walked from its first to its last stage
        assert_eq!(c.trial_temp_c.first().copied(), Some(27.0));
        assert_eq!(c.trial_temp_c.last().copied(), Some(60.0));
        // paper-calibrated mismatch is a *perturbation*: it must actually
        // move the logits, yet stay inside the acceptance envelope
        assert!(
            c.trial_logit_dev.iter().any(|&d| d > 0.0),
            "corner {}: mismatch injected but logits never moved",
            c.node
        );
        assert!(
            c.stuck_cells.iter().all(|&n| n > 0),
            "corner {}: stuck-cell fault planned but nothing injected",
            c.node
        );
        assert!(
            c.mean_agreement >= 1.0 - MEAN_DEGRADATION_ENVELOPE,
            "corner {}: mean agreement {} breached the envelope",
            c.node,
            c.mean_agreement
        );
        assert!(
            c.worst_agreement >= 1.0 - WORST_DEGRADATION_ENVELOPE,
            "corner {}: worst agreement {} breached the collapse floor",
            c.node,
            c.worst_agreement
        );
    }
    assert!(report.infra.resolved_exactly_once);
    assert!(report.infra.drained_in_bound);
    assert!(report.infra.panic_observed, "planned panic never fired");
    assert!(
        report.pass(),
        "default plan must pass: {:?}",
        report.violations()
    );
}

#[test]
fn chaos_identical_seed_replay_is_bit_identical() {
    let plan = FaultPlan::default_plan(4242);
    let cfg = small_cfg(4);
    let a = run_chaos(&plan, &cfg).unwrap();
    let b = run_chaos(&plan, &cfg).unwrap();
    assert_eq!(
        a.canonical_json(),
        b.canonical_json(),
        "identical-seed replay diverged — determinism contract broken"
    );
}

#[test]
fn chaos_different_seed_changes_analog_trials() {
    let cfg = small_cfg(4);
    let a = run_chaos(&FaultPlan::default_plan(1001), &cfg).unwrap();
    let b = run_chaos(&FaultPlan::default_plan(1002), &cfg).unwrap();
    assert_ne!(a.canonical_json(), b.canonical_json());
    // not just the seed echo: the sampled mismatch itself must differ
    assert_ne!(
        a.corners[0].trial_logit_dev, b.corners[0].trial_logit_dev,
        "different seeds drew identical mismatch"
    );
}

#[test]
fn chaos_engine_panic_cannot_deadlock_router() {
    // the nastiest infra composition: a lane that panics on its very
    // first batch, a slow lane ahead of the deadline flusher, and a
    // six-thread submit storm over all lanes
    let plan = FaultPlan {
        seed: 555,
        analog: vec![],
        infra: vec![
            InfraFault::EnginePanic { after_batches: 0 },
            InfraFault::SlowEngine { delay_us: 800 },
            InfraFault::SubmitStorm {
                submitters: 6,
                requests: 60,
            },
        ],
    };
    let infra = run_infra(&plan, &small_cfg(1)).unwrap();
    assert_eq!(infra.submitted, 60, "storm submissions were dropped");
    assert!(infra.panic_observed, "contained panic was not surfaced");
    assert!(infra.failed > 0, "panicking lane produced no failures");
    assert!(infra.answered > 0, "healthy lanes produced no answers");
    assert_eq!(infra.stranded, 0, "requests stranded after drain");
    assert_eq!(infra.double_delivery, 0, "a response was delivered twice");
    assert!(infra.resolved_exactly_once);
    assert!(infra.drained_in_bound, "drain blew its bound (deadlock?)");
}

#[test]
fn chaos_drift_only_plan_keeps_high_agreement() {
    // temperature drift alone (no mismatch, no stuck cells): the
    // chip-calibration-then-drift path must degrade gently, and the trial
    // temperature schedule must follow the plan's step shape
    let plan = FaultPlan {
        seed: 9,
        analog: vec![AnalogFault::TempDrift {
            kind: DriftKind::Step,
            from_c: 27.0,
            to_c: 85.0,
            steps: 2,
        }],
        infra: vec![],
    };
    let cfg = small_cfg(4);
    let report = run_chaos(&plan, &cfg).unwrap();
    for c in &report.corners {
        assert_eq!(c.trial_temp_c, vec![27.0, 27.0, 85.0, 85.0]);
        assert!(c.stuck_cells.iter().all(|&n| n == 0));
        assert!(
            c.mean_agreement >= 1.0 - MEAN_DEGRADATION_ENVELOPE,
            "corner {}: drift-only agreement {}",
            c.node,
            c.mean_agreement
        );
    }
    // no infra faults planned: the storm default still resolves cleanly
    assert!(report.infra.resolved_exactly_once);
    assert!(!report.infra.panic_observed);
    assert!(report.pass(), "{:?}", report.violations());
}
