//! Observability integration suite (ISSUE 7 acceptance).
//!
//! What is pinned here:
//!  * schema stability — the Prometheus and canonical-JSON expositions
//!    are golden-tested byte for byte against hand-computed values (the
//!    golden snapshot uses power-of-two nanosecond latencies so every
//!    derived float is an exact dyadic rational);
//!  * merge algebra — `ServeMetrics::merge` is exactly associative and
//!    commutative: any fold order over random lanes yields a bit-identical
//!    aggregate (property test);
//!  * tracing under concurrency — spans recorded from every worker-pool
//!    thread land in the ring with unique, ordered sequence numbers and
//!    no torn records; the ring wraps with exact drop accounting;
//!  * zero-cost disabled — recording a disabled span performs no heap
//!    allocation (counting global allocator), and the enabled steady
//!    state doesn't allocate either;
//!  * end-to-end counts — chaos corner and infra campaigns, including a
//!    latency-injection fault plan, produce histograms whose counts
//!    equal the delivered requests at every (node, regime, temperature)
//!    corner; the CLI `--metrics-out` / `metrics` surfaces emit the same
//!    invariants through the binary.
//!
//! Trace state is process-global, so every test that enables tracing or
//! records spans in-process serializes on `TRACE_GUARD`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::time::Duration;

use sac::coordinator::{
    metrics_file_json, prometheus_exposition, synthetic_engine, trace_of, ExemplarSet,
    HealthSnapshot, KernelSnapshot, MetricsSnapshot, Router, RouterConfig, ServeMetrics,
    StageSnapshot,
};
use sac::faults::{
    chaos_corners, chaos_net, run_corner_with_metrics, run_infra_with_metrics, AnalogFault,
    ChaosConfig, DriftKind, FaultPlan, InfraFault,
};
use sac::nn::batch::SignalHealthStats;
use sac::prop_assert;
use sac::runtime::FaultyExec;
use sac::util::json::{self, Json};
use sac::util::pool::WorkerPool;
use sac::util::propcheck;
use sac::util::trace::{self, TraceStats};

// ---------------------------------------------------------------------
// counting allocator: per-thread allocation counter for the zero-cost
// tracing assertions (deallocation is uncounted — only new allocations
// matter for the hot path)
// ---------------------------------------------------------------------

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // try_with: TLS may be mid-teardown when a destructor allocates
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// trace-state serialization (tracing is process-global; the test harness
// runs #[test] fns on parallel threads)
// ---------------------------------------------------------------------

static TRACE_GUARD: Mutex<()> = Mutex::new(());

fn trace_lock() -> MutexGuard<'static, ()> {
    TRACE_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// golden snapshot: every latency is a power-of-two nanosecond count, so
// each derived float (mean, quantiles, throughput) is an exact dyadic
// rational and the serialized text is platform-independent.
//
// One batch of 2 rows at 2^20 ns = 1048576 ns:
//   bucket index = (octave 16)·32 + sub 0 = 512, bounds [1048576, 1081344)
//   mean = p50 = p99 = 1.048576 ms (single sample: clamped exact)
//   throughput = 2·10^9 / 2^20 = 1907.3486328125 req/s (dyadic)
// ---------------------------------------------------------------------

fn golden_snapshot() -> MetricsSnapshot {
    let mut alpha = ServeMetrics::default();
    alpha.record_batch(2, Duration::from_nanos(1 << 20));
    let beta = ServeMetrics::default();
    let mut aggregate = alpha.clone();
    aggregate.merge(&beta);
    // exemplar: lane 0's first request (trace_of(0, 0) = 2^48 + 1,
    // exact in f64) at the same dyadic 2^20 ns latency as the histogram
    let mut alpha_ex = ExemplarSet::default();
    alpha_ex.observe(1 << 20, trace_of(0, 0));
    // dyadic signal block: saturation 2/4 = 0.5, fallbacks 3/12 = 0.25,
    // margin stats exact halves/quarters
    let alpha_sig = SignalHealthStats {
        enabled: true,
        mul_elems: 8,
        mul_fallbacks: 3,
        act_samples: 4,
        act_sat_high: 1,
        act_sat_low: 1,
        act_fallbacks: 0,
        heat: [1, 2, 2, 0, 0, 0, 0, 0],
        margin_min: -0.5,
        margin_sum: 2.25,
    };
    MetricsSnapshot {
        name: "golden".into(),
        stages: StageSnapshot {
            submitted: 2,
            rejected: 1,
            batches_enqueued: 1,
            deadline_flushes: 1,
            batches_completed: 1,
            batches_failed: 0,
            rows_delivered: 2,
            responses_taken: 2,
            wait_timeouts: 0,
        },
        lanes: vec![("alpha".into(), alpha), ("beta".into(), beta)],
        aggregate,
        kernel: KernelSnapshot {
            parallel_batches: 4,
            serial_batches: 2,
            grid_cache_hits: 3,
            grid_cache_misses: 1,
        },
        trace: TraceStats {
            enabled: true,
            capacity: 64,
            recorded: 5,
            dropped: 0,
        },
        // rebuild_ns_total = 2^21 ns → exactly 0.002097152 s (dyadic)
        health: HealthSnapshot {
            lanes: vec![
                ("alpha".into(), "degraded".into()),
                ("beta".into(), "healthy".into()),
            ],
            probes: 6,
            probe_disagreements: 2,
            to_degraded: 1,
            to_quarantined: 1,
            recovered: 1,
            rebuilds: 1,
            rebuild_ns_total: 2_097_152,
            shed_deadline: 3,
            shed_queue: 1,
            requeues: 1,
            retries: 1,
            respawns: 1,
        },
        exemplars: vec![
            ("alpha".into(), alpha_ex),
            ("beta".into(), ExemplarSet::default()),
        ],
        signal: vec![
            ("alpha".into(), alpha_sig),
            ("beta".into(), SignalHealthStats::default()),
        ],
    }
}

fn golden_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/goldens")
        .join(name)
}

fn check_golden(name: &str, produced: &str) {
    let path = golden_path(name);
    if std::env::var("SAC_UPDATE_GOLDENS").is_ok() {
        std::fs::write(&path, format!("{}\n", produced.trim_end())).unwrap();
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "reading golden {}: {e} (regenerate with SAC_UPDATE_GOLDENS=1)",
            path.display()
        )
    });
    assert_eq!(
        produced.trim_end(),
        want.trim_end(),
        "golden mismatch for {name} (regenerate with SAC_UPDATE_GOLDENS=1 \
         only if the format change is intentional — this is the schema contract)"
    );
}

// ---------------------------------------------------------------------
// satellite 2: golden-file exposition tests
// ---------------------------------------------------------------------

#[test]
fn golden_json_exposition_is_stable() {
    let snap = golden_snapshot();
    let text = metrics_file_json(std::slice::from_ref(&snap)).to_string();
    check_golden("metrics.json", &text);
    // the canonical text round-trips through the parser unchanged
    let back = json::parse(&text).unwrap();
    assert_eq!(back.to_string(), text);
    assert_eq!(back.get("schema").unwrap().as_str().unwrap(), "sac-metrics/v4");
    let snap_json = &back.get("snapshots").unwrap().as_arr().unwrap()[0];
    assert_eq!(snap_json.get("router").unwrap().as_str().unwrap(), "golden");
}

#[test]
fn golden_prometheus_exposition_is_stable() {
    let snap = golden_snapshot();
    let prom = snap.prometheus();
    check_golden("metrics.prom", &prom);
    // the single-snapshot shorthand equals the slice exposition
    assert_eq!(prom, prometheus_exposition(std::slice::from_ref(&snap)));
}

#[test]
fn golden_values_are_hand_checkable() {
    // the dyadic arithmetic behind the golden files, asserted in-process
    // so a histogram change fails here with numbers, not a text diff
    let snap = golden_snapshot();
    let (task, m) = &snap.lanes[0];
    assert_eq!(task, "alpha");
    assert_eq!(m.batch_latency.buckets(), vec![(512, 1)]);
    assert_eq!(m.request_latency.buckets(), vec![(512, 2)]);
    assert_eq!(sac::coordinator::telemetry::bucket_bounds(512), (1_048_576, 1_081_344));
    assert_eq!(m.mean_latency_ms(), 1.048576);
    assert_eq!(m.p50_latency_ms(), 1.048576);
    assert_eq!(m.p99_latency_ms(), 1.048576);
    assert_eq!(m.throughput_rps(), 1907.3486328125);
    assert_eq!(snap.aggregate, snap.lanes[0].1);
    // the exemplar sits in the same bucket as the histogram sample and
    // carries lane 0's first trace id exactly
    let (_, ex) = &snap.exemplars[0];
    let e = ex.get(512).unwrap();
    assert_eq!(e.trace_id, (1u64 << 48) + 1);
    assert_eq!(e.latency_ns, 1 << 20);
    assert_eq!(ex.len(), 1);
    // the signal fractions behind the golden text are exact dyadics
    let (_, sig) = &snap.signal[0];
    assert_eq!(sig.saturation_fraction(), 0.5);
    assert_eq!(sig.fallback_fraction(), 0.25);
    assert_eq!(sig.score(), 0.5);
}

// ---------------------------------------------------------------------
// satellite 1: merge-order invariance (property test)
// ---------------------------------------------------------------------

#[test]
fn metrics_merge_is_order_and_grouping_invariant() {
    propcheck::check(0x5AC_0B5, 40, |g| -> Result<(), String> {
        let n_lanes = g.usize_in(1, 6);
        let mut lanes: Vec<ServeMetrics> = (0..n_lanes).map(|_| ServeMetrics::default()).collect();
        for _ in 0..g.usize_in(1, 60) {
            let lane = g.usize_in(0, n_lanes - 1);
            let rows = g.usize_in(1, 32);
            let ns = g.usize_in(1, 50_000_000) as u64;
            lanes[lane].record_batch(rows, Duration::from_nanos(ns));
        }

        let mut fwd = ServeMetrics::default();
        for m in &lanes {
            fwd.merge(m);
        }
        let mut rev = ServeMetrics::default();
        for m in lanes.iter().rev() {
            rev.merge(m);
        }
        // pairwise-tree fold: a different *grouping*, not just order
        let mut level = lanes.clone();
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            for pair in level.chunks(2) {
                let mut acc = pair[0].clone();
                if let Some(b) = pair.get(1) {
                    acc.merge(b);
                }
                next.push(acc);
            }
            level = next;
        }
        let tree = level.pop().unwrap();

        prop_assert!(fwd == rev, "forward vs reverse fold diverged");
        prop_assert!(fwd == tree, "sequential vs tree fold diverged");
        prop_assert!(
            fwd.to_json().to_string() == rev.to_json().to_string(),
            "serialized aggregates differ between fold orders"
        );
        prop_assert!(
            fwd.p99_latency_ms().to_bits() == tree.p99_latency_ms().to_bits(),
            "p99 is not bitwise fold-invariant"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// satellite 3: tracing under concurrency + zero-allocation hot path
// ---------------------------------------------------------------------

#[test]
fn spans_from_all_pool_threads_land_without_corruption() {
    let _g = trace_lock();
    trace::enable(65536);
    {
        let pool = WorkerPool::new(4);
        // a barrier job per worker forces every thread to record at
        // least one span concurrently
        let barrier = Arc::new(Barrier::new(4));
        for _ in 0..4 {
            let b = Arc::clone(&barrier);
            pool.execute(move || {
                let _s = trace::span("obs.barrier");
                b.wait();
            });
        }
        for _ in 0..400 {
            pool.execute(|| drop(trace::span("obs.job")));
        }
    } // WorkerPool::drop drains the queue and joins the workers

    let snap = trace::snapshot();
    let barrier_spans: Vec<_> = snap.iter().filter(|r| r.name == "obs.barrier").collect();
    assert_eq!(barrier_spans.len(), 4);
    assert_eq!(snap.iter().filter(|r| r.name == "obs.job").count(), 400);
    let threads: std::collections::BTreeSet<u32> =
        barrier_spans.iter().map(|r| r.thread).collect();
    assert_eq!(threads.len(), 4, "barrier spans must come from 4 distinct threads");

    // no torn records: exit ≥ enter everywhere, sequence numbers unique
    // and strictly increasing in (chronological) snapshot order
    for r in &snap {
        assert!(r.t_exit_ns >= r.t_enter_ns, "torn span record: {r:?}");
    }
    let seqs: Vec<u64> = snap.iter().map(|r| r.seq).collect();
    assert!(
        seqs.windows(2).all(|w| w[0] < w[1]),
        "snapshot is not in unique record order"
    );

    let st = trace::stats();
    assert_eq!(st.recorded, 404);
    assert_eq!(st.dropped, 0);
    trace::disable();
}

#[test]
fn ring_wraps_and_counts_drops_exactly() {
    let _g = trace_lock();
    trace::enable(16);
    for _ in 0..40 {
        drop(trace::span("obs.wrap"));
    }
    let snap = trace::snapshot();
    assert_eq!(snap.len(), 16);
    // the survivors are exactly the 16 most recent records, oldest first
    let seqs: Vec<u64> = snap.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, (24..40).collect::<Vec<u64>>());
    let st = trace::stats();
    assert_eq!(st.capacity, 16);
    assert_eq!(st.recorded, 40);
    assert_eq!(st.dropped, 24);
    trace::disable();
}

// ---------------------------------------------------------------------
// ISSUE 10 tentpole: per-request trace correlation
// ---------------------------------------------------------------------

#[test]
fn correlate_nests_and_set_trace_overrides() {
    let _g = trace_lock();
    trace::enable(64);
    assert_eq!(trace::current_trace(), 0);
    {
        let _outer = trace::correlate(7);
        assert_eq!(trace::current_trace(), 7);
        {
            let _inner = trace::correlate(9);
            assert_eq!(trace::current_trace(), 9);
            drop(trace::span("obs.inner"));
        }
        // the inner guard restored the outer id on drop
        assert_eq!(trace::current_trace(), 7);
        drop(trace::span("obs.outer"));
        // admission mints the request id mid-span: set_trace overrides
        // the id the span inherited at entry
        let mut minted = trace::span("obs.minted");
        minted.set_trace(11);
        drop(minted);
    }
    assert_eq!(trace::current_trace(), 0, "outermost guard restores the idle id");
    let snap = trace::snapshot();
    let tr = |name: &str| snap.iter().find(|r| r.name == name).unwrap().trace;
    assert_eq!(tr("obs.inner"), 9);
    assert_eq!(tr("obs.outer"), 7);
    assert_eq!(tr("obs.minted"), 11);
    trace::disable();
}

/// A request whose early spans were evicted by ring overwrite still
/// exports as a well-formed Chrome document: the rootless trace is
/// listed in `metadata.truncated_traces` and the drop accounting is
/// exact (satellite 3).
#[test]
fn partially_evicted_trace_exports_truncation_marked() {
    let _g = trace_lock();
    trace::enable(4);
    // trace 5: a complete submit → deliver pair, recorded first
    {
        let _c = trace::correlate(5);
        drop(trace::span("router.submit"));
        drop(trace::span("router.deliver"));
    }
    // trace 6: three spans; the third overwrites trace 5's admission
    // root (ring of 4, fifth record evicts seq 0)
    {
        let _c = trace::correlate(6);
        drop(trace::span("router.submit"));
        drop(trace::span("router.deliver"));
        drop(trace::span("router.deliver"));
    }
    let doc = trace::export_chrome_live();
    let text = doc.to_string();
    // well-formed: the canonical text round-trips through the parser
    assert_eq!(json::parse(&text).unwrap().to_string(), text);
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 4, "ring capacity bounds the exported events");
    let meta = doc.get("metadata").unwrap();
    assert_eq!(meta.get("capacity").unwrap().as_usize().unwrap(), 4);
    assert_eq!(meta.get("recorded").unwrap().as_usize().unwrap(), 5);
    assert_eq!(meta.get("dropped").unwrap().as_usize().unwrap(), 1);
    // trace 5 lost its root span; trace 6 is fully rooted
    let trunc = meta.get("truncated_traces").unwrap().as_arr().unwrap();
    assert_eq!(trunc.len(), 1);
    assert_eq!(trunc[0].as_usize().unwrap(), 5);
    // the surviving orphan span still carries its correlation id
    let orphan = events
        .iter()
        .find(|e| {
            e.get("args").unwrap().get("trace_id").unwrap().as_usize().unwrap() == 5
        })
        .expect("trace 5's deliver span survives the wrap");
    assert_eq!(orphan.get("name").unwrap().as_str().unwrap(), "router.deliver");
    trace::disable();
}

/// End to end through the live router: the trace id minted at admission
/// reappears on the pipeline spans, and delivery records exemplars that
/// link the latency histogram back to live traces.
#[test]
fn request_trace_flows_from_submit_to_deliver_with_exemplars() {
    let _g = trace_lock();
    trace::enable(8192);
    let engine = synthetic_engine(21, &[6, 8, 3], 8).unwrap();
    let router = Router::new(
        RouterConfig {
            workers: 2,
            ..RouterConfig::default()
        },
        vec![("lane".into(), engine)],
    );
    let ids: Vec<_> = (0..8)
        .map(|i| router.submit(0, vec![0.1 * i as f32; 6]).unwrap())
        .collect();
    router.drain(Duration::from_secs(30)).unwrap();
    for id in ids {
        router.try_take(id).unwrap().unwrap();
    }
    let snap = trace::snapshot();
    // lane 0's first request: its root span was tagged at admission
    let t0 = trace_of(0, 0);
    assert!(
        snap.iter().any(|r| r.name == "router.submit" && r.trace == t0),
        "admission root span missing trace id {t0}"
    );
    // the batch pipeline correlates each stage to its lead request
    for expected in ["router.batch", "engine.run_batch", "router.deliver"] {
        assert!(
            snap.iter().any(|r| r.name == expected && r.trace != 0),
            "no correlated {expected:?} span"
        );
    }
    // delivery recorded exemplars, every one tied to a real trace
    let m = router.metrics_snapshot("trace-flow");
    let (task, ex) = &m.exemplars[0];
    assert_eq!(task, "lane");
    assert!(!ex.is_empty(), "tracing on: delivery must retain exemplars");
    for e in ex.iter() {
        assert_ne!(e.trace_id, 0);
    }
    // and the Prometheus exposition carries the OpenMetrics suffix
    let prom = m.prometheus();
    assert!(
        prom.contains("# {trace_id=\""),
        "exemplar suffix missing from: {prom}"
    );
    router.shutdown();
    trace::disable();
}

#[test]
fn disabled_tracing_allocates_nothing() {
    let _g = trace_lock();
    trace::disable();
    // warm up lazy thread-local state outside the measured window
    for _ in 0..16 {
        drop(trace::span("obs.warm"));
    }
    let before = thread_allocs();
    for _ in 0..10_000 {
        drop(trace::span("obs.noop"));
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "a disabled span must not allocate (router hot path)"
    );
}

#[test]
fn enabled_tracing_steady_state_allocates_nothing() {
    let _g = trace_lock();
    trace::enable(64);
    // fill past capacity so both the push and the overwrite paths run
    // inside the measured window without growing the ring
    for _ in 0..200 {
        drop(trace::span("obs.fill"));
    }
    let before = thread_allocs();
    for _ in 0..1_000 {
        drop(trace::span("obs.steady"));
    }
    assert_eq!(
        thread_allocs() - before,
        0,
        "steady-state span recording must not allocate"
    );
    trace::disable();
}

/// ISSUE-8 acceptance: once the scratch arena and the caller's logits
/// buffer are warm, `forward_batch_into` performs **zero** heap
/// allocations on the calling thread — serial and row-sharded alike.
/// The ping-pong arena buffers are checked out/returned without
/// reallocation, `run_scoped` publishes its task on the caller's stack,
/// and disabled spans are free (asserted separately above).
#[test]
fn warm_forward_batch_into_allocates_nothing() {
    let _g = trace_lock();
    trace::disable();
    // a private grid resolution so this test's cache entry never collides
    // with another test's (the cache is process-global)
    let cfg = sac::nn::batch::GridConfig {
        proto_range: 6.0,
        proto_density: 259,
        act_range: 16.0,
        act_density: 131,
    };
    let kernel = sac::nn::batch::BatchKernel::new(
        Box::new(sac::cells::Algorithmic::relu()),
        sac::nn::Activation::Phi1,
        3,
        1.0,
        &cfg,
    );
    let sizes = vec![6usize, 8, 4];
    let mut rng = sac::util::rng::Rng::new(88);
    let mut weights: Vec<Vec<f64>> = Vec::new();
    let mut biases: Vec<Vec<f64>> = Vec::new();
    for li in 0..sizes.len() - 1 {
        weights.push(
            (0..sizes[li] * sizes[li + 1])
                .map(|_| rng.uniform_in(-0.8, 0.8))
                .collect(),
        );
        biases.push((0..sizes[li + 1]).map(|_| rng.uniform_in(-0.2, 0.2)).collect());
    }
    // 32 rows: enough for 4 full slabs above the small-batch threshold
    let rows = 32;
    let x: Vec<f32> = (0..rows * sizes[0])
        .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
        .collect();
    let mut logits = Vec::new();
    // warm-up outside the measured window: grows the arena, initializes
    // the lazy process-wide slab pool, sizes the logits buffer
    for threads in [1usize, 4, 1, 4] {
        kernel.forward_batch_into(&sizes, &weights, &biases, &x, rows, threads, &mut logits);
    }
    let want = logits.clone();
    for threads in [1usize, 4] {
        let before = thread_allocs();
        for _ in 0..50 {
            kernel.forward_batch_into(&sizes, &weights, &biases, &x, rows, threads, &mut logits);
        }
        assert_eq!(
            thread_allocs() - before,
            0,
            "steady-state forward_batch_into allocated at {threads} threads"
        );
        assert_eq!(logits, want, "warm path changed the logits at {threads} threads");
    }
}

// ---------------------------------------------------------------------
// tentpole: stage counters through the live router pipeline
// ---------------------------------------------------------------------

#[test]
fn stage_counters_track_the_request_pipeline() {
    let _g = trace_lock();
    let engine = synthetic_engine(11, &[6, 8, 3], 8).unwrap();
    let router = Router::new(
        RouterConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..RouterConfig::default()
        },
        vec![("only".into(), engine)],
    );
    // rejections: unknown lane, then a dimension mismatch
    assert!(router.submit(5, vec![0.0; 6]).is_err());
    assert!(router.submit(0, vec![0.0; 3]).is_err());
    // 3 requests against batch size 8: delivery requires a deadline flush
    let ids: Vec<_> = (0..3)
        .map(|i| router.submit(0, vec![0.1 * i as f32; 6]).unwrap())
        .collect();
    for id in ids {
        router.wait(id, Duration::from_secs(30)).unwrap();
    }
    let s = router.stages();
    assert_eq!(s.submitted, 3);
    assert_eq!(s.rejected, 2);
    assert_eq!(s.rows_delivered, 3);
    assert_eq!(s.responses_taken, 3);
    assert!(s.deadline_flushes >= 1, "partial batch must be deadline-flushed");
    assert!(s.batches_enqueued >= 1);
    assert_eq!(s.batches_completed, s.batches_enqueued);
    assert_eq!(s.batches_failed, 0);
    assert_eq!(s.wait_timeouts, 0);

    let snap = router.metrics_snapshot("pipeline");
    assert_eq!(snap.name, "pipeline");
    assert_eq!(snap.stages, s);
    assert_eq!(snap.lanes.len(), 1);
    assert_eq!(snap.aggregate.request_latency.count(), 3);
    assert_eq!(snap.aggregate.total_rows, 3);
    router.shutdown();
}

#[test]
fn wait_timeouts_are_counted() {
    let _g = trace_lock();
    let engine = synthetic_engine(12, &[4, 6, 3], 4)
        .unwrap()
        .with_faults(Arc::new(FaultyExec::slow(Duration::from_millis(200))));
    let router = Router::new(
        RouterConfig {
            workers: 1,
            ..RouterConfig::default()
        },
        vec![("slow".into(), engine)],
    );
    // a full batch enqueues immediately; the engine sleeps 200 ms per
    // batch, so a 1 ms wait must time out
    let ids: Vec<_> = (0..4)
        .map(|_| router.submit(0, vec![0.25; 4]).unwrap())
        .collect();
    assert!(router.wait(ids[0], Duration::from_millis(1)).is_err());
    assert!(router.stages().wait_timeouts >= 1);
    router.drain(Duration::from_secs(30)).unwrap();
    for id in ids {
        router.try_take(id).unwrap().unwrap();
    }
    let s = router.stages();
    assert_eq!(s.wait_timeouts, 1);
    assert_eq!(s.rows_delivered, 4);
    assert_eq!(s.responses_taken, 4);
    router.shutdown();
}

// ---------------------------------------------------------------------
// satellite 4: histogram counts equal delivered requests at every
// (node, regime, temperature) corner, including under latency injection
// ---------------------------------------------------------------------

#[test]
fn corner_histograms_count_every_delivered_request() {
    let _g = trace_lock();
    // run the full analog campaign with the span ring live: the snapshot
    // must carry the trace stats alongside the histograms
    trace::enable(8192);
    let net = chaos_net();
    let plan = FaultPlan {
        seed: 20260808,
        analog: vec![
            AnalogFault::Mismatch { sigma_scale: 1.0 },
            AnalogFault::TempDrift {
                kind: DriftKind::Step,
                from_c: 0.0,
                to_c: 85.0,
                steps: 2,
            },
        ],
        infra: vec![],
    };
    let cfg = ChaosConfig {
        trials: 2,
        workers: 3,
        eval_rows: 24,
        kernel_threads: None,
    };
    for (node, regime) in chaos_corners() {
        let (report, snap) = run_corner_with_metrics(node, regime, &net, &plan, &cfg).unwrap();
        // trials 0 and 1 pin the two drifted temperatures (0 °C, 85 °C)
        assert_eq!(report.trial_temp_c, vec![0.0, 85.0]);
        assert_eq!(snap.lanes.len(), cfg.trials + 1, "nominal + one lane per trial");
        for (task, m) in &snap.lanes {
            assert_eq!(
                m.total_rows, cfg.eval_rows,
                "lane {task} rows at {}/{}",
                report.node, report.regime
            );
            assert_eq!(
                m.request_latency.count(),
                cfg.eval_rows as u64,
                "lane {task} histogram count at {}/{}",
                report.node,
                report.regime
            );
            assert!(m.batch_latency.count() >= 1);
            assert_eq!(m.total_batches as u64, m.batch_latency.count());
        }
        let total = ((cfg.trials + 1) * cfg.eval_rows) as u64;
        assert_eq!(snap.aggregate.request_latency.count(), total);
        assert_eq!(snap.stages.submitted, total);
        assert_eq!(snap.stages.rows_delivered, total);
        assert_eq!(snap.stages.responses_taken, total);
        assert_eq!(snap.stages.rejected, 0);
        assert_eq!(snap.stages.batches_failed, 0);
        assert_eq!(snap.name, format!("chaos.corner.{}", report.node));
        assert!(snap.trace.enabled, "snapshot must capture live trace state");
        assert!(snap.trace.recorded > 0, "serving under tracing records spans");
    }
    // the campaign's own spans are present by name
    let names: std::collections::BTreeSet<&str> =
        trace::snapshot().iter().map(|r| r.name).collect();
    for expected in ["chaos.corner", "router.submit", "engine.run_batch", "batch.forward"] {
        assert!(names.contains(expected), "missing span {expected:?} in {names:?}");
    }
    trace::disable();
}

#[test]
fn latency_injection_shows_up_in_the_histograms() {
    let _g = trace_lock();
    let plan = FaultPlan {
        seed: 4242,
        analog: vec![],
        infra: vec![
            InfraFault::SlowEngine { delay_us: 2_000 },
            InfraFault::SubmitStorm {
                submitters: 3,
                requests: 45,
            },
        ],
    };
    let cfg = ChaosConfig {
        trials: 1,
        workers: 3,
        eval_rows: 8,
        kernel_threads: None,
    };
    let (report, snap) = run_infra_with_metrics(&plan, &cfg).unwrap();
    assert!(report.resolved_exactly_once);
    assert_eq!(report.submitted, 45);
    assert_eq!(report.answered, 45, "no panic fault: everything answers");
    // every answered request is exactly one histogram sample
    assert_eq!(snap.aggregate.request_latency.count(), 45);
    assert_eq!(snap.aggregate.total_rows, 45);
    assert_eq!(snap.stages.rows_delivered, 45);
    // the injected 2 ms delay bounds every batch on the slow lane from below
    let slow = &snap.lanes.iter().find(|(t, _)| t == "slow").unwrap().1;
    assert!(slow.batch_latency.count() >= 1);
    assert!(
        slow.batch_latency.min_ns() >= 2_000_000,
        "injected 2 ms delay missing from the histogram: min = {} ns",
        slow.batch_latency.min_ns()
    );
    assert!(slow.p50_latency_ms() >= 2.0);
    // the healthy lane served its share too
    let healthy = &snap.lanes.iter().find(|(t, _)| t == "storm").unwrap().1;
    assert!(healthy.total_rows > 0);
    assert_eq!(snap.name, "chaos.infra");
}

// ---------------------------------------------------------------------
// CLI surfaces: bench-serve --metrics-out, sac metrics, chaos --metrics-out
// (subprocesses — no TRACE_GUARD needed)
// ---------------------------------------------------------------------

fn sac_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sac"))
}

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sac-obs-{}-{name}", std::process::id()))
}

#[test]
fn bench_serve_metrics_out_counts_match_delivered_requests() {
    let out = temp_path("bench.json");
    let status = sac_bin()
        .args([
            "bench-serve",
            "--tasks",
            "2",
            "--requests",
            "64",
            "--batch",
            "8",
            "--submitters",
            "2",
            "--workers",
            "3",
            "--metrics-out",
            out.to_str().unwrap(),
        ])
        .env("SAC_TRACE", "1")
        .status()
        .unwrap();
    assert!(status.success());

    let j = json::parse_file(&out).unwrap();
    assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "sac-metrics/v4");
    let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
    assert_eq!(snaps.len(), 1);
    let snap = &snaps[0];
    assert_eq!(snap.get("router").unwrap().as_str().unwrap(), "bench-serve");

    let lanes = snap.get("lanes").unwrap().as_arr().unwrap();
    assert_eq!(lanes.len(), 2);
    let mut rows_total = 0usize;
    for lane in lanes {
        let m = lane.get("metrics").unwrap();
        let rows = m.get("total_rows").unwrap().as_usize().unwrap();
        let hist = m.get("request_latency").unwrap();
        let count = hist.get("count").unwrap().as_usize().unwrap();
        assert_eq!(
            count,
            rows,
            "lane {} histogram count vs delivered rows",
            lane.get("task").unwrap().as_str().unwrap()
        );
        // sparse bucket counts must sum to the total
        let bucket_sum: usize = hist
            .get("buckets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.as_arr().unwrap()[1].as_usize().unwrap())
            .sum();
        assert_eq!(bucket_sum, count);
        rows_total += rows;
    }
    assert_eq!(rows_total, 64);
    let agg = snap.get("aggregate").unwrap();
    assert_eq!(
        agg.get("request_latency").unwrap().get("count").unwrap().as_usize().unwrap(),
        64
    );
    // SAC_TRACE=1 reached the binary: spans were recorded
    let tr = snap.get("trace").unwrap();
    assert!(matches!(tr.get("enabled").unwrap(), Json::Bool(true)));
    assert!(tr.get("recorded").unwrap().as_usize().unwrap() > 0);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn metrics_cli_emits_parseable_canonical_json() {
    let output = sac_bin()
        .args([
            "metrics", "--tasks", "1", "--requests", "32", "--batch", "8", "--seed", "9",
            "--format", "json",
        ])
        .output()
        .unwrap();
    assert!(
        output.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    let stdout = String::from_utf8(output.stdout).unwrap();
    let j = json::parse(stdout.trim()).unwrap();
    assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "sac-metrics/v4");
    let snap = &j.get("snapshots").unwrap().as_arr().unwrap()[0];
    assert_eq!(snap.get("router").unwrap().as_str().unwrap(), "metrics");
    let agg = snap.get("aggregate").unwrap();
    assert_eq!(agg.get("total_rows").unwrap().as_usize().unwrap(), 32);
    assert_eq!(
        agg.get("request_latency").unwrap().get("count").unwrap().as_usize().unwrap(),
        32
    );
}

#[test]
fn metrics_cli_prometheus_exposition_is_wellformed() {
    let output = sac_bin()
        .args([
            "metrics", "--tasks", "2", "--requests", "16", "--batch", "4", "--format", "prom",
        ])
        .output()
        .unwrap();
    assert!(
        output.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    let text = String::from_utf8(output.stdout).unwrap();
    for family in [
        "sac_requests_total",
        "sac_batches_total",
        "sac_busy_seconds_total",
        "sac_stage_total",
        "sac_kernel_batches_total",
        "sac_grid_cache_total",
        "sac_health_state",
        "sac_health_transitions_total",
        "sac_canary_probes_total",
        "sac_shed_total",
        "sac_requeues_total",
        "sac_retries_total",
        "sac_rebuilds_total",
        "sac_rebuild_seconds_total",
        "sac_worker_respawns_total",
        "sac_trace_recorded_total",
        "sac_trace_dropped_total",
        "sac_signal_saturation_ratio",
        "sac_signal_fallback_ratio",
        "sac_signal_margin_min",
        "sac_batch_latency_seconds",
        "sac_request_latency_seconds",
    ] {
        assert!(
            text.contains(&format!("# HELP {family} ")),
            "missing HELP for {family}"
        );
        assert!(
            text.contains(&format!("# TYPE {family} ")),
            "missing TYPE for {family}"
        );
    }
    // HELP/TYPE once per family (valid exposition), histograms terminated
    assert_eq!(text.matches("# TYPE sac_stage_total").count(), 1);
    assert_eq!(text.matches("# TYPE sac_batch_latency_seconds").count(), 1);
    assert!(text.contains("le=\"+Inf\""));
    // format mode "prom" prints no JSON
    assert!(!text.contains("\"schema\""));
}

#[test]
fn chaos_metrics_out_writes_one_snapshot_per_stage() {
    let out_dir = temp_path("chaos-out");
    let metrics = temp_path("chaos-metrics.json");
    // drift-only analog faults keep agreement high (see tests/chaos.rs),
    // so this small campaign passes the envelope deterministically while
    // still exercising latency injection end to end
    let plan = FaultPlan {
        seed: 91,
        analog: vec![AnalogFault::TempDrift {
            kind: DriftKind::Ramp,
            from_c: 27.0,
            to_c: 85.0,
            steps: 2,
        }],
        infra: vec![
            InfraFault::SlowEngine { delay_us: 1_000 },
            InfraFault::SubmitStorm {
                submitters: 3,
                requests: 36,
            },
        ],
    };
    let plan_path = temp_path("chaos-plan.json");
    plan.save(&plan_path).unwrap();
    let status = sac_bin()
        .args([
            "chaos",
            "--plan",
            plan_path.to_str().unwrap(),
            "--trials",
            "2",
            "--workers",
            "3",
            "--out",
            out_dir.to_str().unwrap(),
            "--metrics-out",
            metrics.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success());
    let j = json::parse_file(&metrics).unwrap();
    let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
    // two paper corners, then the infra storm
    assert_eq!(snaps.len(), 3);
    let names: Vec<&str> = snaps
        .iter()
        .map(|s| s.get("router").unwrap().as_str().unwrap())
        .collect();
    assert!(names[0].starts_with("chaos.corner."));
    assert!(names[1].starts_with("chaos.corner."));
    assert_eq!(names[2], "chaos.infra");
    assert_ne!(names[0], names[1], "the two corners are distinct nodes");
    for s in snaps {
        let agg = s.get("aggregate").unwrap();
        let rows = agg.get("total_rows").unwrap().as_usize().unwrap();
        let count = agg
            .get("request_latency")
            .unwrap()
            .get("count")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(count, rows, "snapshot {:?}", s.get("router").unwrap());
        assert!(rows > 0);
    }
    let _ = std::fs::remove_file(&metrics);
    let _ = std::fs::remove_file(&plan_path);
    let _ = std::fs::remove_dir_all(&out_dir);
}

// ---------------------------------------------------------------------
// ISSUE 10 satellites: schema-version compat + `sac trace export`
// ---------------------------------------------------------------------

#[test]
fn metrics_validate_accepts_current_and_rejects_unknown_schema() {
    // a current-schema file written by the binary itself
    let good = temp_path("validate-good.json");
    let status = sac_bin()
        .args([
            "metrics", "--tasks", "1", "--requests", "16", "--batch", "8", "--format", "json",
            "--out", good.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success());
    let out = sac_bin()
        .args(["metrics", "--validate", good.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("sac-metrics/v4"));

    // the same file tagged with a future schema version: typed error,
    // exit code 1, and the offending tag named on stderr
    let bad = temp_path("validate-bad.json");
    let doc = std::fs::read_to_string(&good).unwrap();
    std::fs::write(&bad, doc.replace("sac-metrics/v4", "sac-metrics/v9")).unwrap();
    let out = sac_bin()
        .args(["metrics", "--validate", bad.to_str().unwrap()])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unsupported metrics schema"), "stderr: {err}");
    assert!(err.contains("sac-metrics/v9"), "stderr: {err}");
    assert!(err.contains("sac-metrics/v4"), "stderr names the supported version: {err}");
    let _ = std::fs::remove_file(&good);
    let _ = std::fs::remove_file(&bad);
}

#[test]
fn trace_export_cli_emits_wellformed_chrome_trace() {
    let out = sac_bin().args(["trace", "export"]).output().unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).unwrap();
    let j = json::parse(stdout.trim()).unwrap();
    let meta = j.get("metadata").unwrap();
    assert_eq!(meta.get("schema").unwrap().as_str().unwrap(), "sac-trace/v1");
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut names = std::collections::BTreeSet::new();
    for e in events {
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e.get("cat").unwrap().as_str().unwrap(), "sac");
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        names.insert(e.get("name").unwrap().as_str().unwrap().to_string());
    }
    // the whole pipeline is visible: admission, batch execution, the
    // row-sharded kernel slabs, and delivery
    for expected in [
        "router.submit",
        "router.batch",
        "engine.run_batch",
        "native.run",
        "batch.slab",
        "router.deliver",
    ] {
        assert!(names.contains(expected), "missing {expected:?} in {names:?}");
    }
    // the default capacity swallows the default workload whole: exact
    // accounting says nothing was dropped, so no trace lost its root
    assert_eq!(meta.get("dropped").unwrap().as_usize().unwrap(), 0);
    assert_eq!(
        meta.get("truncated_traces").unwrap().as_arr().unwrap().len(),
        0
    );
    // every correlated trace in the document has its admission root
    let mut seen = std::collections::BTreeSet::new();
    let mut rooted = std::collections::BTreeSet::new();
    for e in events {
        let t = e.get("args").unwrap().get("trace_id").unwrap().as_usize().unwrap();
        if t != 0 {
            seen.insert(t);
            if e.get("name").unwrap().as_str().unwrap() == "router.submit" {
                rooted.insert(t);
            }
        }
    }
    assert!(!seen.is_empty(), "export must carry correlated spans");
    assert_eq!(seen, rooted, "every trace follows submit → … → deliver unbroken");
}
