//! Self-healing serving suite (ISSUE 9 acceptance): canary drift
//! detection, quarantine + rebuild, deadline shedding, and the chaos
//! `--recover` CLI contract.
//!
//! What is pinned here:
//!  * zero false positives — golden probes through nominal
//!    paper-corner engines never leave `Healthy`, at any thread count;
//!  * the full healing loop — a stale-calibration lane walks
//!    Degraded → Quarantined → rebuild → Healthy within a bounded
//!    number of batches, with exactly-once delivery throughout and
//!    post-rebuild agreement back inside the paper envelope;
//!  * determinism — identical-seed recovery replays serialize
//!    bit-identically;
//!  * CLI exit codes — envelope violations exit 1, IO/parse/plan
//!    errors exit 2, a passing `--recover` run exits 0 and leaves the
//!    health-timeline artifact behind.

use std::sync::Arc;
use std::time::Duration;

use sac::cells::multiplier::Multiplier;
use sac::coordinator::{Engine, HealthState, LaneSpec, Router, RouterConfig};
use sac::faults::{
    chaos_grid, chaos_net, eval_features, run_recovery, AnalogFault, ChaosConfig, DriftKind,
    FaultPlan, MEAN_DEGRADATION_ENVELOPE,
};
use sac::nn::batch::BatchKernel;
use sac::pdk::regime::Regime;
use sac::pdk::{ProcessNode, CMOS180, FINFET7};
use sac::runtime::Executable;
use sac::sac::TableModel;

fn small_cfg() -> ChaosConfig {
    ChaosConfig {
        trials: 1,
        workers: 3,
        eval_rows: 24,
        kernel_threads: None,
    }
}

/// A healthy engine: surrogate and multiplier calibrated at the same
/// corner, served through the chaos prototype-detector net.
fn corner_engine(node: &'static ProcessNode, regime: Regime, t_c: f64) -> Engine {
    let net = chaos_net();
    let act = net.activation_kind().unwrap();
    let table = TableModel::calibrate(node, regime, t_c);
    let mult = Multiplier::calibrate(&table, net.splines, net.c);
    let kernel = BatchKernel::with_multiplier(
        Box::new(table),
        mult,
        act,
        net.splines,
        net.c,
        &chaos_grid(),
    );
    let exe = Executable::native_mlp_with_kernel(&net, 8, Arc::new(kernel)).unwrap();
    Engine::from_parts(net, exe).unwrap()
}

#[test]
fn canary_has_zero_false_positives_on_nominal_corner_engines() {
    // Property (per corner × temperature × thread count): a lane whose
    // engine matches its own calibration must never be flagged — no
    // probe disagreement, no health transition, no fallback.
    let corners: [(&'static ProcessNode, Regime, f64); 4] = [
        (&CMOS180, Regime::WeakInversion, 27.0),
        (&CMOS180, Regime::WeakInversion, 60.0),
        (&FINFET7, Regime::ModerateInversion, 27.0),
        (&FINFET7, Regime::ModerateInversion, 85.0),
    ];
    for threads in [1usize, 4] {
        for &(node, regime, t_c) in &corners {
            let engine = corner_engine(node, regime, t_c);
            let router = Router::with_specs(
                RouterConfig {
                    workers: 2,
                    kernel_threads: Some(threads),
                    canary_every: 1,
                    ..RouterConfig::default()
                },
                // probe labels self-captured from the lane's own engine
                vec![LaneSpec::new("nominal", engine)],
            );
            for f in eval_features(7, 16) {
                router.submit(0, f).unwrap();
            }
            router.drain(Duration::from_secs(60)).unwrap();
            let h = router.health_snapshot();
            let timeline = router.health_timeline();
            let states = router.health_states();
            router.shutdown();
            assert!(
                h.probes > 0,
                "{}/{regime:?}@{t_c} t{threads}: canary never probed",
                node.name
            );
            assert_eq!(
                h.probe_disagreements, 0,
                "{}/{regime:?}@{t_c} t{threads}: false-positive probe disagreement",
                node.name
            );
            assert!(
                timeline.is_empty(),
                "{}/{regime:?}@{t_c} t{threads}: spurious transitions {timeline:?}",
                node.name
            );
            assert_eq!(states[0].1, HealthState::Healthy);
            assert_eq!(h.to_degraded, 0);
            assert_eq!(h.to_quarantined, 0);
        }
    }
}

#[test]
fn recovery_campaign_heals_quarantined_lane_within_bounded_batches() {
    let plan = FaultPlan::default_plan(20260808);
    let report = run_recovery(&plan, &small_cfg()).unwrap();

    // the full walk, in order, on the drifted lane
    let drifted: Vec<(HealthState, HealthState)> = report
        .timeline
        .iter()
        .filter(|e| e.lane == "drifted")
        .map(|e| (e.from, e.to))
        .collect();
    assert_eq!(
        drifted,
        vec![
            (HealthState::Healthy, HealthState::Degraded),
            (HealthState::Degraded, HealthState::Quarantined),
            (HealthState::Quarantined, HealthState::Healthy),
        ],
        "unexpected healing walk: {:?}",
        report.timeline
    );
    // detection is prompt: the whole walk happens within the first few
    // completed batches on the lane
    assert!(
        report
            .timeline
            .iter()
            .filter(|e| e.lane == "drifted")
            .all(|e| e.at_batch <= 6),
        "healing took too many batches: {:?}",
        report.timeline
    );
    assert!(report.drift_detected);
    assert!(report.quarantined);
    assert!(report.rebuilt_healthy);
    assert!(report.recovered_in_bound);
    assert_eq!(report.rebuilds, 1, "expected exactly one rebuild");
    assert!(
        report.post_rebuild_agreement >= 1.0 - MEAN_DEGRADATION_ENVELOPE,
        "post-rebuild agreement {} still outside the envelope",
        report.post_rebuild_agreement
    );
    // liveness under the storm that rode along
    assert!(report.resolved_exactly_once);
    assert!(report.transient_panic_retried);
    assert!(report.retries >= 1);
    // deadline shedding hit only the overdue backlog
    assert!(report.fresh_request_answered);
    assert!(report.sheds_only_overdue);
    assert!(report.shed_deadline >= 1);
    // and the healthy reference lane was never flagged
    assert!(report.no_false_positives);
    assert!(report.pass(), "violations: {:?}", report.violations());
}

#[test]
fn recovery_identical_seed_replay_is_bit_identical() {
    let plan = FaultPlan::default_plan(4242);
    let cfg = small_cfg();
    let a = run_recovery(&plan, &cfg).unwrap();
    let b = run_recovery(&plan, &cfg).unwrap();
    assert_eq!(
        a.canonical_json(),
        b.canonical_json(),
        "identical-seed recovery replay diverged — determinism contract broken"
    );
}

// ---------------------------------------------------------------------------
// CLI exit-code contract (`sac chaos`): 0 pass, 1 envelope violation,
// 2 IO / parse / plan error.
// ---------------------------------------------------------------------------

fn sac_chaos(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_sac"))
        .arg("chaos")
        .args(args)
        .output()
        .expect("spawning the sac binary")
}

#[test]
fn chaos_cli_exits_2_on_io_and_parse_errors() {
    let tmp = std::env::temp_dir().join(format!("sac_recovery_cli_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let out = tmp.to_str().unwrap();

    // missing plan file: IO error
    let o = sac_chaos(&["--plan", "/nonexistent/no_such_plan.json", "--out", out]);
    assert_eq!(o.status.code(), Some(2), "stderr: {}", String::from_utf8_lossy(&o.stderr));

    // unparseable plan: parse error
    let bad = tmp.join("bad_plan.json");
    std::fs::write(&bad, "{this is not json").unwrap();
    let o = sac_chaos(&["--plan", bad.to_str().unwrap(), "--out", out]);
    assert_eq!(o.status.code(), Some(2), "stderr: {}", String::from_utf8_lossy(&o.stderr));

    // well-formed JSON, invalid plan (negative duration): typed PlanError
    let invalid = tmp.join("invalid_plan.json");
    std::fs::write(
        &invalid,
        r#"{"seed": 1, "analog": [], "infra": [{"kind": "slow_engine", "delay_us": -5}]}"#,
    )
    .unwrap();
    let o = sac_chaos(&["--plan", invalid.to_str().unwrap(), "--out", out]);
    assert_eq!(o.status.code(), Some(2), "stderr: {}", String::from_utf8_lossy(&o.stderr));
    assert!(
        String::from_utf8_lossy(&o.stderr).contains("invalid fault plan"),
        "stderr should carry the typed plan error: {}",
        String::from_utf8_lossy(&o.stderr)
    );
}

#[test]
fn chaos_cli_exits_1_on_envelope_violation() {
    let tmp = std::env::temp_dir().join(format!("sac_recovery_cli_v_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();

    // a catastrophic plan: most of the multiplier grid stuck at a large
    // value collapses agreement far below the envelope floor
    let plan = FaultPlan {
        seed: 31,
        analog: vec![
            AnalogFault::Mismatch { sigma_scale: 8.0 },
            AnalogFault::TempDrift {
                kind: DriftKind::Step,
                from_c: 27.0,
                to_c: 85.0,
                steps: 2,
            },
            AnalogFault::StuckCells {
                fraction: 0.9,
                value: 5.0,
            },
        ],
        infra: vec![],
    };
    let plan_path = tmp.join("catastrophic_plan.json");
    plan.save(&plan_path).unwrap();
    let o = sac_chaos(&[
        "--plan",
        plan_path.to_str().unwrap(),
        "--trials",
        "2",
        "--workers",
        "2",
        "--out",
        tmp.to_str().unwrap(),
    ]);
    assert_eq!(
        o.status.code(),
        Some(1),
        "stderr: {}",
        String::from_utf8_lossy(&o.stderr)
    );
    assert!(
        String::from_utf8_lossy(&o.stderr).contains("VIOLATION"),
        "violations should be printed: {}",
        String::from_utf8_lossy(&o.stderr)
    );
}

#[test]
fn chaos_cli_recover_passes_and_writes_health_artifact() {
    let tmp = std::env::temp_dir().join(format!("sac_recovery_cli_r_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let o = sac_chaos(&[
        "--recover",
        "--seed",
        "20260808",
        "--workers",
        "3",
        "--out",
        tmp.to_str().unwrap(),
    ]);
    assert_eq!(
        o.status.code(),
        Some(0),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&o.stdout),
        String::from_utf8_lossy(&o.stderr)
    );
    let health = std::fs::read_to_string(tmp.join("chaos_health.json")).unwrap();
    assert!(health.contains("\"timeline\""));
    assert!(health.contains("\"quarantined\""));
    let report = std::fs::read_to_string(tmp.join("chaos_recovery.json")).unwrap();
    assert!(report.contains("\"pass\":true"));
}
