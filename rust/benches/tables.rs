//! Bench-as-harness: regenerate every paper table and time each
//! experiment end-to-end (`cargo bench --bench tables`).  The repro CLI
//! (`sac repro all`) produces the same artifacts; this target exists so
//! `cargo bench` exercises the whole evaluation pipeline and reports
//! wall-clock per experiment — one bench per paper table/figure.

use std::time::Instant;

use sac::repro::{self, ReproOpts};

fn main() {
    let opts = ReproOpts {
        out: std::path::PathBuf::from("results"),
        // keep the NN-scale experiments bounded for bench cadence; the
        // record run in EXPERIMENTS.md uses the full 1000 images
        limit: 200,
        threads: sac::util::pool::default_threads(),
        mc_trials: 20,
    };
    println!("=== paper-table/figure regeneration benchmarks ===");
    let mut total = 0.0;
    for id in repro::ALL_IDS {
        let t0 = Instant::now();
        match repro::run(id, &opts) {
            Ok(_) => {
                let dt = t0.elapsed().as_secs_f64();
                total += dt;
                println!("{id:<10} {dt:>8.2} s   ok");
            }
            Err(e) => println!("{id:<10} FAILED: {e:#}"),
        }
    }
    println!("total: {total:.1} s (CSVs in results/)");
}
