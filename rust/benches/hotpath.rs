//! Hot-path micro-benchmarks (§Perf): the GMP solvers, the device-exact
//! unit solve, cell evaluation, native batched execution and the serving
//! router (the artifact-dependent sections skip on a clean checkout).
//!
//! `cargo bench` (harness=false; uses the in-repo benchkit).

use sac::cells::activations::CellKind;
use sac::cells::{Algorithmic, CircuitCorner};
use sac::pdk::{regime::Regime, CMOS180};
use sac::sac::gmp::{solve_bisect, solve_exact, Shape, GMP_ITERS};
use sac::util::benchkit::{black_box, Bench};
use sac::util::rng::Rng;

fn main() {
    let b = Bench::default();
    let mut reports = Vec::new();

    // --- hot spot 1: the algorithmic GMP solve -----------------------
    let mut rng = Rng::new(1);
    let xs6: Vec<f64> = (0..6).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let xs32: Vec<f64> = (0..32).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    reports.push(b.run("gmp/solve_exact M=6", || black_box(solve_exact(&xs6, 1.0))));
    reports.push(b.run("gmp/solve_exact M=32", || black_box(solve_exact(&xs32, 1.0))));
    reports.push(b.run("gmp/solve_bisect(relu) M=6", || {
        black_box(solve_bisect(&xs6, 1.0, Shape::Relu, GMP_ITERS))
    }));
    reports.push(b.run("gmp/solve_bisect(softplus) M=6", || {
        black_box(solve_bisect(&xs6, 1.0, Shape::Softplus { width: 0.05 }, GMP_ITERS))
    }));
    reports.push(b.run("gmp/solve_soft_newton M=6", || {
        black_box(sac::sac::gmp::solve_soft_newton(&xs6, 1.0, 0.05))
    }));

    // --- hot spot 2: device-exact unit solve ----------------------------
    let cc = CircuitCorner::new(&CMOS180, Regime::WeakInversion);
    reports.push(b.run("circuit/proto_unit S=3 (nested solve)", || {
        black_box(sac::cells::proto_unit(&cc, 0.3, 3, 1.0))
    }));

    // --- hot spot 3: cell + multiplier eval ------------------------------
    let alg = Algorithmic::relu();
    reports.push(b.run("cell/phi1(algorithmic)", || {
        black_box(CellKind::Phi1.eval(&alg, 0.4))
    }));
    let mult = sac::cells::multiplier::Multiplier::calibrate(&alg, 3, 1.0);
    reports.push(b.run("cell/multiply(algorithmic)", || {
        black_box(mult.mul(&alg, 0.37, -0.6))
    }));

    // --- hot spot 4: one full NN forward (table tier) --------------------
    let artifacts = sac::runtime::default_artifacts_dir();
    if let Ok(net) = sac::nn::load_net(&artifacts, "xor") {
        let tm = sac::sac::TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
        let m = sac::cells::multiplier::Multiplier::calibrate(&tm, net.splines, net.c);
        reports.push(b.run("nn/forward xor (table tier)", || {
            black_box(sac::nn::forward(&net, &tm, &m, &[0.4, -0.7]))
        }));
    }

    // --- hot spot 5: native batched execution (needs artifacts) ----------
    if let Ok(rt) = sac::runtime::Runtime::new(&artifacts) {
        if let Ok(exe) = rt.load("gmp_kernel") {
            let exe = exe.with_par_threads(sac::util::pool::default_threads());
            let n: usize = exe.spec.params[0].shape.iter().product();
            let buf: Vec<f32> = (0..n).map(|i| (i % 17) as f32 * 0.1 - 0.8).collect();
            reports.push(b.run("native/gmp_kernel 4096x8", || {
                black_box(exe.run_f32(&[&buf]).unwrap())
            }));
        }
        if let Ok(mut server) = sac::coordinator::InferenceServer::new(&rt, "digits") {
            let ds =
                sac::data::Dataset::load_sacd(&artifacts.join("digits_test.bin")).unwrap();
            let quick = Bench::quick();
            reports.push(quick.run("native/digits_mlp batch=64", || {
                for i in 0..64 {
                    server.submit(ds.row(i).to_vec());
                }
                black_box(server.drain().unwrap())
            }));
        }
    }

    // --- hot spot 6: router concurrent serving (synthetic, no artifacts) -
    {
        use sac::coordinator::{synthetic_engine, Router, RouterConfig};
        use std::time::Duration;
        let router = Router::new(
            RouterConfig {
                workers: sac::util::pool::default_threads().min(8),
                ..RouterConfig::default()
            },
            vec![
                ("a".into(), synthetic_engine(1, &[16, 12, 4], 32).unwrap()),
                ("b".into(), synthetic_engine(2, &[16, 12, 4], 32).unwrap()),
            ],
        );
        let quick = Bench::quick();
        let mut rng = Rng::new(5);
        let feats: Vec<Vec<f32>> = (0..128)
            .map(|_| (0..16).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect())
            .collect();
        reports.push(quick.run("router/2-task 128 reqs (submit+drain)", || {
            let reqs: Vec<_> = feats
                .iter()
                .enumerate()
                .map(|(i, f)| router.submit(i % 2, f.clone()).unwrap())
                .collect();
            router.drain(Duration::from_secs(60)).unwrap();
            for r in reqs {
                black_box(router.try_take(r).unwrap());
            }
        }));
    }

    // --- hot spot 7: scalar vs batched engine, one 64-row batch ----------
    // The ISSUE-2 acceptance floor: the columnar lookup-grid engine must
    // be ≥ 5× faster than the scalar per-row path on a 64-row batch of
    // the bench net.
    let batched_mean_ns;
    {
        use sac::coordinator::{synthetic_engine_with_mode, DynamicBatcher};
        use sac::runtime::ExecMode;
        let sizes = [16usize, 12, 4];
        let scalar = synthetic_engine_with_mode(42, &sizes, 64, ExecMode::Scalar).unwrap();
        let batched = synthetic_engine_with_mode(42, &sizes, 64, ExecMode::Batched).unwrap();
        let mut b64 = DynamicBatcher::new(64, 16);
        let mut rng = Rng::new(9);
        for _ in 0..64 {
            b64.submit((0..16).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect());
        }
        let batch = b64.flush().remove(0);
        let quick = Bench::quick();
        let rs = quick.run("engine/scalar 64×[16,12,4] batch", || {
            black_box(scalar.run_batch(&batch).unwrap())
        });
        let rb = quick.run("engine/batched 64×[16,12,4] batch", || {
            black_box(batched.run_batch(&batch).unwrap())
        });
        let speedup = rs.mean_ns() / rb.mean_ns();
        println!(
            "engine/batched vs engine/scalar on a 64-row batch: {speedup:.1}× \
             (acceptance floor: 5×)"
        );
        assert!(
            speedup >= 5.0,
            "batched engine speedup {speedup:.1}× is below the 5× acceptance floor"
        );
        batched_mean_ns = rb.mean_ns();
        reports.push(rs);
        reports.push(rb);
    }

    // --- hot spot 8: FaultyExec passthrough overhead ---------------------
    // The chaos harness wraps engines in a fault gate on every run; a
    // default (no-fault) gate must cost one atomic increment, not a
    // measurable fraction of the batch. No speedup assert — the numbers
    // are reported for eyeballing regressions.
    {
        use sac::coordinator::{synthetic_engine, DynamicBatcher};
        use sac::runtime::FaultyExec;
        use std::sync::Arc;
        let sizes = [16usize, 12, 4];
        let plain = synthetic_engine(43, &sizes, 64).unwrap();
        let gated = synthetic_engine(43, &sizes, 64)
            .unwrap()
            .with_faults(Arc::new(FaultyExec::default()));
        let mut b64 = DynamicBatcher::new(64, 16);
        let mut rng = Rng::new(10);
        for _ in 0..64 {
            b64.submit((0..16).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect());
        }
        let batch = b64.flush().remove(0);
        let quick = Bench::quick();
        reports.push(quick.run("engine/ungated 64×[16,12,4] batch", || {
            black_box(plain.run_batch(&batch).unwrap())
        }));
        reports.push(quick.run("engine/fault-gated(no-op) 64×[16,12,4] batch", || {
            black_box(gated.run_batch(&batch).unwrap())
        }));
    }

    // --- hot spot 9: disabled-span cost on the batched hot spot ----------
    // Tracing is compiled into the serving path unconditionally; when
    // disabled a span must cost one relaxed atomic load, not a
    // measurable fraction of a batch.  A 64-row batch crosses ~69 span
    // sites (one submit span per row plus the flush/batch/engine/native
    // and delivery spans), so the ISSUE-7 acceptance ceiling is: 69
    // disabled spans ≤ 2% of the batched 64-row hot spot.
    {
        use sac::util::trace;
        assert!(
            !trace::enabled(),
            "tracing must be disabled for the overhead measurement"
        );
        let quick = Bench::quick();
        let rspan = quick.run("trace/disabled span (enter+drop)", || {
            trace::span("bench.noop")
        });
        const SPANS_PER_BATCH: f64 = 69.0;
        let overhead = rspan.mean_ns() * SPANS_PER_BATCH / batched_mean_ns;
        println!(
            "trace/disabled span: {:.2} ns → {SPANS_PER_BATCH:.0} spans are {:.3}% of \
             the batched 64-row hot spot (acceptance ceiling: 2%)",
            rspan.mean_ns(),
            overhead * 100.0
        );
        assert!(
            overhead <= 0.02,
            "disabled tracing costs {:.3}% of the batched hot spot (> 2% ceiling)",
            overhead * 100.0
        );
        reports.push(rspan);
    }

    // --- hot spot 10: row-sharded batched kernel + grid cache ------------
    // The ISSUE-8 acceptance floor: `forward_batch` sharded over 4 threads
    // must be ≥ 1.8× the serial kernel on a 256-row batch (asserted only
    // when the host actually has ≥ 4 cores — the sharding is pure overhead
    // on a single-core box), and the logits must be bit-identical at every
    // thread count regardless.  The grid-cache microbench times a cold
    // grid build against a cached (Arc-shared) kernel construction.
    {
        use sac::nn::batch::{grid_cache_clear, grid_cache_stats, BatchKernel, GridConfig};
        use sac::nn::Activation;
        let sizes = vec![16usize, 12, 4];
        let kernel = BatchKernel::new(
            Box::new(Algorithmic::relu()),
            Activation::Phi1,
            3,
            1.0,
            &GridConfig::default(),
        );
        let mut rng = Rng::new(11);
        let nl = sizes.len() - 1;
        let mut weights: Vec<Vec<f64>> = Vec::new();
        let mut biases: Vec<Vec<f64>> = Vec::new();
        for li in 0..nl {
            weights.push(
                (0..sizes[li] * sizes[li + 1])
                    .map(|_| rng.uniform_in(-0.8, 0.8))
                    .collect(),
            );
            biases.push((0..sizes[li + 1]).map(|_| rng.uniform_in(-0.2, 0.2)).collect());
        }
        let rows = 256;
        let x: Vec<f32> = (0..rows * sizes[0])
            .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
            .collect();
        // bit-identity first: determinism holds on any host
        let serial = kernel.forward_batch_threads(&sizes, &weights, &biases, &x, rows, 1);
        for threads in [2usize, 4] {
            let par = kernel.forward_batch_threads(&sizes, &weights, &biases, &x, rows, threads);
            assert_eq!(serial, par, "kernel logits diverged at {threads} threads");
        }
        let quick = Bench::quick();
        let mut means = Vec::new();
        for threads in [1usize, 2, 4] {
            let r = quick.run(
                &format!("kernel/forward_batch 256×[16,12,4] threads={threads}"),
                || {
                    black_box(kernel.forward_batch_threads(
                        &sizes, &weights, &biases, &x, rows, threads,
                    ))
                },
            );
            means.push(r.mean_ns());
            reports.push(r);
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let speedup = means[0] / means[2];
        println!(
            "kernel/forward_batch 4-thread speedup: {speedup:.2}× \
             (acceptance floor: 1.8× on ≥ 4 cores; this host has {cores})"
        );
        if cores >= 4 {
            assert!(
                speedup >= 1.8,
                "4-thread kernel speedup {speedup:.2}× is below the 1.8× acceptance floor"
            );
        } else {
            println!("  (speedup floor not asserted: {cores} core(s) available)");
        }

        // grid cache: cold build vs Arc-shared cache hit
        grid_cache_clear();
        let cache_cfg = GridConfig {
            proto_range: 6.0,
            proto_density: 2048,
            act_range: 16.0,
            act_density: 1024,
        };
        let s0 = grid_cache_stats();
        let t0 = std::time::Instant::now();
        let cold_kernel = BatchKernel::new(
            Box::new(Algorithmic::relu()),
            Activation::Phi1,
            3,
            1.0,
            &cache_cfg,
        );
        let cold = t0.elapsed();
        let t1 = std::time::Instant::now();
        let warm_kernel = BatchKernel::new(
            Box::new(Algorithmic::relu()),
            Activation::Phi1,
            3,
            1.0,
            &cache_cfg,
        );
        let warm = t1.elapsed();
        let s1 = grid_cache_stats();
        assert!(
            s1.misses >= s0.misses + 1,
            "cold kernel construction must miss the grid cache"
        );
        assert!(
            s1.hits >= s0.hits + 1,
            "second kernel construction must hit the grid cache"
        );
        assert!(
            cold_kernel.shares_grids_with(&warm_kernel),
            "a cache hit must share the grid allocations"
        );
        println!(
            "kernel/grid-cache: cold build {:.3} ms, cached build {:.3} ms \
             (+{} hits / +{} misses)",
            cold.as_secs_f64() * 1e3,
            warm.as_secs_f64() * 1e3,
            s1.hits - s0.hits,
            s1.misses - s0.misses
        );
    }

    // --- hot spot 11: healthy-path canary + supervision overhead ---------
    // The ISSUE-9 acceptance ceiling: the self-healing machinery (golden
    // canary probes + worker supervision) may cost at most 2% of the
    // healthy serving path.  Gated the hot-spot-9 way — derived from
    // stable microbenches, not a noisy end-to-end A/B: one 8-row probe
    // amortized over the DESIGN §11 cadence (`--canary-every 16`) plus
    // the per-batch `catch_unwind` supervision wrapper, as a fraction of
    // a full 32-row batch.  The end-to-end A/B (same 128-request
    // workload, canaries off vs on) is reported for eyeballing.
    {
        use sac::coordinator::{synthetic_engine, Batch, LaneSpec, Router, RouterConfig};
        use std::time::Duration;

        let sizes = [16usize, 12, 4];
        let engine = synthetic_engine(44, &sizes, 32).unwrap();
        let mut rng = Rng::new(12);
        let full_rows: Vec<Vec<f32>> = (0..32)
            .map(|_| (0..16).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect())
            .collect();
        let probe_rows: Vec<Vec<f32>> = full_rows[..8].to_vec();
        let make_batch = |rows: &[Vec<f32>]| {
            let mut data = vec![0.0f32; 32 * 16];
            for (r, row) in rows.iter().enumerate() {
                data[r * 16..(r + 1) * 16].copy_from_slice(row);
            }
            Batch {
                ids: (0..rows.len() as u64).collect(),
                data,
                live: rows.len(),
            }
        };
        let full = make_batch(&full_rows);
        let probe = make_batch(&probe_rows);
        let quick = Bench::quick();
        let rfull = quick.run("engine/full 32×[16,12,4] batch", || {
            black_box(engine.run_batch(&full).unwrap())
        });
        let rprobe = quick.run("canary/probe 8×[16,12,4] rows", || {
            black_box(engine.run_batch(&probe).unwrap())
        });
        // supervision bookkeeping: the worker wraps every batch in
        // catch_unwind plus a handful of relaxed counter updates
        let rsup = quick.run("supervision/catch_unwind(no-op)", || {
            black_box(
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| black_box(1u64)))
                    .unwrap(),
            )
        });
        const CANARY_EVERY: f64 = 16.0;
        let overhead = (rprobe.mean_ns() / CANARY_EVERY + rsup.mean_ns()) / rfull.mean_ns();
        println!(
            "canary+supervision: probe {:.0} ns / {CANARY_EVERY:.0} batches + \
             catch_unwind {:.1} ns = {:.3}% of a full 32-row batch \
             (acceptance ceiling: 2%)",
            rprobe.mean_ns(),
            rsup.mean_ns(),
            overhead * 100.0
        );
        assert!(
            overhead <= 0.02,
            "canary+supervision costs {:.3}% of the healthy path (> 2% ceiling)",
            overhead * 100.0
        );

        // end-to-end A/B (reported, not gated — scheduler noise): same
        // workload through a bare lane and a probed lane at the cadence
        let labels: Vec<usize> = engine
            .run_batch(&probe)
            .unwrap()
            .iter()
            .map(|a| a.1)
            .collect();
        for (tag, every) in [("off", 0u64), ("every=16", 16)] {
            let eng = synthetic_engine(44, &sizes, 32).unwrap();
            let spec = if every == 0 {
                LaneSpec::new("lane", eng)
            } else {
                LaneSpec::new("lane", eng).with_probe(probe_rows.clone(), labels.clone())
            };
            let router = Router::with_specs(
                RouterConfig {
                    workers: 2,
                    canary_every: every,
                    ..RouterConfig::default()
                },
                vec![spec],
            );
            let r = quick.run(&format!("router/supervised 128 reqs canary {tag}"), || {
                let reqs: Vec<_> = full_rows
                    .iter()
                    .cycle()
                    .take(128)
                    .map(|f| router.submit(0, f.clone()).unwrap())
                    .collect();
                router.drain(Duration::from_secs(60)).unwrap();
                for q in reqs {
                    black_box(router.try_take(q).unwrap());
                }
            });
            reports.push(r);
            router.shutdown();
        }
        reports.push(rfull);
        reports.push(rprobe);
        reports.push(rsup);
    }

    // --- hot spot 12: trace correlation + signal-health overhead ---------
    // The ISSUE-10 acceptance ceilings, derived from stable microbenches
    // the hot-spot-9 way.  Per delivered 64-row batch the correlation
    // machinery adds: with tracing *disabled*, one no-op correlate guard,
    // one `trace::enabled()` check at delivery and a relaxed
    // signal-health gate load per slab (≤ 0.5% of the batched hot spot);
    // with tracing *enabled*, a TLS correlate install/restore plus one
    // exemplar-set lock and 64 steady-state observes (≤ 2%).  The
    // signal-health accumulators themselves are opt-in diagnostics
    // (SAC_SIGNAL_HEALTH=1) and their instrumented-kernel cost is
    // reported below for eyeballing, not gated.
    {
        use sac::coordinator::ExemplarSet;
        use sac::nn::batch::signal_health_enabled;
        use sac::util::trace;

        let quick = Bench::quick();
        assert!(
            !trace::enabled(),
            "tracing must be disabled for the baseline measurement"
        );
        let rcorr_off = quick.run("trace/disabled correlate (install+drop)", || {
            black_box(trace::correlate(black_box(3)))
        });
        let rgate = quick.run("signal/disabled gate load", || {
            black_box(signal_health_enabled())
        });
        // 1 correlate + 1 enabled() check (same cost class as the gate
        // load) + 4 slab-gate loads
        let disabled_ns = rcorr_off.mean_ns() + rgate.mean_ns() * 5.0;
        let disabled_frac = disabled_ns / batched_mean_ns;
        println!(
            "correlation disabled: {:.2} ns/batch = {:.4}% of the batched 64-row \
             hot spot (acceptance ceiling: 0.5%)",
            disabled_ns,
            disabled_frac * 100.0
        );
        assert!(
            disabled_frac <= 0.005,
            "disabled correlation costs {:.4}% of the batched hot spot (> 0.5% ceiling)",
            disabled_frac * 100.0
        );

        trace::enable(4096);
        let rcorr_on = quick.run("trace/enabled correlate (install+drop)", || {
            black_box(trace::correlate(black_box(3)))
        });
        // steady-state exemplar retention: rows of one batch share a
        // latency, so after the first insert every observe is a bucket
        // lookup plus a losing (latency, trace-id) comparison — bench
        // the set without the once-per-batch mutex, which is counted
        // via the enabled-correlate guard's cost class
        let mut ex = ExemplarSet::default();
        let mut next_trace = 1u64;
        let robs = quick.run("exemplar/observe (steady state)", || {
            next_trace += 1;
            ex.observe(1_048_576, next_trace)
        });
        trace::disable();
        let enabled_ns = rcorr_on.mean_ns() * 2.0 + robs.mean_ns() * 64.0;
        let enabled_frac = enabled_ns / batched_mean_ns;
        println!(
            "correlation enabled: correlate {:.1} ns + 64 observes × {:.1} ns = \
             {:.3}% of the batched 64-row hot spot (acceptance ceiling: 2%)",
            rcorr_on.mean_ns(),
            robs.mean_ns(),
            enabled_frac * 100.0
        );
        assert!(
            enabled_frac <= 0.02,
            "enabled correlation costs {:.3}% of the batched hot spot (> 2% ceiling)",
            enabled_frac * 100.0
        );

        // opt-in signal-health accounting: instrumented vs nominal
        // kernel on the same 64-row batch (reported, not gated)
        {
            use sac::coordinator::{synthetic_engine_with_mode, DynamicBatcher};
            use sac::runtime::ExecMode;
            let sizes = [16usize, 12, 4];
            let engine = synthetic_engine_with_mode(45, &sizes, 64, ExecMode::Batched).unwrap();
            let mut b64 = DynamicBatcher::new(64, 16);
            let mut rng = Rng::new(13);
            for _ in 0..64 {
                b64.submit((0..16).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect());
            }
            let batch = b64.flush().remove(0);
            let roff = quick.run("engine/batched signal-health off", || {
                black_box(engine.run_batch(&batch).unwrap())
            });
            sac::nn::batch::signal_health_set(true);
            let ron = quick.run("engine/batched signal-health on", || {
                black_box(engine.run_batch(&batch).unwrap())
            });
            sac::nn::batch::signal_health_set(false);
            println!(
                "signal-health accounting (opt-in): {:.1} µs → {:.1} µs per 64-row \
                 batch ({:+.1}%)",
                roff.mean_ns() / 1e3,
                ron.mean_ns() / 1e3,
                (ron.mean_ns() / roff.mean_ns() - 1.0) * 100.0
            );
            reports.push(roff);
            reports.push(ron);
        }
        reports.push(rcorr_off);
        reports.push(rcorr_on);
        reports.push(robs);
        reports.push(rgate);
    }

    println!("\n=== hotpath benchmarks ===");
    for r in &reports {
        println!("{}", r.report());
    }
}
