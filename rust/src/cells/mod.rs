//! S-AC standard-cell library (paper Sec. IV, Figs. 6/9/11).
//!
//! Every cell is a composition of the one primitive `h(x; C)` — provided by
//! one of three backends of increasing fidelity:
//!
//!  * [`Algorithmic`]  — ReLU-shape GMP (the paper's eq. 6), exact solver;
//!  * [`TableModel`]   — per-corner calibrated soft shape (SPICE-table tier);
//!  * [`CircuitCorner`]— the device-exact Fig. 2b circuit solve.
//!
//! Cells take the backend as `&dyn HProvider`, so the *same* cell code runs
//! at all fidelities — which is precisely the paper's synthesizability
//! claim for analog standard cells.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

pub mod activations;
pub mod multiplier;
pub mod wta;

use crate::pdk::{Polarity, ProcessNode, regime::Regime};
use crate::sac::{gmp, splines, SacUnit, Shape, TableModel};

/// Backend interface: the S-AC unit output h (clamped ≥ 0), algorithmic
/// units in, algorithmic units out.
pub trait HProvider {
    fn h(&self, x: &[f64], c: f64) -> f64;

    /// The *internal* common-node value before the output mirror's
    /// rectification (the WTA family reads branch residues off this node,
    /// which can sit below zero in algorithmic units).  Defaults to the
    /// clamped output for backends where the distinction is unobservable.
    fn h_raw(&self, x: &[f64], c: f64) -> f64 {
        self.h(x, c)
    }

    /// Short backend label for reports.
    fn label(&self) -> String;

    /// Stable identity for the process-wide lookup-grid cache
    /// (`nn::batch`).  `Some(key)` promises that two providers returning
    /// the same key produce bit-identical `h` over all inputs, so their
    /// sampled grids may be shared.  Backends that cannot make that
    /// promise cheaply — the device-exact circuit solve with its mutable
    /// mismatch vectors, or the fault harness's drift wrappers — keep the
    /// `None` default and build private grids.
    fn cache_key(&self) -> Option<String> {
        None
    }
}

/// Pure-algorithm backend (ReLU GMP — the paper's eq. 6 with eq. 3).
#[derive(Clone, Debug)]
pub struct Algorithmic {
    pub shape: Shape,
}

impl Algorithmic {
    pub fn relu() -> Self {
        Algorithmic { shape: Shape::Relu }
    }
}

impl HProvider for Algorithmic {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        gmp::sac_h(x, c, self.shape)
    }

    fn h_raw(&self, x: &[f64], c: f64) -> f64 {
        match self.shape {
            Shape::Relu => gmp::solve_exact(x, c),
            _ => gmp::solve_bisect(x, c, self.shape, gmp::GMP_ITERS),
        }
    }

    fn label(&self) -> String {
        format!("algorithmic({:?})", self.shape)
    }

    fn cache_key(&self) -> Option<String> {
        Some(match self.shape {
            Shape::Relu => "alg/relu".to_string(),
            Shape::Softplus { width } => format!("alg/softplus/{:016x}", width.to_bits()),
        })
    }
}

impl HProvider for TableModel {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        TableModel::h(self, x, c)
    }

    fn label(&self) -> String {
        format!("table({}/{}/{}C)", self.node.name, self.regime, self.t_c)
    }

    fn cache_key(&self) -> Option<String> {
        // exact calibration identity: corner name plus the fitted knee
        // width / temperature bits
        Some(format!(
            "table/{}/{}/t={:016x}/w={:016x}",
            self.node.name,
            self.regime,
            self.t_c.to_bits(),
            self.width.to_bits(),
        ))
    }
}

/// Device-exact backend: one operating corner of the Fig. 2b circuit.
#[derive(Clone, Debug)]
pub struct CircuitCorner {
    pub node: &'static ProcessNode,
    pub regime: Regime,
    pub t_c: f64,
    /// supply override (Fig. 4c); None = nominal
    pub vdd: Option<f64>,
    /// per-branch threshold mismatch to inject [V] (Monte-Carlo trials);
    /// cycled over branches if shorter
    pub dvt: Vec<f64>,
    pub dbeta: Vec<f64>,
}

impl CircuitCorner {
    pub fn new(node: &'static ProcessNode, regime: Regime) -> Self {
        CircuitCorner {
            node,
            regime,
            t_c: 27.0,
            vdd: None,
            dvt: Vec::new(),
            dbeta: Vec::new(),
        }
    }

    pub fn at_temp(mut self, t_c: f64) -> Self {
        self.t_c = t_c;
        self
    }

    pub fn with_supply(mut self, vdd: f64) -> Self {
        self.vdd = Some(vdd);
        self
    }

    fn build_unit(&self, m: usize) -> SacUnit {
        let mut u = SacUnit::new(self.node, Polarity::N, self.regime, m)
            .at_temp(self.t_c);
        if let Some(v) = self.vdd {
            u = u.with_supply(v);
        }
        for (i, d) in u.branches.iter_mut().enumerate() {
            if !self.dvt.is_empty() {
                d.dvt = self.dvt[i % self.dvt.len()];
            }
            if !self.dbeta.is_empty() {
                d.dbeta = self.dbeta[i % self.dbeta.len()];
            }
        }
        u
    }
}

impl CircuitCorner {
    /// Input-mirror gain of branch `i`: each input current arrives through
    /// a diode-connected mirror whose ΔV_T / Δβ mismatch multiplies the
    /// current by `f_mm(V_bias) / f_nom(V_bias)` — the classic matched-pair
    /// error, maximal in weak inversion (e^{ΔV_T/nU_T}) and suppressed in
    /// strong inversion (2ΔV_T/V_ov).  This is where Pelgrom mismatch
    /// physically enters the S-AC computation (Figs. 4b, 8).
    fn mirror_gain(&self, i: usize) -> f64 {
        if self.dvt.is_empty() && self.dbeta.is_empty() {
            return 1.0;
        }
        let mut nom = crate::device::Mosfet::square(self.node, Polarity::N);
        nom.w_um = self.node.analog_w_um;
        nom.l_um = self.node.analog_l_um;
        nom.t_c = self.t_c;
        let mut mm = nom.clone();
        if !self.dvt.is_empty() {
            mm.dvt = self.dvt[i % self.dvt.len()];
        }
        if !self.dbeta.is_empty() {
            mm.dbeta = self.dbeta[i % self.dbeta.len()];
        }
        let vg = self.node.bias_for(self.regime, self.t_c);
        mm.forward(vg, 0.0) / nom.forward(vg, 0.0)
    }
}

impl HProvider for CircuitCorner {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        let scale = self.node.bias_current(self.regime);
        let unit = self.build_unit(x.len()).with_bias(c * scale);
        let xc: Vec<f64> = x
            .iter()
            .enumerate()
            .map(|(i, &v)| v * scale * self.mirror_gain(i))
            .collect();
        unit.solve(&xc).h / scale
    }

    fn label(&self) -> String {
        format!("circuit({}/{}/{}C)", self.node.name, self.regime, self.t_c)
    }
}

/// The proto-shape unit h(z) (Fig. 3): input branch + ground reference,
/// spline-expanded per Appendix A.
pub fn proto_unit(p: &dyn HProvider, z: f64, s: usize, c: f64) -> f64 {
    let (offs, c_prime) = splines::schedule(s, c);
    let mut x = Vec::with_capacity(2 * s);
    for &o in &offs {
        x.push(z + o);
    }
    for &o in &offs {
        x.push(o);
    }
    p.h(&x, c_prime)
}

/// Two-input S-AC unit h(a, b), spline expanded.
pub fn pair_unit(p: &dyn HProvider, a: f64, b: f64, s: usize, c: f64) -> f64 {
    let (offs, c_prime) = splines::schedule(s, c);
    let mut x = Vec::with_capacity(2 * s);
    for &o in &offs {
        x.push(a + o);
    }
    for &o in &offs {
        x.push(b + o);
    }
    p.h(&x, c_prime)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::CMOS180;

    #[test]
    fn backends_agree_on_proto_shape() {
        // algorithmic vs table-model vs circuit: same knee within margin
        let alg = Algorithmic::relu();
        let tm = TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
        let cc = CircuitCorner::new(&CMOS180, Regime::WeakInversion);
        for k in 0..=12 {
            let z = -2.4 + 0.3 * k as f64;
            let a = proto_unit(&alg, z, 3, 1.0);
            let t = proto_unit(&tm, z, 3, 1.0);
            let c = proto_unit(&cc, z, 3, 1.0);
            assert!((a - t).abs() < 0.25, "z={z} alg={a} tab={t}");
            assert!((t - c).abs() < 0.15, "z={z} tab={t} circ={c}");
        }
    }

    #[test]
    fn proto_unit_slope_one_asymptote() {
        let alg = Algorithmic::relu();
        let h1 = proto_unit(&alg, 3.0, 3, 1.0);
        let h2 = proto_unit(&alg, 3.5, 3, 1.0);
        assert!(((h2 - h1) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn pair_unit_symmetric() {
        let alg = Algorithmic::relu();
        let a = pair_unit(&alg, 0.4, -0.2, 3, 1.0);
        let b = pair_unit(&alg, -0.2, 0.4, 3, 1.0);
        assert!((a - b).abs() < 1e-12);
    }
}
