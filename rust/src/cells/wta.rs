//! WTA family (Fig. 9, eqs. 22-23): winner-take-all, N-of-M encoder,
//! SoftArgMax and Max — all configurations of one N-input S-AC unit.
//!
//! The shared node h sits below the top-M inputs; per-input outputs are the
//! residues `[x_i − h]_+` (the current each winner branch carries).

use crate::sac::gmp::solve_exact;

use super::HProvider;

/// Per-input WTA outputs `I_out_i = [x_i − h]_+` (eq. 23).  Residues are
/// read off the *internal* node (`h_raw`) — branch currents always sum to
/// C by KCL even when the output mirror would rectify.
pub fn wta_outputs(p: &dyn HProvider, x: &[f64], c: f64) -> Vec<f64> {
    let h = p.h_raw(x, c);
    x.iter().map(|&v| (v - h).max(0.0)).collect()
}

/// Composite N-of-M output current (eq. 22): sum of winner residues.
pub fn nofm_current(p: &dyn HProvider, x: &[f64], c: f64) -> f64 {
    wta_outputs(p, x, c).iter().sum()
}

/// Number of winners currently selected (inputs above the shared node).
pub fn winner_count(p: &dyn HProvider, x: &[f64], c: f64) -> usize {
    wta_outputs(p, x, c).iter().filter(|&&v| v > 0.0).count()
}

/// SoftArgMax: winner residues normalized to a distribution (Sec. IV-I).
pub fn softargmax(p: &dyn HProvider, x: &[f64], c: f64) -> Vec<f64> {
    let y = wta_outputs(p, x, c);
    let s: f64 = y.iter().sum::<f64>().max(1e-30);
    y.into_iter().map(|v| v / s).collect()
}

/// Max selector (Sec. IV-J): h in the C→0 limit approaches max(x)
/// (unclamped internal node plus the residue C).
pub fn max_cell(x: &[f64], c: f64) -> f64 {
    solve_exact(x, c) + c
}

/// Index of the winning input.
pub fn argmax_cell(p: &dyn HProvider, x: &[f64], c: f64) -> usize {
    let y = wta_outputs(p, x, c);
    y.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;
    use crate::prop_assert;
    use crate::util::propcheck::check;

    #[test]
    fn single_winner_small_c() {
        let p = Algorithmic::relu();
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = wta_outputs(&p, &x, 0.5);
        assert_eq!(y.iter().filter(|&&v| v > 0.0).count(), 1);
        assert!(y[4] > 0.0);
    }

    #[test]
    fn winner_count_grows_with_c_fig10() {
        let p = Algorithmic::relu();
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut last = 0;
        for c in [0.5, 1.5, 3.5, 7.0, 12.0] {
            let n = winner_count(&p, &x, c);
            assert!(n >= last, "c={c}");
            last = n;
        }
        assert!(last >= 4);
    }

    #[test]
    fn nofm_matches_eq22() {
        let p = Algorithmic::relu();
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        for c in [0.5, 2.0, 6.0] {
            let h = crate::sac::gmp::solve_exact(&x, c);
            let winners: Vec<f64> = x.iter().cloned().filter(|&v| v > h).collect();
            let m = winners.len() as f64;
            let expect = (winners.iter().sum::<f64>() - c) / m;
            assert!((h - expect).abs() < 1e-12);
            // composite current = Σ (x_i − h) over winners = C by KCL
            let i_out = nofm_current(&p, &x, c);
            assert!((i_out - c).abs() < 1e-9, "c={c} i={i_out}");
        }
    }

    #[test]
    fn softargmax_is_distribution() {
        let p = Algorithmic::relu();
        check(1, 100, |g| -> Result<(), String> {
            let m = g.usize_in(2, 9);
            let x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.2, 4.0);
            let sm = softargmax(&p, &x, c);
            let s: f64 = sm.iter().sum();
            prop_assert!((s - 1.0).abs() < 1e-9, "sum={s}");
            prop_assert!(sm.iter().all(|&v| v >= 0.0));
            // winner has the largest mass
            let arg = argmax_cell(&p, &x, c);
            let true_max = x
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            prop_assert!(arg == true_max, "arg={arg} true={true_max}");
            Ok(())
        });
    }

    #[test]
    fn max_cell_limit() {
        check(2, 100, |g| -> Result<(), String> {
            let m = g.usize_in(1, 8);
            let x = g.vec_f64(m, -3.0, 3.0);
            let y = max_cell(&x, 1e-5);
            let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            prop_assert!((y - mx).abs() < 1e-4, "y={y} max={mx}");
            Ok(())
        });
    }

    #[test]
    fn wta_modular_in_n() {
        // adding a losing input never changes the winner's output (Lazzaro
        // modularity)
        let p = Algorithmic::relu();
        let base = [2.0, 5.0];
        let extended = [2.0, 5.0, 1.0, 0.5];
        let yb = wta_outputs(&p, &base, 0.5);
        let ye = wta_outputs(&p, &extended, 0.5);
        assert!((yb[1] - ye[1]).abs() < 1e-9);
    }
}
