//! Activation standard cells (Fig. 6, eqs. 15-21): cosh, sinh, ReLU,
//! compressive nonlinearity φ1 (tanh-like), sigmoid φ2, soft-plus.
//!
//! Mirrors `python/compile/sacml/ops.py`; the golden-file integration test
//! checks the two implementations produce the same curves.

use super::{pair_unit, proto_unit, HProvider};

/// cosh (eq. 16): h(z) + h(−z), N-type + flipped response summed by KCL.
pub fn cosh_cell(p: &dyn HProvider, z: f64, s: usize, c: f64) -> f64 {
    proto_unit(p, z, s, c) + proto_unit(p, -z, s, c)
}

/// sinh (eq. 18): h(z) − h(−z) (N-type minus P-type by KCL).
pub fn sinh_cell(p: &dyn HProvider, z: f64, s: usize, c: f64) -> f64 {
    proto_unit(p, z, s, c) - proto_unit(p, -z, s, c)
}

/// ReLU (eq. 19): 2-input unit in the C→0 limit; h = [z − C]_+.
pub fn relu_cell(p: &dyn HProvider, z: f64, c: f64) -> f64 {
    p.h(&[z, 0.0], c)
}

/// Soft-plus (Fig. 6e): the proto-unit at a moderate C — a soft knee.
pub fn softplus_cell(p: &dyn HProvider, z: f64, s: usize, c: f64) -> f64 {
    proto_unit(p, z, s, c)
}

/// Compressive nonlinearity φ1 (eq. 20-21): h(0, z+K) − h(z, K).
/// Antisymmetric, saturates at ±K — the tanh equivalent.
pub fn phi1_cell(p: &dyn HProvider, z: f64, k: f64, s: usize, c: f64) -> f64 {
    pair_unit(p, 0.0, z + k, s, c) - pair_unit(p, z, k, s, c)
}

/// Sigmoid φ2 (Sec. IV-E): φ1 shifted by the constant current K.
pub fn phi2_cell(p: &dyn HProvider, z: f64, k: f64, s: usize, c: f64) -> f64 {
    phi1_cell(p, z, k, s, c) + k
}

/// Named cell dispatch used by the analysis/repro harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellKind {
    Cosh,
    Sinh,
    Relu,
    Phi1,
    Phi2,
    Softplus,
}

impl CellKind {
    pub fn all() -> [CellKind; 6] {
        [
            CellKind::Cosh,
            CellKind::Sinh,
            CellKind::Relu,
            CellKind::Phi1,
            CellKind::Phi2,
            CellKind::Softplus,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            CellKind::Cosh => "cosh",
            CellKind::Sinh => "sinh",
            CellKind::Relu => "relu",
            CellKind::Phi1 => "phi1",
            CellKind::Phi2 => "phi2",
            CellKind::Softplus => "softplus",
        }
    }

    pub fn by_name(name: &str) -> Option<CellKind> {
        CellKind::all().into_iter().find(|k| k.name() == name)
    }

    /// Evaluate the cell transfer at `z` with default parameters (S=3,
    /// C=1, K=1; ReLU uses C=0.05 per the eq. 19 limit).
    pub fn eval(&self, p: &dyn HProvider, z: f64) -> f64 {
        match self {
            CellKind::Cosh => cosh_cell(p, z, 3, 1.0),
            CellKind::Sinh => sinh_cell(p, z, 3, 1.0),
            CellKind::Relu => relu_cell(p, z, 0.05),
            CellKind::Phi1 => phi1_cell(p, z, 1.0, 3, 0.5),
            CellKind::Phi2 => phi2_cell(p, z, 1.0, 3, 0.5),
            CellKind::Softplus => softplus_cell(p, z, 3, 1.0),
        }
    }

    /// Number of S-AC units composing the cell (for power/area models,
    /// Fig. 6 schematics).
    pub fn unit_count(&self) -> usize {
        match self {
            CellKind::Cosh => 2,
            CellKind::Sinh => 2,
            CellKind::Relu => 1,
            CellKind::Phi1 => 2,
            CellKind::Phi2 => 2,
            CellKind::Softplus => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;

    fn alg() -> Algorithmic {
        Algorithmic::relu()
    }

    #[test]
    fn relu_limit() {
        let p = alg();
        for z in [-1.0, -0.3, 0.0, 0.4, 1.2] {
            let y = relu_cell(&p, z, 1e-4);
            assert!((y - z.max(0.0)).abs() < 2e-4, "z={z} y={y}");
        }
    }

    #[test]
    fn cosh_even_sinh_odd() {
        let p = alg();
        for z in [0.3, 0.8, 1.5] {
            let cp = cosh_cell(&p, z, 3, 1.0);
            let cm = cosh_cell(&p, -z, 3, 1.0);
            assert!((cp - cm).abs() < 1e-12);
            let sp = sinh_cell(&p, z, 3, 1.0);
            let sm = sinh_cell(&p, -z, 3, 1.0);
            assert!((sp + sm).abs() < 1e-12);
            assert!(cp >= sp.abs() - 1e-12);
        }
    }

    #[test]
    fn phi1_antisymmetric_saturating() {
        let p = alg();
        let k = 1.0;
        for z in [0.2, 0.7, 1.4] {
            let y = phi1_cell(&p, z, k, 3, 0.5);
            let ym = phi1_cell(&p, -z, k, 3, 0.5);
            assert!((y + ym).abs() < 1e-9, "z={z}");
        }
        assert!((phi1_cell(&p, 5.0, k, 3, 0.5) - k).abs() < 1e-6);
        assert!((phi1_cell(&p, -5.0, k, 3, 0.5) + k).abs() < 1e-6);
    }

    #[test]
    fn phi2_is_shifted_phi1() {
        let p = alg();
        for z in [-1.0, 0.0, 1.0] {
            let d = phi2_cell(&p, z, 1.0, 3, 0.5) - phi1_cell(&p, z, 1.0, 3, 0.5);
            assert!((d - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn softplus_between_relu_and_shifted_linear() {
        let p = alg();
        for z in [-2.0, -0.5, 0.5, 2.0] {
            let y = softplus_cell(&p, z, 3, 1.0);
            assert!(y >= z.max(0.0) - 1e-9, "z={z} y={y}");
        }
    }

    #[test]
    fn all_cells_monotone_where_required() {
        let p = alg();
        // relu, phi1, phi2, softplus are monotone; cosh has a single min
        for kind in [CellKind::Relu, CellKind::Phi1, CellKind::Phi2, CellKind::Softplus] {
            let mut last = f64::NEG_INFINITY;
            for k in 0..=40 {
                let z = -2.0 + 0.1 * k as f64;
                let y = kind.eval(&p, z);
                assert!(y >= last - 1e-9, "{} at z={z}", kind.name());
                last = y;
            }
        }
    }

    #[test]
    fn name_roundtrip() {
        for k in CellKind::all() {
            assert_eq!(CellKind::by_name(k.name()), Some(k));
        }
    }
}
