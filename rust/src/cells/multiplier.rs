//! Four-quadrant S-AC multiplier (Fig. 11, eqs. 24-30).
//!
//! `y ≈ x·w` from four proto-unit evaluations at a calibrated operating
//! point `a` with output scale `1/(4h''(0))`-equivalent — the calibration
//! a designer does with the offset currents on silicon (Sec. IV-K).

use super::{proto_unit, HProvider};

/// Calibrated multiplier for a given backend and spline count.
#[derive(Clone, Debug)]
pub struct Multiplier {
    pub s: usize,
    pub c: f64,
    /// operating-point offset current
    pub a: f64,
    /// output scale factor
    pub scale: f64,
}

impl Multiplier {
    /// Grid-search calibration of (a, scale) minimizing max |scale·y − xw|
    /// over the unit square (mirrors `ops.calibrate_multiplier`).
    pub fn calibrate(p: &dyn HProvider, s: usize, c: f64) -> Multiplier {
        let grid: Vec<f64> = (0..17).map(|i| -1.0 + 0.125 * i as f64).collect();
        let mut best = (f64::INFINITY, 0.0, 1.0);
        let mut a = -1.5;
        while a <= 1.5 + 1e-9 {
            let mut num = 0.0;
            let mut den = 0.0;
            let mut ys = Vec::with_capacity(grid.len() * grid.len());
            for &w in &grid {
                for &x in &grid {
                    let y = raw_mult(p, x, w, a, s, c);
                    num += y * (x * w);
                    den += y * y;
                    ys.push((y, x * w));
                }
            }
            if den > 1e-12 {
                let scale = num / den;
                let err = ys
                    .iter()
                    .map(|&(y, t)| (scale * y - t).abs())
                    .fold(0.0, f64::max);
                if err < best.0 {
                    best = (err, a, scale);
                }
            }
            a += 0.1;
        }
        Multiplier {
            s,
            c,
            a: best.1,
            scale: best.2,
        }
    }

    /// y ≈ x·w.
    pub fn mul(&self, p: &dyn HProvider, x: f64, w: f64) -> f64 {
        self.scale * raw_mult(p, x, w, self.a, self.s, self.c)
    }

    /// Error metrics over the unit square (Table II): (max, mean-abs,
    /// bias, std) in fractional units.
    pub fn error_stats(&self, p: &dyn HProvider, n: usize) -> MultErr {
        let mut errs = Vec::with_capacity(n * n);
        for i in 0..n {
            for j in 0..n {
                let x = -1.0 + 2.0 * i as f64 / (n - 1) as f64;
                let w = -1.0 + 2.0 * j as f64 / (n - 1) as f64;
                errs.push(self.mul(p, x, w) - x * w);
            }
        }
        let max = errs.iter().map(|e| e.abs()).fold(0.0, f64::max);
        let mean_abs = errs.iter().map(|e| e.abs()).sum::<f64>() / errs.len() as f64;
        let bias = errs.iter().sum::<f64>() / errs.len() as f64;
        let var = errs
            .iter()
            .map(|e| (e - bias) * (e - bias))
            .sum::<f64>()
            / errs.len() as f64;
        MultErr {
            max,
            mean_abs,
            bias,
            std: var.sqrt(),
        }
    }
}

/// eq. 24: the four-term combination (operating point `a` absorbs the
/// paper's `2C` bias).
fn raw_mult(p: &dyn HProvider, x: f64, w: f64, a: f64, s: usize, c: f64) -> f64 {
    proto_unit(p, a + w + x, s, c) - proto_unit(p, a + w - x, s, c)
        + proto_unit(p, a - w - x, s, c)
        - proto_unit(p, a - w + x, s, c)
}

/// Table-II error metrics (fractions of full scale).
#[derive(Clone, Copy, Debug)]
pub struct MultErr {
    pub max: f64,
    pub mean_abs: f64,
    pub bias: f64,
    pub std: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;

    #[test]
    fn calibrated_s3_accuracy() {
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        let e = m.error_stats(&p, 21);
        assert!(e.max < 0.08, "max err {}", e.max);
    }

    #[test]
    fn four_quadrants() {
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        for (x, w) in [(0.5, 0.5), (-0.5, 0.5), (0.5, -0.5), (-0.5, -0.5)] {
            let y = m.mul(&p, x, w);
            assert!((y - x * w).abs() < 0.07, "x={x} w={w} y={y}");
        }
    }

    #[test]
    fn zero_lines() {
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        for v in [-1.0, -0.4, 0.3, 0.9] {
            assert!(m.mul(&p, v, 0.0).abs() < 0.06);
            assert!(m.mul(&p, 0.0, v).abs() < 0.06);
        }
    }

    #[test]
    fn error_decreases_s1_to_s3_table2() {
        let p = Algorithmic::relu();
        let m1 = Multiplier::calibrate(&p, 1, 1.0);
        let m3 = Multiplier::calibrate(&p, 3, 1.0);
        let e1 = m1.error_stats(&p, 21);
        let e3 = m3.error_stats(&p, 21);
        assert!(
            e3.mean_abs < e1.mean_abs,
            "s1={} s3={}",
            e1.mean_abs,
            e3.mean_abs
        );
    }

    #[test]
    fn symmetric_in_x_and_w() {
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        let a = m.mul(&p, 0.6, 0.3);
        let b = m.mul(&p, 0.3, 0.6);
        assert!((a - b).abs() < 1e-9);
    }
}
