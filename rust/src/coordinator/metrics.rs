//! Serving metrics: batch latency distribution and sustained throughput.

use std::time::Duration;

use crate::util::stats;

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// per-batch latency [s]
    pub batch_latency_s: Vec<f64>,
    /// live rows per batch
    pub batch_rows: Vec<usize>,
}

impl ServeMetrics {
    pub fn record_batch(&mut self, rows: usize, dt: Duration) {
        self.batch_latency_s.push(dt.as_secs_f64());
        self.batch_rows.push(rows);
    }

    pub fn total_requests(&self) -> usize {
        self.batch_rows.iter().sum()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        stats::summarize(&self.batch_latency_s).mean * 1e3
    }

    pub fn p99_latency_ms(&self) -> f64 {
        if self.batch_latency_s.is_empty() {
            return 0.0;
        }
        stats::percentile(&self.batch_latency_s, 99.0) * 1e3
    }

    /// requests / second over the measured batches
    pub fn throughput_rps(&self) -> f64 {
        let total_t: f64 = self.batch_latency_s.iter().sum();
        if total_t <= 0.0 {
            return 0.0;
        }
        self.total_requests() as f64 / total_t
    }

    pub fn report(&self) -> String {
        format!(
            "batches={} requests={} mean={:.3} ms p99={:.3} ms throughput={:.0} req/s",
            self.batch_latency_s.len(),
            self.total_requests(),
            self.mean_latency_ms(),
            self.p99_latency_ms(),
            self.throughput_rps()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let mut m = ServeMetrics::default();
        m.record_batch(4, Duration::from_millis(10));
        m.record_batch(2, Duration::from_millis(20));
        assert_eq!(m.total_requests(), 6);
        assert!((m.mean_latency_ms() - 15.0).abs() < 1e-9);
        let rps = m.throughput_rps();
        assert!((rps - 6.0 / 0.030).abs() < 1.0, "rps={rps}");
        assert!(m.report().contains("requests=6"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.total_requests(), 0);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.p99_latency_ms(), 0.0);
    }
}
