//! Serving metrics: batch latency distribution and sustained throughput.
//!
//! The router keeps one `ServeMetrics` per task lane and
//! [`ServeMetrics::merge`]s them into a fleet-wide aggregate on demand.
//! Lifetime totals (batches, rows, busy time) are exact counters; the
//! per-batch latency samples backing the mean/percentile estimates are a
//! bounded window of the most recent batches, so a long-lived router does
//! not grow without limit.

use std::time::Duration;

use crate::util::stats;

/// Retained latency samples per lane; older samples are evicted in blocks
/// (amortized O(1)) once the window overflows.
const MAX_SAMPLES: usize = 8192;

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// per-batch latency in seconds (bounded window, most recent batches)
    pub batch_latency_s: Vec<f64>,
    /// live rows per batch (window parallel to `batch_latency_s`)
    pub batch_rows: Vec<usize>,
    /// lifetime batch count (exact, survives window eviction)
    pub total_batches: usize,
    /// lifetime request count (exact)
    pub total_rows: usize,
    /// lifetime busy time in seconds (exact)
    pub total_time_s: f64,
}

impl ServeMetrics {
    pub fn record_batch(&mut self, rows: usize, dt: Duration) {
        let secs = dt.as_secs_f64();
        self.batch_latency_s.push(secs);
        self.batch_rows.push(rows);
        self.total_batches += 1;
        self.total_rows += rows;
        self.total_time_s += secs;
        self.evict();
    }

    /// Fold another lane's metrics into this one (per-task → aggregate).
    ///
    /// Deliberately does *not* evict: the aggregate is a transient
    /// snapshot, and evicting here would bias its percentiles toward the
    /// last-merged lane (earlier lanes' samples sit at the front of the
    /// window).  It holds at most `lanes × MAX_SAMPLES` samples.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.batch_latency_s
            .extend_from_slice(&other.batch_latency_s);
        self.batch_rows.extend_from_slice(&other.batch_rows);
        self.total_batches += other.total_batches;
        self.total_rows += other.total_rows;
        self.total_time_s += other.total_time_s;
    }

    fn evict(&mut self) {
        if self.batch_latency_s.len() > MAX_SAMPLES {
            let cut = self.batch_latency_s.len() - MAX_SAMPLES / 2;
            self.batch_latency_s.drain(..cut);
            self.batch_rows.drain(..cut);
        }
    }

    pub fn total_requests(&self) -> usize {
        self.total_rows
    }

    /// Mean batch latency over the retained window, in milliseconds.
    pub fn mean_latency_ms(&self) -> f64 {
        stats::summarize(&self.batch_latency_s).mean * 1e3
    }

    /// p99 batch latency over the retained window, in milliseconds.
    pub fn p99_latency_ms(&self) -> f64 {
        if self.batch_latency_s.is_empty() {
            return 0.0;
        }
        stats::percentile(&self.batch_latency_s, 99.0) * 1e3
    }

    /// Lifetime requests / second of worker busy time.
    pub fn throughput_rps(&self) -> f64 {
        if self.total_time_s <= 0.0 {
            return 0.0;
        }
        self.total_rows as f64 / self.total_time_s
    }

    pub fn report(&self) -> String {
        format!(
            "batches={} requests={} mean={:.3} ms p99={:.3} ms throughput={:.0} req/s",
            self.total_batches,
            self.total_rows,
            self.mean_latency_ms(),
            self.p99_latency_ms(),
            self.throughput_rps()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let mut m = ServeMetrics::default();
        m.record_batch(4, Duration::from_millis(10));
        m.record_batch(2, Duration::from_millis(20));
        assert_eq!(m.total_requests(), 6);
        assert!((m.mean_latency_ms() - 15.0).abs() < 1e-9);
        let rps = m.throughput_rps();
        assert!((rps - 6.0 / 0.030).abs() < 1.0, "rps={rps}");
        assert!(m.report().contains("requests=6"));
    }

    #[test]
    fn merge_aggregates_lanes() {
        let mut a = ServeMetrics::default();
        a.record_batch(4, Duration::from_millis(10));
        let mut b = ServeMetrics::default();
        b.record_batch(2, Duration::from_millis(20));
        b.record_batch(1, Duration::from_millis(5));
        a.merge(&b);
        assert_eq!(a.total_requests(), 7);
        assert_eq!(a.total_batches, 3);
        assert_eq!(a.batch_latency_s.len(), 3);
    }

    #[test]
    fn window_is_bounded_but_totals_exact() {
        let mut m = ServeMetrics::default();
        let n = MAX_SAMPLES * 3;
        for _ in 0..n {
            m.record_batch(2, Duration::from_micros(100));
        }
        assert!(m.batch_latency_s.len() <= MAX_SAMPLES);
        assert_eq!(m.batch_rows.len(), m.batch_latency_s.len());
        assert_eq!(m.total_batches, n);
        assert_eq!(m.total_requests(), 2 * n);
        // throughput uses the exact lifetime counters, not the window
        assert!((m.throughput_rps() - 2.0 / 100e-6).abs() < 1.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.total_requests(), 0);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.p99_latency_ms(), 0.0);
    }
}
