//! Serving metrics: batch/request latency distributions and sustained
//! throughput, backed by the log-bucketed histograms in
//! [`crate::coordinator::telemetry`].
//!
//! The router keeps one `ServeMetrics` per task lane and
//! [`ServeMetrics::merge`]s them into a fleet-wide aggregate on demand.
//! Every accumulator is an integer (bucket counts, nanosecond sums), so
//! `merge` is exactly associative and commutative: the aggregate is
//! bit-identical no matter which order (or grouping) the lanes are
//! folded in.  This replaces the earlier bounded sample-vector design,
//! whose `p99` was biased at small sample counts and whose windowed
//! eviction made merges order-dependent.

use std::time::Duration;

use crate::coordinator::telemetry::LatencyHistogram;
use crate::util::json::Json;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeMetrics {
    /// per-batch engine latency distribution (one sample per batch)
    pub batch_latency: LatencyHistogram,
    /// per-request latency distribution: each delivered row inherits
    /// its batch's latency, so `request_latency.count()` equals the
    /// number of delivered requests
    pub request_latency: LatencyHistogram,
    /// lifetime batch count (exact)
    pub total_batches: usize,
    /// lifetime request count (exact)
    pub total_rows: usize,
    /// lifetime busy time in integer nanoseconds (exact, associative)
    pub total_time_ns: u64,
}

impl ServeMetrics {
    pub fn record_batch(&mut self, rows: usize, dt: Duration) {
        let ns = dt.as_nanos().min(u128::from(u64::MAX)) as u64;
        self.batch_latency.record_ns(ns);
        self.request_latency.record_n_ns(ns, rows as u64);
        self.total_batches += 1;
        self.total_rows += rows;
        self.total_time_ns = self.total_time_ns.saturating_add(ns);
    }

    /// Fold another lane's metrics into this one (per-task → aggregate).
    /// Integer adds only — associative, commutative, lossless.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.batch_latency.merge(&other.batch_latency);
        self.request_latency.merge(&other.request_latency);
        self.total_batches += other.total_batches;
        self.total_rows += other.total_rows;
        self.total_time_ns = self.total_time_ns.saturating_add(other.total_time_ns);
    }

    pub fn total_requests(&self) -> usize {
        self.total_rows
    }

    /// Mean batch latency in milliseconds (exact: integer sum / count).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.total_batches == 0 {
            return 0.0;
        }
        self.batch_latency.sum_ns() as f64 / self.total_batches as f64 / 1e6
    }

    /// Median batch latency in milliseconds (histogram estimate, exact
    /// for a single sample).
    pub fn p50_latency_ms(&self) -> f64 {
        self.batch_latency.quantile_ns(0.50) / 1e6
    }

    /// p99 batch latency in milliseconds.  The histogram walk
    /// interpolates within the landing bucket and clamps to the
    /// observed min/max, so small sample counts are no longer biased
    /// (n = 1 returns the sample itself).
    pub fn p99_latency_ms(&self) -> f64 {
        self.batch_latency.quantile_ns(0.99) / 1e6
    }

    /// Lifetime requests / second of worker busy time.  Computed as
    /// `rows * 1e9 / ns` so the quotient stays exact for power-of-two
    /// nanosecond totals (the golden tests depend on this).
    pub fn throughput_rps(&self) -> f64 {
        if self.total_time_ns == 0 {
            return 0.0;
        }
        self.total_rows as f64 * 1e9 / self.total_time_ns as f64
    }

    pub fn report(&self) -> String {
        format!(
            "batches={} requests={} mean={:.3} ms p99={:.3} ms throughput={:.0} req/s",
            self.total_batches,
            self.total_rows,
            self.mean_latency_ms(),
            self.p99_latency_ms(),
            self.throughput_rps()
        )
    }

    /// Canonical JSON form (alphabetical keys; see DESIGN.md §9).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch_latency", self.batch_latency.to_json()),
            ("mean_latency_ms", Json::Num(self.mean_latency_ms())),
            ("p50_latency_ms", Json::Num(self.p50_latency_ms())),
            ("p99_latency_ms", Json::Num(self.p99_latency_ms())),
            ("request_latency", self.request_latency.to_json()),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("total_batches", Json::Num(self.total_batches as f64)),
            ("total_rows", Json::Num(self.total_rows as f64)),
            ("total_time_ns", Json::Num(self.total_time_ns as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let mut m = ServeMetrics::default();
        m.record_batch(4, Duration::from_millis(10));
        m.record_batch(2, Duration::from_millis(20));
        assert_eq!(m.total_requests(), 6);
        assert!((m.mean_latency_ms() - 15.0).abs() < 1e-9);
        let rps = m.throughput_rps();
        assert!((rps - 6.0 / 0.030).abs() < 1.0, "rps={rps}");
        assert!(m.report().contains("requests=6"));
        // per-request histogram counts every delivered row
        assert_eq!(m.request_latency.count(), 6);
        assert_eq!(m.batch_latency.count(), 2);
    }

    #[test]
    fn merge_aggregates_lanes() {
        let mut a = ServeMetrics::default();
        a.record_batch(4, Duration::from_millis(10));
        let mut b = ServeMetrics::default();
        b.record_batch(2, Duration::from_millis(20));
        b.record_batch(1, Duration::from_millis(5));
        a.merge(&b);
        assert_eq!(a.total_requests(), 7);
        assert_eq!(a.total_batches, 3);
        assert_eq!(a.batch_latency.count(), 3);
        assert_eq!(a.request_latency.count(), 7);
    }

    #[test]
    fn small_sample_p99_is_unbiased() {
        // the old sample-vector percentile returned an extrapolated value
        // for n < 100; the histogram estimate must return the max-ish
        // sample for tiny n and the exact value for n = 1
        let mut one = ServeMetrics::default();
        one.record_batch(1, Duration::from_millis(7));
        assert!((one.p99_latency_ms() - 7.0).abs() < 1e-9);

        let mut few = ServeMetrics::default();
        for ms in [1u64, 2, 3, 4] {
            few.record_batch(1, Duration::from_millis(ms));
        }
        let p99 = few.p99_latency_ms();
        assert!(
            (3.0..=4.0 * 1.04).contains(&p99),
            "n=4 p99 should sit at the top sample's bucket, got {p99}"
        );
    }

    #[test]
    fn totals_are_exact_at_scale() {
        let mut m = ServeMetrics::default();
        let n = 3 * 8192;
        for _ in 0..n {
            m.record_batch(2, Duration::from_micros(100));
        }
        assert_eq!(m.total_batches, n);
        assert_eq!(m.total_requests(), 2 * n);
        assert_eq!(m.batch_latency.count(), n as u64);
        assert_eq!(m.request_latency.count(), 2 * n as u64);
        assert!((m.throughput_rps() - 2.0 / 100e-6).abs() < 1.0);
        // p99 of a constant distribution is that constant (±bucket width)
        let p99 = m.p99_latency_ms();
        assert!((p99 - 0.1).abs() / 0.1 < 0.04, "p99={p99}");
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.total_requests(), 0);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.p99_latency_ms(), 0.0);
        assert_eq!(m.mean_latency_ms(), 0.0);
        let j = m.to_json().to_string();
        assert!(j.contains("\"total_rows\":0"));
    }
}
