//! Layer-3 coordinator: the inference server tying the stack together.
//!
//! Requests → [`DynamicBatcher`] → backend:
//!  * **PJRT fast path** — the AOT-compiled S-AC network (`runtime`),
//!  * **circuit golden path** — the device-exact/table-model evaluator
//!    (`nn`), used for cross-checks and characterization.
//!
//! Python is never on this path; the process is self-contained once
//! `artifacts/` exists.

pub mod batcher;
pub mod metrics;

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

pub use batcher::{Batch, DynamicBatcher};
pub use metrics::ServeMetrics;

use crate::data::TrainedNet;
use crate::runtime::{Executable, Runtime};

/// Inference server for one task's AOT executable.
pub struct InferenceServer {
    pub net: TrainedNet,
    pub exe: Executable,
    pub batcher: DynamicBatcher,
    /// flattened f32 weight buffers in manifest parameter order
    weight_bufs: Vec<Vec<f32>>,
    pub n_classes: usize,
    pub metrics: ServeMetrics,
}

impl InferenceServer {
    /// Build from the artifact directory: loads `<task>_mlp` and
    /// `weights_<task>.json`, pre-materializing the weight literals.
    pub fn new(rt: &Runtime, task: &str) -> Result<InferenceServer> {
        let net = TrainedNet::load(
            &rt.artifacts_dir.join(format!("weights_{task}.json")),
        )?;
        let exe = rt.load(&format!("{task}_mlp"))?;
        // parameter order: w1,b1,w2,b2,...,x  (see aot.py)
        let mut weight_bufs = Vec::new();
        for li in 0..net.n_layers() {
            weight_bufs.push(net.weights[li].iter().map(|&v| v as f32).collect());
            weight_bufs.push(net.biases[li].iter().map(|&v| v as f32).collect());
        }
        let xspec = exe
            .spec
            .params
            .last()
            .ok_or_else(|| anyhow!("no params in manifest"))?;
        let batch = xspec.shape[0];
        let dim = xspec.shape[1];
        if dim != net.sizes[0] {
            return Err(anyhow!("manifest dim {dim} != net input {}", net.sizes[0]));
        }
        let n_classes = *net.sizes.last().unwrap();
        Ok(InferenceServer {
            net,
            exe,
            batcher: DynamicBatcher::new(batch, dim),
            weight_bufs,
            n_classes,
            metrics: ServeMetrics::default(),
        })
    }

    /// Enqueue one request.
    pub fn submit(&mut self, features: Vec<f32>) -> u64 {
        self.batcher.submit(features)
    }

    /// Run one materialized batch through the executable; returns
    /// (request id, predicted class, logits) per live row.
    pub fn run_batch(&mut self, batch: &Batch) -> Result<Vec<(u64, usize, Vec<f32>)>> {
        let t0 = Instant::now();
        let mut params: Vec<&[f32]> =
            self.weight_bufs.iter().map(|b| b.as_slice()).collect();
        params.push(&batch.data);
        let out = self.exe.run_f32(&params)?;
        let dt = t0.elapsed();
        self.metrics.record_batch(batch.live, dt);
        let k = self.n_classes;
        let mut results = Vec::with_capacity(batch.live);
        for (r, &id) in batch.ids.iter().enumerate() {
            let logits = out[r * k..(r + 1) * k].to_vec();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            results.push((id, pred, logits));
        }
        Ok(results)
    }

    /// Drain the queue: run all pending batches (padding the tail).
    pub fn drain(&mut self) -> Result<Vec<(u64, usize, Vec<f32>)>> {
        let batches = self.batcher.flush();
        let mut all = Vec::new();
        for b in &batches {
            all.extend(self.run_batch(b)?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    // InferenceServer needs compiled artifacts; its end-to-end behaviour is
    // covered by rust/tests/integration.rs and examples/mnist_serve.rs.
    // The pure coordination logic is tested in `batcher` and `metrics`.
}
