//! Layer-3 coordinator: the serving subsystem tying the stack together.
//!
//! Three pieces compose, smallest to largest:
//!
//! * [`Engine`] — one task's executable plus its pre-materialized weight
//!   buffers.  Stateless (`run_batch(&self, …)`), `Send + Sync`, so many
//!   workers can execute batches of the same task concurrently.
//! * [`InferenceServer`] — the single-task synchronous facade: an `Engine`
//!   behind a [`DynamicBatcher`] with its own [`ServeMetrics`].  Used by
//!   the `serve` CLI smoke path and the examples.
//! * [`router::Router`] — the multi-task, multi-worker serving subsystem:
//!   N engines behind one submit API, batches dispatched to a
//!   [`crate::util::pool::WorkerPool`], a deadline flusher so tail requests
//!   are never stranded, and per-task metrics aggregation.
//!
//! Requests flow  submit → batcher → worker → engine → results map.  The
//! backend is the native executor (`runtime`); the circuit golden path
//! (`nn` on the table/device tiers) cross-checks it in the integration
//! tests.  Python is never on this path.

pub mod batcher;
pub mod health;
pub mod metrics;
pub mod router;
pub mod scrape;
pub mod telemetry;

use std::time::Instant;

use anyhow::{anyhow, Result};

pub use batcher::{Batch, DynamicBatcher};
pub use health::{HealthConfig, HealthEvent, HealthState, LaneHealth};
pub use metrics::ServeMetrics;
pub use router::{trace_of, LaneSpec, RebuildFn, RequestId, Response, Router, RouterConfig};
pub use scrape::ScrapeServer;
pub use telemetry::{
    check_schema, kernel_stats, metrics_file_json, prometheus_exposition, signal_health_json,
    Exemplar, ExemplarSet, HealthSnapshot, KernelSnapshot, LatencyHistogram, MetricsSnapshot,
    SchemaError, StageCounters, StageSnapshot, METRICS_SCHEMA,
};

use crate::data::TrainedNet;
use crate::runtime::{Executable, ExecMode, Runtime};
use crate::util::rng::Rng;

/// One answered inference row: (request id, predicted class, logits).
pub type Answer = (u64, usize, Vec<f32>);

/// One task's executable with pre-materialized weight parameter buffers.
///
/// Execution is a pure function of the batch, which is what lets the
/// router run many batches of the same task in parallel without locks.
#[derive(Clone, Debug)]
pub struct Engine {
    pub net: TrainedNet,
    pub exe: Executable,
    /// flattened f32 weight buffers in manifest parameter order
    weight_bufs: Vec<Vec<f32>>,
    /// compiled batch dimension
    pub batch_size: usize,
    /// input feature dimension
    pub dim: usize,
    pub n_classes: usize,
}

impl Engine {
    /// Build from the artifact directory: loads `<task>_mlp` and
    /// `weights_<task>.json`, pre-materializing the weight literals
    /// (scalar execution — see [`Engine::new_with_mode`]).
    pub fn new(rt: &Runtime, task: &str) -> Result<Engine> {
        Engine::new_with_mode(rt, task, ExecMode::Scalar)
    }

    /// [`Engine::new`] with an explicit execution strategy (the CLI's
    /// `--engine {scalar,batched}` flag lands here).
    pub fn new_with_mode(rt: &Runtime, task: &str, mode: ExecMode) -> Result<Engine> {
        let net = TrainedNet::load(
            &rt.artifacts_dir.join(format!("weights_{task}.json")),
        )?;
        let exe = rt.load_with_mode(&format!("{task}_mlp"), mode)?;
        Engine::from_parts(net, exe)
    }

    /// Which execution strategy this engine's executable uses.
    pub fn mode(&self) -> ExecMode {
        self.exe.mode()
    }

    /// Analog signal-health stats of the underlying batched kernel;
    /// `None` for scalar engines (no grids, nothing to saturate).
    pub fn signal_health(&self) -> Option<crate::nn::batch::SignalHealthStats> {
        self.exe.signal_health()
    }

    /// Attach an infrastructure fault gate to the underlying executable
    /// (see [`crate::runtime::FaultyExec`]) — chaos-suite surface.
    pub fn with_faults(mut self, faults: std::sync::Arc<crate::runtime::FaultyExec>) -> Engine {
        self.exe = self.exe.with_faults(faults);
        self
    }

    /// Set intra-batch row parallelism on the underlying executable (the
    /// `--threads`/`SAC_THREADS` knob; see
    /// [`Executable::with_par_threads`]).  Results are bit-identical at
    /// any thread count.
    pub fn with_par_threads(mut self, n: usize) -> Engine {
        self.exe = self.exe.with_par_threads(n);
        self
    }

    /// Build from in-memory parts (artifact-free: see
    /// [`Executable::native_mlp`]).
    pub fn from_parts(net: TrainedNet, exe: Executable) -> Result<Engine> {
        // parameter order: w1,b1,w2,b2,…,x  (see aot.py)
        let mut weight_bufs = Vec::new();
        for li in 0..net.n_layers() {
            weight_bufs.push(net.weights[li].iter().map(|&v| v as f32).collect());
            weight_bufs.push(net.biases[li].iter().map(|&v| v as f32).collect());
        }
        let xspec = exe
            .spec
            .params
            .last()
            .ok_or_else(|| anyhow!("no params in manifest"))?;
        let batch_size = xspec.shape[0];
        let dim = xspec.shape[1];
        if dim != net.sizes[0] {
            return Err(anyhow!("manifest dim {dim} != net input {}", net.sizes[0]));
        }
        let n_classes = *net.sizes.last().unwrap();
        Ok(Engine {
            net,
            exe,
            weight_bufs,
            batch_size,
            dim,
            n_classes,
        })
    }

    /// Run one materialized batch through the executable; returns
    /// (request id, predicted class, logits) per live row.  Only the live
    /// rows are computed — a deadline-flushed tail batch with one request
    /// costs one row of solves, not the whole padded batch.
    pub fn run_batch(&self, batch: &Batch) -> Result<Vec<Answer>> {
        let _span = crate::util::trace::span("engine.run_batch");
        let mut params: Vec<&[f32]> =
            self.weight_bufs.iter().map(|b| b.as_slice()).collect();
        params.push(&batch.data);
        let out = self.exe.run_f32_rows(&params, batch.live)?;
        let k = self.n_classes;
        let mut results = Vec::with_capacity(batch.live);
        for (r, &id) in batch.ids.iter().enumerate() {
            let logits = out[r * k..(r + 1) * k].to_vec();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            results.push((id, pred, logits));
        }
        Ok(results)
    }
}

/// A deterministic synthetic engine for benches / demos / tests that must
/// run without any artifact directory: a random-weight S-AC MLP with the
/// cheap `relu`/`S=1` cell configuration (scalar execution).
pub fn synthetic_engine(seed: u64, sizes: &[usize], batch: usize) -> Result<Engine> {
    synthetic_engine_with_mode(seed, sizes, batch, ExecMode::Scalar)
}

/// [`synthetic_engine`] with an explicit execution strategy — the
/// scalar-vs-batched comparison surface of `bench-serve` and
/// `benches/hotpath.rs`.
pub fn synthetic_engine_with_mode(
    seed: u64,
    sizes: &[usize],
    batch: usize,
    mode: ExecMode,
) -> Result<Engine> {
    assert!(sizes.len() >= 2, "need at least [in, out] sizes");
    let mut rng = Rng::new(seed);
    let nl = sizes.len() - 1;
    let mut weights = Vec::with_capacity(nl);
    let mut biases = Vec::with_capacity(nl);
    for li in 0..nl {
        weights.push(
            (0..sizes[li] * sizes[li + 1])
                .map(|_| rng.uniform_in(-0.8, 0.8))
                .collect(),
        );
        biases.push(
            (0..sizes[li + 1])
                .map(|_| rng.uniform_in(-0.1, 0.1))
                .collect(),
        );
    }
    let net = TrainedNet {
        task: format!("synthetic{seed}"),
        sizes: sizes.to_vec(),
        activation: "relu".into(),
        splines: 1,
        c: 1.0,
        acc_sw: 0.0,
        acc_sac_algorithmic: 0.0,
        weights,
        biases,
    };
    let exe = Executable::native_mlp_with_mode(&net, batch, mode)?;
    Engine::from_parts(net, exe)
}

/// Single-task synchronous inference server: an [`Engine`] behind a
/// [`DynamicBatcher`], recording [`ServeMetrics`].
pub struct InferenceServer {
    pub engine: Engine,
    pub batcher: DynamicBatcher,
    pub metrics: ServeMetrics,
}

impl InferenceServer {
    /// Build from the artifact directory (see [`Engine::new`]).
    pub fn new(rt: &Runtime, task: &str) -> Result<InferenceServer> {
        Ok(InferenceServer::from_engine(Engine::new(rt, task)?))
    }

    /// [`InferenceServer::new`] with an explicit execution strategy.
    pub fn new_with_mode(rt: &Runtime, task: &str, mode: ExecMode) -> Result<InferenceServer> {
        Ok(InferenceServer::from_engine(Engine::new_with_mode(
            rt, task, mode,
        )?))
    }

    /// Wrap an existing engine.
    pub fn from_engine(engine: Engine) -> InferenceServer {
        let batcher = DynamicBatcher::new(engine.batch_size, engine.dim);
        InferenceServer {
            engine,
            batcher,
            metrics: ServeMetrics::default(),
        }
    }

    /// Enqueue one request.
    pub fn submit(&mut self, features: Vec<f32>) -> u64 {
        self.batcher.submit(features)
    }

    /// Run one materialized batch, recording latency metrics.
    pub fn run_batch(&mut self, batch: &Batch) -> Result<Vec<Answer>> {
        let t0 = Instant::now();
        let results = self.engine.run_batch(batch)?;
        self.metrics.record_batch(batch.live, t0.elapsed());
        Ok(results)
    }

    /// Drain the queue: run all pending batches (padding the tail).
    pub fn drain(&mut self) -> Result<Vec<Answer>> {
        let batches = self.batcher.flush();
        let mut all = Vec::new();
        for b in &batches {
            all.extend(self.run_batch(b)?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_engine_round_trips() {
        let engine = synthetic_engine(3, &[4, 6, 3], 8).unwrap();
        assert_eq!(engine.batch_size, 8);
        assert_eq!(engine.dim, 4);
        assert_eq!(engine.n_classes, 3);
        let mut server = InferenceServer::from_engine(engine);
        for i in 0..10 {
            server.submit(vec![0.1 * i as f32; 4]);
        }
        let results = server.drain().unwrap();
        assert_eq!(results.len(), 10, "padding leaked into results");
        let ids: Vec<u64> = results.iter().map(|r| r.0).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        assert!(results.iter().all(|r| r.2.len() == 3));
        assert_eq!(server.metrics.total_requests(), 10);
    }

    #[test]
    fn engine_is_deterministic() {
        let engine = synthetic_engine(5, &[3, 4, 2], 4).unwrap();
        let mut b = DynamicBatcher::new(4, 3);
        for i in 0..4 {
            b.submit(vec![0.2 * i as f32, -0.1, 0.4]);
        }
        let batch = &b.flush()[0];
        let a = engine.run_batch(batch).unwrap();
        let b2 = engine.run_batch(batch).unwrap();
        assert_eq!(a, b2);
    }

    #[test]
    fn batched_engine_agrees_with_scalar_engine() {
        let scalar = synthetic_engine_with_mode(9, &[4, 5, 3], 6, ExecMode::Scalar).unwrap();
        let batched = synthetic_engine_with_mode(9, &[4, 5, 3], 6, ExecMode::Batched).unwrap();
        assert_eq!(scalar.mode(), ExecMode::Scalar);
        assert_eq!(batched.mode(), ExecMode::Batched);
        let mut b = DynamicBatcher::new(6, 4);
        for i in 0..6 {
            let t = i as f32;
            b.submit(vec![0.15 * t, -0.1 * t, 0.3 - 0.05 * t, 0.2]);
        }
        let batch = &b.flush()[0];
        let sa = scalar.run_batch(batch).unwrap();
        let ba = batched.run_batch(batch).unwrap();
        assert_eq!(sa.len(), ba.len());
        for ((sid, _, slog), (bid, _, blog)) in sa.iter().zip(&ba) {
            assert_eq!(sid, bid);
            for (j, (&sv, &bv)) in slog.iter().zip(blog).enumerate() {
                assert!(
                    (sv - bv).abs() < 1e-2,
                    "req {sid} logit {j}: scalar {sv} vs batched {bv}"
                );
            }
        }
    }
}
