//! Serving telemetry: log-bucketed latency histograms, lock-free stage
//! counters and the Prometheus/JSON exposition formats (DESIGN.md §9).
//!
//! The histogram is HDR-style log-linear over integer nanoseconds:
//! each power-of-two octave is split into `2^SUB_BITS = 32` equal-width
//! sub-buckets, so the relative width of any bucket is ≤ 1/32 ≈ 3.2%.
//! Indexing is pure bit math (`leading_zeros`), deterministic on every
//! platform, and all accumulators are integers — merges are exactly
//! associative and commutative, which is what makes multi-lane
//! aggregation order-invariant (see the merge property test).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::coordinator::ServeMetrics;
use crate::nn::batch::SignalHealthStats;
use crate::util::json::Json;
use crate::util::trace::TraceStats;

/// Schema tag stamped on every metrics snapshot file.  v2 added the
/// `kernel` block (batched-kernel dispatch + grid-cache counters); v3
/// added the `health` block (self-healing router: canary probes, health
/// transitions, shed/retry/requeue counts, rebuild durations, worker
/// respawns — DESIGN.md §11); v4 added the `signal` block (per-lane
/// analog signal-health: saturation / fallback fractions, grid heat,
/// margin residuals) and per-lane latency `exemplars` linking histogram
/// buckets to trace ids (DESIGN.md §12).
pub const METRICS_SCHEMA: &str = "sac-metrics/v4";

/// Typed rejection for a metrics file whose `schema` tag this build
/// does not understand.  Readers must fail loudly instead of silently
/// misparsing an older/newer layout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchemaError {
    /// The schema string found in the file.
    pub found: String,
    /// The schema this build reads.
    pub supported: &'static str,
}

impl std::fmt::Display for SchemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unsupported metrics schema {:?}: this build reads {:?}",
            self.found, self.supported
        )
    }
}

impl std::error::Error for SchemaError {}

/// Accept exactly the current schema tag; anything else is an error.
pub fn check_schema(found: &str) -> Result<(), SchemaError> {
    if found == METRICS_SCHEMA {
        Ok(())
    } else {
        Err(SchemaError {
            found: found.to_string(),
            supported: METRICS_SCHEMA,
        })
    }
}

/// Sub-bucket resolution: each octave is split into `2^SUB_BITS` buckets.
pub const SUB_BITS: u32 = 5;
const SUB_BUCKETS: u64 = 1 << SUB_BITS;
/// Total bucket count: 60 octaves (u64 range above `2^SUB_BITS`) × 32
/// sub-buckets, plus the exact low range `[0, 2^SUB_BITS)`.
pub const N_BUCKETS: usize = ((64 - SUB_BITS as usize) * SUB_BUCKETS as usize) + SUB_BUCKETS as usize;

/// Log-linear latency histogram over integer nanoseconds.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }
}

/// Bucket index for a nanosecond value.  Values below `2^SUB_BITS` get
/// exact unit-width buckets; above, the index is
/// `(octave - SUB_BITS + 1) * 32 + sub` where `sub` reads the 5 bits
/// below the most significant bit.
pub fn index_of(ns: u64) -> usize {
    if ns < SUB_BUCKETS {
        return ns as usize;
    }
    let msb = 63 - ns.leading_zeros() as u64; // >= SUB_BITS as u64
    let octave = msb - SUB_BITS as u64 + 1;
    let sub = (ns >> (msb - SUB_BITS as u64)) & (SUB_BUCKETS - 1);
    let idx = (octave * SUB_BUCKETS + sub) as usize;
    idx.min(N_BUCKETS - 1)
}

/// Inclusive-lo / exclusive-hi nanosecond bounds of bucket `i` (the top
/// bucket's hi saturates at `u64::MAX`).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    let i = i as u64;
    if i < SUB_BUCKETS {
        return (i, i + 1);
    }
    let octave = i / SUB_BUCKETS; // >= 1
    let sub = i % SUB_BUCKETS;
    let msb = octave + SUB_BITS as u64 - 1;
    let width = 1u64 << (msb - SUB_BITS as u64);
    let lo = (1u64 << msb) + sub * width;
    let hi = lo.checked_add(width).unwrap_or(u64::MAX);
    (lo, hi)
}

impl LatencyHistogram {
    /// Record one sample of `ns` nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.record_n_ns(ns, 1);
    }

    /// Record `n` samples all of `ns` nanoseconds (used to attribute a
    /// batch latency to each request it carried).
    pub fn record_n_ns(&mut self, ns: u64, n: u64) {
        if n == 0 {
            return;
        }
        let i = index_of(ns);
        self.counts[i] = self.counts[i].saturating_add(n);
        self.count = self.count.saturating_add(n);
        self.sum_ns = self.sum_ns.saturating_add(ns.saturating_mul(n));
        if ns < self.min_ns {
            self.min_ns = ns;
        }
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    /// Record a `Duration` sample.
    pub fn record(&mut self, dt: Duration) {
        self.record_ns(dt.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Merge `other` into `self`.  Integer adds only: exactly
    /// associative and commutative.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a = a.saturating_add(*b);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples in nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Smallest sample (0 when empty).
    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// Largest sample.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Sparse `(bucket_index, count)` pairs for the non-empty buckets,
    /// in ascending index order.
    pub fn buckets(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Quantile estimate in nanoseconds (`q` in `[0, 1]`).  Walks the
    /// cumulative counts to the target rank and linearly interpolates
    /// within the landing bucket; the result is clamped to the observed
    /// `[min_ns, max_ns]`, which makes single-sample histograms exact.
    /// Edges are exact: `q <= 0` returns the observed minimum, `q >= 1`
    /// the observed maximum, and an empty histogram returns `0.0`
    /// (never NaN — a NaN `q` reads as `0`).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.max(0.0).min(1.0); // NaN collapses to 0.0 here
        if q <= 0.0 {
            return self.min_ns as f64;
        }
        if q >= 1.0 {
            return self.max_ns as f64;
        }
        let target = (q * self.count as f64).max(1.0);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = seen + c;
            if (next as f64) >= target {
                let (lo, hi) = bucket_bounds(i);
                let frac = (target - seen as f64) / c as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return est.max(self.min_ns as f64).min(self.max_ns as f64);
            }
            seen = next;
        }
        self.max_ns as f64
    }

    /// Canonical JSON form: totals plus the sparse bucket list.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "buckets",
                Json::Arr(
                    self.buckets()
                        .into_iter()
                        .map(|(i, c)| Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)]))
                        .collect(),
                ),
            ),
            ("count", Json::Num(self.count as f64)),
            ("max_ns", Json::Num(self.max_ns() as f64)),
            ("min_ns", Json::Num(self.min_ns() as f64)),
            ("sum_ns", Json::Num(self.sum_ns as f64)),
        ])
    }
}

/// One latency exemplar: a concrete trace id that landed in a given
/// histogram bucket, so a p99 bucket can be followed straight to the
/// span tree of a request that produced it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Exemplar {
    /// Histogram bucket index (`index_of(latency_ns)`).
    pub bucket: usize,
    /// Correlated trace id (never 0 — uncorrelated samples are skipped).
    pub trace_id: u64,
    /// The exact sample latency.
    pub latency_ns: u64,
}

/// At most one exemplar per histogram bucket.  Retention is
/// deterministic and order-invariant: the highest latency in the
/// bucket wins, ties broken by the *lowest* trace id — both rules are
/// commutative and associative, so merging lane sets in any order (or
/// grouping) yields the identical set, mirroring the histogram-merge
/// law the goldens rely on.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExemplarSet {
    slots: BTreeMap<usize, Exemplar>,
}

impl ExemplarSet {
    /// Offer one exemplar; keeps it only if it beats the incumbent
    /// under the (latency desc, trace id asc) retention rule.
    fn absorb(&mut self, e: Exemplar) {
        match self.slots.get_mut(&e.bucket) {
            Some(cur) => {
                if e.latency_ns > cur.latency_ns
                    || (e.latency_ns == cur.latency_ns && e.trace_id < cur.trace_id)
                {
                    *cur = e;
                }
            }
            None => {
                self.slots.insert(e.bucket, e);
            }
        }
    }

    /// Record one correlated latency sample.  Uncorrelated samples
    /// (`trace_id == 0`) are ignored.
    pub fn observe(&mut self, latency_ns: u64, trace_id: u64) {
        if trace_id == 0 {
            return;
        }
        self.absorb(Exemplar {
            bucket: index_of(latency_ns),
            trace_id,
            latency_ns,
        });
    }

    /// Merge `other` into `self` under the same retention rule.
    pub fn merge(&mut self, other: &ExemplarSet) {
        for e in other.slots.values() {
            self.absorb(*e);
        }
    }

    /// Exemplar for bucket `i`, if one was retained.
    pub fn get(&self, i: usize) -> Option<&Exemplar> {
        self.slots.get(&i)
    }

    /// Retained exemplars in ascending bucket order.
    pub fn iter(&self) -> impl Iterator<Item = &Exemplar> {
        self.slots.values()
    }

    /// Number of buckets holding an exemplar.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no exemplar has been retained.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Canonical JSON form: ascending-bucket array of exemplar objects.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.slots
                .values()
                .map(|e| {
                    Json::obj(vec![
                        ("bucket", Json::Num(e.bucket as f64)),
                        ("latency_ns", Json::Num(e.latency_ns as f64)),
                        ("trace_id", Json::Num(e.trace_id as f64)),
                    ])
                })
                .collect(),
        )
    }
}

/// Lock-free per-stage counters for the serving pipeline.  All loads
/// and stores are `Relaxed`: these are statistics, not synchronization.
#[derive(Debug, Default)]
pub struct StageCounters {
    /// Requests accepted by `Router::submit`.
    pub submitted: AtomicU64,
    /// Requests rejected (unknown task / bad dimension / shutdown).
    pub rejected: AtomicU64,
    /// Batches handed to the worker pool.
    pub batches_enqueued: AtomicU64,
    /// Partial batches flushed by the deadline flusher.
    pub deadline_flushes: AtomicU64,
    /// Batches that completed successfully.
    pub batches_completed: AtomicU64,
    /// Batches whose engine failed or panicked.
    pub batches_failed: AtomicU64,
    /// Rows delivered from completed batches.
    pub rows_delivered: AtomicU64,
    /// Responses handed to callers via `try_take` / `wait`.
    pub responses_taken: AtomicU64,
    /// `wait` calls that timed out before a response arrived.
    pub wait_timeouts: AtomicU64,
}

impl StageCounters {
    /// Relaxed increment helper.
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough copy of all counters.
    pub fn snapshot(&self) -> StageSnapshot {
        StageSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches_enqueued: self.batches_enqueued.load(Ordering::Relaxed),
            deadline_flushes: self.deadline_flushes.load(Ordering::Relaxed),
            batches_completed: self.batches_completed.load(Ordering::Relaxed),
            batches_failed: self.batches_failed.load(Ordering::Relaxed),
            rows_delivered: self.rows_delivered.load(Ordering::Relaxed),
            responses_taken: self.responses_taken.load(Ordering::Relaxed),
            wait_timeouts: self.wait_timeouts.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value copy of [`StageCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub batches_enqueued: u64,
    pub deadline_flushes: u64,
    pub batches_completed: u64,
    pub batches_failed: u64,
    pub rows_delivered: u64,
    pub responses_taken: u64,
    pub wait_timeouts: u64,
}

impl StageSnapshot {
    /// `(stage_name, value)` pairs in pipeline order.
    pub fn fields(&self) -> [(&'static str, u64); 9] {
        [
            ("submitted", self.submitted),
            ("rejected", self.rejected),
            ("batches_enqueued", self.batches_enqueued),
            ("deadline_flushes", self.deadline_flushes),
            ("batches_completed", self.batches_completed),
            ("batches_failed", self.batches_failed),
            ("rows_delivered", self.rows_delivered),
            ("responses_taken", self.responses_taken),
            ("wait_timeouts", self.wait_timeouts),
        ]
    }

    /// Canonical JSON form (alphabetical keys, like every `Json::Obj`).
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.fields()
                .iter()
                .map(|&(k, v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        )
    }
}

/// Process-wide batched-kernel counters at capture time: how batches
/// were dispatched (parallel row-slabs vs the serial single-slab path)
/// and how the shared grid cache behaved.  The sources are
/// process-global (`nn::batch`), so concurrent routers see one shared
/// set of counters — like the trace stats.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelSnapshot {
    /// `forward_batch` calls dispatched as parallel row-slabs.
    pub parallel_batches: u64,
    /// `forward_batch` calls run as one serial slab.
    pub serial_batches: u64,
    /// Kernel constructions that reused cached grids.
    pub grid_cache_hits: u64,
    /// Kernel constructions that sampled fresh grids.
    pub grid_cache_misses: u64,
}

/// Capture the current process-wide batched-kernel counters.
pub fn kernel_stats() -> KernelSnapshot {
    let (parallel_batches, serial_batches) = crate::nn::batch::batch_dispatch_counts();
    let cache = crate::nn::batch::grid_cache_stats();
    KernelSnapshot {
        parallel_batches,
        serial_batches,
        grid_cache_hits: cache.hits,
        grid_cache_misses: cache.misses,
    }
}

impl KernelSnapshot {
    /// Canonical JSON form (alphabetical keys).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("grid_cache_hits", Json::Num(self.grid_cache_hits as f64)),
            (
                "grid_cache_misses",
                Json::Num(self.grid_cache_misses as f64),
            ),
            ("parallel_batches", Json::Num(self.parallel_batches as f64)),
            ("serial_batches", Json::Num(self.serial_batches as f64)),
        ])
    }
}

/// The `sac-metrics/v3` health block: per-lane health states plus every
/// self-healing counter of one router (DESIGN.md §11).  Unlike the
/// kernel block these are per-router, not process-wide.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthSnapshot {
    /// `(task, state)` per lane, in lane order; state is the stable
    /// lowercase name (`healthy` / `degraded` / `quarantined`).
    pub lanes: Vec<(String, String)>,
    /// Canary probe rows threaded through live engines.
    pub probes: u64,
    /// Probe rows whose prediction disagreed with the golden label.
    pub probe_disagreements: u64,
    /// Transitions into `Degraded`.
    pub to_degraded: u64,
    /// Transitions into `Quarantined`.
    pub to_quarantined: u64,
    /// Transitions back to `Healthy` (self-recovery or rebuild).
    pub recovered: u64,
    /// Engine rebuild attempts from the quarantine path.
    pub rebuilds: u64,
    /// Total wall time spent in rebuild attempts.
    pub rebuild_ns_total: u64,
    /// Requests shed for exceeding their deadline before execution.
    pub shed_deadline: u64,
    /// Submits rejected by the bounded admission queue.
    pub shed_queue: u64,
    /// Batches requeued exactly once after a worker died mid-delivery.
    pub requeues: u64,
    /// In-place retries of transient (panic-class) batch failures.
    pub retries: u64,
    /// Worker threads respawned by the pool supervisor.
    pub respawns: u64,
}

impl HealthSnapshot {
    /// Canonical JSON form (alphabetical keys).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "lanes",
                Json::Arr(
                    self.lanes
                        .iter()
                        .map(|(task, state)| {
                            Json::obj(vec![
                                ("state", Json::Str(state.clone())),
                                ("task", Json::Str(task.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "probe_disagreements",
                Json::Num(self.probe_disagreements as f64),
            ),
            ("probes", Json::Num(self.probes as f64)),
            ("rebuild_ns_total", Json::Num(self.rebuild_ns_total as f64)),
            ("rebuilds", Json::Num(self.rebuilds as f64)),
            ("recovered", Json::Num(self.recovered as f64)),
            ("requeues", Json::Num(self.requeues as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("shed_deadline", Json::Num(self.shed_deadline as f64)),
            ("shed_queue", Json::Num(self.shed_queue as f64)),
            ("to_degraded", Json::Num(self.to_degraded as f64)),
            ("to_quarantined", Json::Num(self.to_quarantined as f64)),
        ])
    }
}

/// Prometheus gauge encoding of a health-state name (0 = healthy,
/// 1 = degraded, 2 = quarantined; unknown names read as quarantined so
/// a label drift is loud, not silently healthy).
fn health_state_gauge(state: &str) -> u64 {
    match state {
        "healthy" => 0,
        "degraded" => 1,
        _ => 2,
    }
}

/// Canonical JSON form of one lane's analog signal-health stats
/// (alphabetical keys).  Raw counters come first-class; the derived
/// fractions are included so scrapers need no client-side math — they
/// are deterministic functions of the integer counters.
pub fn signal_health_json(s: &SignalHealthStats) -> Json {
    Json::obj(vec![
        ("act_fallbacks", Json::Num(s.act_fallbacks as f64)),
        ("act_samples", Json::Num(s.act_samples as f64)),
        ("act_sat_high", Json::Num(s.act_sat_high as f64)),
        ("act_sat_low", Json::Num(s.act_sat_low as f64)),
        ("enabled", Json::Bool(s.enabled)),
        ("fallback_fraction", Json::Num(s.fallback_fraction())),
        (
            "heat",
            Json::Arr(s.heat.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        ("margin_min", Json::Num(s.margin_min)),
        ("margin_sum", Json::Num(s.margin_sum)),
        ("mul_elems", Json::Num(s.mul_elems as f64)),
        ("mul_fallbacks", Json::Num(s.mul_fallbacks as f64)),
        ("saturation_fraction", Json::Num(s.saturation_fraction())),
        ("score", Json::Num(s.score())),
    ])
}

/// One self-contained metrics snapshot: a named router (or campaign
/// stage), its stage counters, per-lane and aggregate `ServeMetrics`,
/// the kernel counters, and the trace-sink stats at capture time.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Snapshot name, e.g. `"serve"`, `"bench-serve"`, `"chaos.infra"`.
    pub name: String,
    /// Pipeline stage counters.
    pub stages: StageSnapshot,
    /// Per-lane metrics, in lane (task-id) order.
    pub lanes: Vec<(String, ServeMetrics)>,
    /// All lanes merged.
    pub aggregate: ServeMetrics,
    /// Batched-kernel dispatch + grid-cache counters at capture time.
    pub kernel: KernelSnapshot,
    /// Trace sink state at capture time.
    pub trace: TraceStats,
    /// Self-healing health block (lane states + recovery counters).
    pub health: HealthSnapshot,
    /// Per-lane request-latency exemplars, in lane (task-id) order.
    pub exemplars: Vec<(String, ExemplarSet)>,
    /// Per-lane analog signal-health stats, in lane (task-id) order.
    pub signal: Vec<(String, SignalHealthStats)>,
}

impl MetricsSnapshot {
    /// Canonical JSON object for this snapshot.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("aggregate", self.aggregate.to_json()),
            (
                "exemplars",
                Json::Arr(
                    self.exemplars
                        .iter()
                        .map(|(task, set)| {
                            Json::obj(vec![
                                ("slots", set.to_json()),
                                ("task", Json::Str(task.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("health", self.health.to_json()),
            ("kernel", self.kernel.to_json()),
            (
                "lanes",
                Json::Arr(
                    self.lanes
                        .iter()
                        .map(|(task, m)| {
                            Json::obj(vec![
                                ("metrics", m.to_json()),
                                ("task", Json::Str(task.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("router", Json::Str(self.name.clone())),
            ("schema", Json::Str(METRICS_SCHEMA.to_string())),
            (
                "signal",
                Json::Arr(
                    self.signal
                        .iter()
                        .map(|(task, s)| {
                            Json::obj(vec![
                                ("stats", signal_health_json(s)),
                                ("task", Json::Str(task.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("stages", self.stages.to_json()),
            (
                "trace",
                Json::obj(vec![
                    ("capacity", Json::Num(self.trace.capacity as f64)),
                    ("dropped", Json::Num(self.trace.dropped as f64)),
                    ("enabled", Json::Bool(self.trace.enabled)),
                    ("recorded", Json::Num(self.trace.recorded as f64)),
                ]),
            ),
        ])
    }

    /// Canonical single-snapshot JSON text.
    pub fn canonical_json(&self) -> String {
        self.to_json().to_string()
    }

    /// Prometheus text exposition for this snapshot alone.
    pub fn prometheus(&self) -> String {
        prometheus_exposition(std::slice::from_ref(self))
    }
}

/// Canonical metrics file: a schema tag plus every snapshot, in order.
pub fn metrics_file_json(snapshots: &[MetricsSnapshot]) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(METRICS_SCHEMA.to_string())),
        (
            "snapshots",
            Json::Arr(snapshots.iter().map(|s| s.to_json()).collect()),
        ),
    ])
}

fn prom_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Format a nanosecond bound as seconds with no float rounding: the
/// value is printed as `ns / 1e9` in plain decimal (exact, since it is
/// just a decimal point shift).
fn ns_as_seconds(ns: u64) -> String {
    let secs = ns / 1_000_000_000;
    let frac = ns % 1_000_000_000;
    if frac == 0 {
        format!("{secs}")
    } else {
        let mut f = format!("{frac:09}");
        while f.ends_with('0') {
            f.pop();
        }
        format!("{secs}.{f}")
    }
}

fn push_histogram(
    out: &mut String,
    family: &str,
    labels: &str,
    h: &LatencyHistogram,
    exemplars: Option<&ExemplarSet>,
) {
    use std::fmt::Write;
    let mut cum = 0u64;
    for (i, c) in h.buckets() {
        cum += c;
        let (_, hi) = bucket_bounds(i);
        let le = if hi == u64::MAX {
            "+Inf".to_string()
        } else {
            ns_as_seconds(hi)
        };
        // OpenMetrics-style exemplar suffix: bucket line gains
        // ` # {trace_id="N"} <seconds>` when a trace landed here.
        match exemplars.and_then(|ex| ex.get(i)) {
            Some(e) => {
                let _ = writeln!(
                    out,
                    "{family}_bucket{{{labels},le=\"{le}\"}} {cum} # {{trace_id=\"{}\"}} {}",
                    e.trace_id,
                    ns_as_seconds(e.latency_ns)
                );
            }
            None => {
                let _ = writeln!(out, "{family}_bucket{{{labels},le=\"{le}\"}} {cum}");
            }
        }
    }
    let _ = writeln!(out, "{family}_bucket{{{labels},le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{family}_sum{{{labels}}} {}", ns_as_seconds(h.sum_ns()));
    let _ = writeln!(out, "{family}_count{{{labels}}} {}", h.count());
}

/// Prometheus text exposition for a set of snapshots.  Families are
/// emitted in a fixed order; per-lane series carry `router` and `task`
/// labels.  The aggregate lane is intentionally *not* exported to
/// Prometheus (summing the per-task series would double-count).
pub fn prometheus_exposition(snapshots: &[MetricsSnapshot]) -> String {
    use std::fmt::Write;
    let mut out = String::new();

    let _ = writeln!(out, "# HELP sac_requests_total Rows delivered per serving lane.");
    let _ = writeln!(out, "# TYPE sac_requests_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, m) in &s.lanes {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_requests_total{{router=\"{r}\",task=\"{t}\"}} {}",
                m.total_rows
            );
        }
    }

    let _ = writeln!(out, "# HELP sac_batches_total Batches executed per serving lane.");
    let _ = writeln!(out, "# TYPE sac_batches_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, m) in &s.lanes {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_batches_total{{router=\"{r}\",task=\"{t}\"}} {}",
                m.total_batches
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP sac_busy_seconds_total Engine busy time per serving lane."
    );
    let _ = writeln!(out, "# TYPE sac_busy_seconds_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, m) in &s.lanes {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_busy_seconds_total{{router=\"{r}\",task=\"{t}\"}} {}",
                ns_as_seconds(m.total_time_ns)
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP sac_stage_total Pipeline stage counters for the serving router."
    );
    let _ = writeln!(out, "# TYPE sac_stage_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (stage, v) in s.stages.fields() {
            let _ = writeln!(
                out,
                "sac_stage_total{{router=\"{r}\",stage=\"{stage}\"}} {v}"
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP sac_kernel_batches_total Batched-kernel dispatches by mode (process-wide)."
    );
    let _ = writeln!(out, "# TYPE sac_kernel_batches_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_kernel_batches_total{{router=\"{r}\",mode=\"parallel\"}} {}",
            s.kernel.parallel_batches
        );
        let _ = writeln!(
            out,
            "sac_kernel_batches_total{{router=\"{r}\",mode=\"serial\"}} {}",
            s.kernel.serial_batches
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_grid_cache_total Grid-cache lookups by outcome (process-wide)."
    );
    let _ = writeln!(out, "# TYPE sac_grid_cache_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_grid_cache_total{{router=\"{r}\",event=\"hit\"}} {}",
            s.kernel.grid_cache_hits
        );
        let _ = writeln!(
            out,
            "sac_grid_cache_total{{router=\"{r}\",event=\"miss\"}} {}",
            s.kernel.grid_cache_misses
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_health_state Lane health (0 = healthy, 1 = degraded, 2 = quarantined)."
    );
    let _ = writeln!(out, "# TYPE sac_health_state gauge");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, state) in &s.health.lanes {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_health_state{{router=\"{r}\",task=\"{t}\"}} {}",
                health_state_gauge(state)
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP sac_health_transitions_total Health-state transitions by destination state."
    );
    let _ = writeln!(out, "# TYPE sac_health_transitions_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_health_transitions_total{{router=\"{r}\",to=\"degraded\"}} {}",
            s.health.to_degraded
        );
        let _ = writeln!(
            out,
            "sac_health_transitions_total{{router=\"{r}\",to=\"quarantined\"}} {}",
            s.health.to_quarantined
        );
        let _ = writeln!(
            out,
            "sac_health_transitions_total{{router=\"{r}\",to=\"healthy\"}} {}",
            s.health.recovered
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_canary_probes_total Canary probe rows by outcome."
    );
    let _ = writeln!(out, "# TYPE sac_canary_probes_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_canary_probes_total{{router=\"{r}\",outcome=\"agree\"}} {}",
            s.health.probes.saturating_sub(s.health.probe_disagreements)
        );
        let _ = writeln!(
            out,
            "sac_canary_probes_total{{router=\"{r}\",outcome=\"disagree\"}} {}",
            s.health.probe_disagreements
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_shed_total Requests shed by reason (deadline / bounded admission queue)."
    );
    let _ = writeln!(out, "# TYPE sac_shed_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_shed_total{{router=\"{r}\",reason=\"deadline\"}} {}",
            s.health.shed_deadline
        );
        let _ = writeln!(
            out,
            "sac_shed_total{{router=\"{r}\",reason=\"queue_full\"}} {}",
            s.health.shed_queue
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_requeues_total Batches requeued after a worker died mid-delivery."
    );
    let _ = writeln!(out, "# TYPE sac_requeues_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(out, "sac_requeues_total{{router=\"{r}\"}} {}", s.health.requeues);
    }

    let _ = writeln!(
        out,
        "# HELP sac_retries_total In-place retries of transient batch failures."
    );
    let _ = writeln!(out, "# TYPE sac_retries_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(out, "sac_retries_total{{router=\"{r}\"}} {}", s.health.retries);
    }

    let _ = writeln!(
        out,
        "# HELP sac_rebuilds_total Engine rebuild attempts from the quarantine path."
    );
    let _ = writeln!(out, "# TYPE sac_rebuilds_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(out, "sac_rebuilds_total{{router=\"{r}\"}} {}", s.health.rebuilds);
    }

    let _ = writeln!(
        out,
        "# HELP sac_rebuild_seconds_total Wall time spent rebuilding quarantined engines."
    );
    let _ = writeln!(out, "# TYPE sac_rebuild_seconds_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_rebuild_seconds_total{{router=\"{r}\"}} {}",
            ns_as_seconds(s.health.rebuild_ns_total)
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_worker_respawns_total Worker threads respawned by the pool supervisor."
    );
    let _ = writeln!(out, "# TYPE sac_worker_respawns_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_worker_respawns_total{{router=\"{r}\"}} {}",
            s.health.respawns
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_trace_recorded_total Spans recorded by the trace ring."
    );
    let _ = writeln!(out, "# TYPE sac_trace_recorded_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_trace_recorded_total{{router=\"{r}\"}} {}",
            s.trace.recorded
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_trace_dropped_total Spans overwritten after the trace ring filled."
    );
    let _ = writeln!(out, "# TYPE sac_trace_dropped_total counter");
    for s in snapshots {
        let r = prom_escape(&s.name);
        let _ = writeln!(
            out,
            "sac_trace_dropped_total{{router=\"{r}\"}} {}",
            s.trace.dropped
        );
    }

    let _ = writeln!(
        out,
        "# HELP sac_signal_saturation_ratio Fraction of post-gain activations in the outer 5% of grid range."
    );
    let _ = writeln!(out, "# TYPE sac_signal_saturation_ratio gauge");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, sig) in &s.signal {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_signal_saturation_ratio{{router=\"{r}\",task=\"{t}\"}} {}",
                sig.saturation_fraction()
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP sac_signal_fallback_ratio Fraction of grid lookups forced onto the exact-cell fallback path."
    );
    let _ = writeln!(out, "# TYPE sac_signal_fallback_ratio gauge");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, sig) in &s.signal {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_signal_fallback_ratio{{router=\"{r}\",task=\"{t}\"}} {}",
                sig.fallback_fraction()
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP sac_signal_margin_min Worst margin-propagation residual observed (z units; negative = out of grid)."
    );
    let _ = writeln!(out, "# TYPE sac_signal_margin_min gauge");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, sig) in &s.signal {
            let t = prom_escape(task);
            let _ = writeln!(
                out,
                "sac_signal_margin_min{{router=\"{r}\",task=\"{t}\"}} {}",
                sig.margin_min
            );
        }
    }

    // Histograms last (they dominate line count); HELP/TYPE once per family.
    let _ = writeln!(out, "# HELP sac_batch_latency_seconds Per-batch engine latency.");
    let _ = writeln!(out, "# TYPE sac_batch_latency_seconds histogram");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, m) in &s.lanes {
            let t = prom_escape(task);
            push_histogram(
                &mut out,
                "sac_batch_latency_seconds",
                &format!("router=\"{r}\",task=\"{t}\""),
                &m.batch_latency,
                None,
            );
        }
    }
    let _ = writeln!(
        out,
        "# HELP sac_request_latency_seconds Per-request delivered latency (batch latency attributed to each row)."
    );
    let _ = writeln!(out, "# TYPE sac_request_latency_seconds histogram");
    for s in snapshots {
        let r = prom_escape(&s.name);
        for (task, m) in &s.lanes {
            let t = prom_escape(task);
            let ex = s
                .exemplars
                .iter()
                .find(|(et, _)| et == task)
                .map(|(_, set)| set);
            push_histogram(
                &mut out,
                "sac_request_latency_seconds",
                &format!("router=\"{r}\",task=\"{t}\""),
                &m.request_latency,
                ex,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_bounds_are_consistent() {
        // every representable value lands in a bucket whose bounds
        // contain it, and indices are monotone in the value
        let probes: Vec<u64> = vec![
            0,
            1,
            31,
            32,
            33,
            63,
            64,
            100,
            1_000,
            65_535,
            65_536,
            1_048_576,
            1_000_000_000,
            u64::MAX / 2,
            u64::MAX - 1,
            u64::MAX,
        ];
        let mut last_idx = 0usize;
        for &ns in &probes {
            let i = index_of(ns);
            assert!(i < N_BUCKETS, "index {i} out of range for {ns}");
            let (lo, hi) = bucket_bounds(i);
            assert!(
                lo <= ns && (ns < hi || hi == u64::MAX),
                "ns={ns} outside bucket {i} bounds [{lo},{hi})"
            );
            assert!(i >= last_idx, "index not monotone at ns={ns}");
            last_idx = i;
        }
    }

    #[test]
    fn bucket_relative_error_is_bounded() {
        // above the exact range, bucket width / lo ≤ 1/32
        for i in SUB_BUCKETS as usize..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            if hi == u64::MAX {
                continue;
            }
            let width = hi - lo;
            assert!(
                (width as f64) / (lo as f64) <= 1.0 / 32.0 + 1e-12,
                "bucket {i}: width {width} vs lo {lo}"
            );
        }
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let mut h = LatencyHistogram::default();
        h.record_ns(123_456);
        assert_eq!(h.quantile_ns(0.5), 123_456.0);
        assert_eq!(h.quantile_ns(0.99), 123_456.0);
        assert_eq!(h.min_ns(), 123_456);
        assert_eq!(h.max_ns(), 123_456);
    }

    #[test]
    fn quantiles_track_known_distributions() {
        let mut h = LatencyHistogram::default();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1_000); // 1µs .. 1ms uniform
        }
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        // log-bucket resolution is 1/32 ≈ 3.2%
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.05, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn merge_matches_bulk_recording() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        let mut all = LatencyHistogram::default();
        for i in 0..200u64 {
            let ns = 17 * i * i + 3;
            if i % 3 == 0 {
                a.record_ns(ns);
            } else {
                b.record_ns(ns);
            }
            all.record_ns(ns);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, all);
        // and the other order gives the identical struct
        let mut merged2 = b;
        merged2.merge(&a);
        assert_eq!(merged2, all);
    }

    #[test]
    fn stage_counters_snapshot_roundtrip() {
        let c = StageCounters::default();
        StageCounters::bump(&c.submitted);
        StageCounters::bump(&c.submitted);
        StageCounters::bump(&c.rejected);
        c.rows_delivered.fetch_add(7, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.rows_delivered, 7);
        assert_eq!(s.fields().len(), 9);
        let j = s.to_json().to_string();
        assert!(j.contains("\"submitted\":2"));
        assert!(j.contains("\"rows_delivered\":7"));
    }

    #[test]
    fn kernel_snapshot_json_is_canonical() {
        let k = KernelSnapshot {
            parallel_batches: 3,
            serial_batches: 5,
            grid_cache_hits: 2,
            grid_cache_misses: 1,
        };
        let j = k.to_json().to_string();
        assert_eq!(
            j,
            "{\"grid_cache_hits\":2,\"grid_cache_misses\":1,\
             \"parallel_batches\":3,\"serial_batches\":5}"
        );
        // live capture never goes backwards relative to a default
        let live = kernel_stats();
        assert!(live.parallel_batches + live.serial_batches + live.grid_cache_misses
            >= KernelSnapshot::default().grid_cache_misses);
    }

    #[test]
    fn health_snapshot_json_is_canonical() {
        let h = HealthSnapshot {
            lanes: vec![
                ("alpha".into(), "degraded".into()),
                ("beta".into(), "healthy".into()),
            ],
            probes: 6,
            probe_disagreements: 2,
            to_degraded: 1,
            to_quarantined: 1,
            recovered: 1,
            rebuilds: 1,
            rebuild_ns_total: 2_097_152,
            shed_deadline: 3,
            shed_queue: 1,
            requeues: 1,
            retries: 1,
            respawns: 1,
        };
        assert_eq!(
            h.to_json().to_string(),
            "{\"lanes\":[{\"state\":\"degraded\",\"task\":\"alpha\"},\
             {\"state\":\"healthy\",\"task\":\"beta\"}],\
             \"probe_disagreements\":2,\"probes\":6,\
             \"rebuild_ns_total\":2097152,\"rebuilds\":1,\"recovered\":1,\
             \"requeues\":1,\"respawns\":1,\"retries\":1,\
             \"shed_deadline\":3,\"shed_queue\":1,\
             \"to_degraded\":1,\"to_quarantined\":1}"
        );
        // an empty default serializes every counter as zero
        let j = HealthSnapshot::default().to_json().to_string();
        assert!(j.contains("\"lanes\":[]"));
        assert!(j.contains("\"respawns\":0"));
        // gauge encoding is stable, and unknown states read as worst
        assert_eq!(health_state_gauge("healthy"), 0);
        assert_eq!(health_state_gauge("degraded"), 1);
        assert_eq!(health_state_gauge("quarantined"), 2);
        assert_eq!(health_state_gauge("gibberish"), 2);
    }

    #[test]
    fn ns_as_seconds_is_exact_decimal() {
        assert_eq!(ns_as_seconds(0), "0");
        assert_eq!(ns_as_seconds(1), "0.000000001");
        assert_eq!(ns_as_seconds(1_500_000), "0.0015");
        assert_eq!(ns_as_seconds(1_000_000_000), "1");
        assert_eq!(ns_as_seconds(2_250_000_000), "2.25");
        assert_eq!(ns_as_seconds(1_048_576), "0.001048576");
        assert_eq!(ns_as_seconds(1_081_344), "0.001081344");
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        assert_eq!(h.quantile_ns(0.99), 0.0);
        assert!(h.buckets().is_empty());
        let j = h.to_json().to_string();
        assert!(j.contains("\"count\":0"));
    }

    #[test]
    fn quantile_edges_return_exact_extremes() {
        let mut h = LatencyHistogram::default();
        for ns in [100u64, 5_000, 123_456, 9_999_999] {
            h.record_ns(ns);
        }
        // q <= 0 is the exact observed minimum, q >= 1 the exact maximum
        assert_eq!(h.quantile_ns(0.0), 100.0);
        assert_eq!(h.quantile_ns(-1.0), 100.0);
        assert_eq!(h.quantile_ns(1.0), 9_999_999.0);
        assert_eq!(h.quantile_ns(2.0), 9_999_999.0);
        // NaN q collapses to the q=0 edge, never propagates
        assert_eq!(h.quantile_ns(f64::NAN), 100.0);
        // the empty histogram never returns NaN at any edge
        let e = LatencyHistogram::default();
        assert_eq!(e.quantile_ns(0.0), 0.0);
        assert_eq!(e.quantile_ns(1.0), 0.0);
        assert!(!e.quantile_ns(f64::NAN).is_nan());
    }

    #[test]
    fn record_n_saturates_instead_of_wrapping() {
        let mut h = LatencyHistogram::default();
        h.record_n_ns(u64::MAX, u64::MAX);
        h.record_n_ns(u64::MAX, u64::MAX);
        assert_eq!(h.count(), u64::MAX);
        assert_eq!(h.sum_ns(), u64::MAX);
        // further recording and merging stay pinned, no wrap/panic
        h.record_ns(1);
        let mut other = LatencyHistogram::default();
        other.record_n_ns(7, u64::MAX);
        h.merge(&other);
        assert_eq!(h.count(), u64::MAX);
        assert_eq!(h.min_ns(), 1);
        assert_eq!(h.max_ns(), u64::MAX);
    }

    #[test]
    fn merged_quantiles_are_bracketed_by_part_quantiles() {
        // merge-then-quantile must land between the per-part quantiles
        // (mixture law), up to bucket resolution; the q=0 / q=1 edges
        // are exact min-of-mins / max-of-maxes.
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        let mut s = 0x5AC0_D00Du64;
        for i in 0..800u64 {
            s = s
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let ns = 50 + (s >> 40);
            if i % 2 == 0 {
                a.record_ns(ns);
            } else {
                b.record_ns(ns);
            }
        }
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.quantile_ns(0.0), a.min_ns().min(b.min_ns()) as f64);
        assert_eq!(m.quantile_ns(1.0), a.max_ns().max(b.max_ns()) as f64);
        for k in 1..20 {
            let q = k as f64 / 20.0;
            let qa = a.quantile_ns(q);
            let qb = b.quantile_ns(q);
            let qm = m.quantile_ns(q);
            let lo = qa.min(qb) * (1.0 - 1.0 / 16.0) - 1.0;
            let hi = qa.max(qb) * (1.0 + 1.0 / 16.0) + 1.0;
            assert!(
                qm >= lo && qm <= hi,
                "q={q}: merged {qm} outside [{lo},{hi}] (parts {qa}, {qb})"
            );
        }
    }

    #[test]
    fn exemplar_retention_is_deterministic_and_order_invariant() {
        // 1_000 and 1_001 share a bucket (width 16 at that octave):
        // the higher latency wins, ties break to the lowest trace id.
        assert_eq!(index_of(1_000), index_of(1_001));
        let samples: [(u64, u64); 6] = [
            (1_000, 7),
            (1_001, 5),
            (1_001, 4),
            (1_048_576, 9),
            (40, 2),
            (40, 11),
        ];
        let mut fwd = ExemplarSet::default();
        for &(ns, id) in &samples {
            fwd.observe(ns, id);
        }
        let mut rev = ExemplarSet::default();
        for &(ns, id) in samples.iter().rev() {
            rev.observe(ns, id);
        }
        assert_eq!(fwd, rev);
        // split + merge (either direction) gives the identical set
        let (mut x, mut y) = (ExemplarSet::default(), ExemplarSet::default());
        for (i, &(ns, id)) in samples.iter().enumerate() {
            if i % 2 == 0 {
                x.observe(ns, id);
            } else {
                y.observe(ns, id);
            }
        }
        let mut xy = x.clone();
        xy.merge(&y);
        let mut yx = y.clone();
        yx.merge(&x);
        assert_eq!(xy, fwd);
        assert_eq!(yx, fwd);
        // retained winners
        let e = fwd.get(index_of(1_001)).unwrap();
        assert_eq!((e.latency_ns, e.trace_id), (1_001, 4));
        let e = fwd.get(index_of(40)).unwrap();
        assert_eq!((e.latency_ns, e.trace_id), (40, 2));
        assert_eq!(fwd.len(), 3);
        // uncorrelated samples are never retained
        let mut z = ExemplarSet::default();
        z.observe(1_000, 0);
        assert!(z.is_empty());
        // canonical JSON is ascending-bucket with alphabetical keys
        let j = fwd.to_json().to_string();
        assert!(j.starts_with("[{\"bucket\":40,\"latency_ns\":40,\"trace_id\":2}"));
    }

    #[test]
    fn schema_check_rejects_unknown_versions() {
        assert!(check_schema(METRICS_SCHEMA).is_ok());
        let err = check_schema("sac-metrics/v3").unwrap_err();
        assert_eq!(err.found, "sac-metrics/v3");
        assert_eq!(err.supported, "sac-metrics/v4");
        let msg = err.to_string();
        assert!(msg.contains("sac-metrics/v3") && msg.contains("sac-metrics/v4"));
        assert!(check_schema("sac-metrics/v99").is_err());
        assert!(check_schema("").is_err());
    }

    #[test]
    fn signal_health_json_is_canonical() {
        let s = SignalHealthStats {
            enabled: true,
            mul_elems: 8,
            mul_fallbacks: 3,
            act_samples: 4,
            act_sat_high: 1,
            act_sat_low: 1,
            act_fallbacks: 0,
            heat: [1, 2, 2, 0, 0, 0, 0, 0],
            margin_min: -0.5,
            margin_sum: 2.25,
        };
        // dyadic inputs → exact decimal fractions in the pinned string
        assert_eq!(s.saturation_fraction(), 0.5);
        assert_eq!(s.fallback_fraction(), 0.25);
        assert_eq!(
            signal_health_json(&s).to_string(),
            "{\"act_fallbacks\":0,\"act_samples\":4,\"act_sat_high\":1,\
             \"act_sat_low\":1,\"enabled\":true,\"fallback_fraction\":0.25,\
             \"heat\":[1,2,2,0,0,0,0,0],\"margin_min\":-0.5,\
             \"margin_sum\":2.25,\"mul_elems\":8,\"mul_fallbacks\":3,\
             \"saturation_fraction\":0.5,\"score\":0.5}"
        );
    }
}
