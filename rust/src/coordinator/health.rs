//! Lane health-state machine for the self-healing router (DESIGN.md §11).
//!
//! Every lane tracks a three-state machine driven by windowed canary
//! probe scores:
//!
//! ```text
//!             verdict > degrade_above              verdict > quarantine_above
//!   Healthy ────────────────────────► Degraded ────────────────────────► Quarantined
//!      ▲                                 │  ▲                                 │
//!      │   recover_after clean verdicts  │  │  patience degraded verdicts     │
//!      └─────────────────────────────────┘  └───────────(escalation)──────────┘
//!      ▲                                                                      │
//!      └───────────────────── rebuilt() after grid re-calibration ────────────┘
//! ```
//!
//! A *verdict* is the mean canary disagreement fraction over one full
//! window of probe rounds.  The two thresholds default to the paper's
//! chaos envelopes (`faults::chaos`): mean 0.15 / worst 0.40.  Entering
//! `Quarantined` always passes through `Degraded` first, so the timeline
//! records the full escalation even on a single catastrophic verdict.
//! `Quarantined` is sticky: no verdict leaves it — only a successful
//! engine rebuild ([`LaneHealth::rebuilt`]) returns the lane to
//! `Healthy`.  Sustained `Degraded` (disagreement between the two
//! envelopes for `patience` consecutive verdicts) escalates to
//! `Quarantined` too, so a lane never idles in a degraded steady state.

use crate::faults::{MEAN_DEGRADATION_ENVELOPE, WORST_DEGRADATION_ENVELOPE};
use crate::util::json::Json;

/// One lane's serving health, as decided by the canary detector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// canary agreement inside the paper envelope
    Healthy,
    /// windowed disagreement above the mean envelope — still serving,
    /// under observation
    Degraded,
    /// disagreement above the collapse envelope (or sustained
    /// degradation): drained, traffic failed over, awaiting rebuild
    Quarantined,
}

impl HealthState {
    /// Stable lowercase name (telemetry label / JSON value).
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Quarantined => "quarantined",
        }
    }

    /// Prometheus gauge encoding (0 = healthy, 1 = degraded,
    /// 2 = quarantined).
    pub fn gauge(self) -> u64 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Degraded => 1,
            HealthState::Quarantined => 2,
        }
    }
}

/// Detector knobs.  Defaults bind the state machine to the chaos suite's
/// paper envelopes.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// probe rounds per verdict (scores are averaged over the window)
    pub window: usize,
    /// verdict above this mean disagreement ⇒ at least `Degraded`
    /// (default [`MEAN_DEGRADATION_ENVELOPE`])
    pub degrade_above: f64,
    /// verdict above this mean disagreement ⇒ `Quarantined`
    /// (default [`WORST_DEGRADATION_ENVELOPE`])
    pub quarantine_above: f64,
    /// consecutive degraded verdicts before escalating to `Quarantined`
    pub patience: usize,
    /// consecutive clean verdicts before `Degraded` recovers on its own
    pub recover_after: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            window: 2,
            degrade_above: MEAN_DEGRADATION_ENVELOPE,
            quarantine_above: WORST_DEGRADATION_ENVELOPE,
            patience: 2,
            recover_after: 2,
        }
    }
}

/// One recorded transition, for the health timeline artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct HealthEvent {
    /// lane (task) name
    pub lane: String,
    pub from: HealthState,
    pub to: HealthState,
    /// completed-batch count on the lane when the transition fired
    pub at_batch: u64,
}

impl HealthEvent {
    /// Canonical JSON form (alphabetical keys).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_batch", Json::Num(self.at_batch as f64)),
            ("from", Json::Str(self.from.name().into())),
            ("lane", Json::Str(self.lane.clone())),
            ("to", Json::Str(self.to.name().into())),
        ])
    }
}

/// The per-lane detector: accumulates probe scores into windows and
/// advances the state machine on each full window.
#[derive(Clone, Debug)]
pub struct LaneHealth {
    cfg: HealthConfig,
    state: HealthState,
    /// scores of the in-progress window
    scores: Vec<f64>,
    degraded_streak: usize,
    clean_streak: usize,
}

impl LaneHealth {
    pub fn new(cfg: HealthConfig) -> LaneHealth {
        LaneHealth {
            cfg: HealthConfig {
                window: cfg.window.max(1),
                patience: cfg.patience.max(1),
                recover_after: cfg.recover_after.max(1),
                ..cfg
            },
            state: HealthState::Healthy,
            scores: Vec::new(),
            degraded_streak: 0,
            clean_streak: 0,
        }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    /// Record one probe round's disagreement fraction (`0.0` = perfect
    /// agreement).  Returns the states newly entered, in order — empty
    /// until a window fills or while the verdict confirms the current
    /// state.  `Quarantined` ignores further scores until
    /// [`LaneHealth::rebuilt`].
    pub fn observe(&mut self, disagreement: f64) -> Vec<HealthState> {
        if self.state == HealthState::Quarantined {
            return Vec::new();
        }
        self.scores.push(disagreement.clamp(0.0, 1.0));
        if self.scores.len() < self.cfg.window {
            return Vec::new();
        }
        let verdict = self.scores.iter().sum::<f64>() / self.scores.len() as f64;
        self.scores.clear();
        let mut entered = Vec::new();
        if verdict > self.cfg.degrade_above {
            self.clean_streak = 0;
            self.degraded_streak += 1;
            if self.state == HealthState::Healthy {
                self.state = HealthState::Degraded;
                entered.push(HealthState::Degraded);
            }
            let collapse = verdict > self.cfg.quarantine_above;
            if collapse || self.degraded_streak >= self.cfg.patience {
                self.state = HealthState::Quarantined;
                entered.push(HealthState::Quarantined);
            }
        } else {
            self.degraded_streak = 0;
            if self.state == HealthState::Degraded {
                self.clean_streak += 1;
                if self.clean_streak >= self.cfg.recover_after {
                    self.clean_streak = 0;
                    self.state = HealthState::Healthy;
                    entered.push(HealthState::Healthy);
                }
            }
        }
        entered
    }

    /// A quarantined engine was rebuilt and passed its post-rebuild probe:
    /// return to `Healthy`.  Returns `false` (and stays put) when the lane
    /// was not quarantined.
    pub fn rebuilt(&mut self) -> bool {
        if self.state != HealthState::Quarantined {
            return false;
        }
        self.state = HealthState::Healthy;
        self.scores.clear();
        self.degraded_streak = 0;
        self.clean_streak = 0;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> LaneHealth {
        LaneHealth::new(HealthConfig {
            window: 2,
            patience: 2,
            recover_after: 2,
            ..HealthConfig::default()
        })
    }

    #[test]
    fn clean_scores_never_leave_healthy() {
        let mut h = quick();
        for _ in 0..50 {
            assert!(h.observe(0.0).is_empty());
            assert_eq!(h.state(), HealthState::Healthy);
        }
        // scores at the envelope boundary are still clean (strictly-above
        // trips, the paper envelope itself passes)
        for _ in 0..10 {
            assert!(h.observe(MEAN_DEGRADATION_ENVELOPE).is_empty());
        }
        assert_eq!(h.state(), HealthState::Healthy);
    }

    #[test]
    fn collapse_verdict_passes_through_degraded() {
        let mut h = quick();
        assert!(h.observe(0.9).is_empty(), "window not full yet");
        let entered = h.observe(0.9);
        assert_eq!(
            entered,
            vec![HealthState::Degraded, HealthState::Quarantined],
            "a collapse must record the full escalation"
        );
        assert_eq!(h.state(), HealthState::Quarantined);
        // quarantine is sticky under further scores, even clean ones
        for _ in 0..10 {
            assert!(h.observe(0.0).is_empty());
        }
        assert_eq!(h.state(), HealthState::Quarantined);
        assert!(h.rebuilt());
        assert_eq!(h.state(), HealthState::Healthy);
        assert!(!h.rebuilt(), "rebuilt() is a no-op when not quarantined");
    }

    #[test]
    fn sustained_degradation_escalates_after_patience() {
        let mut h = quick();
        // disagreement between the envelopes: degraded, not collapsed
        h.observe(0.25);
        assert_eq!(h.observe(0.25), vec![HealthState::Degraded]);
        assert_eq!(h.state(), HealthState::Degraded);
        h.observe(0.25);
        assert_eq!(
            h.observe(0.25),
            vec![HealthState::Quarantined],
            "second degraded verdict must escalate (patience = 2)"
        );
    }

    #[test]
    fn degraded_recovers_after_clean_verdicts() {
        let mut h = quick();
        h.observe(0.25);
        h.observe(0.25); // verdict 1: degraded
        h.observe(0.0);
        assert!(h.observe(0.0).is_empty()); // clean verdict 1 of 2
        h.observe(0.0);
        assert_eq!(h.observe(0.0), vec![HealthState::Healthy]);
        assert_eq!(h.state(), HealthState::Healthy);
        // and the degraded streak was reset by the clean verdicts
        h.observe(0.25);
        assert_eq!(h.observe(0.25), vec![HealthState::Degraded]);
    }

    #[test]
    fn names_and_gauges_are_stable() {
        assert_eq!(HealthState::Healthy.name(), "healthy");
        assert_eq!(HealthState::Degraded.name(), "degraded");
        assert_eq!(HealthState::Quarantined.name(), "quarantined");
        assert_eq!(HealthState::Healthy.gauge(), 0);
        assert_eq!(HealthState::Degraded.gauge(), 1);
        assert_eq!(HealthState::Quarantined.gauge(), 2);
        let e = HealthEvent {
            lane: "alpha".into(),
            from: HealthState::Healthy,
            to: HealthState::Degraded,
            at_batch: 12,
        };
        assert_eq!(
            e.to_json().to_string(),
            "{\"at_batch\":12,\"from\":\"healthy\",\"lane\":\"alpha\",\"to\":\"degraded\"}"
        );
    }
}
