//! Dynamic batcher: groups incoming inference requests into the fixed
//! batch shape the AOT executable was compiled for.
//!
//! Invariants (property-tested below):
//!  * every submitted request appears in exactly one batch, in order;
//!  * no batch exceeds `batch_size`;
//!  * a flush drains everything, padding the tail batch with zero rows and
//!    recording the pad count so results can be un-padded.

/// One request: a feature row.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
}

/// A materialized batch ready for the executable.
#[derive(Clone, Debug)]
pub struct Batch {
    pub ids: Vec<u64>,
    /// row-major [batch_size × dim] (zero-padded)
    pub data: Vec<f32>,
    /// number of real rows (≤ batch_size)
    pub live: usize,
}

#[derive(Debug)]
pub struct DynamicBatcher {
    pub batch_size: usize,
    pub dim: usize,
    queue: Vec<Request>,
    next_id: u64,
}

impl DynamicBatcher {
    pub fn new(batch_size: usize, dim: usize) -> Self {
        assert!(batch_size > 0 && dim > 0);
        DynamicBatcher {
            batch_size,
            dim,
            queue: Vec::new(),
            next_id: 0,
        }
    }

    /// Enqueue a request; returns its id.  Panics on wrong feature arity
    /// (a malformed request must never silently corrupt a batch).
    pub fn submit(&mut self, features: Vec<f32>) -> u64 {
        assert_eq!(features.len(), self.dim, "feature dim mismatch");
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Request { id, features });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop one full batch if available (no padding).
    pub fn pop_full(&mut self) -> Option<Batch> {
        if self.queue.len() < self.batch_size {
            return None;
        }
        Some(self.materialize(self.batch_size))
    }

    /// Remove and return the oldest queued request's id, without
    /// materializing it into a batch.  Deadline-aware shedding: the
    /// router resolves the shed id as failed instead of executing it.
    pub fn shed_front(&mut self) -> Option<u64> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.queue.remove(0).id)
        }
    }

    /// Drain everything, padding the final partial batch.
    pub fn flush(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(b) = self.pop_full() {
            out.push(b);
        }
        if !self.queue.is_empty() {
            let live = self.queue.len();
            out.push(self.materialize(live));
        }
        out
    }

    fn materialize(&mut self, take: usize) -> Batch {
        let reqs: Vec<Request> = self.queue.drain(..take).collect();
        let mut data = vec![0.0f32; self.batch_size * self.dim];
        let mut ids = Vec::with_capacity(take);
        for (r, req) in reqs.into_iter().enumerate() {
            data[r * self.dim..(r + 1) * self.dim].copy_from_slice(&req.features);
            ids.push(req.id);
        }
        Batch {
            ids,
            data,
            live: take,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::check;

    #[test]
    fn batches_preserve_order_and_content() {
        let mut b = DynamicBatcher::new(4, 2);
        for i in 0..10 {
            b.submit(vec![i as f32, -(i as f32)]);
        }
        let batches = b.flush();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].live, 4);
        assert_eq!(batches[2].live, 2);
        let mut seen = Vec::new();
        for batch in &batches {
            for (r, &id) in batch.ids.iter().enumerate() {
                assert_eq!(batch.data[r * 2], id as f32);
                seen.push(id);
            }
        }
        assert_eq!(seen, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn padding_is_zero() {
        let mut b = DynamicBatcher::new(4, 3);
        b.submit(vec![1.0, 2.0, 3.0]);
        let batches = b.flush();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].live, 1);
        assert!(batches[0].data[3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "feature dim mismatch")]
    fn rejects_wrong_dim() {
        let mut b = DynamicBatcher::new(2, 3);
        b.submit(vec![1.0]);
    }

    #[test]
    fn shed_front_removes_oldest_and_preserves_the_rest() {
        let mut b = DynamicBatcher::new(4, 1);
        for i in 0..3 {
            b.submit(vec![i as f32]);
        }
        assert_eq!(b.shed_front(), Some(0));
        assert_eq!(b.shed_front(), Some(1));
        assert_eq!(b.pending(), 1);
        let batches = b.flush();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].ids, vec![2]);
        assert_eq!(batches[0].data[0], 2.0);
        assert_eq!(b.shed_front(), None);
        // ids keep advancing after a shed — no reuse
        assert_eq!(b.submit(vec![9.0]), 3);
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        check(11, 100, |g| -> Result<(), String> {
            let bs = g.usize_in(1, 8);
            let dim = g.usize_in(1, 5);
            let n = g.usize_in(0, 40);
            let mut b = DynamicBatcher::new(bs, dim);
            for _ in 0..n {
                b.submit(vec![0.5; dim]);
            }
            let batches = b.flush();
            let total: usize = batches.iter().map(|b| b.live).sum();
            prop_assert!(total == n, "lost requests: {total} != {n}");
            let mut ids: Vec<u64> = batches.iter().flat_map(|b| b.ids.clone()).collect();
            let before = ids.len();
            ids.dedup();
            prop_assert!(ids.len() == before, "duplicate ids");
            prop_assert!(batches.iter().all(|b| b.live <= bs));
            prop_assert!(b.pending() == 0);
            Ok(())
        });
    }
}
