//! Multi-task serving router: N task engines behind a single submit API,
//! batches dispatched to a shared worker pool, deadline-based flushing so
//! tail requests are never stranded — plus the self-healing layer:
//! supervised requeue, canary drift detection, engine quarantine/rebuild,
//! and deadline-aware load shedding (DESIGN.md §11).
//!
//! ```text
//!             submit(task, features)  ──► admission bound (max_queue)
//!                      │
//!          ┌───────────▼───────────┐   per-task lane
//!          │  Mutex<LaneBatcher>   │   (DynamicBatcher + enqueue times)
//!          └───────────┬───────────┘
//!        full batch ───┤                 ┌──────────────┐
//!                      ├──◄── flusher ───┤ every tick:  │ + deadline shed
//!                      │   (partial      │ age ≥ max_wait│
//!          ┌───────────▼────────┐  batch)└──────────────┘
//!          │ WorkerPool (shared)│  each job: Engine::run_batch
//!          └───────────┬────────┘  + transient retry + canary probe
//!          ┌───────────▼───────────┐
//!          │ Mutex<results: id→…>  │ ← wait()/try_take() remove exactly once
//!          └───────────────────────┘
//! ```
//!
//! Invariants (tested below and in `tests/integration.rs` /
//! `tests/recovery.rs`):
//!
//!  * every submitted request is answered exactly once — batches are only
//!    materialized under the lane lock, each materialized batch is handed
//!    to exactly one worker, and a worker death mid-delivery requeues its
//!    in-flight batch exactly once (the `RequeueGuard`);
//!  * a partial batch waits at most `max_wait` (+ one flusher tick) before
//!    execution — the deadline flush;
//!  * engines run without write locks (`RwLock` read + stateless
//!    `Engine::run_batch(&self, …)`), so batches of the *same* task
//!    execute concurrently on many workers; the write lock is taken only
//!    to swap in a rebuilt engine, which drains in-flight readers;
//!  * an engine failure resolves every request of its batch with the
//!    error ([`Router::wait`] reports it immediately; [`Router::drain`]
//!    and [`Router::failures`] surface it), never a silent timeout;
//!  * shedding (per-request deadline, bounded admission queue) only ever
//!    rejects — it resolves requests as failed with a `shed:`-prefixed
//!    message, preserving exactly-once accounting;
//!  * metrics are recorded per task and can be aggregated across tasks.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batch, DynamicBatcher};
use super::health::{HealthConfig, HealthEvent, HealthState, LaneHealth};
use super::metrics::ServeMetrics;
use super::telemetry::{
    ExemplarSet, HealthSnapshot, MetricsSnapshot, StageCounters, StageSnapshot,
};
use super::{Answer, Engine};
use crate::nn::batch::SignalHealthStats;
use crate::util::pool::{PoolHandle, WorkerPool};
use crate::util::rng::Rng;
use crate::util::trace;

/// Handle to one submitted request: the task lane plus the per-lane
/// request id assigned by the batcher.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestId {
    pub task: usize,
    pub id: u64,
}

/// One answered request.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub logits: Vec<f32>,
}

/// Deterministic per-request trace id, minted at admission and derivable
/// from any [`RequestId`] — no extra field has to ride through the
/// batcher.  Always nonzero (`0` means "uncorrelated" throughout the
/// trace layer); the task lane lives in the high bits so ids stay unique
/// across lanes.
pub fn trace_of(task: usize, id: u64) -> u64 {
    ((task as u64 + 1) << 48) + id + 1
}

/// Maximum in-place retries of a transient (panic-class) batch failure.
/// Backoff doubles per retry (jittered exponential).
const MAX_TRANSIENT_RETRIES: u32 = 2;

/// Router tuning knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// worker threads shared by all tasks
    pub workers: usize,
    /// maximum time a partial batch may wait before being flushed
    pub max_wait: Duration,
    /// flusher wake-up cadence (effective tail deadline is
    /// `max_wait + flush_tick`)
    pub flush_tick: Duration,
    /// intra-batch row parallelism applied to every engine
    /// (`--threads`/`SAC_THREADS`); `None` keeps each engine's own
    /// setting.  Slab work runs on the process-wide slab pool, not the
    /// router's worker pool, and results are bit-identical at any value.
    pub kernel_threads: Option<usize>,
    /// per-request deadline: a request still unexecuted this long after
    /// submit is shed (resolved as failed with a `shed:` error) instead
    /// of run — bounded-latency rejection under overload.  `None` never
    /// sheds.
    pub deadline: Option<Duration>,
    /// bounded admission queue: reject new submits while roughly this
    /// many accepted requests are unresolved (queued + in flight).
    /// `None` admits without bound.
    pub max_queue: Option<usize>,
    /// run the canary probe set through a lane's engine every this many
    /// completed batches; `0` disables drift detection entirely (no
    /// probes, no fallback engines are built)
    pub canary_every: u64,
    /// health-state machine knobs (window, envelopes, patience)
    pub health: HealthConfig,
    /// retry a transient (panic-class) batch failure in place, after a
    /// jittered exponential backoff, up to `MAX_TRANSIENT_RETRIES` times
    pub retry_transient: bool,
    /// base backoff before the first transient retry (doubles per retry)
    pub retry_backoff: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            workers: crate::util::pool::default_threads().min(8),
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(500),
            kernel_threads: None,
            deadline: None,
            max_queue: None,
            canary_every: 0,
            health: HealthConfig::default(),
            retry_transient: true,
            retry_backoff: Duration::from_micros(500),
        }
    }
}

/// Rebuild recipe for a quarantined lane: produce a fresh [`Engine`]
/// (same batch shape) from the *current* provider state — re-calibration
/// under drift.  Runs on a router worker, outside any lock.
pub type RebuildFn = Arc<dyn Fn() -> Result<Engine> + Send + Sync>;

/// Everything needed to host one task lane.  [`Router::new`] wraps plain
/// `(name, engine)` pairs; [`Router::with_specs`] exposes the full
/// self-healing surface.
pub struct LaneSpec {
    pub name: String,
    pub engine: Engine,
    /// golden probe set: feature rows plus the expected argmax labels
    /// (from a nominal reference engine).  `None` with canaries enabled
    /// self-captures labels from the lane's own engine at build time.
    pub probe: Option<(Vec<Vec<f32>>, Vec<usize>)>,
    /// engine rebuild recipe for the quarantine path; `None` rebuilds a
    /// clean native executable from the lane's own net (same mode)
    pub rebuild: Option<RebuildFn>,
}

impl LaneSpec {
    pub fn new(name: impl Into<String>, engine: Engine) -> LaneSpec {
        LaneSpec {
            name: name.into(),
            engine,
            probe: None,
            rebuild: None,
        }
    }

    pub fn with_probe(mut self, rows: Vec<Vec<f32>>, labels: Vec<usize>) -> LaneSpec {
        self.probe = Some((rows, labels));
        self
    }

    pub fn with_rebuild(mut self, rebuild: RebuildFn) -> LaneSpec {
        self.rebuild = Some(rebuild);
        self
    }
}

/// Per-task batcher plus the enqueue timestamp of every pending request
/// (front = oldest), driving the deadline flush and queue-side shedding.
struct LaneBatcher {
    batcher: DynamicBatcher,
    enqueued_at: VecDeque<Instant>,
}

impl LaneBatcher {
    fn new(batch_size: usize, dim: usize) -> LaneBatcher {
        LaneBatcher {
            batcher: DynamicBatcher::new(batch_size, dim),
            enqueued_at: VecDeque::new(),
        }
    }

    fn submit(&mut self, features: Vec<f32>) -> u64 {
        let id = self.batcher.submit(features);
        self.enqueued_at.push_back(Instant::now());
        id
    }

    /// Drop timestamps of requests that left the queue (always popped from
    /// the front — the batcher materializes in FIFO order).
    fn trim(&mut self) {
        while self.enqueued_at.len() > self.batcher.pending() {
            self.enqueued_at.pop_front();
        }
    }

    fn pop_fulls(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(b) = self.batcher.pop_full() {
            out.push(b);
        }
        self.trim();
        out
    }

    fn flush_all(&mut self) -> Vec<Batch> {
        let out = self.batcher.flush();
        self.enqueued_at.clear();
        out
    }

    /// Full batches always; the partial tail too once its oldest request
    /// has waited `max_wait`.  The second return is `true` when the
    /// deadline fired (a partial batch was force-materialized).
    fn take_overdue(&mut self, max_wait: Duration) -> (Vec<Batch>, bool) {
        let mut out = self.pop_fulls();
        let mut deadline_fired = false;
        if self.batcher.pending() > 0 {
            if let Some(t0) = self.enqueued_at.front() {
                if t0.elapsed() >= max_wait {
                    out.extend(self.flush_all());
                    deadline_fired = true;
                }
            }
        }
        (out, deadline_fired)
    }

    /// Pop the ids of queued requests already past `deadline` off the
    /// front (FIFO: the front is always the oldest).
    fn shed_overdue(&mut self, deadline: Duration) -> Vec<u64> {
        let mut shed = Vec::new();
        while self
            .enqueued_at
            .front()
            .is_some_and(|t0| t0.elapsed() >= deadline)
        {
            match self.batcher.shed_front() {
                Some(id) => {
                    self.enqueued_at.pop_front();
                    shed.push(id);
                }
                None => break,
            }
        }
        shed
    }

    fn pending(&self) -> usize {
        self.batcher.pending()
    }
}

/// Per-lane outcome store: computed responses plus the ids of requests
/// whose batch failed in the engine (so waiters get the error immediately
/// instead of a misleading timeout).
#[derive(Default)]
struct LaneResults {
    ready: HashMap<u64, Response>,
    failed: HashMap<u64, String>,
}

/// The canary probe set: one pre-materialized batch of golden rows plus
/// the expected argmax labels.
struct ProbeSet {
    batch: Batch,
    labels: Vec<usize>,
}

struct Lane {
    name: String,
    /// the serving engine; read-locked per batch, write-locked only to
    /// swap in a rebuilt engine (which thereby drains in-flight readers)
    engine: RwLock<Engine>,
    /// batch shape, cached so `submit` never touches the engine lock
    /// (rebuilds preserve it — enforced before every swap)
    dim: usize,
    batch_size: usize,
    /// scalar exact-cell failover engine serving while quarantined
    /// (built only when canaries are enabled)
    fallback: Option<Engine>,
    use_fallback: AtomicBool,
    rebuild: Option<RebuildFn>,
    probe: Option<ProbeSet>,
    health: Mutex<LaneHealth>,
    /// batches resolved on this lane (canary cadence clock)
    batches_done: AtomicU64,
    queue: Mutex<LaneBatcher>,
    /// Cheap idle hint so the flusher skips lanes without taking the
    /// queue lock; only ever written while holding the queue lock.
    has_pending: AtomicBool,
    results: Mutex<LaneResults>,
    results_cv: Condvar,
    metrics: Mutex<ServeMetrics>,
    /// request-latency exemplars (bucket → trace id), recorded at
    /// delivery while tracing is enabled; kept outside `ServeMetrics`
    /// so metric merges stay a pure integer law
    exemplars: Mutex<ExemplarSet>,
}

/// Self-healing counters (telemetry `sac-metrics/v3` health block).
#[derive(Default)]
struct HealthCounters {
    probes: AtomicU64,
    probe_disagreements: AtomicU64,
    to_degraded: AtomicU64,
    to_quarantined: AtomicU64,
    recovered: AtomicU64,
    rebuilds: AtomicU64,
    rebuild_ns_total: AtomicU64,
    shed_deadline: AtomicU64,
    shed_queue: AtomicU64,
    requeues: AtomicU64,
    retries: AtomicU64,
}

struct Shared {
    lanes: Vec<Lane>,
    cfg: RouterConfig,
    /// batches enqueued on the pool or executing
    inflight: Mutex<usize>,
    idle_cv: Condvar,
    shutdown: AtomicBool,
    failures: Mutex<Vec<String>>,
    /// set by `submit` when a lane gains a pending partial batch; the
    /// flusher parks on this when every lane is empty instead of
    /// tick-polling an idle router
    flush_signal: Mutex<bool>,
    flush_cv: Condvar,
    /// lock-free pipeline stage counters (telemetry, DESIGN.md §9)
    stages: StageCounters,
    /// self-healing counters (telemetry, DESIGN.md §11)
    health: HealthCounters,
    /// health-state transition timeline (CI artifact surface)
    timeline: Mutex<Vec<HealthEvent>>,
}

/// The multi-task serving router.  See the module docs for the dataflow.
pub struct Router {
    shared: Arc<Shared>,
    pool: WorkerPool,
    pool_handle: PoolHandle,
    flusher: Option<thread::JoinHandle<()>>,
}

impl Router {
    /// Host one lane per `(name, engine)` task behind `cfg.workers` shared
    /// workers, and start the deadline flusher.
    pub fn new(cfg: RouterConfig, tasks: Vec<(String, Engine)>) -> Router {
        Router::with_specs(
            cfg,
            tasks
                .into_iter()
                .map(|(name, engine)| LaneSpec::new(name, engine))
                .collect(),
        )
    }

    /// [`Router::new`] with the full self-healing lane surface: golden
    /// probes and rebuild recipes per lane.
    pub fn with_specs(cfg: RouterConfig, specs: Vec<LaneSpec>) -> Router {
        assert!(!specs.is_empty(), "router needs at least one task");
        let canary_on = cfg.canary_every > 0;
        let lanes = specs
            .into_iter()
            .map(|spec| {
                let engine = match cfg.kernel_threads {
                    Some(n) => spec.engine.with_par_threads(n),
                    None => spec.engine,
                };
                let dim = engine.dim;
                let batch_size = engine.batch_size;
                let fallback = if canary_on { scalar_fallback(&engine) } else { None };
                let probe = if canary_on {
                    build_probe(&engine, spec.probe)
                } else {
                    None
                };
                let rebuild = spec.rebuild.or_else(|| {
                    canary_on.then(|| default_rebuild(&engine))
                });
                let queue = Mutex::new(LaneBatcher::new(batch_size, dim));
                Lane {
                    name: spec.name,
                    engine: RwLock::new(engine),
                    dim,
                    batch_size,
                    fallback,
                    use_fallback: AtomicBool::new(false),
                    rebuild,
                    probe,
                    health: Mutex::new(LaneHealth::new(cfg.health)),
                    batches_done: AtomicU64::new(0),
                    queue,
                    has_pending: AtomicBool::new(false),
                    results: Mutex::new(LaneResults::default()),
                    results_cv: Condvar::new(),
                    metrics: Mutex::new(ServeMetrics::default()),
                    exemplars: Mutex::new(ExemplarSet::default()),
                }
            })
            .collect();
        let shared = Arc::new(Shared {
            lanes,
            cfg: cfg.clone(),
            inflight: Mutex::new(0),
            idle_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            failures: Mutex::new(Vec::new()),
            flush_signal: Mutex::new(false),
            flush_cv: Condvar::new(),
            stages: StageCounters::default(),
            health: HealthCounters::default(),
            timeline: Mutex::new(Vec::new()),
        });
        let pool = WorkerPool::new(cfg.workers);
        let pool_handle = pool.handle();

        let flusher = {
            let shared = Arc::clone(&shared);
            let handle = pool.handle();
            let max_wait = cfg.max_wait;
            let tick = cfg.flush_tick.max(Duration::from_micros(50));
            thread::Builder::new()
                .name("sac-flusher".into())
                .spawn(move || loop {
                    // Park while idle: zero wakeups on a quiet router.
                    // `submit` raises flush_signal when a lane gains a
                    // pending partial batch; a bounded wait keeps the
                    // shutdown latency small even if a notify is missed.
                    {
                        let mut sig = shared.flush_signal.lock().unwrap();
                        while !*sig && !shared.shutdown.load(Ordering::SeqCst) {
                            let (guard, _) = shared
                                .flush_cv
                                .wait_timeout(sig, Duration::from_millis(50))
                                .unwrap();
                            sig = guard;
                        }
                    }
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    // Active phase: tick-scan until every lane is empty.
                    loop {
                        if shared.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        // clear the signal *before* scanning: a submit
                        // racing with the scan re-raises it, so the park
                        // loop above re-enters the active phase immediately
                        *shared.flush_signal.lock().unwrap() = false;
                        let mut any_pending = false;
                        {
                            let _scan = trace::span("router.flush");
                            for li in 0..shared.lanes.len() {
                                let lane = &shared.lanes[li];
                                // idle lanes cost one atomic load, not a lock
                                // acquisition contending with submitters
                                if !lane.has_pending.load(Ordering::SeqCst) {
                                    continue;
                                }
                                // enqueue under the lane lock: a batch is never
                                // "in limbo" outside both the queue and the
                                // inflight counter (drain correctness).
                                let mut q = lane.queue.lock().unwrap();
                                if let Some(dl) = shared.cfg.deadline {
                                    let shed = q.shed_overdue(dl);
                                    resolve_shed(&shared, li, &shed, dl);
                                }
                                let (batches, deadline_fired) = q.take_overdue(max_wait);
                                if deadline_fired {
                                    StageCounters::bump(&shared.stages.deadline_flushes);
                                }
                                for b in batches {
                                    enqueue_batch(&shared, &handle, li, b);
                                }
                                let still = q.pending() > 0;
                                lane.has_pending.store(still, Ordering::SeqCst);
                                any_pending |= still;
                            }
                        }
                        if !any_pending {
                            break; // back to the park loop
                        }
                        thread::sleep(tick);
                    }
                })
                .expect("spawn flusher thread")
        };

        Router {
            shared,
            pool,
            pool_handle,
            flusher: Some(flusher),
        }
    }

    /// Number of hosted tasks.
    pub fn n_tasks(&self) -> usize {
        self.shared.lanes.len()
    }

    /// Task names in lane order.
    pub fn task_names(&self) -> Vec<&str> {
        self.shared.lanes.iter().map(|l| l.name.as_str()).collect()
    }

    /// Lane index of a task name.
    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.shared.lanes.iter().position(|l| l.name == name)
    }

    /// Submit one request to a task lane; returns its handle.  The batch
    /// dispatches immediately when full, otherwise within
    /// `max_wait + flush_tick`.  Rejects (without side effects) when the
    /// router is shut down or the admission queue is full.
    pub fn submit(&self, task: usize, features: Vec<f32>) -> Result<RequestId> {
        let mut span = trace::span("router.submit");
        if self.shared.shutdown.load(Ordering::SeqCst) {
            StageCounters::bump(&self.shared.stages.rejected);
            bail!("router is shut down");
        }
        let lane = match self.shared.lanes.get(task) {
            Some(lane) => lane,
            None => {
                StageCounters::bump(&self.shared.stages.rejected);
                bail!("no task lane #{task}");
            }
        };
        if features.len() != lane.dim {
            StageCounters::bump(&self.shared.stages.rejected);
            bail!(
                "task {:?}: feature dim {} != {}",
                lane.name,
                features.len(),
                lane.dim
            );
        }
        let mut q = lane.queue.lock().unwrap();
        if let Some(maxq) = self.shared.cfg.max_queue {
            // approximate unresolved depth: materialized batches in
            // flight (router-wide) × this lane's batch size, plus this
            // lane's queue.  Coarse, but bounds queue growth under storm.
            let backlog =
                *self.shared.inflight.lock().unwrap() * lane.batch_size + q.pending();
            if backlog >= maxq {
                self.shared
                    .health
                    .shed_queue
                    .fetch_add(1, Ordering::Relaxed);
                StageCounters::bump(&self.shared.stages.rejected);
                bail!(
                    "task {:?}: shed: admission queue full ({backlog} unresolved >= {maxq})",
                    lane.name
                );
            }
        }
        StageCounters::bump(&self.shared.stages.submitted);
        let id = q.submit(features);
        // Correlate the admission span with the request it just minted.
        // The id exists only now — after the span opened — so the span
        // takes the trace id explicitly rather than via a TLS scope
        // (which would unwind before the span drops).
        span.set_trace(trace_of(task, id));
        for b in q.pop_fulls() {
            enqueue_batch(&self.shared, &self.pool_handle, task, b);
        }
        let pending = q.pending() > 0;
        lane.has_pending.store(pending, Ordering::SeqCst);
        drop(q);
        if pending {
            // wake the parked flusher so the deadline clock on this
            // partial batch is serviced
            let mut sig = self.shared.flush_signal.lock().unwrap();
            if !*sig {
                *sig = true;
                self.shared.flush_cv.notify_one();
            }
        }
        Ok(RequestId { task, id })
    }

    /// Submit by task name.
    pub fn submit_to(&self, name: &str, features: Vec<f32>) -> Result<RequestId> {
        let task = self
            .task_index(name)
            .ok_or_else(|| anyhow!("no task named {name:?}"))?;
        self.submit(task, features)
    }

    /// Take a response if it is ready (removes it — each response is
    /// delivered at most once).  `Ok(None)` means *not ready yet*; an
    /// engine failure for this request's batch is consumed and returned
    /// as `Err`, so pollers terminate instead of spinning forever.
    pub fn try_take(&self, req: RequestId) -> Result<Option<Response>> {
        let lane = self
            .shared
            .lanes
            .get(req.task)
            .ok_or_else(|| anyhow!("no task lane #{}", req.task))?;
        let mut res = lane.results.lock().unwrap();
        if let Some(r) = res.ready.remove(&req.id) {
            StageCounters::bump(&self.shared.stages.responses_taken);
            return Ok(Some(r));
        }
        if let Some(msg) = res.failed.remove(&req.id) {
            bail!("request {}/{} failed: {msg}", lane.name, req.id);
        }
        Ok(None)
    }

    /// Block until the response arrives (relies on the deadline flusher for
    /// partial batches) or `timeout` elapses.  Reports an engine failure
    /// for this request's batch immediately instead of timing out.
    pub fn wait(&self, req: RequestId, timeout: Duration) -> Result<Response> {
        let lane = self
            .shared
            .lanes
            .get(req.task)
            .ok_or_else(|| anyhow!("no task lane #{}", req.task))?;
        let deadline = Instant::now() + timeout;
        let mut res = lane.results.lock().unwrap();
        loop {
            if let Some(r) = res.ready.remove(&req.id) {
                StageCounters::bump(&self.shared.stages.responses_taken);
                return Ok(r);
            }
            if let Some(msg) = res.failed.remove(&req.id) {
                bail!("request {}/{} failed: {msg}", lane.name, req.id);
            }
            let now = Instant::now();
            if now >= deadline {
                StageCounters::bump(&self.shared.stages.wait_timeouts);
                bail!(
                    "request {}/{} timed out after {timeout:?}",
                    lane.name,
                    req.id
                );
            }
            let (guard, _) = lane
                .results_cv
                .wait_timeout(res, deadline - now)
                .unwrap();
            res = guard;
        }
    }

    /// Force-materialize every pending partial batch right now.
    pub fn flush(&self) {
        for (li, lane) in self.shared.lanes.iter().enumerate() {
            let mut q = lane.queue.lock().unwrap();
            for b in q.flush_all() {
                enqueue_batch(&self.shared, &self.pool_handle, li, b);
            }
            lane.has_pending.store(false, Ordering::SeqCst);
        }
    }

    /// Flush everything and wait until no batch is queued or executing.
    /// Fails on timeout or if any worker reported a failure.
    pub fn drain(&self, timeout: Duration) -> Result<()> {
        self.flush();
        let deadline = Instant::now() + timeout;
        let mut n = self.shared.inflight.lock().unwrap();
        while *n > 0 {
            if Instant::now() >= deadline {
                bail!("drain timed out with {} batch(es) in flight", *n);
            }
            let (guard, _) = self
                .shared
                .idle_cv
                .wait_timeout(n, Duration::from_millis(20))
                .unwrap();
            n = guard;
        }
        drop(n);
        let fails = self.shared.failures.lock().unwrap();
        if !fails.is_empty() {
            bail!("{} worker failure(s): {}", fails.len(), fails.join("; "));
        }
        Ok(())
    }

    /// Requests still waiting in lane queues (not yet materialized).
    pub fn pending(&self) -> usize {
        self.shared
            .lanes
            .iter()
            .map(|l| l.queue.lock().unwrap().pending())
            .sum()
    }

    /// Responses computed but not yet taken.
    pub fn ready(&self) -> usize {
        self.shared
            .lanes
            .iter()
            .map(|l| l.results.lock().unwrap().ready.len())
            .sum()
    }

    /// Snapshot of one task's metrics.
    pub fn metrics(&self, task: usize) -> ServeMetrics {
        self.shared.lanes[task].metrics.lock().unwrap().clone()
    }

    /// Metrics aggregated across every task lane.
    pub fn aggregate_metrics(&self) -> ServeMetrics {
        let mut total = ServeMetrics::default();
        for lane in &self.shared.lanes {
            total.merge(&lane.metrics.lock().unwrap());
        }
        total
    }

    /// Copy of the lock-free pipeline stage counters.
    pub fn stages(&self) -> StageSnapshot {
        self.shared.stages.snapshot()
    }

    /// Current health state of every lane, in lane order.
    pub fn health_states(&self) -> Vec<(String, HealthState)> {
        self.shared
            .lanes
            .iter()
            .map(|l| (l.name.clone(), l.health.lock().unwrap().state()))
            .collect()
    }

    /// Health-state transition timeline so far (CI artifact surface).
    pub fn health_timeline(&self) -> Vec<HealthEvent> {
        self.shared.timeline.lock().unwrap().clone()
    }

    /// The `sac-metrics/v3` health block: lane states plus every
    /// self-healing counter, including the worker pool's respawn count.
    pub fn health_snapshot(&self) -> HealthSnapshot {
        let h = &self.shared.health;
        HealthSnapshot {
            lanes: self
                .shared
                .lanes
                .iter()
                .map(|l| {
                    (
                        l.name.clone(),
                        l.health.lock().unwrap().state().name().to_string(),
                    )
                })
                .collect(),
            probes: h.probes.load(Ordering::Relaxed),
            probe_disagreements: h.probe_disagreements.load(Ordering::Relaxed),
            to_degraded: h.to_degraded.load(Ordering::Relaxed),
            to_quarantined: h.to_quarantined.load(Ordering::Relaxed),
            recovered: h.recovered.load(Ordering::Relaxed),
            rebuilds: h.rebuilds.load(Ordering::Relaxed),
            rebuild_ns_total: h.rebuild_ns_total.load(Ordering::Relaxed),
            shed_deadline: h.shed_deadline.load(Ordering::Relaxed),
            shed_queue: h.shed_queue.load(Ordering::Relaxed),
            requeues: h.requeues.load(Ordering::Relaxed),
            retries: h.retries.load(Ordering::Relaxed),
            respawns: self.pool.respawns(),
        }
    }

    /// Full telemetry snapshot under `name`: stage counters, per-lane
    /// and aggregate metrics, the health block, and the trace-sink stats
    /// at capture time.
    pub fn metrics_snapshot(&self, name: &str) -> MetricsSnapshot {
        let lanes: Vec<(String, ServeMetrics)> = self
            .shared
            .lanes
            .iter()
            .map(|l| (l.name.clone(), l.metrics.lock().unwrap().clone()))
            .collect();
        let mut aggregate = ServeMetrics::default();
        for (_, m) in &lanes {
            aggregate.merge(m);
        }
        MetricsSnapshot {
            name: name.to_string(),
            stages: self.shared.stages.snapshot(),
            lanes,
            aggregate,
            kernel: crate::coordinator::telemetry::kernel_stats(),
            trace: trace::stats(),
            health: self.health_snapshot(),
            exemplars: self.exemplar_sets(),
            signal: self.signal_stats(),
        }
    }

    /// Per-lane request-latency exemplars, in lane order (empty sets
    /// while tracing is disabled).
    pub fn exemplar_sets(&self) -> Vec<(String, ExemplarSet)> {
        self.shared
            .lanes
            .iter()
            .map(|l| (l.name.clone(), l.exemplars.lock().unwrap().clone()))
            .collect()
    }

    /// Per-lane analog signal-health stats, in lane order.  Engines
    /// without a batched kernel (scalar mode) report all-zero stats.
    pub fn signal_stats(&self) -> Vec<(String, SignalHealthStats)> {
        self.shared
            .lanes
            .iter()
            .map(|l| {
                let stats = l
                    .engine
                    .read()
                    .unwrap()
                    .signal_health()
                    .unwrap_or_default();
                (l.name.clone(), stats)
            })
            .collect()
    }

    /// Worker failure messages collected so far (normally empty).
    pub fn failures(&self) -> Vec<String> {
        self.shared.failures.lock().unwrap().clone()
    }

    /// Worker threads serving this router.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Stop accepting new submissions (idempotent).  Work already
    /// accepted still completes: the deadline flusher exits on shutdown,
    /// so pending partial batches are materialized here, and computed
    /// responses remain takeable via
    /// [`Router::try_take`]/[`Router::wait`].  A submit racing this call
    /// may still land in a lane queue just after the final flush — call
    /// [`Router::drain`] for a clean handoff.  Dropping the router
    /// implies shutdown.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.flush_cv.notify_all();
        // The flusher is gone after the flag flips; materialize whatever
        // is already queued so accepted requests are not stranded.
        self.flush();
    }

    /// Whether [`Router::shutdown`] has been called.
    pub fn is_shut_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.flush_cv.notify_all(); // wake a parked flusher
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        // WorkerPool's Drop drains every queued batch before joining, so
        // accepted work still completes; unmaterialized queue tails are
        // dropped (call `drain` first for a clean shutdown).
    }
}

/// Build the scalar exact-cell failover engine for a lane (the bottom of
/// the `ExecMode` fallback chain: no grids, no calibration drift).
fn scalar_fallback(engine: &Engine) -> Option<Engine> {
    use crate::runtime::{ExecMode, Executable};
    let exe =
        Executable::native_mlp_with_mode(&engine.net, engine.batch_size, ExecMode::Scalar).ok()?;
    Engine::from_parts(engine.net.clone(), exe).ok()
}

/// Default rebuild recipe: a clean native executable from the lane's own
/// net, same mode — recovers from in-memory corruption (e.g. poisoned
/// grids), though not from provider drift (supply [`LaneSpec::rebuild`]
/// to re-calibrate against the live provider).
fn default_rebuild(engine: &Engine) -> RebuildFn {
    use crate::runtime::Executable;
    let net = engine.net.clone();
    let batch_size = engine.batch_size;
    let mode = engine.mode();
    Arc::new(move || {
        let exe = Executable::native_mlp_with_mode(&net, batch_size, mode)?;
        Engine::from_parts(net.clone(), exe)
    })
}

/// Materialize the probe rows into one padded batch (ids are local to the
/// probe — probe batches never touch the results map).
fn probe_batch(rows: &[Vec<f32>], dim: usize, batch_size: usize) -> Batch {
    let mut data = vec![0.0f32; batch_size * dim];
    for (r, row) in rows.iter().enumerate() {
        data[r * dim..(r + 1) * dim].copy_from_slice(row);
    }
    Batch {
        ids: (0..rows.len() as u64).collect(),
        data,
        live: rows.len(),
    }
}

/// Assemble a lane's canary probe set.  Supplied golden rows/labels are
/// validated against the engine shape; with none supplied, deterministic
/// rows are generated and labels self-captured from the engine at build
/// time (zero false positives on a drift-free engine by construction).
/// Returns `None` (canaries off for this lane) on any mismatch.
fn build_probe(
    engine: &Engine,
    supplied: Option<(Vec<Vec<f32>>, Vec<usize>)>,
) -> Option<ProbeSet> {
    match supplied {
        Some((rows, labels)) => {
            if rows.is_empty()
                || rows.len() != labels.len()
                || rows.len() > engine.batch_size
                || rows.iter().any(|r| r.len() != engine.dim)
                || labels.iter().any(|&l| l >= engine.n_classes)
            {
                return None;
            }
            let batch = probe_batch(&rows, engine.dim, engine.batch_size);
            Some(ProbeSet { batch, labels })
        }
        None => {
            let n = engine.batch_size.min(8).max(1);
            let mut rng = Rng::new(0x5AC_CA9A);
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    (0..engine.dim)
                        .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
                        .collect()
                })
                .collect();
            let batch = probe_batch(&rows, engine.dim, engine.batch_size);
            let labels = engine
                .run_batch(&batch)
                .ok()?
                .iter()
                .map(|&(_, pred, _)| pred)
                .collect();
            Some(ProbeSet { batch, labels })
        }
    }
}

/// Resolve a set of queue-shed request ids as failed with a bounded
/// `shed:` error, so waiters terminate immediately.
fn resolve_shed(shared: &Arc<Shared>, li: usize, shed: &[u64], deadline: Duration) {
    if shed.is_empty() {
        return;
    }
    let lane = &shared.lanes[li];
    shared
        .health
        .shed_deadline
        .fetch_add(shed.len() as u64, Ordering::Relaxed);
    let mut res = lane.results.lock().unwrap();
    for &id in shed {
        res.failed.insert(
            id,
            format!("shed: deadline {deadline:?} exceeded before execution"),
        );
    }
    drop(res);
    lane.results_cv.notify_all();
}

/// Hand one materialized batch to the worker pool.  Must be called with
/// the originating lane's queue lock held (see the flusher comment).
fn enqueue_batch(shared: &Arc<Shared>, pool: &PoolHandle, li: usize, batch: Batch) {
    StageCounters::bump(&shared.stages.batches_enqueued);
    *shared.inflight.lock().unwrap() += 1;
    dispatch_batch(Arc::clone(shared), pool.clone(), li, batch, Instant::now(), 0);
}

/// Enqueue one execution attempt of a batch.  `attempt` 0 is the first
/// execution; 1 is the single supervised requeue after a worker died
/// mid-delivery.  The inflight count is held across attempts and released
/// exactly once, when the batch resolves.
fn dispatch_batch(
    shared: Arc<Shared>,
    pool: PoolHandle,
    li: usize,
    batch: Batch,
    enqueued: Instant,
    attempt: u8,
) {
    let job_pool = pool.clone();
    pool.execute(move || {
        // Supervision: engine panics are contained below, but if this
        // worker dies anywhere *past* that containment (a poisoned lock,
        // a delivery bug), the guard's Drop requeues the in-flight batch
        // exactly once while the pool's sentinel respawns the worker; a
        // second death resolves the batch as failed.  Normal completion
        // disarms the guard.
        let mut guard = RequeueGuard {
            shared,
            pool: job_pool,
            li,
            batch: Some(batch),
            enqueued,
            attempt,
        };
        run_and_deliver(
            &guard.shared,
            li,
            guard.batch.as_ref().expect("guard holds the batch"),
            enqueued,
            attempt,
        );
        guard.batch = None; // disarm: resolved normally
        let shared = &guard.shared;
        let mut n = shared.inflight.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            shared.idle_cv.notify_all();
        }
    });
}

/// Worker-death supervision guard (see [`dispatch_batch`]).  All lock
/// accesses are fallible: this runs during unwind, and a double panic
/// would abort the process.
struct RequeueGuard {
    shared: Arc<Shared>,
    pool: PoolHandle,
    li: usize,
    batch: Option<Batch>,
    enqueued: Instant,
    attempt: u8,
}

impl Drop for RequeueGuard {
    fn drop(&mut self) {
        let Some(batch) = self.batch.take() else { return };
        // reached only while unwinding — normal completion disarmed us
        if self.attempt == 0 {
            self.shared.health.requeues.fetch_add(1, Ordering::SeqCst);
            dispatch_batch(
                Arc::clone(&self.shared),
                self.pool.clone(),
                self.li,
                batch,
                self.enqueued,
                1,
            );
            return;
        }
        // second death on the same batch: resolve as failed and give up
        let lane = &self.shared.lanes[self.li];
        StageCounters::bump(&self.shared.stages.batches_failed);
        if let Ok(mut res) = lane.results.lock() {
            for &id in &batch.ids {
                res.failed
                    .insert(id, "worker died twice executing this batch".into());
            }
        }
        lane.results_cv.notify_all();
        if let Ok(mut fails) = self.shared.failures.lock() {
            fails.push(format!(
                "lane {:?}: worker died twice on one batch",
                lane.name
            ));
        }
        if let Ok(mut n) = self.shared.inflight.lock() {
            *n -= 1;
            if *n == 0 {
                self.shared.idle_cv.notify_all();
            }
        }
    }
}

/// Whether a batch failure came from a contained engine panic (the
/// transient class eligible for in-place retry).
fn is_panic_class(e: &anyhow::Error) -> bool {
    e.to_string().contains("panicked")
}

/// One engine execution with panic containment.  Quarantined lanes are
/// served by the scalar fallback when one exists; otherwise the (possibly
/// degraded) live engine keeps serving until the rebuild swap.
fn run_engine_once(lane: &Lane, batch: &Batch) -> Result<Vec<Answer>> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if lane.use_fallback.load(Ordering::SeqCst) {
            if let Some(fb) = &lane.fallback {
                return fb.run_batch(batch);
            }
        }
        // read lock: concurrent with other batches; a panic under a read
        // guard does not poison the RwLock (only writers poison)
        lane.engine.read().unwrap().run_batch(batch)
    }))
    .unwrap_or_else(|p| {
        let msg = p
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| p.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "engine panicked".to_string());
        Err(anyhow!("engine panicked: {msg}"))
    })
}

/// The worker job body: deadline shed, engine execution with transient
/// retry, result delivery, and the canary cadence.
fn run_and_deliver(shared: &Arc<Shared>, li: usize, batch: &Batch, enqueued: Instant, attempt: u8) {
    let lane = &shared.lanes[li];
    let cfg = &shared.cfg;
    // Correlate everything this worker does for the batch — engine run,
    // slab spans, delivery — with the batch's first request.  A batch is
    // one execution unit, so one representative trace id per batch keeps
    // the ring usage bounded; the remaining requests still correlate via
    // exemplars (`trace_of` is derivable from any RequestId).
    let trace_id = batch.ids.first().map_or(0, |&id| trace_of(li, id));
    let _corr = trace::correlate(trace_id);
    // Deadline-aware shedding at execution time: every request in this
    // batch was submitted before the batch materialized, so each has
    // waited at least `enqueued.elapsed()` — if the batch itself is past
    // deadline, every one of its requests is too.  Reject with bounded
    // latency instead of computing answers nobody is waiting for.
    if let Some(dl) = cfg.deadline {
        if enqueued.elapsed() > dl {
            shared
                .health
                .shed_deadline
                .fetch_add(batch.live as u64, Ordering::Relaxed);
            StageCounters::bump(&shared.stages.batches_failed);
            let mut res = lane.results.lock().unwrap();
            for &id in &batch.ids {
                res.failed.insert(
                    id,
                    format!("shed: deadline {dl:?} exceeded before execution"),
                );
            }
            drop(res);
            lane.results_cv.notify_all();
            return;
        }
    }
    let t0 = Instant::now();
    let batch_span = trace::span("router.batch");
    let mut outcome = run_engine_once(lane, batch);
    // Transient (panic-class) failures get in-place retries under a
    // jittered exponential backoff: injected `panicking_window` faults
    // and real transient panics recover here; deterministic failures
    // exhaust the retries and fall through to the failure path.
    if cfg.retry_transient && attempt == 0 {
        let mut backoff = cfg.retry_backoff.max(Duration::from_micros(50));
        // deterministic jitter, seeded off the batch identity
        let mut rng = Rng::new(0x5AC7_E772 ^ batch.ids.first().copied().unwrap_or(0));
        let mut tries = 0u32;
        while tries < MAX_TRANSIENT_RETRIES
            && matches!(&outcome, Err(e) if is_panic_class(e))
        {
            shared.health.retries.fetch_add(1, Ordering::Relaxed);
            let jitter = Duration::from_micros(rng.below(backoff.as_micros().max(1) as usize) as u64);
            thread::sleep(backoff + jitter);
            backoff = backoff.saturating_mul(2);
            tries += 1;
            outcome = run_engine_once(lane, batch);
        }
    }
    drop(batch_span);
    match outcome {
        Ok(rows) => {
            StageCounters::bump(&shared.stages.batches_completed);
            shared
                .stages
                .rows_delivered
                .fetch_add(batch.live as u64, std::sync::atomic::Ordering::Relaxed);
            let dt = t0.elapsed();
            lane.metrics.lock().unwrap().record_batch(batch.live, dt);
            // Exemplars only make sense while tracing is on — there is
            // no span tree to follow otherwise, and the disabled path
            // must stay one atomic load.
            if trace::enabled() {
                let ns = dt.as_nanos().min(u128::from(u64::MAX)) as u64;
                let mut ex = lane.exemplars.lock().unwrap();
                for &id in &batch.ids {
                    ex.observe(ns, trace_of(li, id));
                }
            }
            let _deliver = trace::span("router.deliver");
            let mut res = lane.results.lock().unwrap();
            for (id, pred, logits) in rows {
                if res.ready.insert(id, Response { id, pred, logits }).is_some() {
                    shared
                        .failures
                        .lock()
                        .unwrap()
                        .push(format!("duplicate response id {id} on lane {li}"));
                }
            }
            drop(res);
            lane.results_cv.notify_all();
        }
        Err(e) => {
            StageCounters::bump(&shared.stages.batches_failed);
            // resolve every request of the failed batch so waiters get
            // the engine error immediately, not a timeout
            let msg = format!("{e:#}");
            let mut res = lane.results.lock().unwrap();
            for &id in &batch.ids {
                res.failed.insert(id, msg.clone());
            }
            drop(res);
            shared
                .failures
                .lock()
                .unwrap()
                .push(format!("lane {:?}: {msg}", lane.name));
            lane.results_cv.notify_all();
        }
    }
    // canary cadence: drift detection observes failures too (a lane that
    // can only fail must trip quarantine, not hide from it)
    let done = lane.batches_done.fetch_add(1, Ordering::SeqCst) + 1;
    if cfg.canary_every > 0 && done % cfg.canary_every == 0 {
        run_canary(shared, li, done);
    }
}

/// Thread the lane's golden probe rows through the live engine and feed
/// the disagreement fraction to the health-state machine, escalating to
/// quarantine + rebuild when the windowed verdict leaves the paper
/// envelope.  Runs inline on a worker; the healthy-path cost is gated in
/// `benches/hotpath.rs` (hot spot 11).
fn run_canary(shared: &Arc<Shared>, li: usize, at_batch: u64) {
    let lane = &shared.lanes[li];
    let Some(probe) = &lane.probe else { return };
    if lane.use_fallback.load(Ordering::SeqCst) {
        return; // already quarantined and failed over
    }
    let _span = trace::span("router.canary");
    let n = probe.labels.len();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        lane.engine.read().unwrap().run_batch(&probe.batch)
    }));
    let disagree = match &outcome {
        Ok(Ok(answers)) => answers
            .iter()
            .zip(&probe.labels)
            .filter(|((_, pred, _), &want)| *pred != want)
            .count(),
        // an erroring or panicking engine disagrees with everything
        _ => n,
    };
    shared.health.probes.fetch_add(n as u64, Ordering::Relaxed);
    shared
        .health
        .probe_disagreements
        .fetch_add(disagree as u64, Ordering::Relaxed);
    let frac = disagree as f64 / n.max(1) as f64;
    // Analog signal health rides the same verdict scale as canary
    // disagreement: a lane whose kernel reports saturation creep or
    // rising exact-cell fallbacks degrades *before* probe agreement
    // breaks (DESIGN.md §12).  With signal health disabled (the
    // default) the score is exactly 0 and this is the identity.
    let signal_score = lane
        .engine
        .read()
        .unwrap()
        .signal_health()
        .map_or(0.0, |s| s.score());
    let (events, quarantined_now) = {
        let mut h = lane.health.lock().unwrap();
        let mut from = h.state();
        let entered = h.observe(frac.max(signal_score));
        let mut events = Vec::new();
        let mut quarantined_now = false;
        for to in entered {
            match to {
                HealthState::Degraded => {
                    shared.health.to_degraded.fetch_add(1, Ordering::Relaxed);
                }
                HealthState::Quarantined => {
                    shared.health.to_quarantined.fetch_add(1, Ordering::Relaxed);
                    quarantined_now = true;
                }
                HealthState::Healthy => {
                    shared.health.recovered.fetch_add(1, Ordering::Relaxed);
                }
            }
            events.push(HealthEvent {
                lane: lane.name.clone(),
                from,
                to,
                at_batch,
            });
            from = to;
        }
        (events, quarantined_now)
    };
    if !events.is_empty() {
        shared.timeline.lock().unwrap().extend(events);
    }
    if quarantined_now {
        quarantine_and_rebuild(shared, li, at_batch);
    }
}

/// The quarantine path: fail traffic over to the scalar fallback, rebuild
/// the engine from the current provider (re-calibration under drift),
/// verify the rebuilt engine against the golden probes, and swap it in
/// under the write lock — which drains in-flight readers first.  Any
/// rebuild failure leaves the lane quarantined on the fallback and is
/// surfaced via [`Router::failures`].
fn quarantine_and_rebuild(shared: &Arc<Shared>, li: usize, at_batch: u64) {
    let lane = &shared.lanes[li];
    let _span = trace::span("router.rebuild");
    if lane.fallback.is_some() {
        lane.use_fallback.store(true, Ordering::SeqCst);
    }
    let Some(rebuild) = &lane.rebuild else { return };
    let t0 = Instant::now();
    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| rebuild()))
        .unwrap_or_else(|_| Err(anyhow!("rebuild panicked")));
    shared.health.rebuilds.fetch_add(1, Ordering::Relaxed);
    shared
        .health
        .rebuild_ns_total
        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    let new_engine = match built {
        Ok(e) if e.dim == lane.dim && e.batch_size == lane.batch_size => {
            match shared.cfg.kernel_threads {
                Some(n) => e.with_par_threads(n),
                None => e,
            }
        }
        Ok(e) => {
            shared.failures.lock().unwrap().push(format!(
                "lane {:?}: rebuilt engine shape mismatch (dim {}, batch {})",
                lane.name, e.dim, e.batch_size
            ));
            return;
        }
        Err(e) => {
            shared
                .failures
                .lock()
                .unwrap()
                .push(format!("lane {:?}: rebuild failed: {e:#}", lane.name));
            return;
        }
    };
    // Post-rebuild verification: a rebuild that did not fix the drift
    // must not return to service.
    if let Some(probe) = &lane.probe {
        let n = probe.labels.len().max(1);
        match new_engine.run_batch(&probe.batch) {
            Ok(answers) => {
                let bad = answers
                    .iter()
                    .zip(&probe.labels)
                    .filter(|((_, pred, _), &want)| *pred != want)
                    .count();
                if bad as f64 / n as f64 > shared.cfg.health.degrade_above {
                    shared.failures.lock().unwrap().push(format!(
                        "lane {:?}: rebuilt engine still outside envelope ({bad}/{n} probes disagree)",
                        lane.name
                    ));
                    return;
                }
            }
            Err(e) => {
                shared.failures.lock().unwrap().push(format!(
                    "lane {:?}: rebuilt engine failed probes: {e:#}",
                    lane.name
                ));
                return;
            }
        }
    }
    // swap in: the write lock waits out in-flight readers (drain), then
    // traffic leaves the fallback
    *lane.engine.write().unwrap() = new_engine;
    lane.use_fallback.store(false, Ordering::SeqCst);
    if lane.health.lock().unwrap().rebuilt() {
        shared.health.recovered.fetch_add(1, Ordering::Relaxed);
        shared.timeline.lock().unwrap().push(HealthEvent {
            lane: lane.name.clone(),
            from: HealthState::Quarantined,
            to: HealthState::Healthy,
            at_batch,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::synthetic_engine;

    fn quick_cfg(workers: usize) -> RouterConfig {
        RouterConfig {
            workers,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            ..RouterConfig::default()
        }
    }

    fn toy_router(workers: usize) -> Router {
        Router::new(
            quick_cfg(workers),
            vec![
                ("alpha".into(), synthetic_engine(11, &[3, 4, 2], 4).unwrap()),
                ("beta".into(), synthetic_engine(12, &[2, 3, 3], 3).unwrap()),
            ],
        )
    }

    #[test]
    fn kernel_threads_config_is_bit_identical() {
        use crate::coordinator::synthetic_engine_with_mode;
        use crate::runtime::ExecMode;
        let mk = || synthetic_engine_with_mode(31, &[4, 5, 3], 32, ExecMode::Batched).unwrap();
        let serial = Router::new(
            RouterConfig {
                kernel_threads: Some(1),
                ..quick_cfg(2)
            },
            vec![("t".into(), mk())],
        );
        let par = Router::new(
            RouterConfig {
                kernel_threads: Some(4),
                ..quick_cfg(2)
            },
            vec![("t".into(), mk())],
        );
        let mut pairs = Vec::new();
        for i in 0..32 {
            let feat: Vec<f32> = (0..4).map(|j| 0.03 * (i * 4 + j) as f32 - 0.5).collect();
            pairs.push((
                serial.submit(0, feat.clone()).unwrap(),
                par.submit(0, feat).unwrap(),
            ));
        }
        serial.drain(Duration::from_secs(10)).unwrap();
        par.drain(Duration::from_secs(10)).unwrap();
        for (a, b) in pairs {
            let ra = serial.try_take(a).unwrap().expect("serial answer");
            let rb = par.try_take(b).unwrap().expect("parallel answer");
            assert_eq!(ra.pred, rb.pred);
            assert_eq!(ra.logits, rb.logits, "threaded kernel must be bit-identical");
        }
    }

    #[test]
    fn answers_every_request_exactly_once() {
        let router = toy_router(3);
        let mut reqs = Vec::new();
        for i in 0..23 {
            let t = i % 2;
            let dim = if t == 0 { 3 } else { 2 };
            reqs.push(router.submit(t, vec![0.05 * i as f32; dim]).unwrap());
        }
        router.drain(Duration::from_secs(10)).unwrap();
        for &req in &reqs {
            assert!(router.try_take(req).unwrap().is_some(), "unanswered {req:?}");
            assert!(
                router.try_take(req).unwrap().is_none(),
                "answered twice {req:?}"
            );
        }
        assert_eq!(router.ready(), 0);
        assert_eq!(router.pending(), 0);
        assert_eq!(router.aggregate_metrics().total_requests(), 23);
        assert!(router.failures().is_empty());
    }

    #[test]
    fn deadline_flush_rescues_partial_batches() {
        // one request into a batch-of-4 lane: without the deadline flusher
        // this would strand forever
        let router = toy_router(2);
        let req = router.submit(0, vec![0.3, -0.2, 0.1]).unwrap();
        let r = router.wait(req, Duration::from_secs(5)).unwrap();
        assert_eq!(r.id, req.id);
        assert_eq!(r.logits.len(), 2);
    }

    #[test]
    fn per_task_metrics_are_isolated() {
        let router = toy_router(2);
        for i in 0..8 {
            router.submit(0, vec![0.1 * i as f32; 3]).unwrap();
        }
        for i in 0..3 {
            router.submit(1, vec![0.2 * i as f32; 2]).unwrap();
        }
        router.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(router.metrics(0).total_requests(), 8);
        assert_eq!(router.metrics(1).total_requests(), 3);
        assert_eq!(router.aggregate_metrics().total_requests(), 11);
    }

    #[test]
    fn rejects_bad_task_and_bad_dim() {
        let router = toy_router(1);
        assert!(router.submit(9, vec![0.0; 3]).is_err());
        assert!(router.submit(0, vec![0.0; 5]).is_err());
        assert!(router.submit_to("nope", vec![0.0; 3]).is_err());
        assert!(router.submit_to("alpha", vec![0.0, 0.0, 0.0]).is_ok());
    }

    #[test]
    fn concurrent_submitters_all_answered() {
        let router = toy_router(4);
        let n_threads = 6;
        let per_thread = 20;
        let reqs: Vec<Vec<RequestId>> = thread::scope(|scope| {
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let router = &router;
                    scope.spawn(move || {
                        (0..per_thread)
                            .map(|k| {
                                let task = (t + k) % 2;
                                let dim = if task == 0 { 3 } else { 2 };
                                router
                                    .submit(task, vec![0.01 * (t * 100 + k) as f32; dim])
                                    .unwrap()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        router.drain(Duration::from_secs(20)).unwrap();
        let mut seen = std::collections::HashSet::new();
        for req in reqs.into_iter().flatten() {
            let r = router
                .try_take(req)
                .unwrap()
                .expect("every request answered");
            assert!(seen.insert((req.task, r.id)), "duplicate {req:?}");
        }
        assert_eq!(seen.len(), n_threads * per_thread);
        assert_eq!(
            router.aggregate_metrics().total_requests(),
            n_threads * per_thread
        );
    }

    #[test]
    fn engine_failure_is_reported_not_timed_out() {
        use crate::data::TrainedNet;
        use crate::runtime::Executable;
        let mk = |sizes: &[usize]| TrainedNet {
            task: "x".into(),
            sizes: sizes.to_vec(),
            activation: "relu".into(),
            splines: 1,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: sizes.windows(2).map(|w| vec![0.25; w[0] * w[1]]).collect(),
            biases: sizes[1..].iter().map(|&n| vec![0.0; n]).collect(),
        };
        // engine whose weights disagree with its executable's manifest
        // shapes: same input dim (passes from_parts), wrong hidden width
        // (every run_batch fails at the run_f32 shape check)
        let net = mk(&[2, 3, 2]);
        let wrong = mk(&[2, 4, 2]);
        let exe = Executable::native_mlp(&wrong, 4).unwrap();
        let engine = Engine::from_parts(net, exe).unwrap();
        let router = Router::new(quick_cfg(1), vec![("broken".into(), engine)]);
        let req = router.submit(0, vec![0.1, 0.2]).unwrap();
        let err = router.wait(req, Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("failed"), "unexpected error: {err}");
        assert!(!router.failures().is_empty());
        assert!(router.drain(Duration::from_secs(5)).is_err());
        // a polling client sees the failure too (second request, try_take)
        let req2 = router.submit(0, vec![0.3, 0.4]).unwrap();
        let t0 = std::time::Instant::now();
        loop {
            match router.try_take(req2) {
                Ok(None) => {
                    assert!(t0.elapsed() < Duration::from_secs(5), "poll never resolved");
                    thread::sleep(Duration::from_millis(1));
                }
                Ok(Some(_)) => panic!("broken engine produced a response"),
                Err(e) => {
                    assert!(e.to_string().contains("failed"), "{e}");
                    break;
                }
            }
        }
    }

    #[test]
    fn shutdown_rejects_new_submits_but_completes_accepted_work() {
        let router = toy_router(2);
        let req = router.submit(0, vec![0.1, 0.2, 0.3]).unwrap();
        assert!(!router.is_shut_down());
        router.shutdown();
        router.shutdown(); // idempotent
        assert!(router.is_shut_down());
        let err = router.submit(0, vec![0.4, 0.5, 0.6]).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        // the accepted request is still served once flushed
        router.flush();
        router.drain(Duration::from_secs(10)).unwrap();
        assert!(router.try_take(req).unwrap().is_some());
    }

    #[test]
    fn wait_timeout_leaves_request_claimable_exactly_once() {
        use crate::runtime::FaultyExec;
        use std::sync::Arc;
        // a latency-injected engine guarantees wait() times out before the
        // batch lands, so the timeout path itself is what's under test
        let engine = synthetic_engine(21, &[3, 4, 2], 4)
            .unwrap()
            .with_faults(Arc::new(FaultyExec::slow(Duration::from_millis(80))));
        let router = Router::new(quick_cfg(2), vec![("slow".into(), engine)]);
        let req = router.submit(0, vec![0.2, -0.1, 0.4]).unwrap();
        let err = router.wait(req, Duration::from_millis(1)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        // the timed-out request is still delivered — and exactly once
        let t0 = Instant::now();
        let r = loop {
            if let Some(r) = router.try_take(req).unwrap() {
                break r;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "timed-out request never became claimable"
            );
            thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(r.id, req.id);
        assert!(
            router.try_take(req).unwrap().is_none(),
            "ready slot leaked: delivered twice after a wait() timeout"
        );
        assert_eq!(router.ready(), 0);
    }

    #[test]
    fn drain_races_concurrent_submits_without_losing_requests() {
        let router = toy_router(3);
        let n = 40usize;
        let reqs: Vec<RequestId> = thread::scope(|scope| {
            let submitter = {
                let router = &router;
                scope.spawn(move || {
                    (0..n)
                        .map(|k| {
                            let req = router.submit(0, vec![0.02 * k as f32; 3]).unwrap();
                            if k % 8 == 0 {
                                thread::sleep(Duration::from_micros(300));
                            }
                            req
                        })
                        .collect::<Vec<_>>()
                })
            };
            // drain while the submitter is still pushing: each drain only
            // covers batches in flight at its own flush, but must never
            // corrupt bookkeeping for requests racing in behind it
            for _ in 0..6 {
                router.drain(Duration::from_secs(10)).unwrap();
            }
            submitter.join().unwrap()
        });
        // the final drain (no concurrent submits left) covers the tail
        router.drain(Duration::from_secs(10)).unwrap();
        for &req in &reqs {
            assert!(router.try_take(req).unwrap().is_some(), "lost {req:?}");
            assert!(router.try_take(req).unwrap().is_none(), "duplicate {req:?}");
        }
        assert_eq!(reqs.len(), n);
        assert!(router.failures().is_empty());
    }

    #[test]
    fn names_resolve() {
        let router = toy_router(1);
        assert_eq!(router.n_tasks(), 2);
        assert_eq!(router.task_index("beta"), Some(1));
        assert_eq!(router.task_names(), vec!["alpha", "beta"]);
        assert!(router.workers() >= 1);
    }

    // ----- self-healing layer ------------------------------------------

    #[test]
    fn canary_has_zero_false_positives_on_nominal_engines() {
        let cfg = RouterConfig {
            canary_every: 1, // probe after every batch
            ..quick_cfg(2)
        };
        let router = Router::new(
            cfg,
            vec![
                ("alpha".into(), synthetic_engine(11, &[3, 4, 2], 4).unwrap()),
                ("beta".into(), synthetic_engine(12, &[2, 3, 3], 3).unwrap()),
            ],
        );
        let mut reqs = Vec::new();
        for i in 0..40 {
            let t = i % 2;
            let dim = if t == 0 { 3 } else { 2 };
            reqs.push(router.submit(t, vec![0.03 * i as f32; dim]).unwrap());
        }
        router.drain(Duration::from_secs(20)).unwrap();
        for &req in &reqs {
            assert!(router.try_take(req).unwrap().is_some());
        }
        let h = router.health_snapshot();
        assert!(h.probes > 0, "canaries must have run");
        assert_eq!(h.probe_disagreements, 0, "false positive on nominal engine");
        assert_eq!(h.to_degraded, 0);
        assert_eq!(h.to_quarantined, 0);
        assert!(router.health_timeline().is_empty());
        for (_, state) in router.health_states() {
            assert_eq!(state, HealthState::Healthy);
        }
    }

    #[test]
    fn transient_panic_is_retried_and_answered() {
        use crate::runtime::FaultyExec;
        // batch ordinal 0 panics once; the in-place retry's re-run lands
        // past the window and succeeds, so every request is answered and
        // no failure is recorded
        let engine = synthetic_engine(17, &[3, 4, 2], 4)
            .unwrap()
            .with_faults(Arc::new(FaultyExec::panicking_window(0, 1)));
        let router = Router::new(quick_cfg(1), vec![("flaky".into(), engine)]);
        let mut reqs = Vec::new();
        for i in 0..4 {
            reqs.push(router.submit(0, vec![0.1 * i as f32; 3]).unwrap());
        }
        router.drain(Duration::from_secs(10)).unwrap();
        for &req in &reqs {
            assert!(router.try_take(req).unwrap().is_some(), "lost to a transient panic");
        }
        let h = router.health_snapshot();
        assert!(h.retries >= 1, "retry path never exercised");
        assert!(router.failures().is_empty(), "{:?}", router.failures());
    }

    #[test]
    fn admission_bound_sheds_overload_without_losing_accepted_work() {
        use crate::runtime::FaultyExec;
        let engine = synthetic_engine(19, &[3, 4, 2], 2)
            .unwrap()
            .with_faults(Arc::new(FaultyExec::slow(Duration::from_millis(20))));
        let cfg = RouterConfig {
            max_queue: Some(4),
            ..quick_cfg(1)
        };
        let router = Router::new(cfg, vec![("jam".into(), engine)]);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..40 {
            match router.submit(0, vec![0.02 * i as f32; 3]) {
                Ok(req) => accepted.push(req),
                Err(e) => {
                    assert!(e.to_string().contains("admission queue full"), "{e}");
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "storm never hit the admission bound");
        assert!(!accepted.is_empty());
        router.drain(Duration::from_secs(30)).unwrap();
        for &req in &accepted {
            assert!(
                router.try_take(req).unwrap().is_some(),
                "accepted request lost under shed pressure"
            );
        }
        let h = router.health_snapshot();
        assert_eq!(h.shed_queue as usize, rejected);
    }

    #[test]
    fn deadline_sheds_only_overdue_requests() {
        use crate::runtime::FaultyExec;
        // one worker, a 300 ms engine stall, and a 150 ms deadline: the
        // first request starts fresh (age ≈ 0) and completes; requests
        // submitted during the stall exceed the deadline while queued
        // behind it and must be shed, not executed
        let engine = synthetic_engine(23, &[3, 4, 2], 1)
            .unwrap()
            .with_faults(Arc::new(FaultyExec::slow(Duration::from_millis(300))));
        let cfg = RouterConfig {
            deadline: Some(Duration::from_millis(150)),
            ..quick_cfg(1)
        };
        let router = Router::new(cfg, vec![("slow".into(), engine)]);
        let first = router.submit(0, vec![0.1, 0.2, 0.3]).unwrap();
        thread::sleep(Duration::from_millis(50)); // first batch is now in flight
        let late = router.submit(0, vec![0.4, 0.5, 0.6]).unwrap();
        let r = router.wait(first, Duration::from_secs(10)).unwrap();
        assert_eq!(r.id, first.id, "fresh request must not be shed");
        let err = router.wait(late, Duration::from_secs(10)).unwrap_err();
        assert!(err.to_string().contains("shed"), "expected a shed, got: {err}");
        let h = router.health_snapshot();
        assert!(h.shed_deadline >= 1, "shed counter not bumped");
        // shedding is not an engine failure: drain stays clean
        router.drain(Duration::from_secs(10)).unwrap();
    }

    #[test]
    fn quarantine_rebuild_restores_health() {
        use crate::runtime::FaultyExec;
        let clean = synthetic_engine(29, &[3, 4, 2], 4).unwrap();
        // golden probes: rows with labels captured from the clean engine
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|i| vec![0.2 * i as f32 - 0.3, 0.1, -0.15 * i as f32])
            .collect();
        let batch = probe_batch(&rows, 3, 4);
        let labels: Vec<usize> = clean
            .run_batch(&batch)
            .unwrap()
            .iter()
            .map(|&(_, pred, _)| pred)
            .collect();
        // the live engine fails every batch (canary probes error out →
        // full disagreement → collapse verdict → quarantine)
        let broken = clean
            .clone()
            .with_faults(Arc::new(FaultyExec::failing(0)));
        let rebuilt = clean.clone();
        let cfg = RouterConfig {
            canary_every: 1,
            retry_transient: false, // clean failures are not panic-class anyway
            health: HealthConfig {
                window: 1,
                patience: 1,
                ..HealthConfig::default()
            },
            ..quick_cfg(1)
        };
        let spec = LaneSpec::new("healme", broken)
            .with_probe(rows, labels)
            .with_rebuild(Arc::new(move || Ok(rebuilt.clone())));
        let router = Router::with_specs(cfg, vec![spec]);
        // first batch fails in the broken engine, which trips the canary,
        // the collapse verdict, quarantine, rebuild, and recovery
        let mut sacrificial = Vec::new();
        for i in 0..4 {
            sacrificial.push(router.submit(0, vec![0.05 * i as f32; 3]).unwrap());
        }
        let t0 = Instant::now();
        loop {
            let healthy_again = router.health_snapshot().recovered >= 1;
            if healthy_again {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "lane never recovered; timeline: {:?}",
                router.health_timeline()
            );
            thread::sleep(Duration::from_millis(5));
        }
        // sacrificial requests were resolved (as failures), exactly once
        for &req in &sacrificial {
            assert!(router.try_take(req).unwrap_err().to_string().contains("failed"));
        }
        // the lane serves clean traffic again
        let mut after = Vec::new();
        for i in 0..4 {
            after.push(router.submit(0, vec![0.07 * i as f32; 3]).unwrap());
        }
        for &req in &after {
            router.wait(req, Duration::from_secs(10)).unwrap();
        }
        let h = router.health_snapshot();
        assert_eq!(h.rebuilds, 1);
        assert!(h.recovered >= 1);
        assert_eq!(h.to_quarantined, 1);
        let states = router.health_states();
        assert_eq!(states[0].1, HealthState::Healthy);
        // the timeline records the full escalation and the recovery
        let seq: Vec<(HealthState, HealthState)> = router
            .health_timeline()
            .iter()
            .map(|e| (e.from, e.to))
            .collect();
        assert!(seq.contains(&(HealthState::Healthy, HealthState::Degraded)));
        assert!(seq.contains(&(HealthState::Degraded, HealthState::Quarantined)));
        assert!(seq.contains(&(HealthState::Quarantined, HealthState::Healthy)));
    }

    #[test]
    fn trace_ids_are_nonzero_and_unique_across_lanes() {
        assert_ne!(trace_of(0, 0), 0);
        let mut seen = std::collections::HashSet::new();
        for task in 0..4usize {
            for id in 0..100u64 {
                assert!(seen.insert(trace_of(task, id)), "collision {task}/{id}");
            }
        }
        // derivable from a RequestId handle
        let req = RequestId { task: 2, id: 41 };
        assert_eq!(trace_of(req.task, req.id), (3u64 << 48) + 42);
    }

    #[test]
    fn snapshot_carries_per_lane_signal_and_exemplar_blocks() {
        let router = toy_router(2);
        for i in 0..8 {
            router.submit(0, vec![0.1 * i as f32; 3]).unwrap();
        }
        router.drain(Duration::from_secs(10)).unwrap();
        let snap = router.metrics_snapshot("t");
        assert_eq!(snap.signal.len(), 2);
        assert_eq!(snap.exemplars.len(), 2);
        assert_eq!(snap.signal[0].0, "alpha");
        assert_eq!(snap.exemplars[1].0, "beta");
        let j = snap.canonical_json();
        assert!(j.contains("\"schema\":\"sac-metrics/v4\""), "{j}");
        assert!(j.contains("\"signal\":[{"), "{j}");
        assert!(j.contains("\"exemplars\":[{"), "{j}");
        // the prometheus exposition exports the signal gauges per lane
        let prom = snap.prometheus();
        assert!(prom.contains("sac_signal_saturation_ratio{router=\"t\",task=\"alpha\"}"));
        assert!(prom.contains("sac_signal_fallback_ratio{router=\"t\",task=\"beta\"}"));
        assert!(prom.contains("sac_signal_margin_min{router=\"t\",task=\"alpha\"}"));
    }
}
