//! Multi-task serving router: N task engines behind a single submit API,
//! batches dispatched to a shared worker pool, deadline-based flushing so
//! tail requests are never stranded.
//!
//! ```text
//!             submit(task, features)
//!                      │
//!          ┌───────────▼───────────┐   per-task lane
//!          │  Mutex<LaneBatcher>   │   (DynamicBatcher + enqueue times)
//!          └───────────┬───────────┘
//!        full batch ───┤                 ┌──────────────┐
//!                      ├──◄── flusher ───┤ every tick:  │
//!                      │   (partial      │ age ≥ max_wait│
//!          ┌───────────▼────────┐  batch)└──────────────┘
//!          │ WorkerPool (shared)│  each job: Engine::run_batch (lock-free)
//!          └───────────┬────────┘
//!          ┌───────────▼───────────┐
//!          │ Mutex<results: id→…>  │ ← wait()/try_take() remove exactly once
//!          └───────────────────────┘
//! ```
//!
//! Invariants (tested below and in `tests/integration.rs`):
//!
//!  * every submitted request is answered exactly once — batches are only
//!    materialized under the lane lock, and each materialized batch is
//!    handed to exactly one worker;
//!  * a partial batch waits at most `max_wait` (+ one flusher tick) before
//!    execution — the deadline flush;
//!  * engines run without locks (`Engine::run_batch(&self, …)`), so
//!    batches of the *same* task execute concurrently on many workers;
//!  * an engine failure resolves every request of its batch with the
//!    error ([`Router::wait`] reports it immediately; [`Router::drain`]
//!    and [`Router::failures`] surface it), never a silent timeout;
//!  * metrics are recorded per task and can be aggregated across tasks.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batch, DynamicBatcher};
use super::metrics::ServeMetrics;
use super::telemetry::{MetricsSnapshot, StageCounters, StageSnapshot};
use super::Engine;
use crate::util::pool::{PoolHandle, WorkerPool};
use crate::util::trace;

/// Handle to one submitted request: the task lane plus the per-lane
/// request id assigned by the batcher.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestId {
    pub task: usize,
    pub id: u64,
}

/// One answered request.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub logits: Vec<f32>,
}

/// Router tuning knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// worker threads shared by all tasks
    pub workers: usize,
    /// maximum time a partial batch may wait before being flushed
    pub max_wait: Duration,
    /// flusher wake-up cadence (effective tail deadline is
    /// `max_wait + flush_tick`)
    pub flush_tick: Duration,
    /// intra-batch row parallelism applied to every engine
    /// (`--threads`/`SAC_THREADS`); `None` keeps each engine's own
    /// setting.  Slab work runs on the process-wide slab pool, not the
    /// router's worker pool, and results are bit-identical at any value.
    pub kernel_threads: Option<usize>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            workers: crate::util::pool::default_threads().min(8),
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(500),
            kernel_threads: None,
        }
    }
}

/// Per-task batcher plus the enqueue timestamp of every pending request
/// (front = oldest), driving the deadline flush.
struct LaneBatcher {
    batcher: DynamicBatcher,
    enqueued_at: VecDeque<Instant>,
}

impl LaneBatcher {
    fn new(batch_size: usize, dim: usize) -> LaneBatcher {
        LaneBatcher {
            batcher: DynamicBatcher::new(batch_size, dim),
            enqueued_at: VecDeque::new(),
        }
    }

    fn submit(&mut self, features: Vec<f32>) -> u64 {
        let id = self.batcher.submit(features);
        self.enqueued_at.push_back(Instant::now());
        id
    }

    /// Drop timestamps of requests that left the queue (always popped from
    /// the front — the batcher materializes in FIFO order).
    fn trim(&mut self) {
        while self.enqueued_at.len() > self.batcher.pending() {
            self.enqueued_at.pop_front();
        }
    }

    fn pop_fulls(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(b) = self.batcher.pop_full() {
            out.push(b);
        }
        self.trim();
        out
    }

    fn flush_all(&mut self) -> Vec<Batch> {
        let out = self.batcher.flush();
        self.enqueued_at.clear();
        out
    }

    /// Full batches always; the partial tail too once its oldest request
    /// has waited `max_wait`.  The second return is `true` when the
    /// deadline fired (a partial batch was force-materialized).
    fn take_overdue(&mut self, max_wait: Duration) -> (Vec<Batch>, bool) {
        let mut out = self.pop_fulls();
        let mut deadline_fired = false;
        if self.batcher.pending() > 0 {
            if let Some(t0) = self.enqueued_at.front() {
                if t0.elapsed() >= max_wait {
                    out.extend(self.flush_all());
                    deadline_fired = true;
                }
            }
        }
        (out, deadline_fired)
    }

    fn pending(&self) -> usize {
        self.batcher.pending()
    }
}

/// Per-lane outcome store: computed responses plus the ids of requests
/// whose batch failed in the engine (so waiters get the error immediately
/// instead of a misleading timeout).
#[derive(Default)]
struct LaneResults {
    ready: HashMap<u64, Response>,
    failed: HashMap<u64, String>,
}

struct Lane {
    name: String,
    engine: Engine,
    queue: Mutex<LaneBatcher>,
    /// Cheap idle hint so the flusher skips lanes without taking the
    /// queue lock; only ever written while holding the queue lock.
    has_pending: AtomicBool,
    results: Mutex<LaneResults>,
    results_cv: Condvar,
    metrics: Mutex<ServeMetrics>,
}

struct Shared {
    lanes: Vec<Lane>,
    /// batches enqueued on the pool or executing
    inflight: Mutex<usize>,
    idle_cv: Condvar,
    shutdown: AtomicBool,
    failures: Mutex<Vec<String>>,
    /// set by `submit` when a lane gains a pending partial batch; the
    /// flusher parks on this when every lane is empty instead of
    /// tick-polling an idle router
    flush_signal: Mutex<bool>,
    flush_cv: Condvar,
    /// lock-free pipeline stage counters (telemetry, DESIGN.md §9)
    stages: StageCounters,
}

/// The multi-task serving router.  See the module docs for the dataflow.
pub struct Router {
    shared: Arc<Shared>,
    pool: WorkerPool,
    pool_handle: PoolHandle,
    flusher: Option<thread::JoinHandle<()>>,
}

impl Router {
    /// Host one lane per `(name, engine)` task behind `cfg.workers` shared
    /// workers, and start the deadline flusher.
    pub fn new(cfg: RouterConfig, tasks: Vec<(String, Engine)>) -> Router {
        assert!(!tasks.is_empty(), "router needs at least one task");
        let lanes = tasks
            .into_iter()
            .map(|(name, engine)| {
                let engine = match cfg.kernel_threads {
                    Some(n) => engine.with_par_threads(n),
                    None => engine,
                };
                let queue = Mutex::new(LaneBatcher::new(engine.batch_size, engine.dim));
                Lane {
                    name,
                    engine,
                    queue,
                    has_pending: AtomicBool::new(false),
                    results: Mutex::new(LaneResults::default()),
                    results_cv: Condvar::new(),
                    metrics: Mutex::new(ServeMetrics::default()),
                }
            })
            .collect();
        let shared = Arc::new(Shared {
            lanes,
            inflight: Mutex::new(0),
            idle_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            failures: Mutex::new(Vec::new()),
            flush_signal: Mutex::new(false),
            flush_cv: Condvar::new(),
            stages: StageCounters::default(),
        });
        let pool = WorkerPool::new(cfg.workers);
        let pool_handle = pool.handle();

        let flusher = {
            let shared = Arc::clone(&shared);
            let handle = pool.handle();
            let max_wait = cfg.max_wait;
            let tick = cfg.flush_tick.max(Duration::from_micros(50));
            thread::Builder::new()
                .name("sac-flusher".into())
                .spawn(move || loop {
                    // Park while idle: zero wakeups on a quiet router.
                    // `submit` raises flush_signal when a lane gains a
                    // pending partial batch; a bounded wait keeps the
                    // shutdown latency small even if a notify is missed.
                    {
                        let mut sig = shared.flush_signal.lock().unwrap();
                        while !*sig && !shared.shutdown.load(Ordering::SeqCst) {
                            let (guard, _) = shared
                                .flush_cv
                                .wait_timeout(sig, Duration::from_millis(50))
                                .unwrap();
                            sig = guard;
                        }
                    }
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    // Active phase: tick-scan until every lane is empty.
                    loop {
                        if shared.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        // clear the signal *before* scanning: a submit
                        // racing with the scan re-raises it, so the park
                        // loop above re-enters the active phase immediately
                        *shared.flush_signal.lock().unwrap() = false;
                        let mut any_pending = false;
                        {
                            let _scan = trace::span("router.flush");
                            for li in 0..shared.lanes.len() {
                                let lane = &shared.lanes[li];
                                // idle lanes cost one atomic load, not a lock
                                // acquisition contending with submitters
                                if !lane.has_pending.load(Ordering::SeqCst) {
                                    continue;
                                }
                                // enqueue under the lane lock: a batch is never
                                // "in limbo" outside both the queue and the
                                // inflight counter (drain correctness).
                                let mut q = lane.queue.lock().unwrap();
                                let (batches, deadline_fired) = q.take_overdue(max_wait);
                                if deadline_fired {
                                    StageCounters::bump(&shared.stages.deadline_flushes);
                                }
                                for b in batches {
                                    enqueue_batch(&shared, &handle, li, b);
                                }
                                let still = q.pending() > 0;
                                lane.has_pending.store(still, Ordering::SeqCst);
                                any_pending |= still;
                            }
                        }
                        if !any_pending {
                            break; // back to the park loop
                        }
                        thread::sleep(tick);
                    }
                })
                .expect("spawn flusher thread")
        };

        Router {
            shared,
            pool,
            pool_handle,
            flusher: Some(flusher),
        }
    }

    /// Number of hosted tasks.
    pub fn n_tasks(&self) -> usize {
        self.shared.lanes.len()
    }

    /// Task names in lane order.
    pub fn task_names(&self) -> Vec<&str> {
        self.shared.lanes.iter().map(|l| l.name.as_str()).collect()
    }

    /// Lane index of a task name.
    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.shared.lanes.iter().position(|l| l.name == name)
    }

    /// Submit one request to a task lane; returns its handle.  The batch
    /// dispatches immediately when full, otherwise within
    /// `max_wait + flush_tick`.
    pub fn submit(&self, task: usize, features: Vec<f32>) -> Result<RequestId> {
        let _span = trace::span("router.submit");
        if self.shared.shutdown.load(Ordering::SeqCst) {
            StageCounters::bump(&self.shared.stages.rejected);
            bail!("router is shut down");
        }
        let lane = match self.shared.lanes.get(task) {
            Some(lane) => lane,
            None => {
                StageCounters::bump(&self.shared.stages.rejected);
                bail!("no task lane #{task}");
            }
        };
        if features.len() != lane.engine.dim {
            StageCounters::bump(&self.shared.stages.rejected);
            bail!(
                "task {:?}: feature dim {} != {}",
                lane.name,
                features.len(),
                lane.engine.dim
            );
        }
        StageCounters::bump(&self.shared.stages.submitted);
        let mut q = lane.queue.lock().unwrap();
        let id = q.submit(features);
        for b in q.pop_fulls() {
            enqueue_batch(&self.shared, &self.pool_handle, task, b);
        }
        let pending = q.pending() > 0;
        lane.has_pending.store(pending, Ordering::SeqCst);
        drop(q);
        if pending {
            // wake the parked flusher so the deadline clock on this
            // partial batch is serviced
            let mut sig = self.shared.flush_signal.lock().unwrap();
            if !*sig {
                *sig = true;
                self.shared.flush_cv.notify_one();
            }
        }
        Ok(RequestId { task, id })
    }

    /// Submit by task name.
    pub fn submit_to(&self, name: &str, features: Vec<f32>) -> Result<RequestId> {
        let task = self
            .task_index(name)
            .ok_or_else(|| anyhow!("no task named {name:?}"))?;
        self.submit(task, features)
    }

    /// Take a response if it is ready (removes it — each response is
    /// delivered at most once).  `Ok(None)` means *not ready yet*; an
    /// engine failure for this request's batch is consumed and returned
    /// as `Err`, so pollers terminate instead of spinning forever.
    pub fn try_take(&self, req: RequestId) -> Result<Option<Response>> {
        let lane = self
            .shared
            .lanes
            .get(req.task)
            .ok_or_else(|| anyhow!("no task lane #{}", req.task))?;
        let mut res = lane.results.lock().unwrap();
        if let Some(r) = res.ready.remove(&req.id) {
            StageCounters::bump(&self.shared.stages.responses_taken);
            return Ok(Some(r));
        }
        if let Some(msg) = res.failed.remove(&req.id) {
            bail!("request {}/{} failed: {msg}", lane.name, req.id);
        }
        Ok(None)
    }

    /// Block until the response arrives (relies on the deadline flusher for
    /// partial batches) or `timeout` elapses.  Reports an engine failure
    /// for this request's batch immediately instead of timing out.
    pub fn wait(&self, req: RequestId, timeout: Duration) -> Result<Response> {
        let lane = self
            .shared
            .lanes
            .get(req.task)
            .ok_or_else(|| anyhow!("no task lane #{}", req.task))?;
        let deadline = Instant::now() + timeout;
        let mut res = lane.results.lock().unwrap();
        loop {
            if let Some(r) = res.ready.remove(&req.id) {
                StageCounters::bump(&self.shared.stages.responses_taken);
                return Ok(r);
            }
            if let Some(msg) = res.failed.remove(&req.id) {
                bail!("request {}/{} failed: {msg}", lane.name, req.id);
            }
            let now = Instant::now();
            if now >= deadline {
                StageCounters::bump(&self.shared.stages.wait_timeouts);
                bail!(
                    "request {}/{} timed out after {timeout:?}",
                    lane.name,
                    req.id
                );
            }
            let (guard, _) = lane
                .results_cv
                .wait_timeout(res, deadline - now)
                .unwrap();
            res = guard;
        }
    }

    /// Force-materialize every pending partial batch right now.
    pub fn flush(&self) {
        for (li, lane) in self.shared.lanes.iter().enumerate() {
            let mut q = lane.queue.lock().unwrap();
            for b in q.flush_all() {
                enqueue_batch(&self.shared, &self.pool_handle, li, b);
            }
            lane.has_pending.store(false, Ordering::SeqCst);
        }
    }

    /// Flush everything and wait until no batch is queued or executing.
    /// Fails on timeout or if any worker reported a failure.
    pub fn drain(&self, timeout: Duration) -> Result<()> {
        self.flush();
        let deadline = Instant::now() + timeout;
        let mut n = self.shared.inflight.lock().unwrap();
        while *n > 0 {
            if Instant::now() >= deadline {
                bail!("drain timed out with {} batch(es) in flight", *n);
            }
            let (guard, _) = self
                .shared
                .idle_cv
                .wait_timeout(n, Duration::from_millis(20))
                .unwrap();
            n = guard;
        }
        drop(n);
        let fails = self.shared.failures.lock().unwrap();
        if !fails.is_empty() {
            bail!("{} worker failure(s): {}", fails.len(), fails.join("; "));
        }
        Ok(())
    }

    /// Requests still waiting in lane queues (not yet materialized).
    pub fn pending(&self) -> usize {
        self.shared
            .lanes
            .iter()
            .map(|l| l.queue.lock().unwrap().pending())
            .sum()
    }

    /// Responses computed but not yet taken.
    pub fn ready(&self) -> usize {
        self.shared
            .lanes
            .iter()
            .map(|l| l.results.lock().unwrap().ready.len())
            .sum()
    }

    /// Snapshot of one task's metrics.
    pub fn metrics(&self, task: usize) -> ServeMetrics {
        self.shared.lanes[task].metrics.lock().unwrap().clone()
    }

    /// Metrics aggregated across every task lane.
    pub fn aggregate_metrics(&self) -> ServeMetrics {
        let mut total = ServeMetrics::default();
        for lane in &self.shared.lanes {
            total.merge(&lane.metrics.lock().unwrap());
        }
        total
    }

    /// Copy of the lock-free pipeline stage counters.
    pub fn stages(&self) -> StageSnapshot {
        self.shared.stages.snapshot()
    }

    /// Full telemetry snapshot under `name`: stage counters, per-lane
    /// and aggregate metrics, and the trace-sink stats at capture time.
    pub fn metrics_snapshot(&self, name: &str) -> MetricsSnapshot {
        let lanes: Vec<(String, ServeMetrics)> = self
            .shared
            .lanes
            .iter()
            .map(|l| (l.name.clone(), l.metrics.lock().unwrap().clone()))
            .collect();
        let mut aggregate = ServeMetrics::default();
        for (_, m) in &lanes {
            aggregate.merge(m);
        }
        MetricsSnapshot {
            name: name.to_string(),
            stages: self.shared.stages.snapshot(),
            lanes,
            aggregate,
            kernel: crate::coordinator::telemetry::kernel_stats(),
            trace: trace::stats(),
        }
    }

    /// Worker failure messages collected so far (normally empty).
    pub fn failures(&self) -> Vec<String> {
        self.shared.failures.lock().unwrap().clone()
    }

    /// Worker threads serving this router.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Stop accepting new submissions (idempotent).  Work already
    /// accepted still completes: the deadline flusher exits on shutdown,
    /// so pending partial batches are materialized here, and computed
    /// responses remain takeable via
    /// [`Router::try_take`]/[`Router::wait`].  A submit racing this call
    /// may still land in a lane queue just after the final flush — call
    /// [`Router::drain`] for a clean handoff.  Dropping the router
    /// implies shutdown.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.flush_cv.notify_all();
        // The flusher is gone after the flag flips; materialize whatever
        // is already queued so accepted requests are not stranded.
        self.flush();
    }

    /// Whether [`Router::shutdown`] has been called.
    pub fn is_shut_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.flush_cv.notify_all(); // wake a parked flusher
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        // WorkerPool's Drop drains every queued batch before joining, so
        // accepted work still completes; unmaterialized queue tails are
        // dropped (call `drain` first for a clean shutdown).
    }
}

/// Hand one materialized batch to the worker pool.  Must be called with
/// the originating lane's queue lock held (see the flusher comment).
fn enqueue_batch(shared: &Arc<Shared>, pool: &PoolHandle, li: usize, batch: Batch) {
    StageCounters::bump(&shared.stages.batches_enqueued);
    *shared.inflight.lock().unwrap() += 1;
    let shared = Arc::clone(shared);
    pool.execute(move || {
        let lane = &shared.lanes[li];
        let t0 = Instant::now();
        // Contain panics from the engine (e.g. a poisoned artifact): the
        // inflight decrement below must always run, or drain() would hang
        // forever, and the batch's waiters must still be resolved.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            lane.engine.run_batch(&batch)
        }))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "engine panicked".to_string());
            Err(anyhow!("engine panicked: {msg}"))
        });
        match outcome {
            Ok(rows) => {
                StageCounters::bump(&shared.stages.batches_completed);
                shared
                    .stages
                    .rows_delivered
                    .fetch_add(batch.live as u64, std::sync::atomic::Ordering::Relaxed);
                lane.metrics
                    .lock()
                    .unwrap()
                    .record_batch(batch.live, t0.elapsed());
                let _deliver = trace::span("router.deliver");
                let mut res = lane.results.lock().unwrap();
                for (id, pred, logits) in rows {
                    if res.ready.insert(id, Response { id, pred, logits }).is_some() {
                        shared
                            .failures
                            .lock()
                            .unwrap()
                            .push(format!("duplicate response id {id} on lane {li}"));
                    }
                }
                drop(res);
                lane.results_cv.notify_all();
            }
            Err(e) => {
                StageCounters::bump(&shared.stages.batches_failed);
                // resolve every request of the failed batch so waiters get
                // the engine error immediately, not a timeout
                let msg = format!("{e:#}");
                let mut res = lane.results.lock().unwrap();
                for &id in &batch.ids {
                    res.failed.insert(id, msg.clone());
                }
                drop(res);
                shared
                    .failures
                    .lock()
                    .unwrap()
                    .push(format!("lane {:?}: {msg}", lane.name));
                lane.results_cv.notify_all();
            }
        }
        let mut n = shared.inflight.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            shared.idle_cv.notify_all();
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::synthetic_engine;

    fn quick_cfg(workers: usize) -> RouterConfig {
        RouterConfig {
            workers,
            max_wait: Duration::from_millis(2),
            flush_tick: Duration::from_micros(200),
            kernel_threads: None,
        }
    }

    fn toy_router(workers: usize) -> Router {
        Router::new(
            quick_cfg(workers),
            vec![
                ("alpha".into(), synthetic_engine(11, &[3, 4, 2], 4).unwrap()),
                ("beta".into(), synthetic_engine(12, &[2, 3, 3], 3).unwrap()),
            ],
        )
    }

    #[test]
    fn kernel_threads_config_is_bit_identical() {
        use crate::coordinator::synthetic_engine_with_mode;
        use crate::runtime::ExecMode;
        let mk = || synthetic_engine_with_mode(31, &[4, 5, 3], 32, ExecMode::Batched).unwrap();
        let serial = Router::new(
            RouterConfig {
                kernel_threads: Some(1),
                ..quick_cfg(2)
            },
            vec![("t".into(), mk())],
        );
        let par = Router::new(
            RouterConfig {
                kernel_threads: Some(4),
                ..quick_cfg(2)
            },
            vec![("t".into(), mk())],
        );
        let mut pairs = Vec::new();
        for i in 0..32 {
            let feat: Vec<f32> = (0..4).map(|j| 0.03 * (i * 4 + j) as f32 - 0.5).collect();
            pairs.push((
                serial.submit(0, feat.clone()).unwrap(),
                par.submit(0, feat).unwrap(),
            ));
        }
        serial.drain(Duration::from_secs(10)).unwrap();
        par.drain(Duration::from_secs(10)).unwrap();
        for (a, b) in pairs {
            let ra = serial.try_take(a).unwrap().expect("serial answer");
            let rb = par.try_take(b).unwrap().expect("parallel answer");
            assert_eq!(ra.pred, rb.pred);
            assert_eq!(ra.logits, rb.logits, "threaded kernel must be bit-identical");
        }
    }

    #[test]
    fn answers_every_request_exactly_once() {
        let router = toy_router(3);
        let mut reqs = Vec::new();
        for i in 0..23 {
            let t = i % 2;
            let dim = if t == 0 { 3 } else { 2 };
            reqs.push(router.submit(t, vec![0.05 * i as f32; dim]).unwrap());
        }
        router.drain(Duration::from_secs(10)).unwrap();
        for &req in &reqs {
            assert!(router.try_take(req).unwrap().is_some(), "unanswered {req:?}");
            assert!(
                router.try_take(req).unwrap().is_none(),
                "answered twice {req:?}"
            );
        }
        assert_eq!(router.ready(), 0);
        assert_eq!(router.pending(), 0);
        assert_eq!(router.aggregate_metrics().total_requests(), 23);
        assert!(router.failures().is_empty());
    }

    #[test]
    fn deadline_flush_rescues_partial_batches() {
        // one request into a batch-of-4 lane: without the deadline flusher
        // this would strand forever
        let router = toy_router(2);
        let req = router.submit(0, vec![0.3, -0.2, 0.1]).unwrap();
        let r = router.wait(req, Duration::from_secs(5)).unwrap();
        assert_eq!(r.id, req.id);
        assert_eq!(r.logits.len(), 2);
    }

    #[test]
    fn per_task_metrics_are_isolated() {
        let router = toy_router(2);
        for i in 0..8 {
            router.submit(0, vec![0.1 * i as f32; 3]).unwrap();
        }
        for i in 0..3 {
            router.submit(1, vec![0.2 * i as f32; 2]).unwrap();
        }
        router.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(router.metrics(0).total_requests(), 8);
        assert_eq!(router.metrics(1).total_requests(), 3);
        assert_eq!(router.aggregate_metrics().total_requests(), 11);
    }

    #[test]
    fn rejects_bad_task_and_bad_dim() {
        let router = toy_router(1);
        assert!(router.submit(9, vec![0.0; 3]).is_err());
        assert!(router.submit(0, vec![0.0; 5]).is_err());
        assert!(router.submit_to("nope", vec![0.0; 3]).is_err());
        assert!(router.submit_to("alpha", vec![0.0, 0.0, 0.0]).is_ok());
    }

    #[test]
    fn concurrent_submitters_all_answered() {
        let router = toy_router(4);
        let n_threads = 6;
        let per_thread = 20;
        let reqs: Vec<Vec<RequestId>> = thread::scope(|scope| {
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let router = &router;
                    scope.spawn(move || {
                        (0..per_thread)
                            .map(|k| {
                                let task = (t + k) % 2;
                                let dim = if task == 0 { 3 } else { 2 };
                                router
                                    .submit(task, vec![0.01 * (t * 100 + k) as f32; dim])
                                    .unwrap()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        router.drain(Duration::from_secs(20)).unwrap();
        let mut seen = std::collections::HashSet::new();
        for req in reqs.into_iter().flatten() {
            let r = router
                .try_take(req)
                .unwrap()
                .expect("every request answered");
            assert!(seen.insert((req.task, r.id)), "duplicate {req:?}");
        }
        assert_eq!(seen.len(), n_threads * per_thread);
        assert_eq!(
            router.aggregate_metrics().total_requests(),
            n_threads * per_thread
        );
    }

    #[test]
    fn engine_failure_is_reported_not_timed_out() {
        use crate::data::TrainedNet;
        use crate::runtime::Executable;
        let mk = |sizes: &[usize]| TrainedNet {
            task: "x".into(),
            sizes: sizes.to_vec(),
            activation: "relu".into(),
            splines: 1,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: sizes.windows(2).map(|w| vec![0.25; w[0] * w[1]]).collect(),
            biases: sizes[1..].iter().map(|&n| vec![0.0; n]).collect(),
        };
        // engine whose weights disagree with its executable's manifest
        // shapes: same input dim (passes from_parts), wrong hidden width
        // (every run_batch fails at the run_f32 shape check)
        let net = mk(&[2, 3, 2]);
        let wrong = mk(&[2, 4, 2]);
        let exe = Executable::native_mlp(&wrong, 4).unwrap();
        let engine = Engine::from_parts(net, exe).unwrap();
        let router = Router::new(quick_cfg(1), vec![("broken".into(), engine)]);
        let req = router.submit(0, vec![0.1, 0.2]).unwrap();
        let err = router.wait(req, Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("failed"), "unexpected error: {err}");
        assert!(!router.failures().is_empty());
        assert!(router.drain(Duration::from_secs(5)).is_err());
        // a polling client sees the failure too (second request, try_take)
        let req2 = router.submit(0, vec![0.3, 0.4]).unwrap();
        let t0 = std::time::Instant::now();
        loop {
            match router.try_take(req2) {
                Ok(None) => {
                    assert!(t0.elapsed() < Duration::from_secs(5), "poll never resolved");
                    thread::sleep(Duration::from_millis(1));
                }
                Ok(Some(_)) => panic!("broken engine produced a response"),
                Err(e) => {
                    assert!(e.to_string().contains("failed"), "{e}");
                    break;
                }
            }
        }
    }

    #[test]
    fn shutdown_rejects_new_submits_but_completes_accepted_work() {
        let router = toy_router(2);
        let req = router.submit(0, vec![0.1, 0.2, 0.3]).unwrap();
        assert!(!router.is_shut_down());
        router.shutdown();
        router.shutdown(); // idempotent
        assert!(router.is_shut_down());
        let err = router.submit(0, vec![0.4, 0.5, 0.6]).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        // the accepted request is still served once flushed
        router.flush();
        router.drain(Duration::from_secs(10)).unwrap();
        assert!(router.try_take(req).unwrap().is_some());
    }

    #[test]
    fn wait_timeout_leaves_request_claimable_exactly_once() {
        use crate::runtime::FaultyExec;
        use std::sync::Arc;
        // a latency-injected engine guarantees wait() times out before the
        // batch lands, so the timeout path itself is what's under test
        let engine = synthetic_engine(21, &[3, 4, 2], 4)
            .unwrap()
            .with_faults(Arc::new(FaultyExec::slow(Duration::from_millis(80))));
        let router = Router::new(quick_cfg(2), vec![("slow".into(), engine)]);
        let req = router.submit(0, vec![0.2, -0.1, 0.4]).unwrap();
        let err = router.wait(req, Duration::from_millis(1)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        // the timed-out request is still delivered — and exactly once
        let t0 = Instant::now();
        let r = loop {
            if let Some(r) = router.try_take(req).unwrap() {
                break r;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "timed-out request never became claimable"
            );
            thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(r.id, req.id);
        assert!(
            router.try_take(req).unwrap().is_none(),
            "ready slot leaked: delivered twice after a wait() timeout"
        );
        assert_eq!(router.ready(), 0);
    }

    #[test]
    fn drain_races_concurrent_submits_without_losing_requests() {
        let router = toy_router(3);
        let n = 40usize;
        let reqs: Vec<RequestId> = thread::scope(|scope| {
            let submitter = {
                let router = &router;
                scope.spawn(move || {
                    (0..n)
                        .map(|k| {
                            let req = router.submit(0, vec![0.02 * k as f32; 3]).unwrap();
                            if k % 8 == 0 {
                                thread::sleep(Duration::from_micros(300));
                            }
                            req
                        })
                        .collect::<Vec<_>>()
                })
            };
            // drain while the submitter is still pushing: each drain only
            // covers batches in flight at its own flush, but must never
            // corrupt bookkeeping for requests racing in behind it
            for _ in 0..6 {
                router.drain(Duration::from_secs(10)).unwrap();
            }
            submitter.join().unwrap()
        });
        // the final drain (no concurrent submits left) covers the tail
        router.drain(Duration::from_secs(10)).unwrap();
        for &req in &reqs {
            assert!(router.try_take(req).unwrap().is_some(), "lost {req:?}");
            assert!(router.try_take(req).unwrap().is_none(), "duplicate {req:?}");
        }
        assert_eq!(reqs.len(), n);
        assert!(router.failures().is_empty());
    }

    #[test]
    fn names_resolve() {
        let router = toy_router(1);
        assert_eq!(router.n_tasks(), 2);
        assert_eq!(router.task_index("beta"), Some(1));
        assert_eq!(router.task_names(), vec!["alpha", "beta"]);
        assert!(router.workers() >= 1);
    }
}
