//! Live scrape endpoint: a minimal std-only HTTP/1.1 listener exposing
//! the router's telemetry while it serves (DESIGN.md §12).
//!
//! Three routes, all read-only:
//!
//! * `GET /metrics` — Prometheus text exposition (with exemplar
//!   suffixes on the request-latency histogram while tracing is on);
//! * `GET /metrics.json` — the canonical `sac-metrics/v4` file form;
//! * `GET /healthz` — lane health states; `200` while every lane is
//!   healthy or degraded, `503` once any lane is quarantined — the
//!   same nonzero-exit semantics the CLI health check uses.
//!
//! No HTTP library: the accept loop parses exactly the request line of
//! each connection and answers with `Connection: close`.  Scrape
//! cadence is seconds, so a single-threaded accept loop is plenty; a
//! read timeout keeps a stuck client from wedging the endpoint.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use super::telemetry::metrics_file_json;
use super::{HealthState, Router};
use crate::util::json::Json;

/// Handle to a running scrape listener.  Dropping it stops the
/// listener (idempotent with [`ScrapeServer::shutdown`]).
pub struct ScrapeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ScrapeServer {
    /// The bound address (useful with a `:0` ephemeral-port bind).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake a blocked accept with a throwaway connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ScrapeServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start the scrape listener on `addr` (e.g. `127.0.0.1:9464`, or port
/// `0` for an ephemeral port), serving snapshots of `router` under the
/// snapshot name `name`.
pub fn serve(router: Arc<Router>, addr: &str, name: &str) -> Result<ScrapeServer> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind metrics endpoint on {addr:?}"))?;
    let bound = listener.local_addr().context("resolve bound address")?;
    let stop = Arc::new(AtomicBool::new(false));
    let handle = {
        let stop = Arc::clone(&stop);
        let name = name.to_string();
        thread::Builder::new()
            .name("sac-scrape".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(stream) = conn else { continue };
                    // best effort per connection; a bad client never
                    // takes the endpoint down
                    let _ = handle_conn(stream, &router, &name);
                }
            })
            .context("spawn scrape listener thread")?
    };
    Ok(ScrapeServer {
        addr: bound,
        stop,
        handle: Some(handle),
    })
}

/// Serve exactly one request on `stream`.
fn handle_conn(stream: TcpStream, router: &Router, name: &str) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    // drain the header block so well-behaved clients see a clean close
    let mut hdr = String::new();
    while reader.read_line(&mut hdr).is_ok() && hdr.trim() != "" {
        hdr.clear();
    }
    let mut stream = reader.into_inner();
    if method != "GET" {
        return respond(
            &mut stream,
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "only GET is supported\n",
        );
    }
    // ignore any query string — the routes take no parameters
    let route = path.split('?').next().unwrap_or(path);
    match route {
        "/metrics" => {
            let body = router.metrics_snapshot(name).prometheus();
            respond(
                &mut stream,
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &body,
            )
        }
        "/metrics.json" => {
            let snap = router.metrics_snapshot(name);
            let body = format!("{}\n", metrics_file_json(std::slice::from_ref(&snap)));
            respond(&mut stream, "200 OK", "application/json", &body)
        }
        "/healthz" => {
            let states = router.health_states();
            let quarantined = states
                .iter()
                .any(|(_, s)| *s == HealthState::Quarantined);
            let body = format!(
                "{}\n",
                Json::obj(vec![
                    (
                        "lanes",
                        Json::Arr(
                            states
                                .iter()
                                .map(|(task, s)| {
                                    Json::obj(vec![
                                        ("state", Json::Str(s.name().to_string())),
                                        ("task", Json::Str(task.clone())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "status",
                        Json::Str(
                            if quarantined { "unhealthy" } else { "ok" }.to_string(),
                        ),
                    ),
                ])
            );
            let status = if quarantined {
                "503 Service Unavailable"
            } else {
                "200 OK"
            };
            respond(&mut stream, status, "application/json", &body)
        }
        _ => respond(
            &mut stream,
            "404 Not Found",
            "text/plain; charset=utf-8",
            "try /metrics, /metrics.json or /healthz\n",
        ),
    }
}

fn respond(stream: &mut TcpStream, status: &str, content_type: &str, body: &str) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{synthetic_engine, RouterConfig};
    use std::io::Read;

    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (head, body) = buf.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    }

    #[test]
    fn scrape_endpoint_serves_all_routes() {
        let router = Arc::new(Router::new(
            RouterConfig {
                workers: 2,
                ..RouterConfig::default()
            },
            vec![("alpha".into(), synthetic_engine(41, &[3, 4, 2], 4).unwrap())],
        ));
        for i in 0..8 {
            router.submit(0, vec![0.05 * i as f32; 3]).unwrap();
        }
        router.drain(Duration::from_secs(10)).unwrap();
        let mut srv = serve(Arc::clone(&router), "127.0.0.1:0", "scrape-test").unwrap();
        let addr = srv.addr();

        let (head, body) = get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(body.contains("sac_requests_total{router=\"scrape-test\",task=\"alpha\"} 8"));
        assert!(body.contains("sac_signal_saturation_ratio"));

        let (head, body) = get(addr, "/metrics.json");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(head.contains("application/json"));
        let parsed = crate::util::json::parse(&body).unwrap();
        let schema = parsed.get("schema").unwrap();
        assert_eq!(schema.to_string(), "\"sac-metrics/v4\"");

        let (head, body) = get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(body.contains("\"status\":\"ok\""), "{body}");
        assert!(body.contains("\"task\":\"alpha\""), "{body}");

        let (head, _) = get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        srv.shutdown();
        srv.shutdown(); // idempotent
        assert!(TcpStream::connect_timeout(&addr, Duration::from_millis(200)).is_err());
    }
}
