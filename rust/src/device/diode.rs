//! Diode element for the S-AC branch (Fig. 2b's D_ij).
//!
//! The paper allows "Schottky, MOS diode or any other" — the requirement is
//! only rectification.  We model an ideal-factor exponential diode with a
//! saturation current scaled to the process leakage floor, plus the option
//! of a diode-connected MOSFET (gate tied to anode), which is what a
//! compact S-AC layout actually uses.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use super::ekv::Mosfet;
use crate::pdk::{Polarity, ProcessNode};

/// Exponential junction diode.
#[derive(Clone, Debug)]
pub struct Diode {
    pub node: &'static ProcessNode,
    /// saturation current [A]
    pub i_sat: f64,
    /// ideality factor
    pub n_ideal: f64,
    pub t_c: f64,
}

impl Diode {
    pub fn new(node: &'static ProcessNode) -> Self {
        Self {
            node,
            i_sat: node.leak_floor,
            n_ideal: 1.1,
            t_c: 27.0,
        }
    }

    pub fn at_temp(mut self, t_c: f64) -> Self {
        self.t_c = t_c;
        self
    }

    /// Diode current for forward voltage `v` [A]; clamped exponent for
    /// numerical robustness.
    pub fn current(&self, v: f64) -> f64 {
        let ut = ProcessNode::ut(self.t_c) * self.n_ideal;
        let x = (v / ut).min(80.0);
        self.i_sat * (x.exp() - 1.0)
    }

    /// Inverse: forward voltage needed to carry current `i` [V].
    pub fn voltage(&self, i: f64) -> f64 {
        let ut = ProcessNode::ut(self.t_c) * self.n_ideal;
        ut * (i / self.i_sat + 1.0).ln()
    }
}

/// Diode-connected MOSFET (V_g = V_d = anode, source = cathode).
#[derive(Clone, Debug)]
pub struct MosDiode {
    pub dev: Mosfet,
}

impl MosDiode {
    pub fn new(node: &'static ProcessNode) -> Self {
        Self {
            dev: Mosfet::square(node, Polarity::N),
        }
    }

    /// Current from anode (drain+gate) at `va` into cathode at `vk`.
    pub fn current(&self, va: f64, vk: f64) -> f64 {
        self.dev.ids(va, vk, va).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::CMOS180;

    #[test]
    fn diode_rectifies() {
        let d = Diode::new(&CMOS180);
        assert!(d.current(0.5) > 0.0);
        assert!(d.current(-0.5) < 0.0); // reverse leakage, tiny
        assert!(d.current(-0.5).abs() <= d.i_sat * 1.01);
        assert!(d.current(0.0).abs() < 1e-30);
    }

    #[test]
    fn diode_voltage_roundtrip() {
        let d = Diode::new(&CMOS180);
        for i in [1e-12, 1e-9, 1e-6] {
            let v = d.voltage(i);
            let i2 = d.current(v);
            assert!((i2 / i - 1.0).abs() < 1e-6, "i={i} i2={i2}");
        }
    }

    #[test]
    fn diode_exponential_decade_per_ut() {
        let d = Diode::new(&CMOS180);
        let ut = ProcessNode::ut(27.0) * d.n_ideal;
        let v = 0.4;
        let ratio = d.current(v + ut * std::f64::consts::LN_10) / d.current(v);
        assert!((ratio - 10.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn mos_diode_monotone() {
        let d = MosDiode::new(&CMOS180);
        let mut last = 0.0;
        for step in 0..10 {
            let va = 0.2 + 0.1 * step as f64;
            let i = d.current(va, 0.0);
            assert!(i >= last);
            last = i;
        }
    }
}
