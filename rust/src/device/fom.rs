//! Figure-of-merit sweeps (Fig. 1): transconductance efficiency gm/Id and
//! the gm/Id · f_T product versus overdrive voltage, per process node.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use super::ekv::Mosfet;
use crate::pdk::{Polarity, ProcessNode};

/// One sweep point of Fig. 1.
#[derive(Clone, Debug)]
pub struct FomPoint {
    /// overdrive V_gs − V_th [V]
    pub vov: f64,
    /// gm/Id [1/V]
    pub gm_over_id: f64,
    /// f_T [GHz]
    pub ft_ghz: f64,
    /// the paper's FOM: (gm/Id)·f_T [GHz/V]
    pub fom: f64,
}

/// Sweep gm/Id and the FOM across overdrive for a node (Fig. 1 curves).
pub fn fom_sweep(node: &'static ProcessNode, npts: usize) -> Vec<FomPoint> {
    let dev = Mosfet::square(node, Polarity::N);
    let vt = dev.vt_eff();
    let lo = -0.4;
    let hi = (node.vdd - vt).min(1.0);
    (0..npts)
        .map(|i| {
            let vov = lo + (hi - lo) * i as f64 / (npts - 1) as f64;
            let vg = vt + vov;
            let id = dev.forward(vg, 0.0) - node.leak_floor;
            let gm = dev.gm(vg, 0.0);
            let gm_over_id = gm / id.max(1e-30);
            let ft = dev.ft_ghz(vg, 0.0);
            FomPoint {
                vov,
                gm_over_id,
                ft_ghz: ft,
                fom: gm_over_id * ft,
            }
        })
        .collect()
}

/// Overdrive voltage at which the FOM peaks (should land in moderate
/// inversion — the Fig. 1 claim driving the whole paper).
pub fn fom_peak_vov(node: &'static ProcessNode) -> f64 {
    let pts = fom_sweep(node, 141);
    pts.iter()
        .max_by(|a, b| a.fom.partial_cmp(&b.fom).unwrap())
        .map(|p| p.vov)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{CMOS180, CMOS22, FINFET7};

    #[test]
    fn gm_over_id_bounded_by_wi_limit() {
        // gm/Id <= 1/(n UT): the weak-inversion limit
        for node in [&CMOS180, &CMOS22, &FINFET7] {
            let limit = 1.0 / (node.n_slope * ProcessNode::ut(27.0));
            for p in fom_sweep(node, 41) {
                assert!(
                    p.gm_over_id <= limit * 1.05,
                    "{}: gm/Id={} limit={limit}",
                    node.name,
                    p.gm_over_id
                );
            }
        }
    }

    #[test]
    fn finfet_higher_wi_gm_over_id() {
        // smaller n -> closer to the 1/UT ideal (Fig. 1: 7nm curve on top)
        let p7 = fom_sweep(&FINFET7, 41);
        let p180 = fom_sweep(&CMOS180, 41);
        assert!(p7[0].gm_over_id > p180[0].gm_over_id);
    }

    #[test]
    fn fom_peaks_in_moderate_inversion() {
        // Fig. 1: the efficiency-speed product peaks near Vov ~ 0 (MI)
        for node in [&CMOS180, &CMOS22, &FINFET7] {
            let peak = fom_peak_vov(node);
            assert!(
                (-0.15..0.35).contains(&peak),
                "{}: FOM peak at vov={peak}",
                node.name
            );
        }
    }

    #[test]
    fn ft_increases_with_overdrive() {
        let pts = fom_sweep(&CMOS180, 41);
        assert!(pts.last().unwrap().ft_ghz > pts[0].ft_ghz * 10.0);
    }
}
