//! EKV-style all-region MOSFET compact model.
//!
//! The paper's argument (Sec. III-A) needs only the *structure* of the
//! drain current: `I_ds = I_S [ f(V_g, V_s) − f(V_g, V_d) ]` (eq. 10) with a
//! forward-current function `f` that is zero at the origin, non-negative,
//! and monotone (increasing in V_g, decreasing in V_s).  The EKV
//! interpolation supplies exactly that, continuously from weak through
//! moderate to strong inversion:
//!
//! ```text
//!     F(v)  = ln²(1 + e^{v/2})                       (normalized)
//!     v_p   = (V_G − V_T0) / n                        (pinch-off)
//!     i_f   = F((v_p − V_S)/U_T),  i_r = F((v_p − V_D)/U_T)
//!     I_DS  = I_S · (W/L) · (i_f − i_r)
//! ```
//!
//! Weak inversion: `F(v) → e^v` (exponential); strong inversion:
//! `F(v) → (v/2)²` (square law); moderate inversion interpolates — this is
//! what Fig. 1's gm/Id plot and Fig. 3's bias-scalability rest on.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use crate::pdk::{Polarity, ProcessNode};

/// One transistor instance with geometry, temperature and mismatch state.
#[derive(Clone, Debug)]
pub struct Mosfet {
    pub node: &'static ProcessNode,
    pub polarity: Polarity,
    /// width [µm] (for FinFET: fins × per-fin width — use `with_fins`)
    pub w_um: f64,
    /// length [µm]
    pub l_um: f64,
    /// junction temperature [°C]
    pub t_c: f64,
    /// threshold mismatch ΔV_T [V] (sampled by `device::mismatch`)
    pub dvt: f64,
    /// current-factor mismatch Δβ/β (fractional)
    pub dbeta: f64,
    /// source-shift voltage [V] (deep-threshold technique, Fig. 5)
    pub source_shift: f64,
    /// body tied to VDD (channel-conduction manipulation, Sec. III-C):
    /// raises the effective V_T0 via back-gate effect
    pub body_at_vdd: bool,
}

impl Mosfet {
    /// Minimum-geometry device at 27 °C, no mismatch.  (FinFET: one fin at
    /// minimum gate length; planar: minimum W and L.)
    pub fn square(node: &'static ProcessNode, polarity: Polarity) -> Self {
        Mosfet {
            node,
            polarity,
            w_um: node.wmin_um,
            l_um: node.lmin_um,
            t_c: 27.0,
            dvt: 0.0,
            dbeta: 0.0,
            source_shift: 0.0,
            body_at_vdd: false,
        }
    }

    /// FinFET sizing: width quantized to `fins` fins.
    pub fn with_fins(mut self, fins: usize) -> Self {
        self.w_um = fins.max(1) as f64 * self.node.wmin_um;
        self
    }

    pub fn at_temp(mut self, t_c: f64) -> Self {
        self.t_c = t_c;
        self
    }

    /// normalized EKV interpolation F(v) = ln²(1+e^{v/2}),
    /// numerically-stable for large |v|.
    #[inline]
    pub fn f_interp(v: f64) -> f64 {
        let half = 0.5 * v;
        // ln(1+e^x): x>30 -> x; x<-30 -> e^x
        let ln1p = if half > 30.0 {
            half
        } else if half < -30.0 {
            half.exp()
        } else {
            half.exp().ln_1p()
        };
        ln1p * ln1p
    }

    /// Effective V_T0 including temperature, mismatch, and the
    /// channel-conduction body bias (Fig. 5b raises V_T by a body-effect
    /// offset when the bulk is tied to VDD for NMOS).
    pub fn vt_eff(&self) -> f64 {
        let mut vt = self.node.vt0_at(self.t_c) + self.dvt;
        if self.body_at_vdd {
            // reverse body bias for NMOS with bulk at VDD is *forward*;
            // the paper uses it on PMOS-style connection to suppress
            // channel inversion — model as a fixed +120 mV shift.
            vt += 0.12;
        }
        vt
    }

    /// Specific current I_S·W/L at temperature, with β mismatch [A].
    pub fn i_s(&self) -> f64 {
        self.node.i_spec_at(self.t_c) * (self.w_um / self.l_um) * (1.0 + self.dbeta)
    }

    /// Frozen operating-point constants (§Perf: `i_spec_at` hides a `powf`
    /// and `vt_eff` a handful of branches — hoist them out of the nested
    /// solver's inner loop, which evaluates `forward` ~10⁴ times per unit).
    pub fn op_point(&self) -> DevOp {
        DevOp {
            ut: ProcessNode::ut(self.t_c),
            vt: self.vt_eff(),
            i_s: self.i_s(),
            n_slope: self.node.n_slope,
            theta: self.node.theta,
            leak: self.node.leak_floor,
            source_shift: self.source_shift,
        }
    }

    /// The paper's forward-current function f(V_g, V_s) [A] (eq. 10 term).
    ///
    /// Voltages are node voltages for an N-device; P-devices are handled by
    /// sign reflection in `ids`.  Includes the junction-leakage floor so the
    /// deep-threshold regime bottoms out at femtoamps (Fig. 5a).
    pub fn forward(&self, vg: f64, vs: f64) -> f64 {
        self.op_point().forward(vg, vs)
    }

    /// Drain-source current I_DS(V_g, V_s, V_d) [A] (eq. 10).
    pub fn ids(&self, vg: f64, vs: f64, vd: f64) -> f64 {
        match self.polarity {
            Polarity::N => self.forward(vg, vs) - self.forward(vg, vd),
            // P-device: reflect about VDD
            Polarity::P => {
                let vdd = self.node.vdd;
                let refl = |v: f64| vdd - v;
                let n = Mosfet {
                    polarity: Polarity::N,
                    ..self.clone()
                };
                n.forward(refl(vg), refl(vs)) - n.forward(refl(vg), refl(vd))
            }
        }
    }

    /// Saturation drain current (V_d high enough that reverse term ~0).
    pub fn ids_sat(&self, vg: f64, vs: f64) -> f64 {
        self.forward(vg, vs) - self.node.leak_floor + self.node.leak_floor
        // forward() already includes the floor once; keep as-is
    }

    /// Transconductance ∂I_D/∂V_G at a saturated operating point [S]
    /// (central difference — always consistent with `ids`).
    pub fn gm(&self, vg: f64, vs: f64) -> f64 {
        let dv = 1e-5;
        (self.forward(vg + dv, vs) - self.forward(vg - dv, vs)) / (2.0 * dv)
    }

    /// Inversion coefficient IC = I_D / (I_S·W/L) at the operating point.
    pub fn inversion_coefficient(&self, vg: f64, vs: f64) -> f64 {
        (self.forward(vg, vs) - self.node.leak_floor) / self.i_s()
    }

    /// Transit frequency estimate [GHz]: f_T ∝ g_m / C_gg with C_gg from
    /// the node's C_ox and the device geometry.  Calibrated so that strong
    /// inversion at V_ov = 0.3 V hits `node.ft_si_ghz` for a square device.
    pub fn ft_ghz(&self, vg: f64, vs: f64) -> f64 {
        let cgg = self.node.cox_ff_um2 * self.w_um * self.l_um; // fF
        let gm = self.gm(vg, vs); // S
        // reference gm for calibration
        let ref_dev = Mosfet::square(self.node, Polarity::N);
        let vg_ref = ref_dev.vt_eff() + 0.3;
        let gm_ref = ref_dev.gm(vg_ref, 0.0);
        let cgg_ref = self.node.cox_ff_um2 * ref_dev.w_um * ref_dev.l_um;
        self.node.ft_si_ghz * (gm / gm_ref) * (cgg_ref / cgg)
    }
}

/// Hoisted per-device constants for the hot loop (see `Mosfet::op_point`).
#[derive(Clone, Copy, Debug)]
pub struct DevOp {
    pub ut: f64,
    pub vt: f64,
    pub i_s: f64,
    pub n_slope: f64,
    pub theta: f64,
    pub leak: f64,
    pub source_shift: f64,
}

impl DevOp {
    /// f(V_g, V_s) with all device constants pre-resolved.
    #[inline]
    pub fn forward(&self, vg: f64, vs: f64) -> f64 {
        let vs_eff = vs + self.source_shift;
        let vp = (vg - self.vt) / self.n_slope;
        let mut i = self.i_s * Mosfet::f_interp((vp - vs_eff) / self.ut);
        // mobility degradation / velocity saturation above threshold:
        // flattens gm at high overdrive (Fig. 1's MI peak)
        let vov = (vg - self.vt - self.n_slope * vs_eff).max(0.0);
        i /= 1.0 + self.theta * vov;
        i + self.leak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{CMOS180, FINFET7};
    use crate::util::propcheck::check;

    #[test]
    fn f_interp_asymptotes() {
        // weak inversion: F(v) ~ e^v for very negative v
        for v in [-20.0, -15.0, -10.0] {
            let r = Mosfet::f_interp(v) / v.exp();
            assert!((r - 1.0).abs() < 0.02, "v={v} ratio={r}");
        }
        // strong inversion: F(v) ~ (v/2)^2 for large v
        for v in [40.0, 80.0] {
            let r = Mosfet::f_interp(v) / (v / 2.0 * (v / 2.0));
            assert!((r - 1.0).abs() < 0.1, "v={v} ratio={r}");
        }
        assert!(Mosfet::f_interp(0.0) > 0.0);
    }

    #[test]
    fn forward_properties_paper_sec3a() {
        // the three bullet properties of f(.,.) from Sec. III-A
        check(1, 200, |g| -> Result<(), String> {
            let dev = Mosfet::square(&CMOS180, Polarity::N);
            let vg = g.f64_in(0.0, 1.8);
            let vs = g.f64_in(0.0, 1.0);
            let f = dev.forward(vg, vs);
            crate::prop_assert!(f >= 0.0, "f must be non-negative");
            // monotone in vg
            let f_up = dev.forward(vg + 0.05, vs);
            crate::prop_assert!(f_up >= f, "f must increase with Vg");
            // anti-monotone in vs
            let f_vs = dev.forward(vg, vs + 0.05);
            crate::prop_assert!(f_vs <= f, "f must decrease with Vs");
            Ok(())
        });
    }

    #[test]
    fn ids_zero_at_equal_sd() {
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        for vg in [0.2, 0.5, 1.0] {
            let i = dev.ids(vg, 0.3, 0.3);
            assert!(i.abs() < 1e-18, "vg={vg} i={i}");
        }
    }

    #[test]
    fn ids_sign_reverses_with_sd_swap() {
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let a = dev.ids(0.8, 0.0, 0.5);
        let b = dev.ids(0.8, 0.5, 0.0);
        assert!(a > 0.0);
        assert!((a + b).abs() < 1e-12 * a.abs().max(1.0));
    }

    #[test]
    fn subthreshold_slope_matches_n() {
        // in WI, I ~ exp(Vg/(n UT)): slope of ln(I) vs Vg = 1/(n UT)
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let vt = dev.vt_eff();
        let (v1, v2) = (vt - 0.30, vt - 0.25);
        let slope = ((dev.forward(v2, 0.0) - CMOS180.leak_floor).ln()
            - (dev.forward(v1, 0.0) - CMOS180.leak_floor).ln())
            / (v2 - v1);
        let expect = 1.0 / (CMOS180.n_slope * ProcessNode::ut(27.0));
        assert!(
            (slope / expect - 1.0).abs() < 0.05,
            "slope={slope} expect={expect}"
        );
    }

    #[test]
    fn square_law_in_strong_inversion() {
        // I ~ (Vov)^2: doubling the overdrive quadruples the current
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let vt = dev.vt_eff();
        let i1 = dev.forward(vt + 0.4, 0.0);
        let i2 = dev.forward(vt + 0.8, 0.0);
        // ideal square law gives 4.0; mobility degradation shaves it
        let ratio = i2 / i1 * (1.0 + CMOS180.theta * 0.8) / (1.0 + CMOS180.theta * 0.4);
        assert!((ratio - 4.0).abs() < 0.6, "ratio={ratio}");
    }

    #[test]
    fn pmos_mirrors_nmos() {
        let n = Mosfet::square(&CMOS180, Polarity::N);
        let p = Mosfet::square(&CMOS180, Polarity::P);
        let vdd = CMOS180.vdd;
        let a = n.ids(0.9, 0.0, 0.6);
        let b = p.ids(vdd - 0.9, vdd, vdd - 0.6);
        assert!((a - b).abs() < 1e-9 * a.abs().max(1e-12), "a={a} b={b}");
    }

    #[test]
    fn source_shift_reaches_femtoamp_floor() {
        // Fig. 5a: source shifting pushes the minimum current to the
        // leakage floor (~2 fA at 180nm)
        let mut dev = Mosfet::square(&CMOS180, Polarity::N);
        dev.source_shift = 0.3;
        let i = dev.forward(0.0, 0.0);
        assert!(i < 1e-14, "i={i}");
        assert!(i >= CMOS180.leak_floor);
    }

    #[test]
    fn body_bias_raises_threshold() {
        let mut dev = Mosfet::square(&CMOS180, Polarity::N);
        let i0 = dev.forward(0.3, 0.0);
        dev.body_at_vdd = true;
        let i1 = dev.forward(0.3, 0.0);
        assert!(i1 < i0);
    }

    #[test]
    fn gm_positive_and_peaks_in_wi_per_id() {
        // gm/Id must decrease monotonically from WI to SI (Fig. 1)
        let dev = Mosfet::square(&FINFET7, Polarity::N);
        let vt = dev.vt_eff();
        let mut last = f64::INFINITY;
        for vov in [-0.25, -0.1, 0.0, 0.1, 0.25, 0.4] {
            let vg = vt + vov;
            let id = dev.forward(vg, 0.0) - FINFET7.leak_floor;
            let gmid = dev.gm(vg, 0.0) / id;
            assert!(gmid > 0.0);
            assert!(gmid <= last * 1.02, "gm/Id not decreasing at vov={vov}");
            last = gmid;
        }
    }

    #[test]
    fn temperature_increases_wi_current() {
        // WI current rises steeply with T (lower Vt, higher UT)
        let cold = Mosfet::square(&CMOS180, Polarity::N).at_temp(-45.0);
        let hot = Mosfet::square(&CMOS180, Polarity::N).at_temp(125.0);
        let vg = 0.25; // deep WI
        assert!(hot.forward(vg, 0.0) > 10.0 * cold.forward(vg, 0.0));
    }

    #[test]
    fn fin_quantization() {
        let d1 = Mosfet::square(&FINFET7, Polarity::N).with_fins(1);
        let d4 = Mosfet::square(&FINFET7, Polarity::N).with_fins(4);
        let vg = d1.vt_eff() + 0.2;
        let r = d4.forward(vg, 0.0) / d1.forward(vg, 0.0);
        assert!((r - 4.0).abs() < 0.1, "r={r}");
    }

    #[test]
    fn ft_calibration_point() {
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let vg = dev.vt_eff() + 0.3;
        let ft = dev.ft_ghz(vg, 0.0);
        assert!((ft - CMOS180.ft_si_ghz).abs() / CMOS180.ft_si_ghz < 0.01);
    }
}
