//! Pelgrom-law mismatch sampling (the paper's [9], [28]).
//!
//! σ(ΔV_T) = A_VT / sqrt(W·L),  σ(Δβ/β) = A_β / sqrt(W·L), with W·L in µm²
//! and A_VT in mV·µm.  FinFET devices quantize W to fins, so minimum-size
//! devices at 7 nm see *larger relative* mismatch despite the smaller A_VT
//! — Fig. 13b/c's story.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use super::ekv::Mosfet;
use crate::pdk::ProcessNode;
use crate::util::rng::Rng;

/// Mismatch sampler for one process node.
#[derive(Clone, Debug)]
pub struct MismatchModel {
    pub node: &'static ProcessNode,
}

impl MismatchModel {
    pub fn new(node: &'static ProcessNode) -> Self {
        Self { node }
    }

    /// σ(ΔV_T) [V] for a device of area `w_um * l_um`.
    pub fn sigma_vt(&self, w_um: f64, l_um: f64) -> f64 {
        self.node.avt_mv_um * 1e-3 / (w_um * l_um).sqrt()
    }

    /// σ(Δβ/β) (fractional) for a device of given area.
    pub fn sigma_beta(&self, w_um: f64, l_um: f64) -> f64 {
        self.node.abeta_pct_um * 0.01 / (w_um * l_um).sqrt()
    }

    /// Sample mismatch onto a device (returns a perturbed clone).
    pub fn sample(&self, dev: &Mosfet, rng: &mut Rng) -> Mosfet {
        let mut d = dev.clone();
        d.dvt = rng.gauss_ms(0.0, self.sigma_vt(dev.w_um, dev.l_um));
        d.dbeta = rng.gauss_ms(0.0, self.sigma_beta(dev.w_um, dev.l_um));
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{Polarity, CMOS180, FINFET7};
    use crate::util::stats::summarize;

    #[test]
    fn sigma_scales_with_area() {
        let m = MismatchModel::new(&CMOS180);
        // quadrupling area halves sigma
        let s1 = m.sigma_vt(1.0, 1.0);
        let s4 = m.sigma_vt(2.0, 2.0);
        assert!((s1 / s4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sampled_moments_match_pelgrom() {
        let m = MismatchModel::new(&CMOS180);
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let mut rng = Rng::new(5);
        let dvts: Vec<f64> = (0..5000)
            .map(|_| m.sample(&dev, &mut rng).dvt)
            .collect();
        let s = summarize(&dvts);
        let expect = m.sigma_vt(dev.w_um, dev.l_um);
        assert!(s.mean.abs() < 0.1 * expect);
        assert!((s.std / expect - 1.0).abs() < 0.05, "std={} expect={expect}", s.std);
    }

    #[test]
    fn min_size_finfet_worse_relative_mismatch_than_large_cmos() {
        let m7 = MismatchModel::new(&FINFET7);
        let m180 = MismatchModel::new(&CMOS180);
        // one-fin minimum device vs a comfortably sized 180nm device
        let s7 = m7.sigma_vt(FINFET7.wmin_um, FINFET7.lmin_um);
        let s180 = m180.sigma_vt(2.0, 0.5);
        assert!(s7 > s180, "s7={s7} s180={s180}");
    }
}
