//! Pelgrom-law mismatch sampling (the paper's [9], [28]).
//!
//! σ(ΔV_T) = A_VT / sqrt(W·L),  σ(Δβ/β) = A_β / sqrt(W·L), with W·L in µm²
//! and A_VT in mV·µm.  FinFET devices quantize W to fins, so minimum-size
//! devices at 7 nm see *larger relative* mismatch despite the smaller A_VT
//! — Fig. 13b/c's story.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use super::ekv::Mosfet;
use crate::pdk::{regime::Regime, Polarity, ProcessNode};
use crate::util::rng::Rng;

/// Mismatch sampler for one process node.
#[derive(Clone, Debug)]
pub struct MismatchModel {
    pub node: &'static ProcessNode,
}

impl MismatchModel {
    pub fn new(node: &'static ProcessNode) -> Self {
        Self { node }
    }

    /// σ(ΔV_T) [V] for a device of area `w_um * l_um`.
    pub fn sigma_vt(&self, w_um: f64, l_um: f64) -> f64 {
        self.node.avt_mv_um * 1e-3 / (w_um * l_um).sqrt()
    }

    /// σ(Δβ/β) (fractional) for a device of given area.
    pub fn sigma_beta(&self, w_um: f64, l_um: f64) -> f64 {
        self.node.abeta_pct_um * 0.01 / (w_um * l_um).sqrt()
    }

    /// Sample mismatch onto a device (returns a perturbed clone).
    pub fn sample(&self, dev: &Mosfet, rng: &mut Rng) -> Mosfet {
        let mut d = dev.clone();
        d.dvt = rng.gauss_ms(0.0, self.sigma_vt(dev.w_um, dev.l_um));
        d.dbeta = rng.gauss_ms(0.0, self.sigma_beta(dev.w_um, dev.l_um));
        d
    }

    /// Current-mirror gain error of an input matched pair at the node's
    /// analog sizing: the mismatched device's drain current over the nominal
    /// one's, both at the regime bias point `V_bias(regime, t_c)`.
    ///
    /// This is the same matched-pair math `cells::CircuitCorner` applies to
    /// its inputs; exposed here so the fault-injection harness can derive
    /// physically calibrated per-branch gains without paying a full nested
    /// bisection circuit solve per evaluation.
    pub fn mirror_gain(&self, regime: Regime, t_c: f64, dvt: f64, dbeta: f64) -> f64 {
        let mut nom = Mosfet::square(self.node, Polarity::N);
        nom.w_um = self.node.analog_w_um;
        nom.l_um = self.node.analog_l_um;
        nom.t_c = t_c;
        let mut mm = nom.clone();
        mm.dvt = dvt;
        mm.dbeta = dbeta;
        let vg = self.node.bias_for(regime, t_c);
        mm.forward(vg, 0.0) / nom.forward(vg, 0.0)
    }

    /// Sample `n` independent mirror gains at the analog sizing, with the
    /// Pelgrom sigmas scaled by `sigma_scale` (1.0 = paper-calibrated).
    /// Deterministic given `rng`'s state; `sigma_scale == 0.0` yields exact
    /// unit gains without consuming random draws.
    pub fn sample_mirror_gains(
        &self,
        regime: Regime,
        t_c: f64,
        n: usize,
        sigma_scale: f64,
        rng: &mut Rng,
    ) -> Vec<f64> {
        if sigma_scale == 0.0 {
            return vec![1.0; n];
        }
        let s_vt = sigma_scale * self.sigma_vt(self.node.analog_w_um, self.node.analog_l_um);
        let s_b = sigma_scale * self.sigma_beta(self.node.analog_w_um, self.node.analog_l_um);
        (0..n)
            .map(|_| {
                let dvt = rng.gauss_ms(0.0, s_vt);
                let dbeta = rng.gauss_ms(0.0, s_b);
                self.mirror_gain(regime, t_c, dvt, dbeta)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{Polarity, CMOS180, FINFET7};
    use crate::util::stats::summarize;

    #[test]
    fn sigma_scales_with_area() {
        let m = MismatchModel::new(&CMOS180);
        // quadrupling area halves sigma
        let s1 = m.sigma_vt(1.0, 1.0);
        let s4 = m.sigma_vt(2.0, 2.0);
        assert!((s1 / s4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sampled_moments_match_pelgrom() {
        let m = MismatchModel::new(&CMOS180);
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let mut rng = Rng::new(5);
        let dvts: Vec<f64> = (0..5000)
            .map(|_| m.sample(&dev, &mut rng).dvt)
            .collect();
        let s = summarize(&dvts);
        let expect = m.sigma_vt(dev.w_um, dev.l_um);
        assert!(s.mean.abs() < 0.1 * expect);
        assert!((s.std / expect - 1.0).abs() < 0.05, "std={} expect={expect}", s.std);
    }

    #[test]
    fn min_size_finfet_worse_relative_mismatch_than_large_cmos() {
        let m7 = MismatchModel::new(&FINFET7);
        let m180 = MismatchModel::new(&CMOS180);
        // one-fin minimum device vs a comfortably sized 180nm device
        let s7 = m7.sigma_vt(FINFET7.wmin_um, FINFET7.lmin_um);
        let s180 = m180.sigma_vt(2.0, 0.5);
        assert!(s7 > s180, "s7={s7} s180={s180}");
    }

    #[test]
    fn mirror_gain_is_unity_without_mismatch() {
        for node in crate::pdk::ProcessNode::all() {
            let m = MismatchModel::new(node);
            let g = m.mirror_gain(Regime::WeakInversion, 27.0, 0.0, 0.0);
            assert_eq!(g, 1.0, "node {}", node.name);
        }
    }

    #[test]
    fn mirror_gain_suppressed_in_strong_inversion() {
        // WI is exponentially sensitive to dVt; SI only quadratically — the
        // same threshold shift must perturb the SI mirror far less.
        let m = MismatchModel::new(&CMOS180);
        let dvt = 3e-3;
        let g_wi = m.mirror_gain(Regime::WeakInversion, 27.0, dvt, 0.0);
        let g_si = m.mirror_gain(Regime::StrongInversion, 27.0, dvt, 0.0);
        assert!(
            (g_si - 1.0).abs() < (g_wi - 1.0).abs(),
            "g_wi={g_wi} g_si={g_si}"
        );
        assert!((g_wi - 1.0).abs() > 1e-3, "WI gain should visibly move");
    }

    #[test]
    fn mirror_gain_symmetric_in_wi() {
        // WI mirror gain ~ exp(±dVt/(n·UT)): opposite shifts should be
        // (approximately) reciprocal.
        let m = MismatchModel::new(&CMOS180);
        let dvt = 2e-3;
        let gp = m.mirror_gain(Regime::WeakInversion, 27.0, dvt, 0.0);
        let gm = m.mirror_gain(Regime::WeakInversion, 27.0, -dvt, 0.0);
        assert!((gp * gm - 1.0).abs() < 0.01, "gp={gp} gm={gm}");
    }

    #[test]
    fn sampled_gains_scale_with_sigma() {
        let m = MismatchModel::new(&FINFET7);
        let exact = m.sample_mirror_gains(Regime::WeakInversion, 27.0, 16, 0.0, &mut Rng::new(1));
        assert!(exact.iter().all(|&g| g == 1.0));
        let mild: Vec<f64> =
            m.sample_mirror_gains(Regime::WeakInversion, 27.0, 200, 0.5, &mut Rng::new(1));
        let full: Vec<f64> =
            m.sample_mirror_gains(Regime::WeakInversion, 27.0, 200, 1.0, &mut Rng::new(1));
        let spread = |gs: &[f64]| summarize(gs).std;
        assert!(
            spread(&full) > 1.5 * spread(&mild),
            "full={} mild={}",
            spread(&full),
            spread(&mild)
        );
        // paper-calibrated gains stay within a few percent of unity
        assert!(full.iter().all(|&g| (0.8..1.2).contains(&g)));
    }

    #[test]
    fn adjacent_trial_forks_give_uncorrelated_pelgrom_draws() {
        // One deterministic stream per trial: fork(t) and fork(t+1) must be
        // statistically independent, or per-trial mismatch samples would
        // alias across trials.
        let m = MismatchModel::new(&CMOS180);
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        for base in [1u64, 77, 4096] {
            let root = Rng::new(base);
            let mut a = root.fork(10);
            let mut b = root.fork(11);
            let n = 2000;
            let da: Vec<f64> = (0..n).map(|_| m.sample(&dev, &mut a).dvt).collect();
            let db: Vec<f64> = (0..n).map(|_| m.sample(&dev, &mut b).dvt).collect();
            let r = crate::util::stats::pearson(&da, &db);
            assert!(r.abs() < 0.1, "base={base} pearson={r}");
        }
    }
}
