//! Device models: EKV all-region MOSFET (planar + FinFET), diodes,
//! Pelgrom mismatch, noise PSDs and the Fig. 1 figure-of-merit sweeps.

pub mod diode;
pub mod ekv;
pub mod fom;
pub mod mismatch;
pub mod noise;

pub use diode::{Diode, MosDiode};
pub use ekv::Mosfet;
pub use mismatch::MismatchModel;
