//! Device noise PSDs for the SNR analysis (Sec. IV-L3).
//!
//! Channel thermal noise `4kT·γ·gm` (γ: 2/3 SI, 1/2 WI where the channel is
//! shot-noise-like `2qI`), used by `analysis::snr` to verify the paper's
//! claim that N parallel S-AC blocks improve SNR by ~2× per doubling
//! (coherent signal vs incoherent noise summation, eq. 31-36).

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use super::ekv::Mosfet;

const KB: f64 = 1.380_649e-23;
const Q: f64 = 1.602_176_634e-19;

/// Current-noise PSD of a saturated device at its operating point [A²/Hz].
pub fn channel_noise_psd(dev: &Mosfet, vg: f64, vs: f64) -> f64 {
    let t_k = dev.t_c + 273.15;
    let id = dev.forward(vg, vs) - dev.node.leak_floor;
    let gm = dev.gm(vg, vs);
    let ic = dev.inversion_coefficient(vg, vs);
    if ic < 0.1 {
        // weak inversion: full shot noise
        2.0 * Q * id.max(0.0)
    } else {
        // moderate/strong: thermal with gamma interpolated 1/2 -> 2/3
        let gamma = 0.5 + (2.0 / 3.0 - 0.5) * (ic / (ic + 10.0));
        4.0 * KB * t_k * gamma * gm
    }
}

/// RMS noise current over bandwidth `bw_hz` [A].
pub fn rms_noise(dev: &Mosfet, vg: f64, vs: f64, bw_hz: f64) -> f64 {
    (channel_noise_psd(dev, vg, vs) * bw_hz).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{Polarity, CMOS180};

    #[test]
    fn wi_is_shot_noise() {
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let vg = dev.vt_eff() - 0.25; // WI
        let id = dev.forward(vg, 0.0) - CMOS180.leak_floor;
        let psd = channel_noise_psd(&dev, vg, 0.0);
        assert!((psd / (2.0 * Q * id) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn noise_grows_with_current() {
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let vt = dev.vt_eff();
        let lo = channel_noise_psd(&dev, vt - 0.2, 0.0);
        let hi = channel_noise_psd(&dev, vt + 0.4, 0.0);
        assert!(hi > lo);
    }

    #[test]
    fn rms_scales_sqrt_bandwidth() {
        let dev = Mosfet::square(&CMOS180, Polarity::N);
        let vg = dev.vt_eff() + 0.1;
        let r1 = rms_noise(&dev, vg, 0.0, 1e6);
        let r4 = rms_noise(&dev, vg, 0.0, 4e6);
        assert!((r4 / r1 - 2.0).abs() < 1e-9);
    }
}
