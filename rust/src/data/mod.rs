//! Dataset and weight loading (the SACD binary format and the
//! `weights_<task>.json` blobs written by the python training pipeline).
//!
//! The *test sets scored here are byte-identical to the ones the python
//! side trained/evaluated against* — that is what makes the Table IV
//! H/W-vs-S/W comparison meaningful.  Generators for standalone use (demo
//! examples without artifacts) also live here.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::util::json::parse_file;
use crate::util::rng::Rng;

/// A labelled dataset: row-major f32 features + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<u16>,
    pub n: usize,
    pub d: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Load the SACD binary format (see python sacml/data.py::save_dataset).
    pub fn load_sacd(path: &Path) -> Result<Dataset> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        if bytes.len() < 16 || &bytes[..4] != b"SACD" {
            bail!("{}: not an SACD file", path.display());
        }
        let rd32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let ver = rd32(4);
        if ver != 1 {
            bail!("unsupported SACD version {ver}");
        }
        let n = rd32(8) as usize;
        let d = rd32(12) as usize;
        let data_end = 16 + 4 * n * d;
        if bytes.len() < data_end + 2 * n {
            bail!("{}: truncated", path.display());
        }
        let mut x = Vec::with_capacity(n * d);
        for i in 0..n * d {
            let o = 16 + 4 * i;
            x.push(f32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()));
        }
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let o = data_end + 2 * i;
            y.push(u16::from_le_bytes(bytes[o..o + 2].try_into().unwrap()));
        }
        Ok(Dataset { x, y, n, d })
    }
}

/// Trained network weights (+ metadata) from `weights_<task>.json`.
#[derive(Clone, Debug)]
pub struct TrainedNet {
    pub task: String,
    pub sizes: Vec<usize>,
    pub activation: String,
    pub splines: usize,
    pub c: f64,
    pub acc_sw: f64,
    pub acc_sac_algorithmic: f64,
    /// row-major weight matrices w1..wL ([in × out]) and biases b1..bL
    pub weights: Vec<Vec<f64>>,
    pub biases: Vec<Vec<f64>>,
}

impl TrainedNet {
    pub fn load(path: &Path) -> Result<TrainedNet> {
        let j = parse_file(path)?;
        let activation = j.get("activation")?.as_str()?.to_string();
        // Validate here so serving / evaluation hot loops never meet an
        // unknown activation name (nn::forward relies on this).
        crate::nn::Activation::parse(&activation)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let sizes: Vec<usize> = j
            .get("sizes")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        let wobj = j.get("weights")?;
        let nl = sizes.len() - 1;
        let mut weights = Vec::with_capacity(nl);
        let mut biases = Vec::with_capacity(nl);
        for li in 1..=nl {
            let wm = wobj.get(&format!("w{li}"))?.as_f64_mat()?;
            if wm.len() != sizes[li - 1] {
                bail!("w{li} row count {} != {}", wm.len(), sizes[li - 1]);
            }
            weights.push(wm.into_iter().flatten().collect());
            biases.push(wobj.get(&format!("b{li}"))?.as_f64_vec()?);
        }
        Ok(TrainedNet {
            task: j.get("task")?.as_str()?.to_string(),
            sizes,
            activation,
            splines: j.get("splines")?.as_usize()?,
            c: j.get("c")?.as_f64()?,
            acc_sw: j.get("acc_sw")?.as_f64()?,
            acc_sac_algorithmic: j.get("acc_sac_algorithmic")?.as_f64()?,
            weights,
            biases,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// Parsed hidden-activation kind.  `Err` only for hand-constructed
    /// nets with a bogus name — [`TrainedNet::load`] validates on disk
    /// input, so loaded nets always succeed.
    pub fn activation_kind(&self) -> Result<crate::nn::Activation> {
        crate::nn::Activation::parse(&self.activation)
    }

    /// `w[layer][i][k]` accessor (layer 0-based, row-major `[in × out]`).
    pub fn w(&self, layer: usize, i: usize, k: usize) -> f64 {
        let out = self.sizes[layer + 1];
        self.weights[layer][i * out + k]
    }
}

/// Standalone XOR generator (mirror of python make_xor for demos that run
/// without artifacts; not used for Table IV scoring).
pub fn gen_xor(n: usize, seed: u64, noise: f64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 2);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut a = rng.uniform_in(-1.0, 1.0);
        let mut b = rng.uniform_in(-1.0, 1.0);
        a += 0.08 * a.signum();
        b += 0.08 * b.signum();
        let label = ((a > 0.0) ^ (b > 0.0)) as u16;
        x.push((a + rng.gauss_ms(0.0, noise)) as f32);
        x.push((b + rng.gauss_ms(0.0, noise)) as f32);
        y.push(label);
    }
    Dataset { x, y, n, d: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sacd_roundtrip_handwritten() {
        // craft a tiny SACD file by hand
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SACD");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes()); // n
        bytes.extend_from_slice(&3u32.to_le_bytes()); // d
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for l in [7u16, 9u16] {
            bytes.extend_from_slice(&l.to_le_bytes());
        }
        let dir = std::env::temp_dir().join("sac_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        std::fs::write(&p, &bytes).unwrap();
        let ds = Dataset::load_sacd(&p).unwrap();
        assert_eq!((ds.n, ds.d), (2, 3));
        assert_eq!(ds.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(ds.y, vec![7, 9]);
    }

    #[test]
    fn sacd_rejects_garbage() {
        let dir = std::env::temp_dir().join("sac_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE0000000000000000").unwrap();
        assert!(Dataset::load_sacd(&p).is_err());
    }

    #[test]
    fn weights_json_parses() {
        let text = r#"{
            "task": "toy", "sizes": [2, 3, 2], "activation": "phi1",
            "splines": 3, "c": 1.0, "acc_sw": 0.9, "acc_sac_algorithmic": 0.88,
            "weights": {
                "w1": [[1, 2, 3], [4, 5, 6]], "b1": [0.1, 0.2, 0.3],
                "w2": [[1, 0], [0, 1], [1, 1]], "b2": [0, 0]
            }
        }"#;
        let dir = std::env::temp_dir().join("sac_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.json");
        std::fs::write(&p, text).unwrap();
        let net = TrainedNet::load(&p).unwrap();
        assert_eq!(net.sizes, vec![2, 3, 2]);
        assert_eq!(net.n_layers(), 2);
        assert_eq!(net.w(0, 1, 2), 6.0);
        assert_eq!(net.biases[0], vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn weights_json_rejects_unknown_activation() {
        // the load-time validation path of the satellite: an unknown
        // activation is an error here, not a panic inside nn::forward
        let text = r#"{
            "task": "toy", "sizes": [2, 2], "activation": "gelu",
            "splines": 1, "c": 1.0, "acc_sw": 0.0, "acc_sac_algorithmic": 0.0,
            "weights": { "w1": [[1, 0], [0, 1]], "b1": [0, 0] }
        }"#;
        let dir = std::env::temp_dir().join("sac_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad_act.json");
        std::fs::write(&p, text).unwrap();
        let err = TrainedNet::load(&p).unwrap_err();
        assert!(err.to_string().contains("gelu"), "unexpected error: {err:#}");
    }

    #[test]
    fn activation_kind_parses() {
        let text = r#"{
            "task": "toy", "sizes": [2, 2], "activation": "softplus",
            "splines": 1, "c": 1.0, "acc_sw": 0.0, "acc_sac_algorithmic": 0.0,
            "weights": { "w1": [[1, 0], [0, 1]], "b1": [0, 0] }
        }"#;
        let dir = std::env::temp_dir().join("sac_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("good_act.json");
        std::fs::write(&p, text).unwrap();
        let net = TrainedNet::load(&p).unwrap();
        assert_eq!(
            net.activation_kind().unwrap(),
            crate::nn::Activation::Softplus
        );
    }

    #[test]
    fn gen_xor_labels() {
        let ds = gen_xor(200, 3, 0.0);
        let mut correct = 0;
        for i in 0..ds.n {
            let r = ds.row(i);
            let expect = ((r[0] > 0.0) ^ (r[1] > 0.0)) as u16;
            if expect == ds.y[i] {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.n as f64 > 0.97);
    }
}
