//! Criterion-like micro-benchmark harness (criterion is not vendored).
//!
//! Warms up, picks an iteration count targeting a fixed measurement window,
//! collects per-sample timings and reports mean / std / min / p50 /
//! throughput.  Used by `rust/benches/*.rs` (wired as `harness = false`
//! cargo benches) and by the §Perf iteration loop.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// nanoseconds per iteration, one entry per sample
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::summarize(&self.samples_ns).mean
    }

    pub fn std_ns(&self) -> f64 {
        stats::summarize(&self.samples_ns).std
    }

    pub fn min_ns(&self) -> f64 {
        stats::summarize(&self.samples_ns).min
    }

    pub fn p50_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    /// items/second given `items` work items per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns() * 1e-9)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{:>8}, min {:>10}, p50 {:>10}, {} samples × {} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.std_ns()),
            fmt_ns(self.min_ns()),
            fmt_ns(self.p50_ns()),
            self.samples_ns.len(),
            self.iters_per_sample,
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1200),
            samples: 20,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            samples: 8,
        }
    }

    /// Run `f` repeatedly; `f` should return something observable to keep
    /// the optimizer honest (we black-box it).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + estimate cost of one iteration.
        let t0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / warm_iters.max(1) as f64;
        let budget_ns = self.measure.as_nanos() as f64 / self.samples as f64;
        let iters = ((budget_ns / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        BenchResult {
            name: name.to_string(),
            samples_ns,
            iters_per_sample: iters,
        }
    }
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns() > 0.0);
        assert_eq!(r.samples_ns.len(), 8);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn throughput_sane() {
        let b = Bench::quick();
        let r = b.run("noop", || 1u64);
        // a no-op loop iteration should exceed 1M items/s comfortably
        assert!(r.throughput(1.0) > 1e6);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
