//! Minimal JSON parser/writer (serde_json is not vendored in this image).
//!
//! Covers the full JSON grammar; numbers are f64 (adequate for weights,
//! configs and result files).  The parser is a straightforward recursive
//! descent over bytes with proper string-escape handling.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// `[1.0, 2.0, ...]` -> Vec<f64>
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// `[[...], [...]]` -> row-major matrix
    pub fn as_f64_mat(&self) -> Result<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(|r| r.as_f64_vec()).collect()
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // surrogate pairs: join if a high surrogate
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    let joined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(joined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // collect the raw utf-8 byte run
                    let start = self.pos - 1;
                    while self.pos < self.bytes.len()
                        && self.bytes[self.pos] != b'"'
                        && self.bytes[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1], Json::Num(2.0));
        assert_eq!(*arr[2].get("b").unwrap(), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"mat":[[1.5,2],[3,-4.25]],"name":"w1","ok":true}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn matrix_accessor() {
        let j = parse("[[1,2],[3,4]]").unwrap();
        let m = j.as_f64_mat().unwrap();
        assert_eq!(m, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    fn unicode_escape() {
        let j = parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_serialize_clean() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
