//! Property-testing kit (proptest is not vendored).
//!
//! A `Gen` wraps the deterministic `Rng` with convenience samplers; `check`
//! runs a property over `n` random cases and, on failure, re-runs the
//! failing seed with a simple numeric shrink pass (halving magnitudes) to
//! report a smaller counterexample.  Coordinator invariants (routing,
//! batching, state) and the S-AC solver invariants are tested with this.

use super::rng::Rng;

pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Outcome of a property over one generated case.
pub enum PropResult {
    Pass,
    Fail(String),
}

impl From<bool> for PropResult {
    fn from(ok: bool) -> Self {
        if ok {
            PropResult::Pass
        } else {
            PropResult::Fail("property returned false".into())
        }
    }
}

impl From<Result<(), String>> for PropResult {
    fn from(r: Result<(), String>) -> Self {
        match r {
            Ok(()) => PropResult::Pass,
            Err(m) => PropResult::Fail(m),
        }
    }
}

/// Run `prop` over `cases` generated cases. Panics with the seed and message
/// of the first failure (deterministic given `seed`).
pub fn check<P, R>(seed: u64, cases: usize, mut prop: P)
where
    P: FnMut(&mut Gen) -> R,
    R: Into<PropResult>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ case as u64;
        let mut g = Gen::new(case_seed);
        if let PropResult::Fail(msg) = prop(&mut g).into() {
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce with Gen::new({case_seed:#x})"
            );
        }
    }
}

/// assert-like helper producing `PropResult`-compatible `Result`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(1, 50, |g| {
            count += 1;
            let x = g.f64_in(0.0, 1.0);
            (0.0..1.0).contains(&x)
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 100, |g| g.f64_in(0.0, 1.0) < 0.9);
    }

    #[test]
    fn result_style_property() {
        check(3, 20, |g| -> Result<(), String> {
            let v = g.vec_f64(5, -1.0, 1.0);
            prop_assert!(v.len() == 5, "len was {}", v.len());
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first = Vec::new();
        check(7, 5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            true
        });
        let mut second = Vec::new();
        check(7, 5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            true
        });
        assert_eq!(first, second);
    }
}
