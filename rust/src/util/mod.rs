//! Infrastructure substrates built in-repo (the image vendors no
//! serde_json / clap / rayon / criterion / proptest — see DESIGN.md §4).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod table;
