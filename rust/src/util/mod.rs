//! Infrastructure substrates built in-repo — the image vendors no
//! serde_json / clap / rayon / criterion / proptest, so each has a small,
//! property-tested substitute here (see the repo-root DESIGN.md
//! §"Infrastructure substrates" for the full table):
//!
//! * [`json`] — recursive-descent JSON parser/writer
//! * [`cli`] — flag/positional argument parsing
//! * [`rng`] — deterministic SplitMix64/Xoshiro256++ with forkable streams
//! * [`pool`] — scoped `parallel_map` + the persistent serving `WorkerPool`
//! * [`propcheck`] — seeded property-testing runner
//! * [`benchkit`] — warmup/sampling micro-benchmark harness
//! * [`stats`] — summaries, percentiles, confusion matrices, histograms
//! * [`table`] — ASCII tables, CSV writers, terminal plots
//! * [`trace`] — structured spans with a ring-buffer sink, zero-cost
//!   when disabled (DESIGN.md §9)

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod table;
pub mod trace;
