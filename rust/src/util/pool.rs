//! Thread pools (rayon is not vendored).
//!
//! Two substrates live here:
//!
//! * [`parallel_map`] / [`parallel_reduce`] — a *scoped* fork-join pool for
//!   parallel sweeps and Monte-Carlo trials.  The unit of work is an index
//!   range split into contiguous chunks, drained by `nthreads` workers
//!   through an atomic cursor — simple, allocation-free work distribution
//!   that scales fine for coarse-grained trials (each MC trial is thousands
//!   of device evaluations).  `std::thread::scope` gives us safe borrows.
//!
//! * [`WorkerPool`] — a *persistent* pool of named worker threads draining
//!   a queue of boxed jobs.  This is the execution substrate of the serving
//!   router (`coordinator::router`): batches materialize on the submit path
//!   and are executed by whichever worker frees up first.  Shutdown is
//!   graceful — on drop the pool finishes every queued job before joining,
//!   so no accepted work is silently discarded.
//!
//! * [`WorkerPool::run_scoped`] — an *allocation-free* scoped broadcast on
//!   top of the persistent pool: the caller publishes a stack-held shard
//!   task, participates in draining the shard cursor alongside the
//!   workers, and blocks until every claimed shard finished.  This is the
//!   dispatch path of the batched kernel's row-slab parallelism
//!   (`nn::batch`), where the steady state must not allocate.
//!
//! The batched kernel's slabs run on the process-wide [`shared_pool`],
//! *not* on the router's pool: router workers block inside
//! `Engine::run_batch` waiting on slab completion, so handing slabs to the
//! same pool could deadlock once every worker is a waiter.  Two pools (and
//! caller participation in `run_scoped`) make that cycle impossible.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default (leaves one core for the OS).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Kernel thread count requested through the `SAC_THREADS` environment
/// variable, or `None` when unset/unparseable.  `0` clamps to `1` (serial),
/// matching every other thread knob in the crate.
pub fn threads_from_env() -> Option<usize> {
    parse_threads(&std::env::var("SAC_THREADS").ok()?)
}

/// The parse behind [`threads_from_env`], split out so tests need not
/// mutate process-global environment state.
fn parse_threads(s: &str) -> Option<usize> {
    s.trim().parse::<usize>().ok().map(|n| n.max(1))
}

/// Process-wide pool for the batched kernel's row-slab dispatch, created
/// lazily at [`default_threads`] workers and shared by every
/// `BatchKernel` for the process lifetime.  Deliberately distinct from
/// any router [`WorkerPool`] — see the module docs for the deadlock
/// argument.
pub fn shared_pool() -> Arc<WorkerPool> {
    static SLAB_POOL: Mutex<Option<Arc<WorkerPool>>> = Mutex::new(None);
    let mut g = SLAB_POOL.lock().unwrap();
    if let Some(p) = g.as_ref() {
        return Arc::clone(p);
    }
    let p = Arc::new(WorkerPool::new(default_threads()));
    *g = Some(Arc::clone(&p));
    p
}

/// Run `f(i)` for every `i in 0..n` across `nthreads` workers, collecting
/// results in index order.  `f` must be `Sync` (called from many threads).
pub fn parallel_map<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let cursor = AtomicUsize::new(0);
    // fine-grained stealing: chunk of 1..=8 depending on n
    let chunk = (n / (nthreads * 8)).clamp(1, 64);

    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        std::thread::scope(|scope| {
            for _ in 0..nthreads {
                let f = &f;
                let cursor = &cursor;
                let out_ptr = *out_ref;
                scope.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        let val = f(i);
                        // SAFETY: each index i is claimed by exactly one
                        // worker via the atomic cursor, and `out` outlives
                        // the scope.
                        unsafe {
                            *out_ptr.get().add(i) = Some(val);
                        }
                    }
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("worker wrote all")).collect()
}

/// Like `parallel_map` but reduces results with `combine` (order-insensitive).
pub fn parallel_reduce<T, F, R>(n: usize, nthreads: usize, f: F, init: T, combine: R) -> T
where
    T: Send + Clone,
    F: Fn(usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    parallel_map(n, nthreads, f)
        .into_iter()
        .fold(init, combine)
}

struct SendPtr<T>(*mut T);

// manual Clone/Copy: the derive would demand `T: Copy`, but we only copy
// the pointer itself.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Whole-struct accessor: keeps edition-2021 disjoint closure capture
    /// from capturing the raw pointer field (which is not `Send`) directly.
    fn get(&self) -> *mut T {
        self.0
    }
}
// SAFETY: distinct indices are written by distinct workers (atomic cursor).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A stack-held scoped broadcast, published into the pool by
/// [`WorkerPool::run_scoped`].  The closure is type-erased through a
/// `(fn, data)` pair instead of a boxed trait object so publishing a task
/// performs no allocation.
struct ScopedTask {
    /// Invokes the caller's closure: `call(data, shard)`.
    call: unsafe fn(*const (), usize),
    data: *const (),
    /// Shard-claim cursor: `fetch_add(1)` hands out `0..shards`.
    next: AtomicUsize,
    shards: usize,
    /// Workers currently inside the task (caller not counted).
    active: AtomicUsize,
    panicked: AtomicBool,
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

/// Shared-slot pointer to a [`ScopedTask`].  Validity: the publishing
/// caller clears the slot (under the pool lock) and waits for `active` to
/// reach zero before its stack frame — and thus the task — goes away, so
/// any worker that observed the slot non-empty under the lock may
/// dereference until it decrements `active`.
#[derive(Clone, Copy)]
struct ScopedRef(*const ScopedTask);

impl ScopedRef {
    /// Whole-struct accessor (same edition-2021 capture note as [`SendPtr`]).
    fn get(self) -> *const ScopedTask {
        self.0
    }
}
// SAFETY: see the validity argument on the type.
unsafe impl Send for ScopedRef {}

/// Claim and run shards off `task`'s cursor until it is exhausted.  A
/// panicking shard is contained (flagged on the task) so the cursor always
/// drains and the remaining shards still run.
fn claim_scoped(task: &ScopedTask) {
    loop {
        let s = task.next.fetch_add(1, Ordering::Relaxed);
        if s >= task.shards {
            return;
        }
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (task.call)(task.data, s)
        }));
        if r.is_err() {
            task.panicked.store(true, Ordering::SeqCst);
        }
    }
}

struct PoolState {
    jobs: VecDeque<Job>,
    /// At most one scoped broadcast is published at a time; a second
    /// concurrent `run_scoped` runs serially on its caller instead.
    scoped: Option<ScopedRef>,
}

struct PoolInner {
    state: Mutex<PoolState>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Workers respawned by the supervisor after a panic killed one.
    respawns: AtomicU64,
    /// Join handles of respawned workers (drained by `Drop`; a
    /// replacement can itself die and push another handle, so the drop
    /// loop drains until empty).
    respawned: Mutex<Vec<thread::JoinHandle<()>>>,
}

/// A persistent pool of worker threads executing boxed jobs from a shared
/// queue.  Cheap cloneable submit handles ([`PoolHandle`]) let auxiliary
/// threads (e.g. the router's deadline flusher) enqueue work without owning
/// the pool.
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// Submit-only handle to a [`WorkerPool`].
#[derive(Clone)]
pub struct PoolHandle {
    inner: Arc<PoolInner>,
}

impl WorkerPool {
    /// Spawn `nthreads` named workers (`sac-worker-N`), each supervised:
    /// a worker killed by a panicking job is detected and replaced (see
    /// [`RespawnSentinel`]).
    pub fn new(nthreads: usize) -> WorkerPool {
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                scoped: None,
            }),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            respawns: AtomicU64::new(0),
            respawned: Mutex::new(Vec::new()),
        });
        let handles = (0..nthreads.max(1))
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("sac-worker-{i}"))
                    .spawn(move || supervised_worker(inner))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { inner, handles }
    }

    /// A cloneable submit handle.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle {
            inner: Arc::clone(&self.inner),
        }
    }

    /// Enqueue a job for the next free worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.handle().execute(job);
    }

    /// Jobs accepted but not yet started.
    pub fn queued(&self) -> usize {
        self.inner.state.lock().unwrap().jobs.len()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Workers respawned by the supervisor after a panicking job killed
    /// one.  The pool's capacity is invariant under panics: every death
    /// is matched by a replacement (until shutdown).
    pub fn respawns(&self) -> u64 {
        self.inner.respawns.load(Ordering::SeqCst)
    }

    /// Run `f(s)` for every shard `s in 0..shards`, spread across the
    /// pool's workers *and* the calling thread, returning once every shard
    /// completed.  Shards are claimed through an atomic cursor, so each
    /// runs exactly once; which thread runs which shard is unspecified.
    ///
    /// Allocation-free: the task lives on the caller's stack and the
    /// closure is type-erased without boxing, which is what lets the
    /// batched kernel's steady-state forward pass stay zero-alloc.
    /// Caller participation guarantees progress even with zero free
    /// workers, and a second concurrent `run_scoped` (the broadcast slot
    /// holds one task) degrades to running serially on its caller.
    ///
    /// Panics if any shard panicked (after the cursor drained), so a
    /// poisoned result buffer can never be read back as valid.
    pub fn run_scoped<F: Fn(usize) + Sync>(&self, shards: usize, f: F) {
        if shards <= 1 {
            if shards == 1 {
                f(0);
            }
            return;
        }
        unsafe fn call_shard<F: Fn(usize)>(data: *const (), s: usize) {
            (*(data as *const F))(s)
        }
        let task = ScopedTask {
            call: call_shard::<F>,
            data: &f as *const F as *const (),
            next: AtomicUsize::new(0),
            shards,
            active: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
        };
        {
            let mut st = self.inner.state.lock().unwrap();
            if st.scoped.is_some() {
                drop(st);
                claim_scoped(&task);
                if task.panicked.load(Ordering::SeqCst) {
                    panic!("run_scoped: a shard panicked");
                }
                return;
            }
            st.scoped = Some(ScopedRef(&task));
            self.inner.cv.notify_all();
        }
        // The caller drains the cursor alongside the workers.
        claim_scoped(&task);
        // Unpublish: workers that have not yet observed the slot (under
        // the lock) will never enter the task...
        self.inner.state.lock().unwrap().scoped = None;
        // ...and those that did are counted in `active`; wait them out.
        // The decrement happens under `done_mx`, so once we observe zero
        // here no worker touches the task again and the stack frame may
        // safely unwind.
        {
            let mut g = task.done_mx.lock().unwrap();
            while task.active.load(Ordering::SeqCst) > 0 {
                g = task.done_cv.wait(g).unwrap();
            }
        }
        if task.panicked.load(Ordering::SeqCst) {
            panic!("run_scoped: a shard panicked");
        }
    }
}

impl PoolHandle {
    /// Enqueue a job for the next free worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.inner
            .state
            .lock()
            .unwrap()
            .jobs
            .push_back(Box::new(job));
        self.inner.cv.notify_one();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Respawned workers join last — and a replacement dying mid-drain
        // spawns another replacement, so loop until the list stays empty.
        loop {
            let batch: Vec<thread::JoinHandle<()>> = match self.inner.respawned.lock() {
                Ok(mut v) => v.drain(..).collect(),
                Err(_) => return,
            };
            if batch.is_empty() {
                return;
            }
            self.inner.cv.notify_all();
            for h in batch {
                let _ = h.join();
            }
        }
    }
}

enum Work {
    Queued(Job),
    Scoped(ScopedRef),
}

/// Supervision guard living on every worker's stack.  If the worker
/// unwinds (a queued job panicked), the guard's `Drop` runs during the
/// unwind and spawns a replacement, so pool capacity survives any panic
/// storm.  Requeue semantics stay with the job's owner: the router
/// catches engine panics itself and retries the in-flight batch exactly
/// once, so the supervisor never re-runs user code (no double execution).
struct RespawnSentinel {
    inner: Arc<PoolInner>,
}

impl Drop for RespawnSentinel {
    fn drop(&mut self) {
        if !thread::panicking() {
            return; // normal shutdown exit
        }
        let n = self.inner.respawns.fetch_add(1, Ordering::SeqCst) + 1;
        let inner = Arc::clone(&self.inner);
        // Everything here is `if let`: a second panic during unwind would
        // abort the process, so no unwraps on this path.
        if let Ok(h) = thread::Builder::new()
            .name(format!("sac-worker-r{n}"))
            .spawn(move || supervised_worker(inner))
        {
            if let Ok(mut v) = self.inner.respawned.lock() {
                v.push(h);
            }
        }
    }
}

/// Worker entry point: installs the supervision sentinel, then drains the
/// pool until shutdown.
fn supervised_worker(inner: Arc<PoolInner>) {
    let _sentinel = RespawnSentinel {
        inner: Arc::clone(&inner),
    };
    worker_loop(&inner);
}

fn worker_loop(inner: &PoolInner) {
    loop {
        let work = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break Some(Work::Queued(j));
                }
                if let Some(sc) = st.scoped {
                    // SAFETY: the slot is published, so the task outlives
                    // this critical section (the caller needs this same
                    // lock to unpublish it); incrementing `active` while
                    // still inside the lock extends that lifetime until
                    // the matching decrement below.
                    let task = unsafe { &*sc.get() };
                    if task.next.load(Ordering::Relaxed) < task.shards {
                        task.active.fetch_add(1, Ordering::SeqCst);
                        break Some(Work::Scoped(sc));
                    }
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        match work {
            // A panicking job unwinds the worker — deliberately.  The
            // supervision sentinel on this thread's stack detects the
            // unwind and spawns a replacement, so the pool never loses
            // capacity; the job's owner is responsible for reporting its
            // own failures (the router converts engine panics to failure
            // records and retries transient ones itself).
            Some(Work::Queued(j)) => j(),
            Some(Work::Scoped(sc)) => {
                // SAFETY: `active` was incremented under the lock above,
                // so the publishing caller is still waiting on us.
                let task = unsafe { &*sc.get() };
                claim_scoped(task);
                let _g = task.done_mx.lock().unwrap();
                task.active.fetch_sub(1, Ordering::SeqCst);
                task.done_cv.notify_all();
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn reduce_sums() {
        let total = parallel_reduce(100, 3, |i| i as u64, 0u64, |a, b| a + b);
        assert_eq!(total, 4950);
    }

    #[test]
    fn threads_more_than_items() {
        let out = parallel_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn worker_pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful: drains the queue before joining
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_pool_handle_submits() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.workers(), 2);
        let h = pool.handle();
        let flag = Arc::new(AtomicBool::new(false));
        let f = Arc::clone(&flag);
        h.execute(move || f.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn worker_pool_survives_panicking_job() {
        let pool = WorkerPool::new(1);
        pool.execute(|| panic!("job blew up"));
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(done.load(Ordering::SeqCst), "worker died with the panic");
    }

    #[test]
    fn worker_pool_respawns_dead_workers() {
        let pool = WorkerPool::new(1);
        pool.execute(|| panic!("worker down"));
        // wait for the supervisor to notice the death and replace the worker
        let t0 = std::time::Instant::now();
        while pool.respawns() == 0 && t0.elapsed() < std::time::Duration::from_secs(10) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.respawns(), 1, "supervisor never respawned the worker");
        // the replacement drains subsequent work
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(done.load(Ordering::SeqCst));
    }

    #[test]
    fn respawned_workers_are_themselves_supervised() {
        // a panic storm kills the original worker and two replacements;
        // each death is matched by a respawn and the final replacement
        // still drains the queue (Drop's loop-join covers the chain)
        let pool = WorkerPool::new(1);
        for _ in 0..3 {
            pool.execute(|| panic!("again"));
        }
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(done.load(Ordering::SeqCst), "queue stranded by panic storm");
    }

    #[test]
    fn worker_pool_zero_threads_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(done.load(Ordering::SeqCst));
    }

    #[test]
    fn run_scoped_covers_every_shard_exactly_once() {
        let pool = WorkerPool::new(3);
        let shards = 17;
        let hits: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
        pool.run_scoped(shards, |s| {
            hits[s].fetch_add(1, Ordering::SeqCst);
        });
        for (s, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "shard {s}");
        }
    }

    #[test]
    fn run_scoped_single_shard_runs_inline() {
        let pool = WorkerPool::new(2);
        let tid = std::thread::current().id();
        let same = Arc::new(AtomicBool::new(false));
        let same2 = Arc::clone(&same);
        pool.run_scoped(1, move |s| {
            assert_eq!(s, 0);
            same2.store(std::thread::current().id() == tid, Ordering::SeqCst);
        });
        assert!(
            same.load(Ordering::SeqCst),
            "single shard must run on the caller, never touch the pool"
        );
        pool.run_scoped(0, |_| panic!("zero shards must run nothing"));
    }

    #[test]
    fn run_scoped_propagates_shard_panic_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let ran = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&ran);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(8, |s| {
                r2.fetch_add(1, Ordering::SeqCst);
                if s == 3 {
                    panic!("shard blew up");
                }
            });
        }));
        assert!(res.is_err(), "shard panic must propagate to the caller");
        // the contained panic drained the cursor: every shard still ran
        assert_eq!(ran.load(Ordering::SeqCst), 8);
        // and the pool remains fully usable afterwards
        let ok = Arc::new(AtomicUsize::new(0));
        let o2 = Arc::clone(&ok);
        pool.run_scoped(4, move |_| {
            o2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn run_scoped_concurrent_callers_both_complete() {
        let pool = Arc::new(WorkerPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                scope.spawn(move || {
                    for _ in 0..10 {
                        let t = &total;
                        pool.run_scoped(6, |_| {
                            t.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 3 * 10 * 6);
    }

    #[test]
    fn run_scoped_interleaves_with_queued_jobs() {
        let pool = WorkerPool::new(2);
        let jobs = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let j = Arc::clone(&jobs);
            pool.execute(move || {
                j.fetch_add(1, Ordering::SeqCst);
            });
        }
        let shards_run = Arc::new(AtomicUsize::new(0));
        let s2 = Arc::clone(&shards_run);
        pool.run_scoped(12, move |_| {
            s2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(shards_run.load(Ordering::SeqCst), 12);
        drop(pool);
        assert_eq!(jobs.load(Ordering::SeqCst), 32, "queued jobs were lost");
    }

    #[test]
    fn parse_threads_clamps_and_rejects() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads("0"), Some(1), "0 clamps to serial");
        assert_eq!(parse_threads(""), None);
        assert_eq!(parse_threads("many"), None);
    }

    #[test]
    fn shared_pool_is_process_wide() {
        let a = shared_pool();
        let b = shared_pool();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.workers() >= 1);
    }
}
