//! Scoped thread pool for parallel sweeps and Monte-Carlo trials
//! (rayon is not vendored; std::thread::scope gives us safe borrows).
//!
//! The unit of work is an index range split into contiguous chunks, one
//! queue entry per chunk, drained by `nthreads` workers through an atomic
//! cursor — simple, allocation-free work distribution that scales fine for
//! our coarse-grained trials (each MC trial is thousands of device
//! evaluations).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (leaves one core for the OS).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n` across `nthreads` workers, collecting
/// results in index order.  `f` must be `Sync` (called from many threads).
pub fn parallel_map<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let cursor = AtomicUsize::new(0);
    // fine-grained stealing: chunk of 1..=8 depending on n
    let chunk = (n / (nthreads * 8)).clamp(1, 64);

    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        std::thread::scope(|scope| {
            for _ in 0..nthreads {
                let f = &f;
                let cursor = &cursor;
                let out_ptr = *out_ref;
                scope.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        let val = f(i);
                        // SAFETY: each index i is claimed by exactly one
                        // worker via the atomic cursor, and `out` outlives
                        // the scope.
                        unsafe {
                            *out_ptr.get().add(i) = Some(val);
                        }
                    }
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("worker wrote all")).collect()
}

/// Like `parallel_map` but reduces results with `combine` (order-insensitive).
pub fn parallel_reduce<T, F, R>(n: usize, nthreads: usize, f: F, init: T, combine: R) -> T
where
    T: Send + Clone,
    F: Fn(usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    parallel_map(n, nthreads, f)
        .into_iter()
        .fold(init, combine)
}

struct SendPtr<T>(*mut T);

// manual Clone/Copy: the derive would demand `T: Copy`, but we only copy
// the pointer itself.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Whole-struct accessor: keeps edition-2021 disjoint closure capture
    /// from capturing the raw pointer field (which is not `Send`) directly.
    fn get(&self) -> *mut T {
        self.0
    }
}
// SAFETY: distinct indices are written by distinct workers (atomic cursor).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn reduce_sums() {
        let total = parallel_reduce(100, 3, |i| i as u64, 0u64, |a, b| a + b);
        assert_eq!(total, 4950);
    }

    #[test]
    fn threads_more_than_items() {
        let out = parallel_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }
}
