//! Thread pools (rayon is not vendored).
//!
//! Two substrates live here:
//!
//! * [`parallel_map`] / [`parallel_reduce`] — a *scoped* fork-join pool for
//!   parallel sweeps and Monte-Carlo trials.  The unit of work is an index
//!   range split into contiguous chunks, drained by `nthreads` workers
//!   through an atomic cursor — simple, allocation-free work distribution
//!   that scales fine for coarse-grained trials (each MC trial is thousands
//!   of device evaluations).  `std::thread::scope` gives us safe borrows.
//!
//! * [`WorkerPool`] — a *persistent* pool of named worker threads draining
//!   a queue of boxed jobs.  This is the execution substrate of the serving
//!   router (`coordinator::router`): batches materialize on the submit path
//!   and are executed by whichever worker frees up first.  Shutdown is
//!   graceful — on drop the pool finishes every queued job before joining,
//!   so no accepted work is silently discarded.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default (leaves one core for the OS).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n` across `nthreads` workers, collecting
/// results in index order.  `f` must be `Sync` (called from many threads).
pub fn parallel_map<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let cursor = AtomicUsize::new(0);
    // fine-grained stealing: chunk of 1..=8 depending on n
    let chunk = (n / (nthreads * 8)).clamp(1, 64);

    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        std::thread::scope(|scope| {
            for _ in 0..nthreads {
                let f = &f;
                let cursor = &cursor;
                let out_ptr = *out_ref;
                scope.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        let val = f(i);
                        // SAFETY: each index i is claimed by exactly one
                        // worker via the atomic cursor, and `out` outlives
                        // the scope.
                        unsafe {
                            *out_ptr.get().add(i) = Some(val);
                        }
                    }
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("worker wrote all")).collect()
}

/// Like `parallel_map` but reduces results with `combine` (order-insensitive).
pub fn parallel_reduce<T, F, R>(n: usize, nthreads: usize, f: F, init: T, combine: R) -> T
where
    T: Send + Clone,
    F: Fn(usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    parallel_map(n, nthreads, f)
        .into_iter()
        .fold(init, combine)
}

struct SendPtr<T>(*mut T);

// manual Clone/Copy: the derive would demand `T: Copy`, but we only copy
// the pointer itself.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Whole-struct accessor: keeps edition-2021 disjoint closure capture
    /// from capturing the raw pointer field (which is not `Send`) directly.
    fn get(&self) -> *mut T {
        self.0
    }
}
// SAFETY: distinct indices are written by distinct workers (atomic cursor).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolInner {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A persistent pool of worker threads executing boxed jobs from a shared
/// queue.  Cheap cloneable submit handles ([`PoolHandle`]) let auxiliary
/// threads (e.g. the router's deadline flusher) enqueue work without owning
/// the pool.
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// Submit-only handle to a [`WorkerPool`].
#[derive(Clone)]
pub struct PoolHandle {
    inner: Arc<PoolInner>,
}

impl WorkerPool {
    /// Spawn `nthreads` named workers (`sac-worker-N`).
    pub fn new(nthreads: usize) -> WorkerPool {
        let inner = Arc::new(PoolInner {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..nthreads.max(1))
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("sac-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { inner, handles }
    }

    /// A cloneable submit handle.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle {
            inner: Arc::clone(&self.inner),
        }
    }

    /// Enqueue a job for the next free worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.handle().execute(job);
    }

    /// Jobs accepted but not yet started.
    pub fn queued(&self) -> usize {
        self.inner.jobs.lock().unwrap().len()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl PoolHandle {
    /// Enqueue a job for the next free worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.inner.jobs.lock().unwrap().push_back(Box::new(job));
        self.inner.cv.notify_one();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &PoolInner) {
    loop {
        let job = {
            let mut q = inner.jobs.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = inner.cv.wait(q).unwrap();
            }
        };
        match job {
            // A panicking job must not kill the worker: the pool would
            // silently lose capacity for the rest of the process.  The
            // job's owner is responsible for reporting its own failures
            // (the router converts panics to failure records itself).
            Some(j) => {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j));
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn reduce_sums() {
        let total = parallel_reduce(100, 3, |i| i as u64, 0u64, |a, b| a + b);
        assert_eq!(total, 4950);
    }

    #[test]
    fn threads_more_than_items() {
        let out = parallel_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn worker_pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful: drains the queue before joining
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_pool_handle_submits() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.workers(), 2);
        let h = pool.handle();
        let flag = Arc::new(AtomicBool::new(false));
        let f = Arc::clone(&flag);
        h.execute(move || f.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn worker_pool_survives_panicking_job() {
        let pool = WorkerPool::new(1);
        pool.execute(|| panic!("job blew up"));
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(done.load(Ordering::SeqCst), "worker died with the panic");
    }

    #[test]
    fn worker_pool_zero_threads_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicBool::new(false));
        let d = Arc::clone(&done);
        pool.execute(move || d.store(true, Ordering::SeqCst));
        drop(pool);
        assert!(done.load(Ordering::SeqCst));
    }
}
