//! ASCII tables, CSV writers and terminal sparkline plots for the repro
//! harness (`sac repro ...`) — every paper table/figure is rendered through
//! these so the output is diffable and lands in `results/*.csv`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Simple column-aligned ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "── {} ──", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = width[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = width.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Write the table as CSV.
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            let esc: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", esc.join(","))?;
        }
        Ok(())
    }
}

/// Render an xy-series as a compact ASCII plot (rows = amplitude bins).
pub fn ascii_plot(series: &[(&str, &[f64])], height: usize, width: usize) -> String {
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .filter(|y| y.is_finite())
        .collect();
    if all.is_empty() {
        return String::from("(empty plot)\n");
    }
    let lo = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let n = ys.len();
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let col = if n <= 1 { 0 } else { i * (width - 1) / (n - 1) };
            let rowf = (y - lo) / span * (height - 1) as f64;
            let row = height - 1 - (rowf.round() as usize).min(height - 1);
            grid[row][col] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{hi:>10.3}")
        } else if r == height - 1 {
            format!("{lo:>10.3}")
        } else {
            " ".repeat(10)
        };
        let _ = writeln!(out, "{label} |{}", row.iter().collect::<String>());
    }
    let mut legend = String::from(" ".repeat(11));
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = write!(legend, " {}={}", marks[si % marks.len()], name);
    }
    let _ = writeln!(out, "{legend}");
    out
}

/// Write a generic xy CSV (x plus one column per series).
pub fn write_xy_csv(
    path: &Path,
    xname: &str,
    xs: &[f64],
    series: &[(&str, &[f64])],
) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    let names: Vec<&str> = series.iter().map(|(n, _)| *n).collect();
    writeln!(f, "{},{}", xname, names.join(","))?;
    for (i, x) in xs.iter().enumerate() {
        let mut line = format!("{x}");
        for (_, ys) in series {
            let _ = write!(line, ",{}", ys.get(i).copied().unwrap_or(f64::NAN));
        }
        writeln!(f, "{line}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.5".into()]);
        t.row(vec!["b".into(), "200".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("| alpha | 1.5   |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let dir = std::env::temp_dir().join("sac_table_test");
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["has,comma".into()]);
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"has,comma\""));
    }

    #[test]
    fn plot_contains_marks() {
        let ys: Vec<f64> = (0..20).map(|i| (i as f64 * 0.3).sin()).collect();
        let s = ascii_plot(&[("sin", &ys)], 8, 40);
        assert!(s.contains('*'));
        assert!(s.contains("sin"));
    }

    #[test]
    fn xy_csv_roundtrip() {
        let dir = std::env::temp_dir().join("sac_table_test");
        let p = dir.join("xy.csv");
        write_xy_csv(&p, "x", &[0.0, 1.0], &[("y", &[5.0, 6.0][..])]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("x,y"));
        assert!(text.contains("1,6"));
    }
}
