//! Tiny CLI argument parser (clap is not vendored).
//!
//! Model: `sac <command> [positional...] [--flag] [--key value]`.
//! Unknown flags are errors; `--help` is synthesized from registered specs.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the binary name). `switch_names` lists
    /// boolean flags that take no value.
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short flags not supported: {a}");
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects a number: {e}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer: {e}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positional() {
        let a = Args::parse(
            &argv(&["repro", "fig3", "--node", "7nm", "--verbose", "--c=2.5"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.command, "repro");
        assert_eq!(a.positional, vec!["fig3"]);
        assert_eq!(a.get("node"), Some("7nm"));
        assert_eq!(a.get("c"), Some("2.5"));
        assert!(a.has("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&argv(&["x", "--n", "42", "--t", "1.5"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert!((a.get_f64("t", 0.0).unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_f64("n", 0.0).is_ok());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["x", "--k"]), &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv(&["x", "--n", "abc"]), &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
