//! Deterministic PRNG substrate (no external rand crates are vendored).
//!
//! `SplitMix64` seeds `Xoshiro256++`, the standard pairing.  Gaussian
//! samples use Box–Muller with a cached spare.  Everything is `Send` and
//! cheap to fork per worker thread, which is what the Monte-Carlo engine
//! (`analysis::montecarlo`) needs: one independent, reproducible stream per
//! trial regardless of thread scheduling.

/// SplitMix64 — used for seeding and cheap stateless mixing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare_gauss: Option<f64>,
}

impl Rng {
    /// Seed from a single u64 (SplitMix64 expansion per the reference impl).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_gauss: None,
        }
    }

    /// Independent stream for worker `i` (used by the MC threadpool).
    pub fn fork(&self, i: u64) -> Self {
        // combine current state with the fork index through SplitMix
        let mut sm = SplitMix64::new(self.s[0] ^ i.wrapping_mul(0xA076_1D64_78BD_642F));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_gauss: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_gauss = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn gauss_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let base = Rng::new(7);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn adjacent_seeds_give_uncorrelated_gauss_streams() {
        // The fault-injection harness seeds one stream per trial with
        // consecutive integers; Pelgrom draws from seed s and s+1 must not
        // correlate. |pearson| for n iid pairs is ~N(0, 1/sqrt(n)); 0.1 at
        // n=4096 is a >6-sigma bound, so a failure means real structure.
        crate::util::propcheck::check(0xC0FFEE, 10, |g| -> Result<(), String> {
            let seed = g.rng.next_u64();
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed.wrapping_add(1));
            let n = 4096;
            let xs: Vec<f64> = (0..n).map(|_| a.gauss()).collect();
            let ys: Vec<f64> = (0..n).map(|_| b.gauss()).collect();
            let r = crate::util::stats::pearson(&xs, &ys);
            crate::prop_assert!(r.abs() < 0.1, "seed={seed} pearson={r}");
            Ok(())
        });
    }

    #[test]
    fn adjacent_forks_give_uncorrelated_uniform_streams() {
        crate::util::propcheck::check(0xBEEF, 10, |g| -> Result<(), String> {
            let root = Rng::new(g.rng.next_u64());
            let t = g.rng.below(1000) as u64;
            let mut a = root.fork(t);
            let mut b = root.fork(t + 1);
            let n = 4096;
            let xs: Vec<f64> = (0..n).map(|_| a.uniform()).collect();
            let ys: Vec<f64> = (0..n).map(|_| b.uniform()).collect();
            let r = crate::util::stats::pearson(&xs, &ys);
            crate::prop_assert!(r.abs() < 0.1, "fork={t} pearson={r}");
            Ok(())
        });
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
