//! Lightweight structured tracing spans (§Observability, DESIGN.md §9).
//!
//! A [`Span`] records an enter/exit pair with a monotonic timestamp, the
//! recording thread's id and a global sequence number into a fixed-size
//! ring buffer.  The design goals, in order:
//!
//!  1. **zero cost when disabled** — `span()` is a relaxed atomic load
//!     plus a two-word struct; no clock read, no lock, no allocation;
//!  2. **no interleaving corruption** — a span is written as one
//!     [`SpanRecord`] on drop (enter and exit together), so concurrent
//!     threads can never tear a record in half;
//!  3. **bounded memory** — the ring overwrites the oldest record once
//!     full and counts what it dropped.
//!
//! Tracing is process-global.  Enable it programmatically with
//! [`enable`], or from the environment with [`init_from_env`]
//! (`SAC_TRACE=1`, optional `SAC_TRACE_CAPACITY=<n>`).
//!
//! **Per-request correlation** (DESIGN.md §12): every span carries a
//! `trace` id (0 = uncorrelated).  The id is minted at router admission
//! and propagated through a thread-local — [`correlate`] installs it for
//! the current scope and restores the previous id on drop, so nested
//! work (engine run, kernel, delivery) inherits the request's id without
//! any plumbing through function signatures.  Worker threads that fan a
//! batch out (row slabs) re-install the caller's id inside the pool
//! closure.  [`export_chrome`] reconstructs the per-request span trees
//! from the ring as Chrome trace-event ("Perfetto") JSON.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Default ring capacity used by [`init_from_env`] when
/// `SAC_TRACE_CAPACITY` is not set.
pub const DEFAULT_CAPACITY: usize = 65536;

/// One completed span: a named enter/exit pair with monotonic
/// nanosecond offsets from the trace epoch (the `enable()` call).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Static span name, e.g. `"router.submit"`.
    pub name: &'static str,
    /// Small dense id of the recording thread (assigned on first span).
    pub thread: u32,
    /// Request correlation id (0 = uncorrelated / infrastructure span).
    pub trace: u64,
    /// Global sequence number in record order (gap-free while enabled).
    pub seq: u64,
    /// Nanoseconds from the trace epoch to span entry.
    pub t_enter_ns: u64,
    /// Nanoseconds from the trace epoch to span exit.
    pub t_exit_ns: u64,
}

impl SpanRecord {
    /// Span duration in nanoseconds (saturating).
    pub fn duration_ns(&self) -> u64 {
        self.t_exit_ns.saturating_sub(self.t_enter_ns)
    }
}

/// Counters describing the trace sink, for exposition in metrics
/// snapshots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Whether tracing is currently enabled.
    pub enabled: bool,
    /// Ring capacity in records (0 when tracing was never enabled).
    pub capacity: usize,
    /// Total spans recorded since the last `enable()`.
    pub recorded: u64,
    /// Spans overwritten after the ring filled.
    pub dropped: u64,
}

struct Ring {
    epoch: Instant,
    buf: Vec<SpanRecord>,
    capacity: usize,
    head: usize,
    seq: u64,
    recorded: u64,
    dropped: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: Mutex<Option<Ring>> = Mutex::new(None);
static NEXT_THREAD_ID: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static THREAD_ID: Cell<u32> = const { Cell::new(0) };
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
}

fn thread_id() -> u32 {
    THREAD_ID.with(|c| {
        let id = c.get();
        if id != 0 {
            return id;
        }
        let id = NEXT_THREAD_ID.fetch_add(1, Ordering::Relaxed);
        c.set(id);
        id
    })
}

/// Turn tracing on with a fresh ring of `capacity` records (clamped to
/// at least 1).  Any previously recorded spans are discarded.
pub fn enable(capacity: usize) {
    let capacity = capacity.max(1);
    let mut g = RING.lock().unwrap();
    *g = Some(Ring {
        epoch: Instant::now(),
        buf: Vec::with_capacity(capacity),
        capacity,
        head: 0,
        seq: 0,
        recorded: 0,
        dropped: 0,
    });
    ENABLED.store(true, Ordering::Release);
}

/// Turn tracing off.  The ring (and its stats) are kept readable via
/// [`snapshot`] / [`stats`] until the next [`enable`].
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// Whether spans are currently being recorded.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable tracing if `SAC_TRACE` is set to `1`/`true`/`on`/`yes`
/// (case-insensitive).  `SAC_TRACE_CAPACITY` overrides the ring size.
pub fn init_from_env() {
    let on = std::env::var("SAC_TRACE")
        .map(|v| {
            let v = v.trim().to_ascii_lowercase();
            v == "1" || v == "true" || v == "on" || v == "yes"
        })
        .unwrap_or(false);
    if !on {
        return;
    }
    let capacity = std::env::var("SAC_TRACE_CAPACITY")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(DEFAULT_CAPACITY);
    enable(capacity);
}

/// Current sink counters (all zero if tracing was never enabled).
pub fn stats() -> TraceStats {
    let g = RING.lock().unwrap();
    match g.as_ref() {
        Some(r) => TraceStats {
            enabled: enabled(),
            capacity: r.capacity,
            recorded: r.recorded,
            dropped: r.dropped,
        },
        None => TraceStats::default(),
    }
}

/// Chronological copy of the ring contents (oldest record first).
pub fn snapshot() -> Vec<SpanRecord> {
    let g = RING.lock().unwrap();
    match g.as_ref() {
        Some(r) => {
            if r.buf.len() < r.capacity || r.head == 0 {
                r.buf.clone()
            } else {
                let mut out = Vec::with_capacity(r.capacity);
                out.extend_from_slice(&r.buf[r.head..]);
                out.extend_from_slice(&r.buf[..r.head]);
                out
            }
        }
        None => Vec::new(),
    }
}

/// Request correlation id currently installed on this thread
/// (0 = none).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

/// Guard returned by [`correlate`]; restores the previously installed
/// trace id when dropped.
#[must_use = "dropping the guard immediately uninstalls the trace id"]
pub struct TraceScope {
    prev: Option<u64>,
}

/// Install `trace` as the current thread's correlation id for the
/// lifetime of the returned guard.  When tracing is disabled this is a
/// relaxed atomic load and a one-word struct — the thread-local is not
/// touched, so the disabled serving path stays free of TLS traffic.
#[inline]
pub fn correlate(trace: u64) -> TraceScope {
    if !ENABLED.load(Ordering::Relaxed) {
        return TraceScope { prev: None };
    }
    TraceScope {
        prev: Some(CURRENT_TRACE.with(|c| c.replace(trace))),
    }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            CURRENT_TRACE.with(|c| c.set(prev));
        }
    }
}

/// Span name of a correlated request's root (minted at router
/// admission).  A correlated trace id present in the ring *without*
/// this root span lost its head to ring overwrite and is marked
/// truncated on export.
pub const ROOT_SPAN: &str = "router.submit";

/// Render spans as a Chrome trace-event ("Perfetto") JSON object:
/// complete events (`ph:"X"`, microsecond `ts`/`dur`) with the
/// correlation id and sequence number in `args`, plus a `metadata`
/// block carrying the exact drop accounting and the list of correlated
/// traces whose root span was evicted by ring overwrite (load the
/// output in `chrome://tracing` or https://ui.perfetto.dev).
pub fn export_chrome(spans: &[SpanRecord], stats: &TraceStats) -> Json {
    let mut events = Vec::with_capacity(spans.len());
    let mut seen = std::collections::BTreeSet::new();
    let mut rooted = std::collections::BTreeSet::new();
    for s in spans {
        if s.trace != 0 {
            seen.insert(s.trace);
            if s.name == ROOT_SPAN {
                rooted.insert(s.trace);
            }
        }
        events.push(Json::obj(vec![
            (
                "args",
                Json::obj(vec![
                    ("seq", Json::Num(s.seq as f64)),
                    ("trace_id", Json::Num(s.trace as f64)),
                ]),
            ),
            ("cat", Json::Str("sac".into())),
            ("dur", Json::Num(s.duration_ns() as f64 / 1000.0)),
            ("name", Json::Str(s.name.into())),
            ("ph", Json::Str("X".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(f64::from(s.thread))),
            ("ts", Json::Num(s.t_enter_ns as f64 / 1000.0)),
        ]));
    }
    let truncated: Vec<Json> = seen
        .difference(&rooted)
        .map(|&t| Json::Num(t as f64))
        .collect();
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        (
            "metadata",
            Json::obj(vec![
                ("capacity", Json::Num(stats.capacity as f64)),
                ("dropped", Json::Num(stats.dropped as f64)),
                ("recorded", Json::Num(stats.recorded as f64)),
                ("schema", Json::Str("sac-trace/v1".into())),
                ("truncated_traces", Json::Arr(truncated)),
            ]),
        ),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// [`export_chrome`] over the live ring (chronological snapshot + current
/// stats in one call).
pub fn export_chrome_live() -> Json {
    export_chrome(&snapshot(), &stats())
}

/// An in-flight span.  Records itself into the ring when dropped; does
/// nothing (and allocated nothing) if tracing was disabled at entry.
#[must_use = "a span records its duration when dropped; binding it to _ drops it immediately"]
pub struct Span {
    name: &'static str,
    enter: Option<Instant>,
    trace: u64,
}

impl Span {
    /// Override the correlation id captured at entry — for spans opened
    /// before the request id exists (router admission mints the id
    /// mid-span).
    pub fn set_trace(&mut self, trace: u64) {
        self.trace = trace;
    }
}

/// Open a span.  When tracing is disabled this is a relaxed atomic load
/// and a small struct — no clock read, no lock, no allocation.  The
/// span inherits the thread's current correlation id (see
/// [`correlate`]).
#[inline]
pub fn span(name: &'static str) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span {
            name,
            enter: None,
            trace: 0,
        };
    }
    Span {
        name,
        enter: Some(Instant::now()),
        trace: current_trace(),
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let enter = match self.enter {
            Some(t) => t,
            None => return,
        };
        let exit = Instant::now();
        let tid = thread_id();
        let mut g = RING.lock().unwrap();
        let r = match g.as_mut() {
            Some(r) => r,
            None => return,
        };
        // `duration_since` saturates to zero for pre-epoch instants, so
        // a span opened across an `enable()` cannot panic.
        let epoch = r.epoch;
        let ns = |t: Instant| {
            t.duration_since(epoch)
                .as_nanos()
                .min(u128::from(u64::MAX)) as u64
        };
        let rec = SpanRecord {
            name: self.name,
            thread: tid,
            trace: self.trace,
            seq: r.seq,
            t_enter_ns: ns(enter),
            t_exit_ns: ns(exit),
        };
        r.seq += 1;
        r.recorded += 1;
        if r.buf.len() < r.capacity {
            r.buf.push(rec);
        } else {
            let head = r.head;
            r.buf[head] = rec;
            r.head = (head + 1) % r.capacity;
            r.dropped += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_records_nothing() {
        // NOTE: trace state is process-global; unit tests here only make
        // filtered / monotone assertions so they stay robust under the
        // parallel test harness.  Exact-count tests live in
        // tests/observability.rs behind a serialization guard.
        let before = stats().recorded;
        if !enabled() {
            let _s = span("trace.test.disabled");
            drop(span("trace.test.disabled"));
            assert_eq!(stats().recorded, before);
        }
    }

    #[test]
    fn enabled_span_lands_with_ordered_timestamps() {
        enable(4096);
        {
            let _s = span("trace.test.enabled_unique_xyzzy");
            std::hint::black_box(3 + 4);
        }
        let snap = snapshot();
        let mine: Vec<_> = snap
            .iter()
            .filter(|r| r.name == "trace.test.enabled_unique_xyzzy")
            .collect();
        assert!(!mine.is_empty(), "span missing from ring");
        for r in &mine {
            assert!(r.t_exit_ns >= r.t_enter_ns);
        }
        disable();
    }

    #[test]
    fn pre_epoch_span_saturates_instead_of_panicking() {
        let s = span("trace.test.pre_epoch"); // possibly disabled → None
        enable(64);
        let s2 = span("trace.test.pre_epoch_live");
        drop(s); // enter (if any) predates the new epoch: must not panic
        drop(s2);
        let snap = snapshot();
        assert!(snap
            .iter()
            .filter(|r| r.name.starts_with("trace.test.pre_epoch"))
            .all(|r| r.t_exit_ns >= r.t_enter_ns));
        disable();
    }

    // NOTE: correlation tests that need the global ring (correlate
    // nesting, set_trace capture) live in tests/observability.rs behind
    // its serialization guard — the ring is process-global and the unit
    // tests here run concurrently.

    #[test]
    fn chrome_export_shape_and_truncation_marking() {
        let spans = vec![
            SpanRecord {
                name: ROOT_SPAN,
                thread: 1,
                trace: 5,
                seq: 0,
                t_enter_ns: 1_000,
                t_exit_ns: 3_500,
            },
            SpanRecord {
                name: "batch.forward",
                thread: 2,
                trace: 5,
                seq: 1,
                t_enter_ns: 1_200,
                t_exit_ns: 2_000,
            },
            // trace 8 has no ROOT_SPAN record → truncated
            SpanRecord {
                name: "router.deliver",
                thread: 1,
                trace: 8,
                seq: 2,
                t_enter_ns: 4_000,
                t_exit_ns: 4_100,
            },
        ];
        let st = TraceStats {
            enabled: true,
            capacity: 4,
            recorded: 7,
            dropped: 3,
        };
        let j = export_chrome(&spans, &st);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        let e0 = &events[0];
        assert_eq!(e0.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e0.get("name").unwrap().as_str().unwrap(), ROOT_SPAN);
        assert_eq!(e0.get("ts").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(e0.get("dur").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(
            e0.get("args").unwrap().get("trace_id").unwrap().as_f64().unwrap(),
            5.0
        );
        let meta = j.get("metadata").unwrap();
        assert_eq!(meta.get("dropped").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(meta.get("recorded").unwrap().as_f64().unwrap(), 7.0);
        let trunc = meta.get("truncated_traces").unwrap().as_arr().unwrap();
        assert_eq!(trunc, &[Json::Num(8.0)]);
        // valid JSON round-trip
        let text = j.to_string();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn thread_ids_are_stable_within_a_thread() {
        let a = thread_id();
        let b = thread_id();
        assert_eq!(a, b);
        assert_ne!(a, 0);
        let other = std::thread::spawn(thread_id).join().unwrap();
        assert_ne!(other, a, "two threads shared a trace thread id");
    }
}
