//! Statistics helpers shared by the analysis engines, the serving metrics
//! and the repro harness: sample summaries ([`summarize`]), interpolated
//! [`percentile`]s (the p99 latency numbers), curve-deviation metrics,
//! Pearson correlation, k-class [`Confusion`] matrices (Table IV / Fig. 15)
//! and fixed-width [`Histogram`]s.

/// Running summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute mean / std (population) / min / max of a slice.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Percentile with linear interpolation, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean absolute deviation between two equal-length series.
pub fn mean_abs_dev(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Max absolute deviation between two equal-length series.
pub fn max_abs_dev(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Pearson correlation coefficient.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let sa = summarize(a);
    let sb = summarize(b);
    let cov = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - sa.mean) * (y - sb.mean))
        .sum::<f64>()
        / a.len() as f64;
    cov / (sa.std * sb.std + 1e-300)
}

/// Confusion matrix for `k`-class classification.
#[derive(Clone, Debug)]
pub struct Confusion {
    pub k: usize,
    /// `counts[true][pred]`
    pub counts: Vec<Vec<usize>>,
}

impl Confusion {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            counts: vec![vec![0; k]; k],
        }
    }

    pub fn record(&mut self, truth: usize, pred: usize) {
        self.counts[truth][pred] += 1;
    }

    pub fn total(&self) -> usize {
        self.counts.iter().flatten().sum()
    }

    pub fn correct(&self) -> usize {
        (0..self.k).map(|i| self.counts[i][i]).sum()
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.correct() as f64 / t as f64
        }
    }

    /// Per-class recall.
    pub fn recall(&self, class: usize) -> f64 {
        let row: usize = self.counts[class].iter().sum();
        if row == 0 {
            0.0
        } else {
            self.counts[class][class] as f64 / row as f64
        }
    }
}

/// Fixed-width histogram over [lo, hi].
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<usize>,
    pub underflow: usize,
    pub overflow: usize,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let b = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[b.min(last)] += 1;
        }
    }

    pub fn total(&self) -> usize {
        self.bins.iter().sum::<usize>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0, 20.0, 30.0];
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 30.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn deviations() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.5, 2.0, 1.0];
        assert!((mean_abs_dev(&a, &b) - (0.5 + 0.0 + 2.0) / 3.0).abs() < 1e-12);
        assert!((max_abs_dev(&a, &b) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn confusion_accuracy() {
        let mut cm = Confusion::new(3);
        cm.record(0, 0);
        cm.record(1, 1);
        cm.record(2, 0);
        cm.record(2, 2);
        assert_eq!(cm.total(), 4);
        assert!((cm.accuracy() - 0.75).abs() < 1e-12);
        assert!((cm.recall(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        h.record(-1.0);
        h.record(11.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}
