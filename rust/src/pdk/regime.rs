//! Transistor biasing regimes (Fig. 1): weak / moderate / strong inversion.

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Regime {
    WeakInversion,
    ModerateInversion,
    StrongInversion,
}

impl Regime {
    pub fn all() -> [Regime; 3] {
        [
            Regime::WeakInversion,
            Regime::ModerateInversion,
            Regime::StrongInversion,
        ]
    }

    pub fn short(&self) -> &'static str {
        match self {
            Regime::WeakInversion => "WI",
            Regime::ModerateInversion => "MI",
            Regime::StrongInversion => "SI",
        }
    }

    pub fn by_name(name: &str) -> Option<Regime> {
        match name.to_ascii_uppercase().as_str() {
            "WI" | "WEAK" => Some(Regime::WeakInversion),
            "MI" | "MODERATE" => Some(Regime::ModerateInversion),
            "SI" | "STRONG" => Some(Regime::StrongInversion),
            _ => None,
        }
    }

    /// Classify an operating point by inversion coefficient
    /// IC = I_D / I_spec (Fig. 15b's regime census uses this).
    pub fn classify_ic(ic: f64) -> Regime {
        if ic < 0.1 {
            Regime::WeakInversion
        } else if ic < 10.0 {
            Regime::ModerateInversion
        } else {
            Regime::StrongInversion
        }
    }
}

impl fmt::Display for Regime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.short())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_names() {
        for r in Regime::all() {
            assert_eq!(Regime::by_name(r.short()), Some(r));
        }
        assert_eq!(Regime::by_name("weak"), Some(Regime::WeakInversion));
        assert!(Regime::by_name("xx").is_none());
    }

    #[test]
    fn ic_classification_boundaries() {
        assert_eq!(Regime::classify_ic(0.01), Regime::WeakInversion);
        assert_eq!(Regime::classify_ic(1.0), Regime::ModerateInversion);
        assert_eq!(Regime::classify_ic(100.0), Regime::StrongInversion);
    }
}
