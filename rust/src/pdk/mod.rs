//! Process design kits (PDK) — the synthetic foundry decks.
//!
//! The paper evaluates on a planar CMOS 180 nm foundry PDK and the ASAP7
//! 7 nm FinFET predictive PDK (plus a 22 nm point in Fig. 1).  Neither is
//! redistributable, so each node here is a *PTM/ASAP7-inspired* parameter
//! set for the EKV-style all-region device model in `crate::device`.  The
//! numbers are chosen to reproduce the paper's qualitative physics:
//!
//!  * supply: 1.8 V (180 nm) / 0.8 V (22 nm) / 0.7 V (7 nm)  [Fig. 1 caption]
//!  * subthreshold slope factor `n` approaching 1 for FinFET (better gate
//!    control) — this is what makes moderate inversion dominate the 7 nm
//!    dynamic range (Fig. 1's story);
//!  * Pelgrom mismatch coefficients shrinking with feature size but
//!    mismatch *increasing* for minimum-size devices;
//!  * temperature behaviour: `U_T = kT/q`, `V_T0(T)` linear decrease,
//!    mobility `~ (T/T0)^-1.5`.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

pub mod regime;

use regime::Regime;

/// Device polarity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Polarity {
    N,
    P,
}

/// Process node family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    PlanarCmos,
    FinFet,
}

/// A process node: everything the device model needs.
#[derive(Clone, Debug)]
pub struct ProcessNode {
    pub name: &'static str,
    pub kind: NodeKind,
    /// feature size in nm (drawn channel length)
    pub feature_nm: f64,
    /// nominal supply [V]
    pub vdd: f64,
    /// zero-bias threshold voltage at 300 K [V] (NMOS; PMOS mirrored)
    pub vt0: f64,
    /// subthreshold slope factor n (1 + Cd/Cox); FinFETs near 1
    pub n_slope: f64,
    /// specific current I_S = 2 n beta U_T^2 at W/L=1, 300 K [A]
    pub i_spec: f64,
    /// threshold tempco dVt/dT [V/K] (negative)
    pub vt_tempco: f64,
    /// mobility temperature exponent (I ~ (T/T0)^-m in SI)
    pub mobility_exp: f64,
    /// Pelgrom area coefficient for Vt mismatch [mV·µm]
    pub avt_mv_um: f64,
    /// Pelgrom coefficient for current-factor mismatch [%·µm]
    pub abeta_pct_um: f64,
    /// minimum device width [µm] (per-fin width for FinFET)
    pub wmin_um: f64,
    /// minimum channel length [µm]
    pub lmin_um: f64,
    /// transit-frequency scale: f_T at strong inversion, V_ov = 0.3 V [GHz]
    pub ft_si_ghz: f64,
    /// junction/diode leakage floor [A] (deep-threshold floor, Fig. 5a)
    pub leak_floor: f64,
    /// gate capacitance per area [fF/µm²] — used by the energy model
    pub cox_ff_um2: f64,
    /// mobility-degradation / velocity-saturation factor θ [1/V]:
    /// I_SI ~ F(v)/(1 + θ·V_ov).  Stronger at short channel — this is what
    /// pushes the gm/Id·f_T peak into moderate inversion (Fig. 1).
    pub theta: f64,
    /// analog cell device sizing [µm] — matched-pair sizing a designer
    /// uses for the S-AC branches/mirrors (well above minimum, Pelgrom)
    pub analog_w_um: f64,
    pub analog_l_um: f64,
}

/// CMOS 180 nm planar node (paper's "180nm").
pub const CMOS180: ProcessNode = ProcessNode {
    name: "cmos180",
    kind: NodeKind::PlanarCmos,
    feature_nm: 180.0,
    vdd: 1.8,
    vt0: 0.45,
    n_slope: 1.35,
    i_spec: 6.0e-7,
    vt_tempco: -1.0e-3,
    mobility_exp: 1.5,
    avt_mv_um: 5.0,
    abeta_pct_um: 1.0,
    wmin_um: 0.22,
    lmin_um: 0.18,
    ft_si_ghz: 50.0,
    leak_floor: 2.0e-15, // ~1.97 fA NMOS floor measured in the paper (Fig. 5a)
    cox_ff_um2: 8.5,
    theta: 0.6,
    analog_w_um: 10.0,
    analog_l_um: 2.5,
};

/// CMOS 22 nm planar node (Fig. 1's middle curve).
pub const CMOS22: ProcessNode = ProcessNode {
    name: "cmos22",
    kind: NodeKind::PlanarCmos,
    feature_nm: 22.0,
    vdd: 0.8,
    vt0: 0.38,
    n_slope: 1.18,
    i_spec: 2.0e-7,
    vt_tempco: -0.8e-3,
    mobility_exp: 1.4,
    avt_mv_um: 2.5,
    abeta_pct_um: 0.6,
    wmin_um: 0.08,
    lmin_um: 0.022,
    ft_si_ghz: 280.0,
    leak_floor: 8.0e-15,
    cox_ff_um2: 14.0,
    theta: 1.8,
    analog_w_um: 2.0,
    analog_l_um: 0.5,
};

/// FinFET 7 nm node (ASAP7-inspired, paper's "7nm").
pub const FINFET7: ProcessNode = ProcessNode {
    name: "finfet7",
    kind: NodeKind::FinFet,
    feature_nm: 7.0,
    vdd: 0.7,
    vt0: 0.32,
    n_slope: 1.05, // near-ideal gate control
    i_spec: 2.5e-8, // per-square; minimum cells bias at nA scale (paper
                    // drives the 7nm WTA at 10 nA inputs, Fig. 10 caption)
    vt_tempco: -0.7e-3,
    mobility_exp: 1.2,
    avt_mv_um: 0.6,
    abeta_pct_um: 0.4,
    wmin_um: 0.027, // effective per-fin width (2*Hfin + Tfin ≈ 27 nm)
    lmin_um: 0.007,
    ft_si_ghz: 450.0,
    leak_floor: 3.0e-14,
    cox_ff_um2: 22.0,
    theta: 3.0,
    analog_w_um: 4.32,
    analog_l_um: 0.1,
};

impl ProcessNode {
    /// Look up a node by name (CLI spelling).
    pub fn by_name(name: &str) -> Option<&'static ProcessNode> {
        match name {
            "cmos180" | "180nm" | "180" => Some(&CMOS180),
            "cmos22" | "22nm" | "22" => Some(&CMOS22),
            "finfet7" | "7nm" | "7" => Some(&FINFET7),
            _ => None,
        }
    }

    pub fn all() -> [&'static ProcessNode; 3] {
        [&CMOS180, &CMOS22, &FINFET7]
    }

    /// The two nodes the paper's evaluation section sweeps.
    pub fn paper_pair() -> [&'static ProcessNode; 2] {
        [&CMOS180, &FINFET7]
    }

    /// Thermal voltage U_T = kT/q [V] at temperature `t_c` in Celsius.
    pub fn ut(t_c: f64) -> f64 {
        const K_OVER_Q: f64 = 8.617_333e-5; // V/K
        K_OVER_Q * (t_c + 273.15)
    }

    /// Threshold voltage at temperature `t_c` [V].
    pub fn vt0_at(&self, t_c: f64) -> f64 {
        self.vt0 + self.vt_tempco * (t_c - 27.0)
    }

    /// Specific current at temperature `t_c` [A] (U_T² growth times
    /// mobility decay).
    pub fn i_spec_at(&self, t_c: f64) -> f64 {
        let t = t_c + 273.15;
        let t0 = 300.15;
        let ut_ratio = (t / t0) * (t / t0);
        self.i_spec * ut_ratio * (t / t0).powf(-self.mobility_exp)
    }

    /// Gate-bias point [V] that centres the device in `regime` (for a
    /// square device, V_S = 0).  WI: V_ov < -4 nU_T below V_T; MI: at V_T;
    /// SI: well above.
    pub fn bias_for(&self, regime: Regime, t_c: f64) -> f64 {
        let ut = Self::ut(t_c);
        let vt = self.vt0_at(t_c);
        match regime {
            Regime::WeakInversion => vt - 5.0 * self.n_slope * ut,
            Regime::ModerateInversion => vt + 1.0 * self.n_slope * ut,
            Regime::StrongInversion => {
                // keep headroom on low-vdd nodes
                (vt + 8.0 * self.n_slope * ut).min(0.85 * self.vdd)
            }
        }
    }

    /// Unit-cell bias current in `regime` [A]: the "C" scale the circuits
    /// run at.  WI ~ 0.05·I_S, MI ~ I_S, SI ~ 20·I_S  (inversion-coefficient
    /// 0.05 / 1 / 20, the usual IC boundaries).
    pub fn bias_current(&self, regime: Regime) -> f64 {
        match regime {
            Regime::WeakInversion => 0.05 * self.i_spec,
            Regime::ModerateInversion => 1.0 * self.i_spec,
            Regime::StrongInversion => 20.0 * self.i_spec,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(ProcessNode::by_name("180nm").unwrap().name, "cmos180");
        assert_eq!(ProcessNode::by_name("finfet7").unwrap().name, "finfet7");
        assert!(ProcessNode::by_name("3nm").is_none());
    }

    #[test]
    fn thermal_voltage() {
        let ut27 = ProcessNode::ut(27.0);
        assert!((ut27 - 0.02587).abs() < 2e-4, "U_T(27C)={ut27}");
        assert!(ProcessNode::ut(125.0) > ut27);
        assert!(ProcessNode::ut(-45.0) < ut27);
    }

    #[test]
    fn vt_decreases_with_temperature() {
        for node in ProcessNode::all() {
            assert!(node.vt0_at(125.0) < node.vt0_at(27.0));
            assert!(node.vt0_at(-45.0) > node.vt0_at(27.0));
        }
    }

    #[test]
    fn finfet_has_better_gate_control() {
        assert!(FINFET7.n_slope < CMOS22.n_slope);
        assert!(CMOS22.n_slope < CMOS180.n_slope);
    }

    #[test]
    fn supplies_match_paper_caption() {
        assert_eq!(CMOS180.vdd, 1.8);
        assert_eq!(CMOS22.vdd, 0.8);
        assert_eq!(FINFET7.vdd, 0.7);
    }

    #[test]
    fn bias_ordering() {
        for node in ProcessNode::all() {
            let wi = node.bias_for(Regime::WeakInversion, 27.0);
            let mi = node.bias_for(Regime::ModerateInversion, 27.0);
            let si = node.bias_for(Regime::StrongInversion, 27.0);
            assert!(wi < mi && mi < si, "{}", node.name);
            assert!(si <= node.vdd);
            let iw = node.bias_current(Regime::WeakInversion);
            let im = node.bias_current(Regime::ModerateInversion);
            let is = node.bias_current(Regime::StrongInversion);
            assert!(iw < im && im < is);
        }
    }

    #[test]
    fn mismatch_coefficients_shrink_with_node() {
        assert!(FINFET7.avt_mv_um < CMOS22.avt_mv_um);
        assert!(CMOS22.avt_mv_um < CMOS180.avt_mv_um);
    }
}
