//! Device-exact S-AC unit: the Fig. 2b (N-type) / Fig. 2c (P-type) circuit,
//! solved at transistor level.
//!
//! The circuit realizes (paper eqs. 11-12):
//!
//! ```text
//!     Σ_ij f(V_ij, V_B) = C                      KCL at the common node V_B
//!     f(V_B,0) − f(V_B,V_ij) + f(V_ij,V_B) = x_ij   per-branch balance
//!     h(X) = f(V_B, 0)                           output mirror
//! ```
//!
//! with `f` the device forward-current function (`Mosfet::forward`).  The
//! solve is a nested root find:
//!
//!  * inner: for a trial V_B, each branch's balance equation is monotone in
//! ```text
//!    V_ij → bisection (Newton-accelerated) per branch;
//! ```
//!  * outer: the KCL residual is monotone decreasing in V_B → bisection.
//!
//! This is the "SPICE tier": every regime/process/temperature effect enters
//! through the device model.  The table-model tier
//! (`sac::table_model`) is calibrated against it.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use crate::device::Mosfet;
use crate::pdk::{Polarity, ProcessNode, regime::Regime};
use crate::util::rng::Rng;

/// Configuration of one S-AC unit instance.
#[derive(Clone, Debug)]
pub struct SacUnit {
    pub node: &'static ProcessNode,
    pub polarity: Polarity,
    pub regime: Regime,
    pub t_c: f64,
    /// supply override [V] (Fig. 4c sweeps this); default node.vdd
    pub vdd: f64,
    /// tail bias current C [A]
    pub c_bias: f64,
    /// branch devices (one per input column; mismatch lives here)
    pub branches: Vec<Mosfet>,
    /// output device (h = f(V_B, 0))
    pub out_dev: Mosfet,
    /// deep-threshold mode (Fig. 5b): source shift + body bias
    pub deep: bool,
}

/// Result of a unit solve.
#[derive(Clone, Debug)]
pub struct SolveOut {
    /// output current h [A]
    pub h: f64,
    /// common-node voltage [V]
    pub vb: f64,
    /// per-branch gate voltages [V]
    pub branch_v: Vec<f64>,
    /// KCL residual at the solution [A]
    pub residual: f64,
}

// §Perf: 48/40 bisection halvings resolve V_B / V_i to ~1e-11 V on a ~3 V
// bracket — still 9 orders below U_T; cut from 64/56 after profiling the
// nested solve (KCL-residual tests bound the error at 1e-3·C).
const OUTER_ITERS: usize = 48;
const INNER_ITERS: usize = 40;

impl SacUnit {
    /// Unit with `m` branches, nominal devices.
    pub fn new(
        node: &'static ProcessNode,
        polarity: Polarity,
        regime: Regime,
        m: usize,
    ) -> Self {
        let dev = Mosfet::square(node, Polarity::N); // internal math is N-type
        SacUnit {
            node,
            polarity,
            regime,
            t_c: 27.0,
            vdd: node.vdd,
            c_bias: node.bias_current(regime),
            branches: vec![dev.clone(); m],
            out_dev: dev,
            deep: false,
        }
    }

    pub fn at_temp(mut self, t_c: f64) -> Self {
        self.t_c = t_c;
        for d in &mut self.branches {
            d.t_c = t_c;
        }
        self.out_dev.t_c = t_c;
        self
    }

    pub fn with_supply(mut self, vdd: f64) -> Self {
        self.vdd = vdd;
        self
    }

    pub fn with_bias(mut self, c_bias: f64) -> Self {
        self.c_bias = c_bias;
        self
    }

    /// Deep-threshold variant (Fig. 5b): fixed source shift plus channel-
    /// conduction manipulation (body at VDD), dropping operation to fA.
    pub fn deep_threshold(mut self, source_shift: f64) -> Self {
        self.deep = true;
        for d in &mut self.branches {
            d.source_shift = source_shift;
            d.body_at_vdd = true;
        }
        self.out_dev.source_shift = source_shift;
        self.out_dev.body_at_vdd = true;
        self
    }

    /// Apply sampled mismatch to every device (Monte-Carlo trials).
    pub fn with_mismatch(mut self, rng: &mut Rng) -> Self {
        let mm = crate::device::MismatchModel::new(self.node);
        for d in &mut self.branches {
            *d = mm.sample(d, rng);
        }
        self.out_dev = mm.sample(&self.out_dev, rng);
        self
    }

    /// Inner solve: V_i such that
    /// f(V_B,0) − f(V_B,V_i) + f(V_i,V_B) = x  (eq. 12), monotone in V_i.
    /// §Perf: operates on hoisted `DevOp` constants (no powf in the loop).
    fn solve_branch_op(
        &self,
        op: &crate::device::ekv::DevOp,
        vb: f64,
        x: f64,
        h_vb: f64,
    ) -> f64 {
        let (mut lo, mut hi) = (-0.6, self.vdd + 0.6);
        // the residual is increasing in V_i; bisect
        for _ in 0..INNER_ITERS {
            let mid = 0.5 * (lo + hi);
            let r = h_vb - op.forward(vb, mid) + op.forward(mid, vb) - x;
            if r < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Full unit solve for input currents `x` [A] (length = #branches).
    ///
    /// Inputs are currents, hence clamped at the leakage floor.
    pub fn solve(&self, x: &[f64]) -> SolveOut {
        assert_eq!(x.len(), self.branches.len(), "input arity");
        let xc: Vec<f64> = x
            .iter()
            .map(|&v| v.max(self.node.leak_floor))
            .collect();
        // hoist per-device constants out of the nested loops (§Perf)
        let ops: Vec<crate::device::ekv::DevOp> =
            self.branches.iter().map(|d| d.op_point()).collect();
        let out_op = self.out_dev.op_point();

        // outer bisection on V_B: KCL residual decreasing in V_B
        let mut lo = -0.6;
        let mut hi = self.vdd + 0.2;
        let mut branch_v = vec![0.0; xc.len()];
        for _ in 0..OUTER_ITERS {
            let vb = 0.5 * (lo + hi);
            let h_vb = out_op.forward(vb, 0.0);
            let mut sum = 0.0;
            for (i, &xi) in xc.iter().enumerate() {
                let vi = self.solve_branch_op(&ops[i], vb, xi, h_vb);
                branch_v[i] = vi;
                sum += ops[i].forward(vi, vb);
            }
            if sum > self.c_bias {
                lo = vb;
            } else {
                hi = vb;
            }
        }
        let vb = 0.5 * (lo + hi);
        let h_vb = out_op.forward(vb, 0.0);
        let mut sum = 0.0;
        for (i, &xi) in xc.iter().enumerate() {
            let vi = self.solve_branch_op(&ops[i], vb, xi, h_vb);
            branch_v[i] = vi;
            sum += ops[i].forward(vi, vb);
        }
        SolveOut {
            h: h_vb,
            vb,
            branch_v,
            residual: sum - self.c_bias,
        }
    }

    /// Normalized proto-shape (Fig. 3): input `z` in algorithmic units,
    /// spline-expanded with a ground reference branch; output h normalized
    /// by the unit's bias current.
    ///
    /// Current mapping: algorithmic value `v` ↦ `v * c_bias` (the
    /// hyper-parameter C is the unit current of the cell).
    pub fn proto_shape(&self, z: f64, s: usize) -> f64 {
        let (offs, c_prime) = super::splines::schedule(s, 1.0);
        let scale = self.c_bias;
        let mut x = Vec::with_capacity(2 * s);
        for &o in &offs {
            x.push((z + o) * scale);
        }
        for &o in &offs {
            x.push(o * scale);
        }
        let unit = self.resized(2 * s).with_bias(c_prime * scale);
        unit.solve(&x).h / scale
    }

    /// Same unit config with a different branch count.
    pub fn resized(&self, m: usize) -> SacUnit {
        let mut u = self.clone();
        let proto = u.branches.first().cloned().unwrap_or_else(|| {
            Mosfet::square(self.node, Polarity::N)
        });
        u.branches = vec![proto; m];
        u
    }

    /// Static power estimate of this unit at its bias point [W]:
    /// tail current C plus the mirrored output current, times VDD.
    pub fn static_power(&self, h: f64) -> f64 {
        (self.c_bias + h) * self.vdd
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{CMOS180, FINFET7};
    use crate::sac::gmp::{sac_h, Shape};

    fn unit(node: &'static ProcessNode, regime: Regime, m: usize) -> SacUnit {
        SacUnit::new(node, Polarity::N, regime, m)
    }

    #[test]
    fn kcl_satisfied_at_solution() {
        let u = unit(&CMOS180, Regime::WeakInversion, 3);
        let c = u.c_bias;
        let out = u.solve(&[0.8 * c, 0.3 * c, 1.4 * c]);
        assert!(
            out.residual.abs() < 1e-3 * c,
            "residual={} c={c}",
            out.residual
        );
        assert!(out.h >= 0.0);
    }

    #[test]
    fn output_monotone_in_inputs() {
        let u = unit(&CMOS180, Regime::WeakInversion, 2);
        let c = u.c_bias;
        let mut last = 0.0;
        for k in 0..8 {
            let x0 = 0.3 * c + 0.3 * c * k as f64;
            let h = u.solve(&[x0, 0.5 * c]).h;
            assert!(h >= last - 1e-18, "k={k}");
            last = h;
        }
    }

    #[test]
    fn circuit_matches_algorithmic_gmp_wi() {
        // In weak inversion the circuit must track the algorithmic GMP
        // with a soft shape — the MP equivalence the framework rests on.
        let u = unit(&CMOS180, Regime::WeakInversion, 4);
        let c = u.c_bias;
        let xn = [1.3, 0.4, 0.9, 1.8]; // algorithmic units
        let x: Vec<f64> = xn.iter().map(|v| v * c).collect();
        let h_circ = u.solve(&x).h / c;
        // compare against relu-GMP: agreement within the soft-knee margin
        let h_alg = sac_h(&xn, 1.0, Shape::Relu);
        assert!(
            (h_circ - h_alg).abs() < 0.25,
            "h_circ={h_circ} h_alg={h_alg}"
        );
    }

    #[test]
    fn proto_shape_monotone_and_saturating() {
        for regime in [Regime::WeakInversion, Regime::ModerateInversion] {
            let u = unit(&CMOS180, regime, 1);
            let mut last = -1.0;
            for k in 0..=20 {
                let z = -3.0 + 0.25 * k as f64;
                let h = u.proto_shape(z, 3);
                assert!(h >= last - 1e-6, "regime {regime} z={z}");
                last = h;
            }
            assert!(last > 0.5, "regime {regime}: shape never rose (h={last})");
        }
    }

    #[test]
    fn shape_invariant_across_nodes_fig3() {
        // Fig. 3a/b: normalized shapes at 180nm and 7nm coincide within a
        // few percent of full scale.
        let zs: Vec<f64> = (0..=24).map(|k| -2.5 + 0.15 * k as f64).collect();
        let u180 = unit(&CMOS180, Regime::WeakInversion, 1);
        let u7 = unit(&FINFET7, Regime::WeakInversion, 1);
        let s180: Vec<f64> = zs.iter().map(|&z| u180.proto_shape(z, 3)).collect();
        let s7: Vec<f64> = zs.iter().map(|&z| u7.proto_shape(z, 3)).collect();
        let max180 = s180.iter().cloned().fold(0.0, f64::max);
        let max7 = s7.iter().cloned().fold(0.0, f64::max);
        for i in 0..zs.len() {
            let d = (s180[i] / max180 - s7[i] / max7).abs();
            assert!(d < 0.08, "z={} dev={d}", zs[i]);
        }
    }

    #[test]
    fn shape_robust_to_temperature_fig4a() {
        let zs: Vec<f64> = (0..=16).map(|k| -2.0 + 0.2 * k as f64).collect();
        let cold = unit(&CMOS180, Regime::WeakInversion, 1).at_temp(-45.0);
        let hot = unit(&CMOS180, Regime::WeakInversion, 1).at_temp(125.0);
        let sc: Vec<f64> = zs.iter().map(|&z| cold.proto_shape(z, 3)).collect();
        let sh: Vec<f64> = zs.iter().map(|&z| hot.proto_shape(z, 3)).collect();
        let mc = sc.iter().cloned().fold(0.0, f64::max);
        let mh = sh.iter().cloned().fold(0.0, f64::max);
        for i in 0..zs.len() {
            assert!(
                (sc[i] / mc - sh[i] / mh).abs() < 0.10,
                "z={} cold={} hot={}",
                zs[i],
                sc[i] / mc,
                sh[i] / mh
            );
        }
    }

    #[test]
    fn deep_threshold_operates_at_femtoamps() {
        // Fig. 5c: with source shifting the unit still computes at fA bias
        let u = unit(&CMOS180, Regime::WeakInversion, 1)
            .deep_threshold(0.35)
            .with_bias(5.0e-14);
        let h_low = u.proto_shape(-2.0, 3);
        let h_high = u.proto_shape(1.0, 3);
        assert!(
            h_high > 4.0 * h_low.max(1e-3),
            "shape collapsed: lo={h_low} hi={h_high}"
        );
    }

    #[test]
    fn static_power_scales_with_bias() {
        let wi = unit(&CMOS180, Regime::WeakInversion, 2);
        let si = unit(&CMOS180, Regime::StrongInversion, 2);
        assert!(si.static_power(0.0) > 100.0 * wi.static_power(0.0));
    }
}
