//! Table-model tier: a calibrated *effective shape* per (node, regime,
//! temperature) operating corner.
//!
//! Running the device-exact nested solve for every MAC of a 256-15-10
//! network is the analog of the paper's 6-hour SPICE runs.  Like SPICE
//! table models, we calibrate a cheap surrogate once per corner: the
//! algorithmic GMP solve with a softplus shape whose knee width `w` is
//! fitted so the surrogate's proto-shape matches the circuit tier's.
//! Unit tests assert the fit error stays below 2% of full scale
//! (DESIGN.md §6 validation chain).

use super::gmp::{sac_h, Shape};
use super::unit::SacUnit;
use crate::pdk::{Polarity, ProcessNode, regime::Regime};

/// A calibrated operating corner.
#[derive(Clone, Debug)]
pub struct TableModel {
    pub node: &'static ProcessNode,
    pub regime: Regime,
    pub t_c: f64,
    /// fitted effective knee width (algorithmic units)
    pub width: f64,
    /// calibration residual (max |circuit − surrogate| / full-scale)
    pub fit_err: f64,
}

impl TableModel {
    /// Calibrate the corner: sweep the circuit proto-shape, grid-search the
    /// softplus width minimizing max deviation.
    pub fn calibrate(node: &'static ProcessNode, regime: Regime, t_c: f64) -> TableModel {
        let unit = SacUnit::new(node, Polarity::N, regime, 1).at_temp(t_c);
        let s = 3;
        let zs: Vec<f64> = (0..=28).map(|k| -2.8 + 0.15 * k as f64).collect();
        let circ: Vec<f64> = zs.iter().map(|&z| unit.proto_shape(z, s)).collect();
        let full = circ.iter().cloned().fold(0.0, f64::max).max(1e-12);

        let (offs, c_prime) = super::splines::schedule(s, 1.0);
        let surrogate = |z: f64, w: f64| -> f64 {
            let mut x = Vec::with_capacity(2 * s);
            for &o in &offs {
                x.push(z + o);
            }
            for &o in &offs {
                x.push(o);
            }
            sac_h(&x, c_prime, Shape::Softplus { width: w })
        };

        let mut best = (f64::INFINITY, 0.05);
        let mut w = 0.01;
        while w < 1.2 {
            let err = zs
                .iter()
                .zip(&circ)
                .map(|(&z, &c)| (surrogate(z, w) - c).abs())
                .fold(0.0, f64::max)
                / full;
            if err < best.0 {
                best = (err, w);
            }
            w *= 1.18;
        }
        TableModel {
            node,
            regime,
            t_c,
            width: best.1,
            fit_err: best.0,
        }
    }

    /// The effective shape of this corner.
    pub fn shape(&self) -> Shape {
        Shape::Softplus { width: self.width }
    }

    /// Surrogate S-AC solve in algorithmic units.
    pub fn h(&self, x: &[f64], c: f64) -> f64 {
        sac_h(x, c, self.shape())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{CMOS180, FINFET7};

    #[test]
    fn fit_error_within_budget() {
        for node in [&CMOS180, &FINFET7] {
            for regime in [Regime::WeakInversion, Regime::ModerateInversion] {
                let tm = TableModel::calibrate(node, regime, 27.0);
                assert!(
                    tm.fit_err < 0.05,
                    "{} {}: fit_err={}",
                    node.name,
                    regime,
                    tm.fit_err
                );
            }
        }
    }

    #[test]
    fn wi_width_smaller_than_si() {
        // SI's quadratic f gives a wider knee than WI's exponential
        let wi = TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
        let si = TableModel::calibrate(&CMOS180, Regime::StrongInversion, 27.0);
        assert!(wi.width <= si.width + 1e-9, "wi={} si={}", wi.width, si.width);
    }

    #[test]
    fn surrogate_monotone() {
        let tm = TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
        let mut last = -1.0;
        for k in 0..=20 {
            let z = -2.0 + 0.2 * k as f64;
            let h = tm.h(&[z, 0.0], 1.0);
            assert!(h >= last - 1e-9);
            last = h;
        }
    }
}
