//! The paper's core contribution: shape-based analog computing.
//!
//! * `gmp` — the algorithmic GMP solvers (exact + bisection), mirroring the
//!   python kernels bit-for-bit-ish.
//! * `splines` — the Appendix-A dyadic spline schedule.
//! * `unit` — the device-exact Fig. 2b/2c circuit (nested KCL solve).
//! * `table_model` — calibrated per-corner surrogate used at NN scale.

pub mod gmp;
pub mod splines;
pub mod table_model;
pub mod unit;

pub use gmp::{sac_h, solve_bisect, solve_exact, Shape, GMP_ITERS};
pub use table_model::TableModel;
pub use unit::SacUnit;
