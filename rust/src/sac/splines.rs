//! Appendix-A dyadic spline schedule (mirror of
//! `python/compile/sacml/splines.py` — see that file for the derivation).
//!
//! ```text
//!     Q_j = (j − (S+1)/2)·ln2          tangent points (symmetric, dyadic)
//!     T_1 = Q_1 − 1;  T_j = 2Q_j − Q_{j−1} − 1
//!     O_j = −C·T_j                     per-spline offsets (eq. 53)
//!     C'  = C / e^{Q_1}                unit-slope rescale
//! ```

pub const LN2: f64 = std::f64::consts::LN_2;

/// Tangent points Q_1..Q_S.
pub fn tangent_points(s: usize) -> Vec<f64> {
    assert!(s >= 1);
    (1..=s)
        .map(|j| (j as f64 - (s as f64 + 1.0) / 2.0) * LN2)
        .collect()
}

/// Tuning (break) points T_1..T_S (eq. 46/49-51).
pub fn tuning_points(s: usize) -> Vec<f64> {
    let q = tangent_points(s);
    let mut t = vec![0.0; s];
    t[0] = q[0] - 1.0;
    for j in 1..s {
        t[j] = 2.0 * q[j] - q[j - 1] - 1.0;
    }
    t
}

/// `(offsets O_j, rescaled constraint C')` for an S-spline unit.
pub fn schedule(s: usize, c: f64) -> (Vec<f64>, f64) {
    let t = tuning_points(s);
    let offsets: Vec<f64> = t.iter().map(|&tj| -c * tj).collect();
    let c_prime = c / tangent_points(s)[0].exp();
    (offsets, c_prime)
}

/// Open-loop S-spline approximation of e^x (eq. 48, Fig. 2a).
pub fn exp_spline_approx(x: f64, s: usize) -> f64 {
    let q = tangent_points(s);
    let t = tuning_points(s);
    let eq: Vec<f64> = q.iter().map(|&v| v.exp()).collect();
    let mut out = 0.0;
    let mut prefix = 0.0;
    for j in 0..s {
        let coef = eq[j] - prefix;
        prefix += eq[j];
        out += coef * (x - t[j]).max(0.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s3_matches_paper_eq49_53() {
        let (offs, cp) = schedule(3, 1.0);
        assert!((offs[0] - (1.0 + LN2)).abs() < 1e-12);
        assert!((offs[1] - (1.0 - LN2)).abs() < 1e-12);
        assert!((offs[2] - (1.0 - 2.0 * LN2)).abs() < 1e-12);
        assert!((cp - 2.0).abs() < 1e-12);
    }

    #[test]
    fn s1_is_classic_mp() {
        let (offs, cp) = schedule(1, 1.0);
        assert_eq!(offs.len(), 1);
        assert!((offs[0] - 1.0).abs() < 1e-12); // T_1 = −1 → O = C
        assert!((cp - 1.0).abs() < 1e-12);
    }

    #[test]
    fn error_shrinks_s1_to_s3_fig2a() {
        // Fig. 2a compares S=1 against S=3: the margin narrows.  (The
        // dyadic schedule trades *range* for density beyond that, so the
        // comparison is only meaningful on the margin window.)
        let grid: Vec<f64> = (0..=100).map(|i| -1.0 + 0.02 * i as f64).collect();
        let max_err = |s: usize| {
            grid.iter()
                .map(|&x| (exp_spline_approx(x, s) - x.exp()).abs())
                .fold(0.0, f64::max)
        };
        let e1 = max_err(1);
        let e3 = max_err(3);
        assert!(e3 < e1, "e1={e1} e3={e3}");
    }

    #[test]
    fn gmp_lse_approximation_improves_with_s() {
        // The operative Fig. 2a claim: the *multi-input* GMP h approximates
        // log-sum-exp more tightly with more splines.
        use crate::sac::gmp::{solve_exact};
        let pairs = [(0.3, -0.4), (1.0, 0.2), (-0.8, -0.1), (0.5, 0.45)];
        let max_err = |s: usize| {
            let (offs, cp) = schedule(s, 1.0);
            pairs
                .iter()
                .map(|&(a, b)| {
                    let mut x = Vec::new();
                    for &o in &offs {
                        x.push(a + o);
                        x.push(b + o);
                    }
                    let h = solve_exact(&x, cp);
                    let lse = (a.exp() + b.exp()).ln();
                    (h - lse).abs()
                })
                .fold(0.0, f64::max)
        };
        let e1 = max_err(1);
        let e3 = max_err(3);
        assert!(e3 < e1, "e1={e1} e3={e3}");
    }

    #[test]
    fn matches_python_goldens_shape() {
        // spot values cross-checked against sacml.splines
        let t = tuning_points(3);
        assert!((t[0] - (-LN2 - 1.0)).abs() < 1e-12);
        assert!((t[1] - (LN2 - 1.0)).abs() < 1e-12);
        assert!((t[2] - (2.0 * LN2 - 1.0)).abs() < 1e-12);
    }
}
