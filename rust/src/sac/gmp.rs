//! Algorithmic Generalized-Margin-Propagation solvers (paper eq. 6/9).
//!
//! Mirrors `python/compile/kernels/{ref,gmp}.py` exactly: the same two
//! algorithms (sort-based exact solve for the ReLU shape, fixed-iteration
//! bisection for any shape), the same iteration count, so rust and the AOT
//! artifacts produce the same numbers (cross-checked against
//! `artifacts/goldens_gmp.json` in the integration tests).

/// Number of bisection iterations — keep in sync with `ref.GMP_ITERS`.
pub const GMP_ITERS: usize = 60;

/// The GMP shape function g (paper Sec. II-B).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Shape {
    /// `g(z) = [z]_+` (eq. 3, the MP limit)
    Relu,
    /// g(z) = w·ln(1+e^{z/w}) — the weak-inversion device shape with knee
    /// width `w` (normalized units)
    Softplus { width: f64 },
}

impl Shape {
    /// Evaluate g(z).
    #[inline]
    pub fn g(&self, z: f64) -> f64 {
        match *self {
            Shape::Relu => z.max(0.0),
            Shape::Softplus { width } => {
                let t = z / width;
                // stable softplus
                if t > 30.0 {
                    z
                } else if t < -30.0 {
                    width * t.exp()
                } else {
                    width * t.exp().ln_1p()
                }
            }
        }
    }

    /// g'(z) (for gradients / sensitivity analysis).
    #[inline]
    pub fn gprime(&self, z: f64) -> f64 {
        match *self {
            Shape::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Shape::Softplus { width } => {
                let t = (z / width).clamp(-40.0, 40.0);
                1.0 / (1.0 + (-t).exp())
            }
        }
    }

    /// knee pad used to widen the bisection bracket for soft shapes
    fn pad(&self) -> f64 {
        match *self {
            Shape::Relu => 0.0,
            Shape::Softplus { width } => 4.0 * width,
        }
    }
}

/// Exact ReLU-shape solve: h with Σ [x_j − h]_+ = C (unclamped).
///
/// Sort descending, prefix sums S_k, candidate h_k = (S_k − C)/k; the
/// consistent k is the largest with x_(k) > h_k (monotone condition).
pub fn solve_exact(x: &[f64], c: f64) -> f64 {
    debug_assert!(!x.is_empty() && c > 0.0);
    let mut xs = x.to_vec();
    xs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cum = 0.0;
    let mut h = f64::NEG_INFINITY;
    for (k, &v) in xs.iter().enumerate() {
        cum += v;
        let hk = (cum - c) / (k + 1) as f64;
        if v > hk {
            h = hk; // still consistent with k+1 active
        } else {
            break;
        }
    }
    h
}

/// Bisection solve for any shape: h with Σ g(x_j − h) = C (unclamped).
/// Bracket: [max(x) − C − pad, max(x) + pad]; fixed `iters` halvings.
pub fn solve_bisect(x: &[f64], c: f64, shape: Shape, iters: usize) -> f64 {
    debug_assert!(!x.is_empty() && c > 0.0);
    let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let pad = shape.pad();
    let mut lo = mx - c - pad;
    let mut hi = mx + pad;
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        let s: f64 = x.iter().map(|&v| shape.g(v - mid)).sum();
        if s > c {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Newton solve for the softplus shape, warm-started from the exact ReLU
/// solution (§Perf optimization: the bisection burns 60·M transcendentals;
/// Newton from the ReLU point — which is within ~4·width of the root —
/// converges quadratically in ≤ 8 steps).  Falls back to bisection if the
/// iteration leaves its bracket (never observed in tests, but cheap
/// insurance).
pub fn solve_soft_newton(x: &[f64], c: f64, width: f64) -> f64 {
    let shape = Shape::Softplus { width };
    let h_relu = solve_exact(x, c);
    // softplus(z) >= relu(z), so the soft solution sits at or above h_relu
    let lo = h_relu - 1e-12;
    let hi = h_relu + 4.0 * width + 1e-12;
    let mut h = h_relu + 0.5 * width;
    for _ in 0..8 {
        let mut s = 0.0;
        let mut sp = 0.0;
        for &v in x {
            let z = v - h;
            s += shape.g(z);
            sp += shape.gprime(z);
        }
        if sp <= 1e-30 {
            break;
        }
        let step = (s - c) / sp; // residual decreasing in h → move up when s>C
        h += step;
        if step.abs() < 1e-12 * width.max(1e-12) {
            break;
        }
        if !(lo..=hi).contains(&h) {
            return solve_bisect(x, c, shape, GMP_ITERS);
        }
    }
    h
}

/// Residual Σ g(x_j − h) − C (zero at the solution).
pub fn residual(x: &[f64], h: f64, c: f64, shape: Shape) -> f64 {
    x.iter().map(|&v| shape.g(v - h)).sum::<f64>() - c
}

/// S-AC unit output: solve then clamp to ≥ 0 (the output is a current).
pub fn sac_h(x: &[f64], c: f64, shape: Shape) -> f64 {
    let h = match shape {
        Shape::Relu => solve_exact(x, c),
        Shape::Softplus { width } => solve_soft_newton(x, c, width),
    };
    h.max(0.0)
}

/// Implicit-function gradient dh/dx_j = g'(x_j−h)/Σ g' (paper eq. 22/23
/// structure).
pub fn grad(x: &[f64], h: f64, shape: Shape) -> Vec<f64> {
    let gp: Vec<f64> = x.iter().map(|&v| shape.gprime(v - h)).collect();
    let denom: f64 = gp.iter().sum::<f64>().max(1e-30);
    gp.into_iter().map(|g| g / denom).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::check;

    #[test]
    fn exact_matches_bisect() {
        check(1, 300, |g| -> Result<(), String> {
            let m = g.usize_in(1, 16);
            let x = g.vec_f64(m, -4.0, 4.0);
            let c = g.f64_in(0.05, 8.0);
            let he = solve_exact(&x, c);
            let hb = solve_bisect(&x, c, Shape::Relu, GMP_ITERS);
            prop_assert!((he - hb).abs() < 1e-9, "he={he} hb={hb}");
            Ok(())
        });
    }

    #[test]
    fn exact_satisfies_constraint() {
        check(2, 300, |g| -> Result<(), String> {
            let m = g.usize_in(1, 20);
            let x = g.vec_f64(m, -5.0, 5.0);
            let c = g.f64_in(0.05, 10.0);
            let h = solve_exact(&x, c);
            let r = residual(&x, h, c, Shape::Relu);
            prop_assert!(r.abs() < 1e-9 * c.max(1.0), "resid={r}");
            Ok(())
        });
    }

    #[test]
    fn softplus_satisfies_constraint() {
        check(3, 200, |g| -> Result<(), String> {
            let m = g.usize_in(1, 12);
            let x = g.vec_f64(m, -3.0, 3.0);
            let c = g.f64_in(0.1, 5.0);
            let w = g.f64_in(0.01, 0.5);
            let shape = Shape::Softplus { width: w };
            let h = solve_bisect(&x, c, shape, GMP_ITERS);
            let r = residual(&x, h, c, shape);
            prop_assert!(r.abs() < 1e-7 * c.max(1.0), "resid={r}");
            Ok(())
        });
    }

    #[test]
    fn translation_invariance() {
        check(4, 200, |g| -> Result<(), String> {
            let m = g.usize_in(1, 10);
            let x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.1, 4.0);
            let d = g.f64_in(-3.0, 3.0);
            let h0 = solve_exact(&x, c);
            let xs: Vec<f64> = x.iter().map(|v| v + d).collect();
            let h1 = solve_exact(&xs, c);
            prop_assert!((h1 - h0 - d).abs() < 1e-9, "h0={h0} h1={h1} d={d}");
            Ok(())
        });
    }

    #[test]
    fn monotone_in_each_input() {
        check(5, 150, |g| -> Result<(), String> {
            let m = g.usize_in(2, 10);
            let mut x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.1, 4.0);
            let j = g.usize_in(0, m - 1);
            let h0 = solve_exact(&x, c);
            x[j] += 0.3;
            prop_assert!(solve_exact(&x, c) >= h0 - 1e-12);
            Ok(())
        });
    }

    #[test]
    fn bounded_by_logsumexp() {
        check(6, 150, |g| -> Result<(), String> {
            let m = g.usize_in(1, 8);
            let x = g.vec_f64(m, -3.0, 3.0);
            let c = g.f64_in(0.2, 4.0);
            let h = solve_exact(&x, c);
            let lse = c * x.iter().map(|v| (v / c).exp()).sum::<f64>().ln();
            let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            prop_assert!(h <= lse + 1e-9, "h={h} lse={lse}");
            prop_assert!(h >= mx - c - 1e-9);
            Ok(())
        });
    }

    #[test]
    fn grad_rows_sum_to_one() {
        check(7, 100, |g| -> Result<(), String> {
            let m = g.usize_in(1, 12);
            let x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.2, 3.0);
            let h = solve_exact(&x, c);
            let gr = grad(&x, h, Shape::Relu);
            let s: f64 = gr.iter().sum();
            prop_assert!((s - 1.0).abs() < 1e-9, "sum={s}");
            prop_assert!(gr.iter().all(|&v| (0.0..=1.0).contains(&v)));
            Ok(())
        });
    }

    #[test]
    fn winner_residue_formula_eq22() {
        // eq. 22: h = (Σ winners − C)/M
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        for c in [0.5, 2.0, 6.0] {
            let h = solve_exact(&x, c);
            let winners: Vec<f64> = x.iter().cloned().filter(|&v| v > h).collect();
            let m = winners.len() as f64;
            let expect = (winners.iter().sum::<f64>() - c) / m;
            assert!((h - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn newton_matches_bisection() {
        check(8, 300, |g| -> Result<(), String> {
            let m = g.usize_in(1, 16);
            let x = g.vec_f64(m, -3.0, 3.0);
            let c = g.f64_in(0.1, 6.0);
            let w = g.f64_in(0.005, 0.6);
            let hn = solve_soft_newton(&x, c, w);
            let hb = solve_bisect(&x, c, Shape::Softplus { width: w }, GMP_ITERS);
            prop_assert!((hn - hb).abs() < 1e-7, "newton={hn} bisect={hb} w={w}");
            Ok(())
        });
    }

    #[test]
    fn sac_h_monotone_in_each_input_any_shape() {
        // raising any one input must never lower the unit output,
        // whichever shape the device presents
        check(9, 200, |g| -> Result<(), String> {
            let m = g.usize_in(2, 10);
            let mut x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.1, 4.0);
            let shape = if g.bool() {
                Shape::Relu
            } else {
                Shape::Softplus {
                    width: g.f64_in(0.02, 0.5),
                }
            };
            let j = g.usize_in(0, m - 1);
            let h0 = sac_h(&x, c, shape);
            x[j] += g.f64_in(0.05, 1.0);
            let h1 = sac_h(&x, c, shape);
            prop_assert!(h1 >= h0 - 1e-6, "h0={h0} h1={h1} shape={shape:?}");
            Ok(())
        });
    }

    #[test]
    fn scale_invariance_both_shapes() {
        // the constraint Σ g(x−h)=C is 1-homogeneous: h(λx; λC) = λ·h(x; C)
        // (for softplus the knee width scales with λ too:
        //  λ·g_w(z) = g_{λw}(λz))
        check(10, 200, |g| -> Result<(), String> {
            let m = g.usize_in(1, 10);
            let x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.2, 3.0);
            let lam = g.f64_in(0.25, 4.0);
            let xs: Vec<f64> = x.iter().map(|v| v * lam).collect();
            let h0 = solve_exact(&x, c);
            let h1 = solve_exact(&xs, c * lam);
            prop_assert!(
                (h1 - lam * h0).abs() < 1e-9 * lam.max(1.0),
                "relu: h0={h0} h1={h1} lam={lam}"
            );
            let w = g.f64_in(0.02, 0.4);
            let s0 = solve_soft_newton(&x, c, w);
            let s1 = solve_soft_newton(&xs, c * lam, w * lam);
            prop_assert!(
                (s1 - lam * s0).abs() < 1e-6 * lam.max(1.0),
                "softplus: s0={s0} s1={s1} lam={lam} w={w}"
            );
            Ok(())
        });
    }

    #[test]
    fn translation_invariance_soft_newton() {
        // shift invariance for the soft shape's production solver
        check(13, 150, |g| -> Result<(), String> {
            let m = g.usize_in(1, 10);
            let x = g.vec_f64(m, -2.0, 2.0);
            let c = g.f64_in(0.2, 3.0);
            let w = g.f64_in(0.02, 0.4);
            let d = g.f64_in(-2.0, 2.0);
            let h0 = solve_soft_newton(&x, c, w);
            let xs: Vec<f64> = x.iter().map(|v| v + d).collect();
            let h1 = solve_soft_newton(&xs, c, w);
            prop_assert!((h1 - h0 - d).abs() < 1e-6, "h0={h0} h1={h1} d={d}");
            Ok(())
        });
    }

    #[test]
    fn spline_expanded_solvers_agree_across_s() {
        // randomized spline counts: the Appendix-A expanded proto problem
        // must solve identically under the exact and bisection solvers,
        // and its output must stay monotone in z
        check(12, 150, |g| -> Result<(), String> {
            let s = g.usize_in(1, 5);
            let c = g.f64_in(0.3, 2.0);
            let z = g.f64_in(-2.5, 2.5);
            let (offs, cp) = crate::sac::splines::schedule(s, c);
            let expand = |z: f64| -> Vec<f64> {
                let mut x = Vec::with_capacity(2 * s);
                for &o in &offs {
                    x.push(z + o);
                }
                for &o in &offs {
                    x.push(o);
                }
                x
            };
            let x = expand(z);
            let he = solve_exact(&x, cp);
            let hb = solve_bisect(&x, cp, Shape::Relu, GMP_ITERS);
            prop_assert!((he - hb).abs() < 1e-9, "s={s} he={he} hb={hb}");
            let dz = g.f64_in(0.01, 0.5);
            let x2 = expand(z + dz);
            prop_assert!(
                sac_h(&x2, cp, Shape::Relu) >= sac_h(&x, cp, Shape::Relu) - 1e-12,
                "s={s} z={z} dz={dz}"
            );
            Ok(())
        });
    }

    #[test]
    fn softplus_approaches_relu_as_width_shrinks() {
        let x = [0.3, -0.7, 1.4, 0.0];
        let c = 1.0;
        let hr = solve_exact(&x, c);
        let hs = solve_bisect(&x, c, Shape::Softplus { width: 1e-4 }, GMP_ITERS);
        assert!((hr - hs).abs() < 1e-3);
    }
}
