//! S-AC neural-network evaluation (Sec. V): the algorithm→hardware mapping
//! of eq. 40, scored on the exported test sets at every (node, regime)
//! corner — the Table IV "H/W" columns — plus the Fig. 15 confusion matrix
//! and operating-regime census.

use std::cell::RefCell;
use std::path::Path;

use anyhow::Result;

use crate::cells::activations as act;
use crate::cells::multiplier::Multiplier;
use crate::cells::HProvider;
use crate::data::{Dataset, TrainedNet};
use crate::pdk::{ProcessNode, regime::Regime};
use crate::util::pool;
use crate::util::stats::Confusion;

/// Activation gain mapping pre-activation currents into the cell's input
/// range (mirrors python nets.sac_forward's `act_gain`).
pub const ACT_GAIN: f64 = 4.0;

/// Forward one input row through the S-AC network on a backend.
pub fn forward(
    net: &TrainedNet,
    p: &dyn HProvider,
    mult: &Multiplier,
    x: &[f32],
) -> Vec<f64> {
    let nl = net.n_layers();
    let mut h: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    for li in 0..nl {
        let n_in = net.sizes[li];
        let n_out = net.sizes[li + 1];
        let mut out = vec![0.0; n_out];
        for k in 0..n_out {
            // eq. 40: the dot product as 4-term S-AC multiplies, KCL-summed
            let mut acc = net.biases[li][k];
            for i in 0..n_in {
                acc += mult.mul(p, h[i], net.w(li, i, k));
            }
            out[k] = acc;
        }
        if li < nl - 1 {
            for v in out.iter_mut() {
                let z = *v * ACT_GAIN;
                *v = match net.activation.as_str() {
                    "phi1" => act::phi1_cell(p, z, 1.0, net.splines, 0.5),
                    "phi2" => act::phi2_cell(p, z, 1.0, net.splines, 0.5) - 1.0,
                    "relu" => act::relu_cell(p, z, 0.05),
                    "softplus" => act::softplus_cell(p, z, net.splines, 0.5),
                    other => panic!("unknown activation {other}"),
                };
            }
        }
        h = out;
    }
    h
}

/// Evaluate accuracy + confusion over a dataset (parallel over samples).
pub fn evaluate<P>(
    net: &TrainedNet,
    make_provider: P,
    ds: &Dataset,
    limit: usize,
    threads: usize,
) -> Confusion
where
    P: Fn() -> Box<dyn HProvider> + Sync,
{
    let n = ds.n.min(limit);
    let k = *net.sizes.last().unwrap();
    // calibrate the multiplier once (operating point is a property of the
    // backend family, not of the sample)
    let cal = {
        let p = make_provider();
        Multiplier::calibrate(p.as_ref(), net.splines, net.c)
    };
    let preds: Vec<usize> = pool::parallel_map(n, threads, |i| {
        let p = make_provider();
        let m = cal.clone();
        let logits = forward(net, p.as_ref(), &m, ds.row(i));
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0)
    });
    let mut cm = Confusion::new(k);
    for (i, &pred) in preds.iter().enumerate() {
        cm.record(ds.y[i] as usize, pred);
    }
    cm
}

/// Load a trained net from `artifacts/weights_<task>.json`.
pub fn load_net(artifacts: &Path, task: &str) -> Result<TrainedNet> {
    TrainedNet::load(&artifacts.join(format!("weights_{task}.json")))
}

// ---------------------------------------------------------------------------
// Operating-regime census (Fig. 15b)
// ---------------------------------------------------------------------------

/// Provider wrapper that records every branch input it evaluates.
pub struct CensusProvider<'a> {
    pub inner: &'a dyn HProvider,
    pub log: RefCell<Vec<f64>>,
}

impl<'a> HProvider for CensusProvider<'a> {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        self.log.borrow_mut().extend_from_slice(x);
        self.inner.h(x, c)
    }

    fn h_raw(&self, x: &[f64], c: f64) -> f64 {
        self.log.borrow_mut().extend_from_slice(x);
        self.inner.h_raw(x, c)
    }

    fn label(&self) -> String {
        format!("census({})", self.inner.label())
    }
}

/// Census result: fraction of branch transistors operating outside the
/// intended regime during inference.
#[derive(Clone, Debug)]
pub struct Census {
    pub total: usize,
    pub shifted: usize,
    pub fraction_shifted: f64,
}

/// Classify recorded branch inputs: algorithmic value ↦ branch current
/// `v·I_bias(regime)`; inversion coefficient against the branch device's
/// specific current; compare with the intended regime.
pub fn regime_census(
    node: &'static ProcessNode,
    regime: Regime,
    values: &[f64],
) -> Census {
    let bias = node.bias_current(regime);
    let dev = crate::device::Mosfet::square(node, crate::pdk::Polarity::N);
    let i_s = node.i_spec_at(27.0) * (dev.w_um / dev.l_um);
    let mut shifted = 0;
    let mut total = 0;
    for &v in values {
        let i = (v.abs() * bias).max(node.leak_floor);
        let ic = i / i_s;
        total += 1;
        if Regime::classify_ic(ic) != regime {
            shifted += 1;
        }
    }
    Census {
        total,
        shifted,
        fraction_shifted: if total == 0 {
            0.0
        } else {
            shifted as f64 / total as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;

    fn toy_net() -> TrainedNet {
        TrainedNet {
            task: "toy".into(),
            sizes: vec![2, 3, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            // hand-built XOR-ish weights
            weights: vec![
                vec![0.8, -0.8, 0.5, -0.8, 0.8, 0.5],
                vec![0.9, -0.9, 0.9, -0.9, -0.9, 0.9],
            ],
            biases: vec![vec![-0.2, -0.2, -0.6], vec![0.0, 0.0]],
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        let y = forward(&net, &p, &m, &[0.5, -0.5]);
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn evaluate_runs_parallel() {
        // single-layer sign classifier: w = [[1,-1],[0,0]] ⇒ argmax tracks
        // sign(x0); exercises the parallel evaluate path end to end.
        let net = TrainedNet {
            task: "sign".into(),
            sizes: vec![2, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![vec![1.0, -1.0, 0.0, 0.0]],
            biases: vec![vec![0.0, 0.0]],
        };
        let xor = crate::data::gen_xor(64, 5, 0.0);
        // relabel: class = 1 if x0 < 0
        let mut ds = xor.clone();
        for i in 0..ds.n {
            ds.y[i] = (ds.row(i)[0] < 0.0) as u16;
        }
        let cm = evaluate(&net, || Box::new(Algorithmic::relu()), &ds, 64, 3);
        assert_eq!(cm.total(), 64);
        assert!(cm.accuracy() > 0.95, "acc={}", cm.accuracy());
    }

    #[test]
    fn census_counts_shifts() {
        use crate::pdk::CMOS180;
        // values spanning decades: some land outside WI
        let vals = [0.001, 0.5, 1.0, 50.0, 2000.0];
        let c = regime_census(&CMOS180, Regime::WeakInversion, &vals);
        assert_eq!(c.total, 5);
        assert!(c.shifted >= 1 && c.shifted < 5);
        assert!((0.0..=1.0).contains(&c.fraction_shifted));
    }

    #[test]
    fn census_provider_records() {
        let inner = Algorithmic::relu();
        let cp = CensusProvider {
            inner: &inner,
            log: RefCell::new(Vec::new()),
        };
        let _ = cp.h(&[0.5, 1.0], 1.0);
        let _ = cp.h_raw(&[2.0], 0.5);
        assert_eq!(cp.log.borrow().len(), 3);
    }
}
