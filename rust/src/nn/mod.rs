//! S-AC neural-network evaluation (Sec. V): the algorithm→hardware mapping
//! of eq. 40, scored on the exported test sets at every (node, regime)
//! corner — the Table IV "H/W" columns — plus the Fig. 15 confusion matrix
//! and operating-regime census.

pub mod batch;

use std::cell::RefCell;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::cells::activations as act;
use crate::cells::multiplier::Multiplier;
use crate::cells::HProvider;
use crate::data::{Dataset, TrainedNet};
use crate::pdk::{ProcessNode, regime::Regime};
use crate::util::pool;
use crate::util::stats::Confusion;

/// Activation gain mapping pre-activation currents into the cell's input
/// range (mirrors python nets.sac_forward's `act_gain`).
pub const ACT_GAIN: f64 = 4.0;

/// Hidden-layer activation of the eq. 40 network.
///
/// Parsed (and thereby validated) when a net is loaded —
/// [`TrainedNet::load`] rejects unknown names with an error instead of
/// the hot loop panicking per element mid-inference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Phi1,
    Phi2,
    Relu,
    Softplus,
}

impl Activation {
    /// The python trainer's activation vocabulary.
    pub const NAMES: [&'static str; 4] = ["phi1", "phi2", "relu", "softplus"];

    /// Parse a trained net's activation name.
    pub fn parse(name: &str) -> Result<Activation> {
        match name {
            "phi1" => Ok(Activation::Phi1),
            "phi2" => Ok(Activation::Phi2),
            "relu" => Ok(Activation::Relu),
            "softplus" => Ok(Activation::Softplus),
            other => Err(anyhow!(
                "unknown activation {other:?} (expected one of {:?})",
                Activation::NAMES
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Activation::Phi1 => "phi1",
            Activation::Phi2 => "phi2",
            Activation::Relu => "relu",
            Activation::Softplus => "softplus",
        }
    }

    /// The cell transfer applied between layers (the `− 1` on φ2 recenters
    /// the sigmoid's `[0, 2K]` output around zero, mirroring
    /// `nets.sac_forward`).
    pub fn eval(self, p: &dyn HProvider, z: f64, splines: usize) -> f64 {
        match self {
            Activation::Phi1 => act::phi1_cell(p, z, 1.0, splines, 0.5),
            Activation::Phi2 => act::phi2_cell(p, z, 1.0, splines, 0.5) - 1.0,
            Activation::Relu => act::relu_cell(p, z, 0.05),
            Activation::Softplus => act::softplus_cell(p, z, splines, 0.5),
        }
    }
}

/// The activation applied between layers, parsed once.  Single-layer
/// nets never evaluate a hidden activation, so their activation string
/// is not consulted (load-time validation in [`TrainedNet::load`] still
/// rejects unknown names on disk input).
fn hidden_activation(net: &TrainedNet) -> Activation {
    if net.n_layers() <= 1 {
        // never evaluated — any placeholder works
        return Activation::Relu;
    }
    net.activation_kind()
        .expect("TrainedNet activation is validated at load time")
}

/// Forward one input row through the S-AC network on a backend.
pub fn forward(
    net: &TrainedNet,
    p: &dyn HProvider,
    mult: &Multiplier,
    x: &[f32],
) -> Vec<f64> {
    forward_with(net, p, mult, hidden_activation(net), x)
}

/// Like [`forward`], with the activation pre-parsed so batch drivers
/// ([`batch::BatchKernel`], [`evaluate`]) hoist the parse out of their
/// loops.
pub fn forward_with(
    net: &TrainedNet,
    p: &dyn HProvider,
    mult: &Multiplier,
    act: Activation,
    x: &[f32],
) -> Vec<f64> {
    let nl = net.n_layers();
    let mut h: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    for li in 0..nl {
        let n_in = net.sizes[li];
        let n_out = net.sizes[li + 1];
        let mut out = vec![0.0; n_out];
        for k in 0..n_out {
            // eq. 40: the dot product as 4-term S-AC multiplies, KCL-summed
            let mut acc = net.biases[li][k];
            for i in 0..n_in {
                acc += mult.mul(p, h[i], net.w(li, i, k));
            }
            out[k] = acc;
        }
        if li < nl - 1 {
            for v in out.iter_mut() {
                *v = act.eval(p, *v * ACT_GAIN, net.splines);
            }
        }
        h = out;
    }
    h
}

/// Evaluate accuracy + confusion over a dataset (parallel over samples).
pub fn evaluate<P>(
    net: &TrainedNet,
    make_provider: P,
    ds: &Dataset,
    limit: usize,
    threads: usize,
) -> Confusion
where
    P: Fn() -> Box<dyn HProvider> + Sync,
{
    let n = ds.n.min(limit);
    let k = *net.sizes.last().unwrap();
    let act = hidden_activation(net);
    // calibrate the multiplier once (operating point is a property of the
    // backend family, not of the sample)
    let cal = {
        let p = make_provider();
        Multiplier::calibrate(p.as_ref(), net.splines, net.c)
    };
    let preds: Vec<usize> = pool::parallel_map(n, threads, |i| {
        let p = make_provider();
        let m = cal.clone();
        let logits = forward_with(net, p.as_ref(), &m, act, ds.row(i));
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0)
    });
    let mut cm = Confusion::new(k);
    for (i, &pred) in preds.iter().enumerate() {
        cm.record(ds.y[i] as usize, pred);
    }
    cm
}

/// Load a trained net from `artifacts/weights_<task>.json`.
pub fn load_net(artifacts: &Path, task: &str) -> Result<TrainedNet> {
    TrainedNet::load(&artifacts.join(format!("weights_{task}.json")))
}

// ---------------------------------------------------------------------------
// Operating-regime census (Fig. 15b)
// ---------------------------------------------------------------------------

/// Provider wrapper that records every branch input it evaluates.
pub struct CensusProvider<'a> {
    pub inner: &'a dyn HProvider,
    pub log: RefCell<Vec<f64>>,
}

impl<'a> HProvider for CensusProvider<'a> {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        self.log.borrow_mut().extend_from_slice(x);
        self.inner.h(x, c)
    }

    fn h_raw(&self, x: &[f64], c: f64) -> f64 {
        self.log.borrow_mut().extend_from_slice(x);
        self.inner.h_raw(x, c)
    }

    fn label(&self) -> String {
        format!("census({})", self.inner.label())
    }
}

/// Census result: fraction of branch transistors operating outside the
/// intended regime during inference.
#[derive(Clone, Debug)]
pub struct Census {
    pub total: usize,
    pub shifted: usize,
    pub fraction_shifted: f64,
}

/// Classify recorded branch inputs: algorithmic value ↦ branch current
/// `v·I_bias(regime)`; inversion coefficient against the branch device's
/// specific current; compare with the intended regime.
pub fn regime_census(
    node: &'static ProcessNode,
    regime: Regime,
    values: &[f64],
) -> Census {
    let bias = node.bias_current(regime);
    let dev = crate::device::Mosfet::square(node, crate::pdk::Polarity::N);
    let i_s = node.i_spec_at(27.0) * (dev.w_um / dev.l_um);
    let mut shifted = 0;
    let mut total = 0;
    for &v in values {
        let i = (v.abs() * bias).max(node.leak_floor);
        let ic = i / i_s;
        total += 1;
        if Regime::classify_ic(ic) != regime {
            shifted += 1;
        }
    }
    Census {
        total,
        shifted,
        fraction_shifted: if total == 0 {
            0.0
        } else {
            shifted as f64 / total as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;

    fn toy_net() -> TrainedNet {
        TrainedNet {
            task: "toy".into(),
            sizes: vec![2, 3, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            // hand-built XOR-ish weights
            weights: vec![
                vec![0.8, -0.8, 0.5, -0.8, 0.8, 0.5],
                vec![0.9, -0.9, 0.9, -0.9, -0.9, 0.9],
            ],
            biases: vec![vec![-0.2, -0.2, -0.6], vec![0.0, 0.0]],
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        let y = forward(&net, &p, &m, &[0.5, -0.5]);
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn evaluate_runs_parallel() {
        // single-layer sign classifier: w = [[1,-1],[0,0]] ⇒ argmax tracks
        // sign(x0); exercises the parallel evaluate path end to end.
        let net = TrainedNet {
            task: "sign".into(),
            sizes: vec![2, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![vec![1.0, -1.0, 0.0, 0.0]],
            biases: vec![vec![0.0, 0.0]],
        };
        let xor = crate::data::gen_xor(64, 5, 0.0);
        // relabel: class = 1 if x0 < 0
        let mut ds = xor.clone();
        for i in 0..ds.n {
            ds.y[i] = (ds.row(i)[0] < 0.0) as u16;
        }
        let cm = evaluate(&net, || Box::new(Algorithmic::relu()), &ds, 64, 3);
        assert_eq!(cm.total(), 64);
        assert!(cm.accuracy() > 0.95, "acc={}", cm.accuracy());
    }

    #[test]
    fn census_counts_shifts() {
        use crate::pdk::CMOS180;
        // values spanning decades: some land outside WI
        let vals = [0.001, 0.5, 1.0, 50.0, 2000.0];
        let c = regime_census(&CMOS180, Regime::WeakInversion, &vals);
        assert_eq!(c.total, 5);
        assert!(c.shifted >= 1 && c.shifted < 5);
        assert!((0.0..=1.0).contains(&c.fraction_shifted));
    }

    #[test]
    fn activation_parse_roundtrip_and_rejection() {
        for name in Activation::NAMES {
            assert_eq!(Activation::parse(name).unwrap().name(), name);
        }
        let err = Activation::parse("gelu").unwrap_err();
        assert!(err.to_string().contains("gelu"), "{err}");
    }

    #[test]
    fn single_layer_net_ignores_activation_string() {
        // no hidden layer → the activation is never evaluated; a
        // hand-built placeholder name must not panic (load-time
        // validation still rejects it on disk input)
        let net = TrainedNet {
            task: "lin".into(),
            sizes: vec![2, 2],
            activation: "linear".into(),
            splines: 1,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![vec![1.0, 0.0, 0.0, 1.0]],
            biases: vec![vec![0.0, 0.0]],
        };
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 1, 1.0);
        let y = forward(&net, &p, &m, &[0.3, -0.2]);
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_with_matches_forward() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        let act = net.activation_kind().unwrap();
        let a = forward(&net, &p, &m, &[0.3, -0.6]);
        let b = forward_with(&net, &p, &m, act, &[0.3, -0.6]);
        assert_eq!(a, b);
    }

    #[test]
    fn census_provider_records() {
        let inner = Algorithmic::relu();
        let cp = CensusProvider {
            inner: &inner,
            log: RefCell::new(Vec::new()),
        };
        let _ = cp.h(&[0.5, 1.0], 1.0);
        let _ = cp.h_raw(&[2.0], 0.5);
        assert_eq!(cp.log.borrow().len(), 3);
    }
}
