//! Batch-major columnar execution engine for S-AC network inference.
//!
//! The scalar path (`nn::forward`) re-derives the S-AC shape math per MAC:
//! every `Multiplier::mul` is four proto-unit GMP solves, so a `B×D` batch
//! through a `[D, H, K]` net costs `4·B·(D·H + H·K)` solver calls.  The
//! paper's point is that the *shapes* are robust across (node, regime,
//! temperature) corners — so at NN scale the shape responses can be
//! sampled **once per corner** into dense lookup grids and replayed by
//! interpolation for the whole serving lifetime (the crossbar-style
//! batched-MAC structure of Binas et al. / Liu-Strachan-Basu).
//!
//! Two grids per corner, both sampled from the calibrated backend
//! ([`crate::cells::HProvider`]) at engine-build time:
//!
//! * [`MulGrid`] — the four-quadrant multiplier's lookup.  Eq. 24
//!   factorizes through the 1-D proto-shape response `P(z)` —
//!   `mul(x,w) = scale·(P(a+w+x) − P(a+w−x) + P(a−w−x) − P(a−w+x))` —
//!   so the dense grid is one very fine 1-D table of `P` rather than a
//!   coarse 2-D surface: build cost `O(points)`, and on the ReLU-shape
//!   tier `P` is piecewise linear, making linear interpolation *exact*
//!   away from the (measure-zero) kink cells.
//! * [`ActGrid`] — the hidden activation cell's 1-D transfer, sampled
//!   post-gain (`z = ACT_GAIN · preactivation`).
//!
//! Operands outside a grid's range fall back to the exact cell evaluation
//! (never clamped — correctness is preserved, only speed degrades), so
//! the engine is numerically safe for unbounded activations (relu /
//! softplus hidden layers) and out-of-distribution inputs.
//!
//! The kernel itself is **columnar**: activations live column-major
//! (`h[i·rows + r]`), the weight loop is outermost and the row loop
//! innermost, so one weight's four grid bases are hoisted across the
//! whole batch and both the input column and the accumulator column are
//! contiguous.  Padded tail rows are skipped by the `rows` (live-row)
//! argument — the padded-row contract of `coordinator::batcher::Batch`.
//!
//! DESIGN.md §7 documents grid resolution and the interpolation error
//! budget; `tests/integration.rs` pins batched-vs-scalar equivalence at
//! every corner the table tier exercises.

use std::fmt;

use anyhow::Result;

use crate::cells::multiplier::Multiplier;
use crate::cells::{proto_unit, HProvider};
use crate::data::TrainedNet;
use crate::util::rng::Rng;

use super::{Activation, ACT_GAIN};

/// Resolution / range knobs for the per-corner lookup grids.
///
/// Defaults give a proto-shape step of `1/2048` over `z ∈ [−12, 12]`
/// (393 KB, L2-resident) and an activation step of `1/1024` over
/// `z ∈ [−8, 8]` — see DESIGN.md §7 for the error budget behind these.
#[derive(Clone, Copy, Debug)]
pub struct GridConfig {
    /// half-range of the proto-shape grid (covers `a ± w ± x`)
    pub proto_range: f64,
    /// proto-shape samples per unit of z
    pub proto_density: usize,
    /// half-range of the activation grid (post-gain z)
    pub act_range: f64,
    /// activation samples per unit of z
    pub act_density: usize,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            proto_range: 12.0,
            proto_density: 2048,
            act_range: 8.0,
            act_density: 1024,
        }
    }
}

/// A dense 1-D sample table with linear interpolation.
#[derive(Clone, Debug)]
pub struct Grid1D {
    lo: f64,
    hi: f64,
    inv_step: f64,
    values: Vec<f64>,
}

impl Grid1D {
    /// Sample `f` on `n ≥ 2` evenly spaced points over `[lo, hi]`.
    pub fn sample<F: Fn(f64) -> f64>(lo: f64, hi: f64, n: usize, f: F) -> Grid1D {
        assert!(n >= 2 && hi > lo, "grid needs n>=2 points and hi>lo");
        let step = (hi - lo) / (n - 1) as f64;
        let values: Vec<f64> = (0..n).map(|i| f(lo + step * i as f64)).collect();
        Grid1D {
            lo,
            hi,
            inv_step: 1.0 / step,
            values,
        }
    }

    #[inline]
    pub fn contains(&self, z: f64) -> bool {
        z >= self.lo && z <= self.hi
    }

    /// Linear interpolation at `z`; the caller guarantees `contains(z)`.
    #[inline]
    pub fn eval(&self, z: f64) -> f64 {
        let t = (z - self.lo) * self.inv_step;
        // min() guards the z == hi endpoint (t lands exactly on the last
        // sample); anything further out is the caller's contract breach.
        let i = (t as usize).min(self.values.len() - 2);
        let f = t - i as f64;
        self.values[i] + (self.values[i + 1] - self.values[i]) * f
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Sample value at index `i` (fault-injection / diagnostic surface).
    pub fn value(&self, i: usize) -> f64 {
        self.values[i]
    }

    /// Overwrite sample `i` — models a stuck-at / dead storage cell in a
    /// physical lookup crossbar (`faults::` uses this; never called on the
    /// nominal serving path).
    pub fn set(&mut self, i: usize, v: f64) {
        self.values[i] = v;
    }
}

/// Dense lookup grid for the calibrated four-quadrant multiplier
/// (Fig. 11): a fine 1-D table of the proto-shape response `P(z)` plus
/// the calibration's operating point `a` and output scale.
#[derive(Clone, Debug)]
pub struct MulGrid {
    grid: Grid1D,
    a: f64,
    scale: f64,
}

impl MulGrid {
    /// Sample the backend's proto-shape once over the configured range.
    pub fn build(p: &dyn HProvider, mult: &Multiplier, cfg: &GridConfig) -> MulGrid {
        let n = (2.0 * cfg.proto_range * cfg.proto_density as f64) as usize + 1;
        let s = mult.s;
        let c = mult.c;
        let grid = Grid1D::sample(-cfg.proto_range, cfg.proto_range, n, |z| {
            proto_unit(p, z, s, c)
        });
        MulGrid {
            grid,
            a: mult.a,
            scale: mult.scale,
        }
    }

    /// eq. 24 through the interpolated proto-shape (all four arguments in
    /// range; `wp = a + w`, `wm = a − w` hoisted by the caller).
    #[inline]
    fn eval(&self, x: f64, wp: f64, wm: f64) -> f64 {
        self.scale
            * (self.grid.eval(wp + x) - self.grid.eval(wp - x) + self.grid.eval(wm - x)
                - self.grid.eval(wm + x))
    }

    /// `dst[r] += mul(xs[r], w)` for every row: the grid where the proto
    /// arguments stay in range, the exact cell (`mult.mul`) otherwise.
    pub fn accumulate(
        &self,
        p: &dyn HProvider,
        mult: &Multiplier,
        xs: &[f64],
        w: f64,
        dst: &mut [f64],
    ) {
        debug_assert_eq!(xs.len(), dst.len());
        let wp = self.a + w;
        let wm = self.a - w;
        // every proto argument obeys |arg| ≤ max(|wp|, |wm|) + |x|, so
        // |x| < margin keeps all four lookups inside the grid
        let margin = self.grid.hi - wp.abs().max(wm.abs());
        for (d, &x) in dst.iter_mut().zip(xs) {
            if x.abs() < margin {
                *d += self.eval(x, wp, wm);
            } else {
                *d += mult.mul(p, x, w);
            }
        }
    }

    /// Single interpolated multiply (test/diagnostic surface; the batch
    /// path uses [`MulGrid::accumulate`]).
    pub fn mul(&self, p: &dyn HProvider, mult: &Multiplier, x: f64, w: f64) -> f64 {
        let mut acc = [0.0f64];
        self.accumulate(p, mult, &[x], w, &mut acc);
        acc[0]
    }

    /// Number of proto-shape samples backing the grid.
    pub fn points(&self) -> usize {
        self.grid.len()
    }

    /// Force `round(fraction · points)` randomly chosen proto-shape samples
    /// to `value` (stuck-at cells).  Draws may collide, so the number of
    /// *distinct* corrupted cells can be slightly lower than the returned
    /// write count.  Deterministic given `rng`'s state.
    pub fn inject_stuck(&mut self, rng: &mut Rng, fraction: f64, value: f64) -> usize {
        let n = self.grid.len();
        let k = ((n as f64) * fraction).round() as usize;
        for _ in 0..k {
            let i = rng.below(n);
            self.grid.set(i, value);
        }
        k
    }
}

/// Dense 1-D lookup grid for a hidden-activation cell's transfer.
#[derive(Clone, Debug)]
pub struct ActGrid {
    grid: Grid1D,
    act: Activation,
    splines: usize,
}

impl ActGrid {
    /// Sample `act` on the backend once over the configured post-gain range.
    pub fn build(p: &dyn HProvider, act: Activation, splines: usize, cfg: &GridConfig) -> ActGrid {
        let n = (2.0 * cfg.act_range * cfg.act_density as f64) as usize + 1;
        let grid = Grid1D::sample(-cfg.act_range, cfg.act_range, n, |z| act.eval(p, z, splines));
        ActGrid { grid, act, splines }
    }

    /// `v ← act(v · gain)` elementwise: interpolated where in range, the
    /// exact cell otherwise (unbounded activations stay correct).
    pub fn apply(&self, p: &dyn HProvider, vals: &mut [f64], gain: f64) {
        for v in vals.iter_mut() {
            let z = *v * gain;
            *v = if self.grid.contains(z) {
                self.grid.eval(z)
            } else {
                self.act.eval(p, z, self.splines)
            };
        }
    }

    /// Number of samples backing the grid.
    pub fn points(&self) -> usize {
        self.grid.len()
    }
}

/// One corner's batched execution kernel: the calibrated multiplier and
/// activation grids plus the backend they were sampled from (kept for
/// exact out-of-range fallbacks).  Weight-independent — the same kernel
/// serves every net sharing `(activation, splines, C)` on this corner.
///
/// `Send + Sync` (plain data + a `Send + Sync` backend), so the serving
/// router can run many batches through one kernel concurrently.
pub struct BatchKernel {
    provider: Box<dyn HProvider + Send + Sync>,
    mult: Multiplier,
    act: Activation,
    splines: usize,
    c: f64,
    mul_grid: MulGrid,
    act_grid: ActGrid,
}

impl fmt::Debug for BatchKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("BatchKernel")
            .field("backend", &self.provider.label())
            .field("activation", &self.act)
            .field("splines", &self.splines)
            .field("c", &self.c)
            .field("mul_grid_points", &self.mul_grid.points())
            .field("act_grid_points", &self.act_grid.points())
            .finish()
    }
}

impl BatchKernel {
    /// Calibrate the multiplier on `provider` and sample both grids.
    pub fn new(
        provider: Box<dyn HProvider + Send + Sync>,
        act: Activation,
        splines: usize,
        c: f64,
        cfg: &GridConfig,
    ) -> BatchKernel {
        let mult = Multiplier::calibrate(provider.as_ref(), splines, c);
        let mul_grid = MulGrid::build(provider.as_ref(), &mult, cfg);
        let act_grid = ActGrid::build(provider.as_ref(), act, splines, cfg);
        BatchKernel {
            provider,
            mult,
            act,
            splines,
            c,
            mul_grid,
            act_grid,
        }
    }

    /// Kernel matching a trained net's `(activation, splines, C)` triple.
    pub fn for_net(
        provider: Box<dyn HProvider + Send + Sync>,
        net: &TrainedNet,
        cfg: &GridConfig,
    ) -> Result<BatchKernel> {
        let act = net.activation_kind()?;
        Ok(BatchKernel::new(provider, act, net.splines, net.c, cfg))
    }

    /// Like [`BatchKernel::new`] but with a pre-calibrated multiplier:
    /// grids are sampled from `provider` while the operating point / scale
    /// come from `mult`.  This is the chip-calibration-then-drift semantics
    /// the fault harness needs — calibrate once on the nominal corner, then
    /// replay that calibration on a perturbed backend.
    pub fn with_multiplier(
        provider: Box<dyn HProvider + Send + Sync>,
        mult: Multiplier,
        act: Activation,
        splines: usize,
        c: f64,
        cfg: &GridConfig,
    ) -> BatchKernel {
        debug_assert_eq!(mult.s, splines, "multiplier/spline-count mismatch");
        let mul_grid = MulGrid::build(provider.as_ref(), &mult, cfg);
        let act_grid = ActGrid::build(provider.as_ref(), act, splines, cfg);
        BatchKernel {
            provider,
            mult,
            act,
            splines,
            c,
            mul_grid,
            act_grid,
        }
    }

    /// Stuck-at fault injection into the multiplier lookup grid (see
    /// [`MulGrid::inject_stuck`]); returns the write count.
    pub fn inject_stuck_cells(&mut self, rng: &mut Rng, fraction: f64, value: f64) -> usize {
        self.mul_grid.inject_stuck(rng, fraction, value)
    }

    /// The multiplier calibration the grids were sampled with (identical
    /// to what the scalar path computes for the same backend).
    pub fn multiplier(&self) -> &Multiplier {
        &self.mult
    }

    pub fn activation(&self) -> Activation {
        self.act
    }

    /// Spline count the kernel was sampled for.
    pub fn splines(&self) -> usize {
        self.splines
    }

    /// Shape parameter C the kernel was sampled for.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Evaluate eq. 40 over a whole batch.
    ///
    /// * `x` — row-major `[batch × sizes[0]]` feature buffer (at least
    ///   `rows` rows; padded tail rows are never read),
    /// * `rows` — live-row count (the `Batch::live` contract),
    /// * `weights[li]` — row-major `[sizes[li] × sizes[li+1]]`,
    ///
    /// Returns row-major `[rows × sizes.last()]` logits.
    pub fn forward_batch(
        &self,
        sizes: &[usize],
        weights: &[Vec<f64>],
        biases: &[Vec<f64>],
        x: &[f32],
        rows: usize,
    ) -> Vec<f64> {
        let _span = crate::util::trace::span("batch.forward");
        let nl = sizes.len() - 1;
        let din = sizes[0];
        debug_assert!(x.len() >= rows * din, "input batch shorter than rows");
        let p = self.provider.as_ref();

        // columnar layout: h[i·rows + r] holds input i of row r
        let mut h = vec![0.0f64; din * rows];
        for r in 0..rows {
            for i in 0..din {
                h[i * rows + r] = x[r * din + i] as f64;
            }
        }

        for li in 0..nl {
            let n_in = sizes[li];
            let n_out = sizes[li + 1];
            let w = &weights[li];
            let mut out = vec![0.0f64; n_out * rows];
            for (k, &b) in biases[li].iter().enumerate() {
                for v in &mut out[k * rows..(k + 1) * rows] {
                    *v = b;
                }
            }
            // weights outermost, rows innermost: one weight's grid bases
            // are hoisted across the whole batch, and both the input
            // column and the accumulator column are contiguous
            for i in 0..n_in {
                let col = &h[i * rows..i * rows + rows];
                for k in 0..n_out {
                    let dst = &mut out[k * rows..(k + 1) * rows];
                    self.mul_grid
                        .accumulate(p, &self.mult, col, w[i * n_out + k], dst);
                }
            }
            if li < nl - 1 {
                self.act_grid.apply(p, &mut out, ACT_GAIN);
            }
            h = out;
        }

        // transpose back to the row-major contract of the runtime
        let k_out = sizes[nl];
        let mut logits = vec![0.0f64; rows * k_out];
        for k in 0..k_out {
            for r in 0..rows {
                logits[r * k_out + k] = h[k * rows + r];
            }
        }
        logits
    }

    /// [`BatchKernel::forward_batch`] with the shapes taken from a
    /// [`TrainedNet`] (test / direct-evaluation convenience).
    pub fn forward_net(&self, net: &TrainedNet, x: &[f32], rows: usize) -> Vec<f64> {
        debug_assert_eq!(
            net.splines, self.splines,
            "kernel calibrated for a different spline count"
        );
        self.forward_batch(&net.sizes, &net.weights, &net.biases, x, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;
    use crate::nn;
    use crate::util::rng::Rng;

    #[test]
    fn grid1d_exact_on_linear_function() {
        let g = Grid1D::sample(-2.0, 2.0, 41, |z| 3.0 * z - 0.5);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let z = rng.uniform_in(-2.0, 2.0);
            assert!((g.eval(z) - (3.0 * z - 0.5)).abs() < 1e-12, "z={z}");
        }
        // endpoints are in range and safe
        assert!(g.contains(2.0) && g.contains(-2.0));
        assert!((g.eval(2.0) - 5.5).abs() < 1e-12);
        assert!((g.eval(-2.0) - (-6.5)).abs() < 1e-12);
        assert_eq!(g.len(), 41);
        assert!(!g.is_empty());
    }

    #[test]
    fn mul_grid_matches_exact_multiplier() {
        let p = Algorithmic::relu();
        let mult = Multiplier::calibrate(&p, 3, 1.0);
        let grid = MulGrid::build(&p, &mult, &GridConfig::default());
        let mut rng = Rng::new(7);
        let mut worst = 0.0f64;
        for _ in 0..500 {
            let x = rng.uniform_in(-1.5, 1.5);
            let w = rng.uniform_in(-1.0, 1.0);
            let got = grid.mul(&p, &mult, x, w);
            let want = mult.mul(&p, x, w);
            worst = worst.max((got - want).abs());
        }
        assert!(worst < 2e-3, "worst grid-vs-exact error {worst}");
    }

    #[test]
    fn mul_grid_out_of_range_falls_back_exactly() {
        let p = Algorithmic::relu();
        let mult = Multiplier::calibrate(&p, 3, 1.0);
        let grid = MulGrid::build(&p, &mult, &GridConfig::default());
        // |x| far beyond the grid: the fallback is the exact cell, so the
        // answers are bit-identical
        for x in [25.0, -40.0, 1e3] {
            let got = grid.mul(&p, &mult, x, 0.7);
            let want = mult.mul(&p, x, 0.7);
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn act_grid_matches_cell_and_falls_back() {
        let p = Algorithmic::relu();
        for act in [
            Activation::Phi1,
            Activation::Phi2,
            Activation::Relu,
            Activation::Softplus,
        ] {
            let g = ActGrid::build(&p, act, 3, &GridConfig::default());
            let mut vals = vec![-1.5, -0.25, 0.0, 0.4, 1.9, 30.0, -30.0];
            let expect: Vec<f64> = vals
                .iter()
                .map(|&v| act.eval(&p, v * ACT_GAIN, 3))
                .collect();
            g.apply(&p, &mut vals, ACT_GAIN);
            for (got, want) in vals.iter().zip(&expect) {
                assert!(
                    (got - want).abs() < 1e-3,
                    "{act:?}: got {got} want {want}"
                );
            }
        }
    }

    fn toy_net() -> TrainedNet {
        TrainedNet {
            task: "toy".into(),
            sizes: vec![2, 3, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![
                vec![0.8, -0.8, 0.5, -0.8, 0.8, 0.5],
                vec![0.9, -0.9, 0.9, -0.9, -0.9, 0.9],
            ],
            biases: vec![vec![-0.2, -0.2, -0.6], vec![0.0, 0.0]],
        }
    }

    #[test]
    fn forward_net_matches_scalar_forward() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let mult = Multiplier::calibrate(&p, net.splines, net.c);
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75, 0.1, 0.9, -0.8, -0.3];
        let rows = 4;
        let batched = kernel.forward_net(&net, &x, rows);
        assert_eq!(batched.len(), rows * 2);
        for r in 0..rows {
            let golden = nn::forward(&net, &p, &mult, &x[r * 2..(r + 1) * 2]);
            for (j, &want) in golden.iter().enumerate() {
                let got = batched[r * 2 + j];
                assert!(
                    (got - want).abs() < 5e-3,
                    "row {r} logit {j}: batched {got} vs scalar {want}"
                );
            }
        }
    }

    #[test]
    fn forward_batch_skips_padded_rows() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        // 4-row buffer, 2 live rows: output covers only the live rows and
        // equals the full-batch prefix
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75, 0.0, 0.0, 0.0, 0.0];
        let full = kernel.forward_net(&net, &x, 4);
        let live = kernel.forward_net(&net, &x, 2);
        assert_eq!(live.len(), 4);
        assert_eq!(&full[..4], &live[..]);
        // zero rows is a clean no-op
        assert!(kernel.forward_net(&net, &x, 0).is_empty());
    }

    #[test]
    fn with_multiplier_replays_calibration_and_stuck_cells_perturb() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let act = net.activation_kind().unwrap();
        let cfg = GridConfig::default();
        let mult = Multiplier::calibrate(&p, net.splines, net.c);
        let fresh = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        let replay = BatchKernel::with_multiplier(
            Box::new(Algorithmic::relu()),
            mult.clone(),
            act,
            net.splines,
            net.c,
            &cfg,
        );
        assert_eq!(replay.splines(), net.splines);
        assert_eq!(replay.c(), net.c);
        // same backend + same calibration → bit-identical outputs
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75];
        let want = fresh.forward_net(&net, &x, 2);
        assert_eq!(replay.forward_net(&net, &x, 2), want);

        // zero-fraction injection is a no-op
        let mut faulty = BatchKernel::with_multiplier(
            Box::new(Algorithmic::relu()),
            mult.clone(),
            act,
            net.splines,
            net.c,
            &cfg,
        );
        assert_eq!(faulty.inject_stuck_cells(&mut Rng::new(3), 0.0, 0.0), 0);
        assert_eq!(faulty.forward_net(&net, &x, 2), want);
        // a dense stuck-at-zero sweep must visibly perturb the output
        let writes = faulty.inject_stuck_cells(&mut Rng::new(3), 0.05, 0.0);
        assert!(writes > 100, "writes={writes}");
        assert_ne!(faulty.forward_net(&net, &x, 2), want);
    }

    #[test]
    fn kernel_debug_is_informative() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let s = format!("{kernel:?}");
        assert!(s.contains("BatchKernel") && s.contains("algorithmic"), "{s}");
        assert_eq!(kernel.activation(), Activation::Phi1);
        assert!(kernel.multiplier().scale.is_finite());
    }
}
