//! Batch-major columnar execution engine for S-AC network inference.
//!
//! The scalar path (`nn::forward`) re-derives the S-AC shape math per MAC:
//! every `Multiplier::mul` is four proto-unit GMP solves, so a `B×D` batch
//! through a `[D, H, K]` net costs `4·B·(D·H + H·K)` solver calls.  The
//! paper's point is that the *shapes* are robust across (node, regime,
//! temperature) corners — so at NN scale the shape responses can be
//! sampled **once per corner** into dense lookup grids and replayed by
//! interpolation for the whole serving lifetime (the crossbar-style
//! batched-MAC structure of Binas et al. / Liu-Strachan-Basu).
//!
//! Two grids per corner, both sampled from the calibrated backend
//! ([`crate::cells::HProvider`]) at engine-build time:
//!
//! * [`MulGrid`] — the four-quadrant multiplier's lookup.  Eq. 24
//!   factorizes through the 1-D proto-shape response `P(z)` —
//!   `mul(x,w) = scale·(P(a+w+x) − P(a+w−x) + P(a−w−x) − P(a−w+x))` —
//!   so the dense grid is one very fine 1-D table of `P` rather than a
//!   coarse 2-D surface: build cost `O(points)`, and on the ReLU-shape
//!   tier `P` is piecewise linear, making linear interpolation *exact*
//!   away from the (measure-zero) kink cells.
//! * [`ActGrid`] — the hidden activation cell's 1-D transfer, sampled
//!   post-gain (`z = ACT_GAIN · preactivation`).
//!
//! Operands outside a grid's range fall back to the exact cell evaluation
//! (never clamped — correctness is preserved, only speed degrades), so
//! the engine is numerically safe for unbounded activations (relu /
//! softplus hidden layers) and out-of-distribution inputs.
//!
//! The kernel itself is **columnar**: activations live column-major
//! (`h[i·rows + r]`), the weight loop is outermost and the row loop
//! innermost, so one weight's four grid bases are hoisted across the
//! whole batch and both the input column and the accumulator column are
//! contiguous.  Padded tail rows are skipped by the `rows` (live-row)
//! argument — the padded-row contract of `coordinator::batcher::Batch`.
//!
//! Three hot-path properties on top of the layout (DESIGN.md §10):
//!
//! * **Row-slab parallelism** — `forward_batch_threads` shards the live
//!   rows into contiguous slabs dispatched over the process-wide
//!   [`crate::util::pool::shared_pool`]; each slab runs the *full*
//!   layer pipeline over its own disjoint row range, so no per-layer
//!   barrier exists and every row's accumulation order is unchanged —
//!   results are **bit-identical** to the serial kernel at any thread
//!   count.  Batches under `2 ×` [`MIN_SLAB_ROWS`] skip parallel
//!   dispatch entirely.
//! * **Zero-alloc steady state** — the per-layer column buffers live in
//!   a reusable ping-pong scratch arena checked out per call (sized to
//!   `widest layer × rows`, grown monotonically), and
//!   `forward_batch_into` writes into a caller-owned logits buffer, so
//!   a warmed kernel's forward pass performs no heap allocation
//!   (asserted by the counting-allocator harness in
//!   `tests/observability.rs`).
//! * **Shared grid cache** — grids for cacheable backends (see
//!   [`crate::cells::HProvider::cache_key`]) are sampled once per
//!   `(backend, multiplier, activation, splines, GridConfig)` key and
//!   `Arc`-shared process-wide across engines, tasks and chaos lanes;
//!   [`BatchKernel::inject_stuck_cells`] copy-on-writes the shared grid
//!   so faults never leak into sibling kernels.
//!
//! DESIGN.md §7 documents grid resolution and the interpolation error
//! budget; `tests/integration.rs` pins batched-vs-scalar equivalence at
//! every corner the table tier exercises plus bit-identical
//! parallel-vs-serial logits.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cells::multiplier::Multiplier;
use crate::cells::{proto_unit, HProvider};
use crate::data::TrainedNet;
use crate::util::rng::Rng;

use super::{Activation, ACT_GAIN};

/// Resolution / range knobs for the per-corner lookup grids.
///
/// Defaults give a proto-shape step of `1/2048` over `z ∈ [−12, 12]`
/// (393 KB, L2-resident) and an activation step of `1/1024` over
/// `z ∈ [−8, 8]` — see DESIGN.md §7 for the error budget behind these.
#[derive(Clone, Copy, Debug)]
pub struct GridConfig {
    /// half-range of the proto-shape grid (covers `a ± w ± x`)
    pub proto_range: f64,
    /// proto-shape samples per unit of z
    pub proto_density: usize,
    /// half-range of the activation grid (post-gain z)
    pub act_range: f64,
    /// activation samples per unit of z
    pub act_density: usize,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            proto_range: 12.0,
            proto_density: 2048,
            act_range: 8.0,
            act_density: 1024,
        }
    }
}

/// A dense 1-D sample table with linear interpolation.
#[derive(Clone, Debug)]
pub struct Grid1D {
    lo: f64,
    hi: f64,
    inv_step: f64,
    values: Vec<f64>,
}

impl Grid1D {
    /// Sample `f` on `n ≥ 2` evenly spaced points over `[lo, hi]`.
    pub fn sample<F: Fn(f64) -> f64>(lo: f64, hi: f64, n: usize, f: F) -> Grid1D {
        assert!(n >= 2 && hi > lo, "grid needs n>=2 points and hi>lo");
        let step = (hi - lo) / (n - 1) as f64;
        let values: Vec<f64> = (0..n).map(|i| f(lo + step * i as f64)).collect();
        Grid1D {
            lo,
            hi,
            inv_step: 1.0 / step,
            values,
        }
    }

    #[inline]
    pub fn contains(&self, z: f64) -> bool {
        z >= self.lo && z <= self.hi
    }

    /// Linear interpolation at `z`; the caller guarantees `contains(z)`.
    #[inline]
    pub fn eval(&self, z: f64) -> f64 {
        let t = (z - self.lo) * self.inv_step;
        // min() guards the z == hi endpoint (t lands exactly on the last
        // sample); anything further out is the caller's contract breach.
        let i = (t as usize).min(self.values.len() - 2);
        let f = t - i as f64;
        self.values[i] + (self.values[i + 1] - self.values[i]) * f
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Sample value at index `i` (fault-injection / diagnostic surface).
    pub fn value(&self, i: usize) -> f64 {
        self.values[i]
    }

    /// Overwrite sample `i` — models a stuck-at / dead storage cell in a
    /// physical lookup crossbar (`faults::` uses this; never called on the
    /// nominal serving path).
    pub fn set(&mut self, i: usize, v: f64) {
        self.values[i] = v;
    }
}

/// Dense lookup grid for the calibrated four-quadrant multiplier
/// (Fig. 11): a fine 1-D table of the proto-shape response `P(z)` plus
/// the calibration's operating point `a` and output scale.
#[derive(Clone, Debug)]
pub struct MulGrid {
    grid: Grid1D,
    a: f64,
    scale: f64,
}

impl MulGrid {
    /// Sample the backend's proto-shape once over the configured range.
    pub fn build(p: &dyn HProvider, mult: &Multiplier, cfg: &GridConfig) -> MulGrid {
        let n = (2.0 * cfg.proto_range * cfg.proto_density as f64) as usize + 1;
        let s = mult.s;
        let c = mult.c;
        let grid = Grid1D::sample(-cfg.proto_range, cfg.proto_range, n, |z| {
            proto_unit(p, z, s, c)
        });
        MulGrid {
            grid,
            a: mult.a,
            scale: mult.scale,
        }
    }

    /// eq. 24 through the interpolated proto-shape (all four arguments in
    /// range; `wp = a + w`, `wm = a − w` hoisted by the caller).
    #[inline]
    fn eval(&self, x: f64, wp: f64, wm: f64) -> f64 {
        self.scale
            * (self.grid.eval(wp + x) - self.grid.eval(wp - x) + self.grid.eval(wm - x)
                - self.grid.eval(wm + x))
    }

    /// `dst[r] += mul(xs[r], w)` for every row: the grid where the proto
    /// arguments stay in range, the exact cell (`mult.mul`) otherwise.
    pub fn accumulate(
        &self,
        p: &dyn HProvider,
        mult: &Multiplier,
        xs: &[f64],
        w: f64,
        dst: &mut [f64],
    ) {
        debug_assert_eq!(xs.len(), dst.len());
        let wp = self.a + w;
        let wm = self.a - w;
        // every proto argument obeys |arg| ≤ max(|wp|, |wm|) + |x|, so
        // |x| < margin keeps all four lookups inside the grid
        let margin = self.grid.hi - wp.abs().max(wm.abs());
        for (d, &x) in dst.iter_mut().zip(xs) {
            if x.abs() < margin {
                *d += self.eval(x, wp, wm);
            } else {
                *d += mult.mul(p, x, w);
            }
        }
    }

    /// Single interpolated multiply (test/diagnostic surface; the batch
    /// path uses [`MulGrid::accumulate`]).
    pub fn mul(&self, p: &dyn HProvider, mult: &Multiplier, x: f64, w: f64) -> f64 {
        let mut acc = [0.0f64];
        self.accumulate(p, mult, &[x], w, &mut acc);
        acc[0]
    }

    /// [`MulGrid::accumulate`] with per-element signal-health accounting
    /// into `sig` (DESIGN.md §12).  Numerically identical to the plain
    /// path — the instrumentation only counts; it never changes which of
    /// grid / exact-cell evaluates an element.
    pub fn accumulate_signal(
        &self,
        p: &dyn HProvider,
        mult: &Multiplier,
        xs: &[f64],
        w: f64,
        dst: &mut [f64],
        sig: &mut SlabSignal,
    ) {
        debug_assert_eq!(xs.len(), dst.len());
        let wp = self.a + w;
        let wm = self.a - w;
        let margin = self.grid.hi - wp.abs().max(wm.abs());
        let lo = self.grid.lo;
        let inv_span = HEAT_BINS as f64 / (self.grid.hi - lo);
        sig.mul_elems += xs.len() as u64;
        for (d, &x) in dst.iter_mut().zip(xs) {
            // margin-propagation residual: headroom (in z units) left
            // before this element would leave the proto grid; negative ⇒
            // the element fell back to the exact cell
            let residual = margin - x.abs();
            if residual < sig.margin_min {
                sig.margin_min = residual;
            }
            if residual > 0.0 {
                sig.margin_sum += residual;
                // access heat: bin the representative `wp + x` probe over
                // the grid range (negative offsets saturate to bin 0)
                let b = ((wp + x - lo) * inv_span) as usize;
                sig.heat[b.min(HEAT_BINS - 1)] += 1;
                *d += self.eval(x, wp, wm);
            } else {
                sig.mul_fallbacks += 1;
                *d += mult.mul(p, x, w);
            }
        }
    }

    /// Number of proto-shape samples backing the grid.
    pub fn points(&self) -> usize {
        self.grid.len()
    }

    /// Force `round(fraction · points)` randomly chosen proto-shape samples
    /// to `value` (stuck-at cells).  Draws may collide, so the number of
    /// *distinct* corrupted cells can be slightly lower than the returned
    /// write count.  Deterministic given `rng`'s state.
    pub fn inject_stuck(&mut self, rng: &mut Rng, fraction: f64, value: f64) -> usize {
        let n = self.grid.len();
        let k = ((n as f64) * fraction).round() as usize;
        for _ in 0..k {
            let i = rng.below(n);
            self.grid.set(i, value);
        }
        k
    }
}

/// Dense 1-D lookup grid for a hidden-activation cell's transfer.
#[derive(Clone, Debug)]
pub struct ActGrid {
    grid: Grid1D,
    act: Activation,
    splines: usize,
}

impl ActGrid {
    /// Sample `act` on the backend once over the configured post-gain range.
    pub fn build(p: &dyn HProvider, act: Activation, splines: usize, cfg: &GridConfig) -> ActGrid {
        let n = (2.0 * cfg.act_range * cfg.act_density as f64) as usize + 1;
        let grid = Grid1D::sample(-cfg.act_range, cfg.act_range, n, |z| act.eval(p, z, splines));
        ActGrid { grid, act, splines }
    }

    /// `v ← act(v · gain)` elementwise: interpolated where in range, the
    /// exact cell otherwise (unbounded activations stay correct).
    pub fn apply(&self, p: &dyn HProvider, vals: &mut [f64], gain: f64) {
        for v in vals.iter_mut() {
            let z = *v * gain;
            *v = if self.grid.contains(z) {
                self.grid.eval(z)
            } else {
                self.act.eval(p, z, self.splines)
            };
        }
    }

    /// [`ActGrid::apply`] with per-element signal-health accounting into
    /// `sig`: pre-activation values landing in the top/bottom 5% of the
    /// grid's post-gain range count as saturated (dynamic-range misuse,
    /// per Binas et al.), and out-of-range exact-cell evaluations count
    /// as fallbacks.  Numerically identical to the plain path.
    pub fn apply_signal(&self, p: &dyn HProvider, vals: &mut [f64], gain: f64, sig: &mut SlabSignal) {
        let lo = self.grid.lo;
        let hi = self.grid.hi;
        let band = 0.05 * (hi - lo);
        let lo_thr = lo + band;
        let hi_thr = hi - band;
        sig.act_samples += vals.len() as u64;
        for v in vals.iter_mut() {
            let z = *v * gain;
            if z >= hi_thr {
                sig.act_sat_high += 1;
            } else if z <= lo_thr {
                sig.act_sat_low += 1;
            }
            *v = if self.grid.contains(z) {
                self.grid.eval(z)
            } else {
                sig.act_fallbacks += 1;
                self.act.eval(p, z, self.splines)
            };
        }
    }

    /// Number of samples backing the grid.
    pub fn points(&self) -> usize {
        self.grid.len()
    }
}

// ---------------------------------------------------------------------------
// Process-wide grid cache
// ---------------------------------------------------------------------------

/// Counters describing the process-wide grid cache (telemetry surface;
/// see `coordinator::telemetry::KernelSnapshot`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GridCacheStats {
    /// Kernel constructions that reused a cached grid pair.
    pub hits: u64,
    /// Kernel constructions that sampled fresh grids (uncacheable
    /// backends count here too — they bypass the map entirely).
    pub misses: u64,
    /// Grid pairs currently held by the cache.
    pub entries: usize,
    /// Entries evicted by targeted invalidation
    /// ([`grid_cache_invalidate`] — quarantine/rebuild path).
    pub invalidations: u64,
}

static GRID_CACHE: Mutex<Option<HashMap<String, (Arc<MulGrid>, Arc<ActGrid>)>>> =
    Mutex::new(None);
static GRID_CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static GRID_CACHE_MISSES: AtomicU64 = AtomicU64::new(0);
static GRID_CACHE_INVALIDATIONS: AtomicU64 = AtomicU64::new(0);

/// Current grid-cache counters.
pub fn grid_cache_stats() -> GridCacheStats {
    let entries = GRID_CACHE
        .lock()
        .unwrap()
        .as_ref()
        .map_or(0, |m| m.len());
    GridCacheStats {
        hits: GRID_CACHE_HITS.load(Ordering::Relaxed),
        misses: GRID_CACHE_MISSES.load(Ordering::Relaxed),
        entries,
        invalidations: GRID_CACHE_INVALIDATIONS.load(Ordering::Relaxed),
    }
}

/// Drop every cached grid pair (benchmarks use this to measure cold
/// builds; live kernels keep their `Arc`s and are unaffected).  The
/// hit/miss counters are monotonic and survive the clear.
pub fn grid_cache_clear() {
    if let Some(m) = GRID_CACHE.lock().unwrap().as_mut() {
        m.clear();
    }
}

/// Evict every cached grid pair whose key contains `fragment`, returning
/// the number evicted.  The self-healing router's quarantine path calls
/// this with the stale backend's [`HProvider::cache_key`] before
/// re-calibrating, so the rebuilt kernel samples fresh grids from the
/// *current* provider instead of resurrecting drifted tables.  Live
/// kernels keep their `Arc`s — only future constructions see the
/// eviction.  An empty `fragment` matches (and evicts) everything.
pub fn grid_cache_invalidate(fragment: &str) -> usize {
    let mut g = GRID_CACHE.lock().unwrap();
    let Some(map) = g.as_mut() else { return 0 };
    let before = map.len();
    map.retain(|k, _| !k.contains(fragment));
    let evicted = before - map.len();
    GRID_CACHE_INVALIDATIONS.fetch_add(evicted as u64, Ordering::Relaxed);
    evicted
}

/// Fetch-or-build the grid pair for one kernel.  Cache key =
/// backend identity ([`HProvider::cache_key`]) ⊕ exact multiplier
/// calibration bits ⊕ activation ⊕ spline count ⊕ exact [`GridConfig`]
/// bits, so two kernels share grids only when they would sample
/// bit-identical tables.  Uncacheable backends (`cache_key() == None`,
/// e.g. the fault harness's mismatch wrappers) build privately and count
/// as misses.  Builds happen under the cache lock: each key is sampled
/// at most once per process.
fn grids_for(
    p: &dyn HProvider,
    mult: &Multiplier,
    act: Activation,
    splines: usize,
    cfg: &GridConfig,
) -> (Arc<MulGrid>, Arc<ActGrid>) {
    let build = || {
        (
            Arc::new(MulGrid::build(p, mult, cfg)),
            Arc::new(ActGrid::build(p, act, splines, cfg)),
        )
    };
    let key = match p.cache_key() {
        Some(k) => format!(
            "{k}|a={:016x}|sc={:016x}|c={:016x}|S={}|act={}|sp={}|pr={:016x}|pd={}|ar={:016x}|ad={}",
            mult.a.to_bits(),
            mult.scale.to_bits(),
            mult.c.to_bits(),
            mult.s,
            act.name(),
            splines,
            cfg.proto_range.to_bits(),
            cfg.proto_density,
            cfg.act_range.to_bits(),
            cfg.act_density,
        ),
        None => {
            GRID_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
            return build();
        }
    };
    let mut g = GRID_CACHE.lock().unwrap();
    let map = g.get_or_insert_with(HashMap::new);
    if let Some((m, a)) = map.get(&key) {
        GRID_CACHE_HITS.fetch_add(1, Ordering::Relaxed);
        return (Arc::clone(m), Arc::clone(a));
    }
    GRID_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    let pair = build();
    map.insert(key, (Arc::clone(&pair.0), Arc::clone(&pair.1)));
    pair
}

// ---------------------------------------------------------------------------
// Analog signal-health accounting (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Coarse access-heat bins per [`MulGrid`] (each bin covers 1/8 of the
/// proto grid's z range).
pub const HEAT_BINS: usize = 8;

/// Process-global gate for signal-health accounting.  Off by default:
/// the nominal forward path stays byte-identical with zero extra work
/// beyond one relaxed load per slab.
static SIGNAL_HEALTH: AtomicBool = AtomicBool::new(false);

/// Turn signal-health accounting on/off process-wide.
pub fn signal_health_set(on: bool) {
    SIGNAL_HEALTH.store(on, Ordering::Release);
}

/// Whether the instrumented forward path is active.
pub fn signal_health_enabled() -> bool {
    SIGNAL_HEALTH.load(Ordering::Relaxed)
}

/// Enable signal-health accounting if `SAC_SIGNAL_HEALTH` is set to
/// `1`/`true`/`on`/`yes` (case-insensitive).
pub fn signal_health_init_from_env() {
    let on = std::env::var("SAC_SIGNAL_HEALTH")
        .map(|v| {
            let v = v.trim().to_ascii_lowercase();
            v == "1" || v == "true" || v == "on" || v == "yes"
        })
        .unwrap_or(false);
    if on {
        signal_health_set(true);
    }
}

/// Slab-local signal counters: plain integers bumped in the hot loops,
/// absorbed into the kernel's shared accumulators once per slab so the
/// instrumented path adds no atomics or locks inside the element loops.
#[derive(Clone, Copy, Debug)]
pub struct SlabSignal {
    /// multiplier elements processed (grid + fallback)
    pub mul_elems: u64,
    /// elements evaluated by the exact cell (outside the proto grid)
    pub mul_fallbacks: u64,
    /// activation inputs observed
    pub act_samples: u64,
    /// pre-activations in the top 5% of the act grid's post-gain range
    pub act_sat_high: u64,
    /// pre-activations in the bottom 5% of the range
    pub act_sat_low: u64,
    /// activation inputs outside the grid (exact-cell evaluations)
    pub act_fallbacks: u64,
    /// proto-grid access heat, binned over the grid's z range
    pub heat: [u64; HEAT_BINS],
    /// minimum margin-propagation residual seen (negative ⇒ fallback)
    pub margin_min: f64,
    /// sum of positive residuals (mean headroom = sum / in-grid elems)
    pub margin_sum: f64,
}

impl Default for SlabSignal {
    fn default() -> Self {
        SlabSignal {
            mul_elems: 0,
            mul_fallbacks: 0,
            act_samples: 0,
            act_sat_high: 0,
            act_sat_low: 0,
            act_fallbacks: 0,
            heat: [0; HEAT_BINS],
            margin_min: f64::INFINITY,
            margin_sum: 0.0,
        }
    }
}

/// Shared per-kernel accumulators (one [`BatchKernel`] per lane/corner,
/// so these are per-corner totals).  Grids themselves are `Arc`-shared
/// across kernels via the process-wide cache, so the mutable state lives
/// here, not on [`MulGrid`]/[`ActGrid`].
struct SignalHealth {
    mul_elems: AtomicU64,
    mul_fallbacks: AtomicU64,
    act_samples: AtomicU64,
    act_sat_high: AtomicU64,
    act_sat_low: AtomicU64,
    act_fallbacks: AtomicU64,
    heat: [AtomicU64; HEAT_BINS],
    /// f64 bit pattern of the minimum residual (init +∞)
    margin_min_bits: AtomicU64,
    /// positive-residual sum in micro-z units (integer so the merge is
    /// atomic and associative)
    margin_sum_micro: AtomicU64,
}

impl Default for SignalHealth {
    fn default() -> Self {
        SignalHealth {
            mul_elems: AtomicU64::new(0),
            mul_fallbacks: AtomicU64::new(0),
            act_samples: AtomicU64::new(0),
            act_sat_high: AtomicU64::new(0),
            act_sat_low: AtomicU64::new(0),
            act_fallbacks: AtomicU64::new(0),
            heat: Default::default(),
            margin_min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            margin_sum_micro: AtomicU64::new(0),
        }
    }
}

impl SignalHealth {
    fn absorb(&self, s: &SlabSignal) {
        if s.mul_elems == 0 && s.act_samples == 0 {
            return;
        }
        self.mul_elems.fetch_add(s.mul_elems, Ordering::Relaxed);
        self.mul_fallbacks.fetch_add(s.mul_fallbacks, Ordering::Relaxed);
        self.act_samples.fetch_add(s.act_samples, Ordering::Relaxed);
        self.act_sat_high.fetch_add(s.act_sat_high, Ordering::Relaxed);
        self.act_sat_low.fetch_add(s.act_sat_low, Ordering::Relaxed);
        self.act_fallbacks.fetch_add(s.act_fallbacks, Ordering::Relaxed);
        for (a, &v) in self.heat.iter().zip(&s.heat) {
            if v != 0 {
                a.fetch_add(v, Ordering::Relaxed);
            }
        }
        if s.margin_min.is_finite() {
            let mut cur = self.margin_min_bits.load(Ordering::Relaxed);
            while s.margin_min < f64::from_bits(cur) {
                match self.margin_min_bits.compare_exchange_weak(
                    cur,
                    s.margin_min.to_bits(),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
        let micro = (s.margin_sum * 1e6) as u64;
        if micro != 0 {
            self.margin_sum_micro.fetch_add(micro, Ordering::Relaxed);
        }
    }

    fn snapshot(&self) -> SignalHealthStats {
        let min_bits = self.margin_min_bits.load(Ordering::Relaxed);
        let margin_min = f64::from_bits(min_bits);
        let mut heat = [0u64; HEAT_BINS];
        for (h, a) in heat.iter_mut().zip(&self.heat) {
            *h = a.load(Ordering::Relaxed);
        }
        SignalHealthStats {
            enabled: signal_health_enabled(),
            mul_elems: self.mul_elems.load(Ordering::Relaxed),
            mul_fallbacks: self.mul_fallbacks.load(Ordering::Relaxed),
            act_samples: self.act_samples.load(Ordering::Relaxed),
            act_sat_high: self.act_sat_high.load(Ordering::Relaxed),
            act_sat_low: self.act_sat_low.load(Ordering::Relaxed),
            act_fallbacks: self.act_fallbacks.load(Ordering::Relaxed),
            heat,
            margin_min: if margin_min.is_finite() { margin_min } else { 0.0 },
            margin_sum: self.margin_sum_micro.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

/// Point-in-time copy of one kernel's signal-health accumulators
/// (telemetry surface — `coordinator::telemetry` renders these per lane
/// as the `sac-metrics/v4` `signal` block).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SignalHealthStats {
    /// whether the instrumented path was active at snapshot time
    pub enabled: bool,
    /// multiplier elements processed
    pub mul_elems: u64,
    /// exact-cell fallbacks outside the proto grid
    pub mul_fallbacks: u64,
    /// activation inputs observed
    pub act_samples: u64,
    /// pre-activations in the top 5% of the act range
    pub act_sat_high: u64,
    /// pre-activations in the bottom 5% of the act range
    pub act_sat_low: u64,
    /// activation inputs outside the grid
    pub act_fallbacks: u64,
    /// proto-grid access heat bins
    pub heat: [u64; HEAT_BINS],
    /// minimum margin residual seen (0.0 when nothing was observed)
    pub margin_min: f64,
    /// sum of positive margin residuals
    pub margin_sum: f64,
}

impl SignalHealthStats {
    /// Fraction of pre-activations in the saturation bands.
    pub fn saturation_fraction(&self) -> f64 {
        (self.act_sat_high + self.act_sat_low) as f64 / self.act_samples.max(1) as f64
    }

    /// Fraction of all grid evaluations that fell back to exact cells.
    pub fn fallback_fraction(&self) -> f64 {
        (self.mul_fallbacks + self.act_fallbacks) as f64
            / (self.mul_elems + self.act_samples).max(1) as f64
    }

    /// Health score on the canary disagreement scale: compared against
    /// the paper's 0.15 / 0.40 degradation envelopes by the router, so
    /// saturation creep degrades a lane before canary agreement breaks
    /// (DESIGN.md §12).  Zero whenever accounting is disabled.
    pub fn score(&self) -> f64 {
        self.saturation_fraction().max(self.fallback_fraction())
    }

    /// Merge another kernel's stats (element-wise; min/sum laws).
    pub fn merge(&mut self, other: &SignalHealthStats) {
        // a side that saw no multiplier elements carries the 0.0
        // placeholder min, which must not clobber a real observation
        self.margin_min = match (self.mul_elems > 0, other.mul_elems > 0) {
            (true, true) => self.margin_min.min(other.margin_min),
            (false, true) => other.margin_min,
            _ => self.margin_min,
        };
        self.enabled |= other.enabled;
        self.mul_elems += other.mul_elems;
        self.mul_fallbacks += other.mul_fallbacks;
        self.act_samples += other.act_samples;
        self.act_sat_high += other.act_sat_high;
        self.act_sat_low += other.act_sat_low;
        self.act_fallbacks += other.act_fallbacks;
        for (a, b) in self.heat.iter_mut().zip(&other.heat) {
            *a += *b;
        }
        self.margin_sum += other.margin_sum;
    }
}

// ---------------------------------------------------------------------------
// Slab dispatch bookkeeping
// ---------------------------------------------------------------------------

/// Minimum live rows per slab.  Batches with fewer than
/// `2 × MIN_SLAB_ROWS` live rows never take the parallel dispatch path —
/// the per-slab coordination would cost more than it saves.
pub const MIN_SLAB_ROWS: usize = 8;

static PARALLEL_BATCHES: AtomicU64 = AtomicU64::new(0);
static SERIAL_BATCHES: AtomicU64 = AtomicU64::new(0);

/// `(parallel, serial)` `forward_batch` dispatch counts since process
/// start (telemetry surface — `sac_kernel_batches_total`).
pub fn batch_dispatch_counts() -> (u64, u64) {
    (
        PARALLEL_BATCHES.load(Ordering::Relaxed),
        SERIAL_BATCHES.load(Ordering::Relaxed),
    )
}

/// Slab count actually dispatched for a `(threads, rows)` request.
fn effective_shards(threads: usize, rows: usize) -> usize {
    threads.max(1).min((rows / MIN_SLAB_ROWS).max(1))
}

/// Raw-pointer courier into the disjoint-slab buffers (same
/// edition-2021 capture note as `util::pool`'s `SendPtr`).  Soundness:
/// every shard writes only its own `[r0, r1)` row range of each column,
/// and `run_scoped` establishes the happens-before edge back to the
/// caller before the buffers are read.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);

impl SendPtr {
    fn get(self) -> *mut f64 {
        self.0
    }
}
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Ping-pong column-buffer pair for one in-flight `forward_batch` call,
/// checked out of the kernel's arena and returned when the call ends.
/// Buffers grow monotonically and are never zeroed between uses — every
/// read is preceded by the bias fill / input transpose of the same call.
#[derive(Default)]
struct Scratch {
    a: Vec<f64>,
    b: Vec<f64>,
}

/// One corner's batched execution kernel: the calibrated multiplier and
/// activation grids plus the backend they were sampled from (kept for
/// exact out-of-range fallbacks).  Weight-independent — the same kernel
/// serves every net sharing `(activation, splines, C)` on this corner.
///
/// `Send + Sync` (plain data + a `Send + Sync` backend), so the serving
/// router can run many batches through one kernel concurrently.
pub struct BatchKernel {
    provider: Box<dyn HProvider + Send + Sync>,
    mult: Multiplier,
    act: Activation,
    splines: usize,
    c: f64,
    mul_grid: Arc<MulGrid>,
    act_grid: Arc<ActGrid>,
    scratch: Mutex<Vec<Scratch>>,
    signal: SignalHealth,
}

impl fmt::Debug for BatchKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("BatchKernel")
            .field("backend", &self.provider.label())
            .field("activation", &self.act)
            .field("splines", &self.splines)
            .field("c", &self.c)
            .field("mul_grid_points", &self.mul_grid.points())
            .field("act_grid_points", &self.act_grid.points())
            .finish()
    }
}

impl BatchKernel {
    /// Calibrate the multiplier on `provider` and sample both grids.
    pub fn new(
        provider: Box<dyn HProvider + Send + Sync>,
        act: Activation,
        splines: usize,
        c: f64,
        cfg: &GridConfig,
    ) -> BatchKernel {
        let mult = Multiplier::calibrate(provider.as_ref(), splines, c);
        let (mul_grid, act_grid) = grids_for(provider.as_ref(), &mult, act, splines, cfg);
        BatchKernel {
            provider,
            mult,
            act,
            splines,
            c,
            mul_grid,
            act_grid,
            scratch: Mutex::new(Vec::new()),
            signal: SignalHealth::default(),
        }
    }

    /// Kernel matching a trained net's `(activation, splines, C)` triple.
    pub fn for_net(
        provider: Box<dyn HProvider + Send + Sync>,
        net: &TrainedNet,
        cfg: &GridConfig,
    ) -> Result<BatchKernel> {
        let act = net.activation_kind()?;
        Ok(BatchKernel::new(provider, act, net.splines, net.c, cfg))
    }

    /// Like [`BatchKernel::new`] but with a pre-calibrated multiplier:
    /// grids are sampled from `provider` while the operating point / scale
    /// come from `mult`.  This is the chip-calibration-then-drift semantics
    /// the fault harness needs — calibrate once on the nominal corner, then
    /// replay that calibration on a perturbed backend.
    pub fn with_multiplier(
        provider: Box<dyn HProvider + Send + Sync>,
        mult: Multiplier,
        act: Activation,
        splines: usize,
        c: f64,
        cfg: &GridConfig,
    ) -> BatchKernel {
        debug_assert_eq!(mult.s, splines, "multiplier/spline-count mismatch");
        let (mul_grid, act_grid) = grids_for(provider.as_ref(), &mult, act, splines, cfg);
        BatchKernel {
            provider,
            mult,
            act,
            splines,
            c,
            mul_grid,
            act_grid,
            scratch: Mutex::new(Vec::new()),
            signal: SignalHealth::default(),
        }
    }

    /// Point-in-time copy of this kernel's signal-health accumulators
    /// (all zero until [`signal_health_set`] turns accounting on).
    pub fn signal_health(&self) -> SignalHealthStats {
        self.signal.snapshot()
    }

    /// Stuck-at fault injection into the multiplier lookup grid (see
    /// [`MulGrid::inject_stuck`]); returns the write count.
    ///
    /// The grid may be shared through the process-wide cache, so the
    /// injection copy-on-writes it (`Arc::make_mut`): this kernel gets a
    /// private corrupted copy while the cached original — and every
    /// sibling kernel holding it — stays pristine.
    pub fn inject_stuck_cells(&mut self, rng: &mut Rng, fraction: f64, value: f64) -> usize {
        Arc::make_mut(&mut self.mul_grid).inject_stuck(rng, fraction, value)
    }

    /// True when both lookup grids are the same shared allocations as
    /// `other`'s (i.e. the cache deduplicated them).
    pub fn shares_grids_with(&self, other: &BatchKernel) -> bool {
        Arc::ptr_eq(&self.mul_grid, &other.mul_grid) && Arc::ptr_eq(&self.act_grid, &other.act_grid)
    }

    /// The multiplier calibration the grids were sampled with (identical
    /// to what the scalar path computes for the same backend).
    pub fn multiplier(&self) -> &Multiplier {
        &self.mult
    }

    pub fn activation(&self) -> Activation {
        self.act
    }

    /// Spline count the kernel was sampled for.
    pub fn splines(&self) -> usize {
        self.splines
    }

    /// Shape parameter C the kernel was sampled for.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Evaluate eq. 40 over a whole batch on the calling thread.
    ///
    /// * `x` — row-major `[batch × sizes[0]]` feature buffer (at least
    ///   `rows` rows; padded tail rows are never read),
    /// * `rows` — live-row count (the `Batch::live` contract),
    /// * `weights[li]` — row-major `[sizes[li] × sizes[li+1]]`,
    ///
    /// Returns row-major `[rows × sizes.last()]` logits.
    pub fn forward_batch(
        &self,
        sizes: &[usize],
        weights: &[Vec<f64>],
        biases: &[Vec<f64>],
        x: &[f32],
        rows: usize,
    ) -> Vec<f64> {
        self.forward_batch_threads(sizes, weights, biases, x, rows, 1)
    }

    /// [`BatchKernel::forward_batch`] sharded row-wise over up to
    /// `threads` slabs on the process-wide slab pool.  Bit-identical to
    /// the serial kernel at any thread count: each row's accumulation
    /// order is unchanged (weights ascending), and slabs touch disjoint
    /// row ranges of every buffer.
    pub fn forward_batch_threads(
        &self,
        sizes: &[usize],
        weights: &[Vec<f64>],
        biases: &[Vec<f64>],
        x: &[f32],
        rows: usize,
        threads: usize,
    ) -> Vec<f64> {
        let mut logits = Vec::new();
        self.forward_batch_into(sizes, weights, biases, x, rows, threads, &mut logits);
        logits
    }

    /// [`BatchKernel::forward_batch_threads`] writing into a caller-owned
    /// logits buffer (cleared and resized to `rows × sizes.last()`).
    /// With a warm arena and a reused `logits` vector this is the
    /// zero-allocation steady-state entry point.
    pub fn forward_batch_into(
        &self,
        sizes: &[usize],
        weights: &[Vec<f64>],
        biases: &[Vec<f64>],
        x: &[f32],
        rows: usize,
        threads: usize,
        logits: &mut Vec<f64>,
    ) {
        let _span = crate::util::trace::span("batch.forward");
        let k_out = sizes[sizes.len() - 1];
        logits.clear();
        logits.resize(rows * k_out, 0.0);
        if rows == 0 {
            return;
        }
        debug_assert!(x.len() >= rows * sizes[0], "input batch shorter than rows");

        let max_w = *sizes.iter().max().unwrap();
        let mut scratch = self.checkout_scratch(max_w * rows);
        let shards = effective_shards(threads, rows);
        if shards > 1 {
            PARALLEL_BATCHES.fetch_add(1, Ordering::Relaxed);
            let buf_a = SendPtr(scratch.a.as_mut_ptr());
            let buf_b = SendPtr(scratch.b.as_mut_ptr());
            let out = SendPtr(logits.as_mut_ptr());
            let base = rows / shards;
            let extra = rows % shards;
            // The caller's correlation id is thread-local; capture it by
            // value so the slab spans on pool threads stay attached to
            // the request that dispatched them.
            let caller_trace = crate::util::trace::current_trace();
            // The slab pool is distinct from the router's request pool:
            // router workers block right here waiting for slabs, so
            // dispatching slabs onto their own pool could deadlock (see
            // `util::pool` docs).
            crate::util::pool::shared_pool().run_scoped(shards, |s| {
                let _corr = crate::util::trace::correlate(caller_trace);
                let _slab = crate::util::trace::span("batch.slab");
                let r0 = s * base + s.min(extra);
                let r1 = r0 + base + usize::from(s < extra);
                self.forward_slab(
                    sizes,
                    weights,
                    biases,
                    x,
                    rows,
                    r0,
                    r1,
                    buf_a.get(),
                    buf_b.get(),
                    out.get(),
                );
            });
        } else {
            SERIAL_BATCHES.fetch_add(1, Ordering::Relaxed);
            self.forward_slab(
                sizes,
                weights,
                biases,
                x,
                rows,
                0,
                rows,
                scratch.a.as_mut_ptr(),
                scratch.b.as_mut_ptr(),
                logits.as_mut_ptr(),
            );
        }
        self.return_scratch(scratch);
    }

    /// Check a ping-pong buffer pair out of the arena, growing it to at
    /// least `len` f64s per side.  Steady state (same shapes as an
    /// earlier call) pops a ready pair without allocating.
    fn checkout_scratch(&self, len: usize) -> Scratch {
        let mut s = self.scratch.lock().unwrap().pop().unwrap_or_default();
        if s.a.len() < len {
            s.a.resize(len, 0.0);
            s.b.resize(len, 0.0);
        }
        s
    }

    fn return_scratch(&self, s: Scratch) {
        self.scratch.lock().unwrap().push(s);
    }

    /// Run the full layer pipeline over the contiguous row slab
    /// `[r0, r1)`: input transpose, per-layer bias fill + weight-outer /
    /// row-inner accumulation + activation, final row-major transpose
    /// into `logits`.
    ///
    /// Determinism: per (row, output) the accumulation order is weights
    /// ascending — exactly the serial kernel's — so slab partitioning
    /// never reorders a float sum.  No inter-slab barrier is needed:
    /// every read and write below lands in this slab's own `[r0, r1)`
    /// rows of each column, which no other slab touches.
    ///
    /// Safety: `buf_a`/`buf_b` must each hold `max(sizes) × rows` f64s
    /// and `logits` must hold `rows × sizes.last()`; callers pass each
    /// pointer trio to at most one concurrent slab per row range.
    #[allow(clippy::too_many_arguments)]
    fn forward_slab(
        &self,
        sizes: &[usize],
        weights: &[Vec<f64>],
        biases: &[Vec<f64>],
        x: &[f32],
        rows: usize,
        r0: usize,
        r1: usize,
        buf_a: *mut f64,
        buf_b: *mut f64,
        logits: *mut f64,
    ) {
        let nl = sizes.len() - 1;
        let din = sizes[0];
        let seg = r1 - r0;
        let p = self.provider.as_ref();
        let (mut cur, mut nxt) = (buf_a, buf_b);

        // One relaxed load per slab decides the instrumented path; the
        // slab-local counters are plain integers flushed once at the end,
        // so the nominal path (`instrument == false`) is unchanged.
        let instrument = signal_health_enabled();
        let mut sig = SlabSignal::default();

        // columnar layout: cur[i·rows + r] holds input i of row r
        for r in r0..r1 {
            for i in 0..din {
                unsafe { *cur.add(i * rows + r) = x[r * din + i] as f64 };
            }
        }

        for li in 0..nl {
            let n_in = sizes[li];
            let n_out = sizes[li + 1];
            let w = &weights[li];
            for (k, &b) in biases[li].iter().enumerate() {
                let dst =
                    unsafe { std::slice::from_raw_parts_mut(nxt.add(k * rows + r0), seg) };
                for v in dst {
                    *v = b;
                }
            }
            // weights outermost, rows innermost: one weight's grid bases
            // are hoisted across the whole slab, and both the input
            // column segment and the accumulator segment are contiguous
            for i in 0..n_in {
                let col = unsafe { std::slice::from_raw_parts(cur.add(i * rows + r0), seg) };
                for k in 0..n_out {
                    let dst =
                        unsafe { std::slice::from_raw_parts_mut(nxt.add(k * rows + r0), seg) };
                    if instrument {
                        self.mul_grid.accumulate_signal(
                            p,
                            &self.mult,
                            col,
                            w[i * n_out + k],
                            dst,
                            &mut sig,
                        );
                    } else {
                        self.mul_grid
                            .accumulate(p, &self.mult, col, w[i * n_out + k], dst);
                    }
                }
            }
            if li < nl - 1 {
                for k in 0..n_out {
                    let seg_mut =
                        unsafe { std::slice::from_raw_parts_mut(nxt.add(k * rows + r0), seg) };
                    if instrument {
                        self.act_grid.apply_signal(p, seg_mut, ACT_GAIN, &mut sig);
                    } else {
                        self.act_grid.apply(p, seg_mut, ACT_GAIN);
                    }
                }
            }
            std::mem::swap(&mut cur, &mut nxt);
        }

        // transpose back to the row-major contract of the runtime,
        // iterating row-major over the destination so `logits` is
        // written stride-1
        let k_out = sizes[nl];
        for r in r0..r1 {
            for k in 0..k_out {
                unsafe { *logits.add(r * k_out + k) = *cur.add(k * rows + r) };
            }
        }

        if instrument {
            self.signal.absorb(&sig);
        }
    }

    /// [`BatchKernel::forward_batch`] with the shapes taken from a
    /// [`TrainedNet`] (test / direct-evaluation convenience).
    pub fn forward_net(&self, net: &TrainedNet, x: &[f32], rows: usize) -> Vec<f64> {
        debug_assert_eq!(
            net.splines, self.splines,
            "kernel calibrated for a different spline count"
        );
        self.forward_batch(&net.sizes, &net.weights, &net.biases, x, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;
    use crate::nn;
    use crate::util::rng::Rng;

    #[test]
    fn grid1d_exact_on_linear_function() {
        let g = Grid1D::sample(-2.0, 2.0, 41, |z| 3.0 * z - 0.5);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let z = rng.uniform_in(-2.0, 2.0);
            assert!((g.eval(z) - (3.0 * z - 0.5)).abs() < 1e-12, "z={z}");
        }
        // endpoints are in range and safe
        assert!(g.contains(2.0) && g.contains(-2.0));
        assert!((g.eval(2.0) - 5.5).abs() < 1e-12);
        assert!((g.eval(-2.0) - (-6.5)).abs() < 1e-12);
        assert_eq!(g.len(), 41);
        assert!(!g.is_empty());
    }

    #[test]
    fn mul_grid_matches_exact_multiplier() {
        let p = Algorithmic::relu();
        let mult = Multiplier::calibrate(&p, 3, 1.0);
        let grid = MulGrid::build(&p, &mult, &GridConfig::default());
        let mut rng = Rng::new(7);
        let mut worst = 0.0f64;
        for _ in 0..500 {
            let x = rng.uniform_in(-1.5, 1.5);
            let w = rng.uniform_in(-1.0, 1.0);
            let got = grid.mul(&p, &mult, x, w);
            let want = mult.mul(&p, x, w);
            worst = worst.max((got - want).abs());
        }
        assert!(worst < 2e-3, "worst grid-vs-exact error {worst}");
    }

    #[test]
    fn mul_grid_out_of_range_falls_back_exactly() {
        let p = Algorithmic::relu();
        let mult = Multiplier::calibrate(&p, 3, 1.0);
        let grid = MulGrid::build(&p, &mult, &GridConfig::default());
        // |x| far beyond the grid: the fallback is the exact cell, so the
        // answers are bit-identical
        for x in [25.0, -40.0, 1e3] {
            let got = grid.mul(&p, &mult, x, 0.7);
            let want = mult.mul(&p, x, 0.7);
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn act_grid_matches_cell_and_falls_back() {
        let p = Algorithmic::relu();
        for act in [
            Activation::Phi1,
            Activation::Phi2,
            Activation::Relu,
            Activation::Softplus,
        ] {
            let g = ActGrid::build(&p, act, 3, &GridConfig::default());
            let mut vals = vec![-1.5, -0.25, 0.0, 0.4, 1.9, 30.0, -30.0];
            let expect: Vec<f64> = vals
                .iter()
                .map(|&v| act.eval(&p, v * ACT_GAIN, 3))
                .collect();
            g.apply(&p, &mut vals, ACT_GAIN);
            for (got, want) in vals.iter().zip(&expect) {
                assert!(
                    (got - want).abs() < 1e-3,
                    "{act:?}: got {got} want {want}"
                );
            }
        }
    }

    fn toy_net() -> TrainedNet {
        TrainedNet {
            task: "toy".into(),
            sizes: vec![2, 3, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![
                vec![0.8, -0.8, 0.5, -0.8, 0.8, 0.5],
                vec![0.9, -0.9, 0.9, -0.9, -0.9, 0.9],
            ],
            biases: vec![vec![-0.2, -0.2, -0.6], vec![0.0, 0.0]],
        }
    }

    #[test]
    fn forward_net_matches_scalar_forward() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let mult = Multiplier::calibrate(&p, net.splines, net.c);
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75, 0.1, 0.9, -0.8, -0.3];
        let rows = 4;
        let batched = kernel.forward_net(&net, &x, rows);
        assert_eq!(batched.len(), rows * 2);
        for r in 0..rows {
            let golden = nn::forward(&net, &p, &mult, &x[r * 2..(r + 1) * 2]);
            for (j, &want) in golden.iter().enumerate() {
                let got = batched[r * 2 + j];
                assert!(
                    (got - want).abs() < 5e-3,
                    "row {r} logit {j}: batched {got} vs scalar {want}"
                );
            }
        }
    }

    #[test]
    fn forward_batch_skips_padded_rows() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        // 4-row buffer, 2 live rows: output covers only the live rows and
        // equals the full-batch prefix
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75, 0.0, 0.0, 0.0, 0.0];
        let full = kernel.forward_net(&net, &x, 4);
        let live = kernel.forward_net(&net, &x, 2);
        assert_eq!(live.len(), 4);
        assert_eq!(&full[..4], &live[..]);
        // zero rows is a clean no-op
        assert!(kernel.forward_net(&net, &x, 0).is_empty());
    }

    #[test]
    fn with_multiplier_replays_calibration_and_stuck_cells_perturb() {
        let net = toy_net();
        let p = Algorithmic::relu();
        let act = net.activation_kind().unwrap();
        let cfg = GridConfig::default();
        let mult = Multiplier::calibrate(&p, net.splines, net.c);
        let fresh = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        let replay = BatchKernel::with_multiplier(
            Box::new(Algorithmic::relu()),
            mult.clone(),
            act,
            net.splines,
            net.c,
            &cfg,
        );
        assert_eq!(replay.splines(), net.splines);
        assert_eq!(replay.c(), net.c);
        // same backend + same calibration → bit-identical outputs
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75];
        let want = fresh.forward_net(&net, &x, 2);
        assert_eq!(replay.forward_net(&net, &x, 2), want);

        // zero-fraction injection is a no-op
        let mut faulty = BatchKernel::with_multiplier(
            Box::new(Algorithmic::relu()),
            mult.clone(),
            act,
            net.splines,
            net.c,
            &cfg,
        );
        assert_eq!(faulty.inject_stuck_cells(&mut Rng::new(3), 0.0, 0.0), 0);
        assert_eq!(faulty.forward_net(&net, &x, 2), want);
        // a dense stuck-at-zero sweep must visibly perturb the output
        let writes = faulty.inject_stuck_cells(&mut Rng::new(3), 0.05, 0.0);
        assert!(writes > 100, "writes={writes}");
        assert_ne!(faulty.forward_net(&net, &x, 2), want);
    }

    #[test]
    fn kernel_debug_is_informative() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let s = format!("{kernel:?}");
        assert!(s.contains("BatchKernel") && s.contains("algorithmic"), "{s}");
        assert_eq!(kernel.activation(), Activation::Phi1);
        assert!(kernel.multiplier().scale.is_finite());
    }

    #[test]
    fn effective_shards_honors_threshold() {
        assert_eq!(effective_shards(8, 4), 1);
        assert_eq!(effective_shards(8, 15), 1);
        assert_eq!(effective_shards(8, 16), 2);
        assert_eq!(effective_shards(4, 64), 4);
        assert_eq!(effective_shards(1, 1000), 1);
        assert_eq!(effective_shards(0, 64), 1);
        assert_eq!(effective_shards(3, 1000), 3);
    }

    #[test]
    fn parallel_forward_is_bit_identical_to_serial() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let rows = 33;
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..rows * 2).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let serial = kernel.forward_net(&net, &x, rows);
        for threads in [2, 3, 8] {
            let par =
                kernel.forward_batch_threads(&net.sizes, &net.weights, &net.biases, &x, rows, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
        // counters: this exercised both dispatch paths at least once
        let (p, s) = batch_dispatch_counts();
        assert!(p >= 1 && s >= 1, "parallel={p} serial={s}");
    }

    #[test]
    fn forward_batch_into_reuses_caller_buffer() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75];
        let want = kernel.forward_net(&net, &x, 2);
        let mut logits = vec![9.0; 64]; // stale, oversized
        kernel.forward_batch_into(&net.sizes, &net.weights, &net.biases, &x, 2, 1, &mut logits);
        assert_eq!(logits, want);
        kernel.forward_batch_into(&net.sizes, &net.weights, &net.biases, &x, 0, 4, &mut logits);
        assert!(logits.is_empty());
    }

    #[test]
    fn grid_cache_shares_across_kernels_and_cow_isolates_faults() {
        let net = toy_net();
        // unique GridConfig → unique cache key: immune to sibling tests
        // touching the same process-wide cache
        let cfg = GridConfig {
            proto_range: 6.0,
            proto_density: 733,
            act_range: 8.0,
            act_density: 97,
        };
        let before = grid_cache_stats();
        let a = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        let mut b = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        let after = grid_cache_stats();
        assert!(a.shares_grids_with(&b), "cache should deduplicate grids");
        assert!(after.misses >= before.misses + 1, "first build is a miss");
        assert!(after.hits >= before.hits + 1, "second build is a hit");

        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75];
        let pristine = a.forward_net(&net, &x, 2);
        // dense injection into b copy-on-writes the shared grid: b detaches
        // and perturbs, while a and the cached original stay pristine
        let writes = b.inject_stuck_cells(&mut Rng::new(9), 0.2, 0.0);
        assert!(writes > 0, "writes={writes}");
        assert!(!a.shares_grids_with(&b), "injection must detach the grid");
        assert_ne!(b.forward_net(&net, &x, 2), pristine);
        assert_eq!(a.forward_net(&net, &x, 2), pristine);
        let c = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        assert!(c.shares_grids_with(&a), "cache copy must remain pristine");
        assert_eq!(c.forward_net(&net, &x, 2), pristine);
    }

    #[test]
    fn targeted_invalidation_forces_a_fresh_build() {
        let net = toy_net();
        // unique GridConfig → unique cache key, disjoint from every other
        // test touching the process-wide cache
        let cfg = GridConfig {
            proto_range: 6.0,
            proto_density: 739,
            act_range: 8.0,
            act_density: 101,
        };
        let a = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        let b = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        assert!(a.shares_grids_with(&b));
        // the cache key embeds the exact GridConfig bits — evict by the
        // density fragment unique to this test
        let before = grid_cache_stats();
        let evicted = grid_cache_invalidate("pd=739");
        assert_eq!(evicted, 1, "exactly this test's entry is evicted");
        let after = grid_cache_stats();
        // (no entry-count assertion: sibling tests insert concurrently)
        assert_eq!(after.invalidations, before.invalidations + 1);
        // live kernels are unaffected; the next construction re-samples
        let c = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        assert!(a.shares_grids_with(&b), "live kernels keep their grids");
        assert!(!c.shares_grids_with(&a), "rebuild must sample fresh grids");
        // a fragment matching nothing evicts nothing
        assert_eq!(grid_cache_invalidate("no-such-key-fragment"), 0);
    }

    #[test]
    fn signal_health_accounting_is_numerically_identical_and_exact() {
        let net = toy_net();
        let kernel =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        let rows = 16;
        let mut rng = Rng::new(17);
        let mut x: Vec<f32> = (0..rows * 2)
            .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
            .collect();
        // force out-of-grid fallbacks and saturated activations
        x[0] = 50.0;
        x[3] = -40.0;
        signal_health_set(false);
        let want = kernel.forward_net(&net, &x, rows);
        let zero = kernel.signal_health();
        assert_eq!(zero.mul_elems, 0, "disabled path must not account");
        signal_health_set(true);
        let got = kernel.forward_net(&net, &x, rows);
        signal_health_set(false);
        assert_eq!(got, want, "instrumentation must not change the math");
        let s = kernel.signal_health();
        // toy net [2,3,2]: 2·3 + 3·2 = 12 mul elements and 3 activation
        // inputs per row
        assert_eq!(s.mul_elems, rows as u64 * 12);
        assert_eq!(s.act_samples, rows as u64 * 3);
        assert!(s.mul_fallbacks > 0, "x=50 must leave the proto grid");
        let heat_total: u64 = s.heat.iter().sum();
        assert_eq!(
            heat_total + s.mul_fallbacks,
            s.mul_elems,
            "every element is either binned or a fallback"
        );
        assert!(s.margin_min < 0.0, "fallback ⇒ negative residual");
        assert!(s.margin_sum > 0.0);
        assert!(s.score() > 0.0 && s.score() <= 1.0);
        // the parallel path flushes per slab and lands the same totals
        let kernel2 =
            BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &GridConfig::default())
                .unwrap();
        signal_health_set(true);
        let par = kernel2.forward_batch_threads(&net.sizes, &net.weights, &net.biases, &x, rows, 4);
        signal_health_set(false);
        assert_eq!(par, want);
        let s2 = kernel2.signal_health();
        assert_eq!(s2.mul_elems, s.mul_elems);
        assert_eq!(s2.mul_fallbacks, s.mul_fallbacks);
        assert_eq!(s2.act_samples, s.act_samples);
        assert_eq!(s2.heat, s.heat);
    }

    #[test]
    fn signal_health_stats_merge_laws() {
        let mut a = SignalHealthStats {
            enabled: true,
            mul_elems: 10,
            mul_fallbacks: 2,
            act_samples: 4,
            act_sat_high: 1,
            act_sat_low: 0,
            act_fallbacks: 1,
            heat: [1, 0, 0, 2, 0, 0, 0, 5],
            margin_min: -0.5,
            margin_sum: 3.25,
        };
        let b = SignalHealthStats {
            enabled: false,
            mul_elems: 6,
            mul_fallbacks: 0,
            act_samples: 2,
            act_sat_high: 0,
            act_sat_low: 2,
            act_fallbacks: 0,
            heat: [0, 1, 1, 0, 0, 0, 4, 0],
            margin_min: 0.25,
            margin_sum: 1.75,
        };
        a.merge(&b);
        assert_eq!(a.mul_elems, 16);
        assert_eq!(a.act_samples, 6);
        assert_eq!(a.heat, [1, 1, 1, 2, 0, 0, 4, 5]);
        assert_eq!(a.margin_min, -0.5);
        assert_eq!(a.margin_sum, 5.0);
        assert!((a.saturation_fraction() - 0.5).abs() < 1e-12);
        // an empty side must not clobber the real min with its 0.0
        let mut empty = SignalHealthStats::default();
        empty.merge(&a);
        assert_eq!(empty.margin_min, -0.5);
        let mut c = a;
        c.merge(&SignalHealthStats::default());
        assert_eq!(c.margin_min, -0.5);
    }

    #[test]
    fn uncacheable_backend_builds_private_grids() {
        let net = toy_net();
        let cfg = GridConfig {
            proto_range: 6.0,
            proto_density: 731,
            act_range: 8.0,
            act_density: 93,
        };
        // CircuitCorner-style backends report no cache key; emulate with a
        // wrapper that erases it
        struct NoKey(Algorithmic);
        impl HProvider for NoKey {
            fn h(&self, x: &[f64], c: f64) -> f64 {
                self.0.h(x, c)
            }
            fn h_raw(&self, x: &[f64], c: f64) -> f64 {
                self.0.h_raw(x, c)
            }
            fn label(&self) -> String {
                self.0.label()
            }
        }
        let a = BatchKernel::new(
            Box::new(NoKey(Algorithmic::relu())),
            Activation::Phi1,
            net.splines,
            net.c,
            &cfg,
        );
        let b = BatchKernel::new(
            Box::new(NoKey(Algorithmic::relu())),
            Activation::Phi1,
            net.splines,
            net.c,
            &cfg,
        );
        assert!(!a.shares_grids_with(&b), "keyless backends must not share");
        let entries = grid_cache_stats().entries;
        let _cached = BatchKernel::for_net(Box::new(Algorithmic::relu()), &net, &cfg).unwrap();
        assert!(
            grid_cache_stats().entries > entries.saturating_sub(1),
            "cache still usable"
        );
    }
}
