//! The chaos campaign runner: replay a [`FaultPlan`] against the serving
//! stack and report accuracy degradation + router liveness invariants.
//!
//! Two campaigns compose into one [`ChaosReport`]:
//!
//! * **Analog** ([`run_corner`], once per paper corner): a fixed
//!   high-margin prototype-detector net is served through a [`Router`]
//!   with one *nominal* lane and `trials` *faulted* lanes.  Each trial
//!   lane's [`BatchKernel`] is rebuilt from the plan's analog faults —
//!   Pelgrom mirror-gain mismatch sampled through
//!   [`MismatchModel`], the temperature-drift schedule stage the trial
//!   falls in, and stuck multiplier-grid cells — while reusing the one
//!   nominal multiplier calibration (chip-calibration-then-drift).  The
//!   report is per-trial label agreement against the nominal lane.
//! * **Infrastructure** ([`run_infra`]): three synthetic-engine lanes —
//!   healthy, latency-injected, panic-injected — under a multi-threaded
//!   submit storm.  The report is the router's liveness invariants:
//!   every request resolved exactly once (answered or failed, never
//!   stranded, never delivered twice) and a bounded drain.
//!
//! Determinism contract: every field serialized by
//! [`ChaosReport::canonical_json`] is a pure function of the plan — per-row
//! analog results do not depend on worker scheduling (each row is computed
//! independently and matched back by request id), and the infra fields are
//! scheduling-independent booleans/counts.  Wall-clock timings and the
//! answered/failed split (which *does* depend on batch ordinal timing) are
//! reported on the struct but excluded from the canonical serialization, so
//! identical-seed replays are bit-identical.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cells::multiplier::Multiplier;
use crate::cells::HProvider;
use crate::coordinator::{
    synthetic_engine, Batch, Engine, HealthConfig, HealthEvent, HealthState, LaneSpec,
    MetricsSnapshot, Response, Router, RouterConfig,
};
use crate::data::TrainedNet;
use crate::device::MismatchModel;
use crate::nn::batch::{BatchKernel, GridConfig};
use crate::pdk::regime::Regime;
use crate::pdk::{ProcessNode, CMOS180, FINFET7};
use crate::runtime::{Executable, FaultyExec};
use crate::sac::TableModel;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::drift::{stage_for_progress, temperature_schedule};
use super::drift::MismatchedProvider;
use super::plan::{DriftKind, FaultPlan};

/// Acceptance envelope on the *campaign mean*: mean label agreement with
/// the nominal lane must stay ≥ `1 − MEAN_DEGRADATION_ENVELOPE`.  The
/// paper's Fig. 8 shows ≤ ~10% full-scale output deviation under combined
/// PVT + mismatch; 15% on label agreement adds margin for the stuck-cell
/// fault class the paper does not model.
pub const MEAN_DEGRADATION_ENVELOPE: f64 = 0.15;

/// Collapse guard on the *worst single trial*: no trial may fall below
/// `1 − WORST_DEGRADATION_ENVELOPE` agreement.  A single unlucky stuck
/// cell in a high-traffic grid region can systematically skew one class,
/// so this floor is intentionally loose — it catches collapse (outputs
/// decorrelated from the nominal), not ordinary degradation.
pub const WORST_DEGRADATION_ENVELOPE: f64 = 0.40;

/// Drain bound for the infrastructure campaign [s] — generous versus the
/// ~ms of injected latency, so only a genuine liveness bug trips it.
pub const DRAIN_BOUND_SECS: u64 = 30;

/// Bound on the detect → quarantine → rebuild → healthy loop in the
/// recovery campaign [s].
pub const RECOVERY_BOUND_SECS: u64 = 60;

/// Typed envelope-violation error.  The chaos/recovery CLI wraps its
/// violation list in this so `main` can exit 1 for an envelope breach
/// while every other error (IO, parse, invalid plan) exits 2.
#[derive(Clone, Debug)]
pub struct EnvelopeViolation(pub Vec<String>);

impl std::fmt::Display for EnvelopeViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} envelope violation(s): {}",
            self.0.len(),
            self.0.join("; ")
        )
    }
}

impl std::error::Error for EnvelopeViolation {}

/// Drain bound for the analog campaign [s] (many lanes, table-backed).
const ANALOG_DRAIN_SECS: u64 = 120;

/// Compiled batch dimension of the chaos net's engines.
const CHAOS_BATCH: usize = 8;

/// Distinct mirror-gain branches sampled per trial (cycled across the
/// solver's inputs by [`MismatchedProvider`]).
const GAIN_BRANCHES: usize = 16;

/// Junction temperature when the plan carries no drift fault [°C].
const NOMINAL_T_C: f64 = 27.0;

/// Campaign knobs not carried by the plan (the plan is *what* to inject;
/// this is *how hard* to sample it).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// faulted lanes per corner
    pub trials: usize,
    /// router worker threads
    pub workers: usize,
    /// evaluation rows per lane (kept a multiple of the batch size so the
    /// analog campaign never depends on the deadline flusher)
    pub eval_rows: usize,
    /// intra-batch row parallelism for every lane engine (the CLI's
    /// `--threads`; forwarded to `RouterConfig::kernel_threads`).  `None`
    /// keeps the engine default.  Agreement numbers are unaffected — the
    /// sharded kernel is bit-identical to the serial one.
    pub kernel_threads: Option<usize>,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            trials: 12,
            workers: 4,
            eval_rows: 32,
            kernel_threads: None,
        }
    }
}

/// The paper's evaluation corners, at the regime each node's story
/// centers on (Fig. 1: weak inversion at 180 nm, moderate at 7 nm).
pub fn chaos_corners() -> [(&'static ProcessNode, Regime); 2] {
    [
        (&CMOS180, Regime::WeakInversion),
        (&FINFET7, Regime::ModerateInversion),
    ]
}

/// Grid sizing for the chaos kernels: coarse enough that a stuck cell is
/// a meaningful fraction of the table, fine enough to stay within
/// `BATCH_TOL` of the scalar path on the nominal lane.
pub fn chaos_grid() -> GridConfig {
    GridConfig {
        proto_range: 6.0,
        proto_density: 96,
        act_range: 8.0,
        act_density: 64,
    }
}

/// Orthogonal ±1 prototypes (Hadamard rows), one per class.
const PROTOS: [[f64; 8]; 3] = [
    [1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0],
    [1.0, 1.0, -1.0, -1.0, 1.0, 1.0, -1.0, -1.0],
    [1.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0, -1.0],
];

/// The fixed chaos net: a hand-constructed prototype detector
/// `[8, 6, 3]` whose nominal logit margins are far larger than any
/// in-envelope analog perturbation, so agreement loss measures fault
/// severity rather than razor-edge class boundaries.
///
/// Hidden unit `k < 3` detects prototype `k` (weights `0.22·p_k`, bias
/// −0.5 so non-matching rows stay below the ReLU knee); hidden units
/// 3..6 are low-gain spares for the same prototypes.  The output layer
/// routes each detector to its class.
pub fn chaos_net() -> TrainedNet {
    let (din, hid, kout) = (8usize, 6usize, 3usize);
    let mut w1 = vec![0.0; din * hid];
    for i in 0..din {
        for k in 0..hid {
            w1[i * hid + k] = if k < 3 {
                0.22 * PROTOS[k][i]
            } else {
                0.06 * PROTOS[k - 3][i]
            };
        }
    }
    let b1 = vec![-0.5, -0.5, -0.5, -0.15, -0.15, -0.15];
    let mut w2 = vec![0.0; hid * kout];
    for j in 0..kout {
        w2[j * kout + j] = 0.3;
        w2[(3 + j) * kout + j] = 0.1;
    }
    let b2 = vec![0.0; kout];
    TrainedNet {
        task: "chaos".into(),
        sizes: vec![din, hid, kout],
        activation: "relu".into(),
        splines: 1,
        c: 1.0,
        acc_sw: 0.0,
        acc_sac_algorithmic: 0.0,
        weights: vec![w1, w2],
        biases: vec![b1, b2],
    }
}

/// Evaluation rows: noisy prototypes, class `r % 3`, seeded off the plan.
pub fn eval_features(seed: u64, rows: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed).fork(0xFEA7);
    (0..rows)
        .map(|r| {
            let p = &PROTOS[r % PROTOS.len()];
            p.iter()
                .map(|&pi| (0.75 * pi + rng.uniform_in(-0.15, 0.15)) as f32)
                .collect()
        })
        .collect()
}

/// One corner's analog campaign result.
#[derive(Clone, Debug)]
pub struct CornerReport {
    pub node: String,
    pub regime: String,
    /// junction temperature each trial was served at [°C]
    pub trial_temp_c: Vec<f64>,
    /// per-trial label agreement with the nominal lane ∈ [0, 1]
    pub trial_agreement: Vec<f64>,
    /// per-trial mean |logit − nominal logit|
    pub trial_logit_dev: Vec<f64>,
    /// stuck multiplier-grid cells injected per trial
    pub stuck_cells: Vec<usize>,
    pub mean_agreement: f64,
    pub worst_agreement: f64,
}

impl CornerReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::Str(self.node.clone())),
            ("regime", Json::Str(self.regime.clone())),
            ("trial_temp_c", Json::from_f64_slice(&self.trial_temp_c)),
            ("trial_agreement", Json::from_f64_slice(&self.trial_agreement)),
            ("trial_logit_dev", Json::from_f64_slice(&self.trial_logit_dev)),
            (
                "stuck_cells",
                Json::Arr(self.stuck_cells.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("mean_agreement", Json::Num(self.mean_agreement)),
            ("worst_agreement", Json::Num(self.worst_agreement)),
        ])
    }
}

/// The infrastructure campaign result.  `answered`/`failed`/`drain_ms`
/// depend on worker scheduling (which batch ordinal trips the panic gate)
/// and are excluded from the canonical serialization; the invariant
/// fields are deterministic.
#[derive(Clone, Debug)]
pub struct InfraReport {
    pub submitted: usize,
    pub answered: usize,
    pub failed: usize,
    /// requests neither answered nor failed after a full drain
    pub stranded: usize,
    /// requests delivered more than once by `try_take`
    pub double_delivery: usize,
    /// `answered + failed == submitted` with no strands or doubles
    pub resolved_exactly_once: bool,
    /// drain returned (successfully or with collected worker failures)
    /// before [`DRAIN_BOUND_SECS`]
    pub drained_in_bound: bool,
    /// at least one engine panic was contained and surfaced
    pub panic_observed: bool,
    pub drain_ms: f64,
}

impl InfraReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::Num(self.submitted as f64)),
            ("stranded", Json::Num(self.stranded as f64)),
            ("double_delivery", Json::Num(self.double_delivery as f64)),
            ("resolved_exactly_once", Json::Bool(self.resolved_exactly_once)),
            ("drained_in_bound", Json::Bool(self.drained_in_bound)),
            ("panic_observed", Json::Bool(self.panic_observed)),
        ])
    }
}

/// The full campaign report for one plan.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub plan: FaultPlan,
    pub corners: Vec<CornerReport>,
    pub infra: InfraReport,
}

impl ChaosReport {
    /// Envelope / invariant breaches (empty = pass).
    pub fn violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        let mean_floor = 1.0 - MEAN_DEGRADATION_ENVELOPE;
        let worst_floor = 1.0 - WORST_DEGRADATION_ENVELOPE;
        for c in &self.corners {
            if c.mean_agreement < mean_floor {
                v.push(format!(
                    "corner {}/{}: mean agreement {:.4} below envelope floor {:.2}",
                    c.node, c.regime, c.mean_agreement, mean_floor
                ));
            }
            if c.worst_agreement < worst_floor {
                v.push(format!(
                    "corner {}/{}: worst trial agreement {:.4} below collapse floor {:.2}",
                    c.node, c.regime, c.worst_agreement, worst_floor
                ));
            }
        }
        let i = &self.infra;
        if !i.resolved_exactly_once {
            v.push(format!(
                "infra: {} submitted but {} answered + {} failed, {} stranded, {} double-delivered",
                i.submitted, i.answered, i.failed, i.stranded, i.double_delivery
            ));
        }
        if !i.drained_in_bound {
            v.push(format!("infra: drain exceeded the {DRAIN_BOUND_SECS}s bound"));
        }
        if self.plan.panic_after().is_some() && !i.panic_observed {
            v.push("infra: planned engine panic was never observed".into());
        }
        v
    }

    pub fn pass(&self) -> bool {
        self.violations().is_empty()
    }

    /// Deterministic serialization: a pure function of the plan (see the
    /// module docs for the replay contract).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan", self.plan.to_json()),
            ("mean_envelope", Json::Num(MEAN_DEGRADATION_ENVELOPE)),
            ("worst_envelope", Json::Num(WORST_DEGRADATION_ENVELOPE)),
            (
                "corners",
                Json::Arr(self.corners.iter().map(|c| c.to_json()).collect()),
            ),
            ("infra", self.infra.to_json()),
            (
                "violations",
                Json::Arr(
                    self.violations().into_iter().map(Json::Str).collect(),
                ),
            ),
            ("pass", Json::Bool(self.pass())),
        ])
    }

    pub fn canonical_json(&self) -> String {
        self.to_json().to_string()
    }
}

fn engine_with_kernel(net: &TrainedNet, kernel: BatchKernel) -> Result<Engine> {
    let exe = Executable::native_mlp_with_kernel(net, CHAOS_BATCH, Arc::new(kernel))?;
    Engine::from_parts(net.clone(), exe)
}

/// Run the analog campaign at one corner: a nominal lane plus
/// `cfg.trials` faulted lanes served through one router, reported as
/// per-trial agreement against the nominal lane.
pub fn run_corner(
    node: &'static ProcessNode,
    regime: Regime,
    net: &TrainedNet,
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<CornerReport> {
    Ok(run_corner_with_metrics(node, regime, net, plan, cfg)?.0)
}

/// [`run_corner`] plus the corner router's telemetry snapshot (captured
/// after the drain, before shutdown — see `--metrics-out` on `chaos`).
pub fn run_corner_with_metrics(
    node: &'static ProcessNode,
    regime: Regime,
    net: &TrainedNet,
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(CornerReport, MetricsSnapshot)> {
    let _span = crate::util::trace::span("chaos.corner");
    let grid = chaos_grid();
    let act = net.activation_kind()?;
    let (dkind, from_c, to_c, steps) = plan
        .drift()
        .unwrap_or((DriftKind::Ramp, NOMINAL_T_C, NOMINAL_T_C, 1));
    let temps = temperature_schedule(dkind, from_c, to_c, steps);

    // Chip-calibration-then-drift: one surrogate per schedule stage, one
    // multiplier calibration on the nominal (first-stage) corner, reused
    // by every trial kernel.
    let stage_tables: Vec<TableModel> = temps
        .iter()
        .map(|&t| TableModel::calibrate(node, regime, t))
        .collect();
    let mult = Multiplier::calibrate(&stage_tables[0], net.splines, net.c);
    let mm = MismatchModel::new(node);
    let sigma_scale = plan.sigma_scale();

    let mut lanes: Vec<(String, Engine)> = Vec::with_capacity(cfg.trials + 1);
    let nominal_provider: Box<dyn HProvider + Send + Sync> =
        Box::new(stage_tables[0].clone());
    let nominal_kernel = BatchKernel::with_multiplier(
        nominal_provider,
        mult.clone(),
        act,
        net.splines,
        net.c,
        &grid,
    );
    lanes.push(("nominal".into(), engine_with_kernel(net, nominal_kernel)?));

    let mut trial_temp_c = Vec::with_capacity(cfg.trials);
    let mut stuck_cells = Vec::with_capacity(cfg.trials);
    for t in 0..cfg.trials {
        let mut rng = Rng::new(plan.seed).fork(0x5AC0_0000 + t as u64);
        let progress = if cfg.trials <= 1 {
            0.0
        } else {
            t as f64 / (cfg.trials - 1) as f64
        };
        let stage = stage_for_progress(progress, temps.len());
        let t_c = temps[stage];
        let gains = mm.sample_mirror_gains(regime, t_c, GAIN_BRANCHES, sigma_scale, &mut rng);
        let provider: Box<dyn HProvider + Send + Sync> = Box::new(MismatchedProvider::new(
            Box::new(stage_tables[stage].clone()),
            gains,
        ));
        let mut kernel = BatchKernel::with_multiplier(
            provider,
            mult.clone(),
            act,
            net.splines,
            net.c,
            &grid,
        );
        let stuck = match plan.stuck() {
            Some((fraction, value)) => kernel.inject_stuck_cells(&mut rng, fraction, value),
            None => 0,
        };
        trial_temp_c.push(t_c);
        stuck_cells.push(stuck);
        lanes.push((format!("trial{t}"), engine_with_kernel(net, kernel)?));
    }

    let n_lanes = lanes.len();
    let router = Router::new(
        RouterConfig {
            workers: cfg.workers.max(1),
            kernel_threads: cfg.kernel_threads,
            ..Default::default()
        },
        lanes,
    );
    let feats = eval_features(plan.seed, cfg.eval_rows);
    let mut reqs = Vec::with_capacity(n_lanes);
    for lane in 0..n_lanes {
        let mut ids = Vec::with_capacity(feats.len());
        for f in &feats {
            ids.push(router.submit(lane, f.clone())?);
        }
        reqs.push(ids);
    }
    router.drain(Duration::from_secs(ANALOG_DRAIN_SECS))?;
    let mut lane_answers: Vec<Vec<Response>> = Vec::with_capacity(n_lanes);
    for ids in &reqs {
        let mut rows = Vec::with_capacity(ids.len());
        for &id in ids {
            rows.push(
                router
                    .try_take(id)?
                    .ok_or_else(|| anyhow!("analog request stranded after drain"))?,
            );
        }
        lane_answers.push(rows);
    }
    let snapshot = router.metrics_snapshot(&format!("chaos.corner.{}", node.name));
    router.shutdown();

    let nominal = &lane_answers[0];
    let mut trial_agreement = Vec::with_capacity(cfg.trials);
    let mut trial_logit_dev = Vec::with_capacity(cfg.trials);
    for rows in lane_answers.iter().skip(1) {
        let mut agree = 0usize;
        let mut dev = 0.0f64;
        let mut dev_n = 0usize;
        for (nom, got) in nominal.iter().zip(rows) {
            if nom.pred == got.pred {
                agree += 1;
            }
            for (&a, &b) in nom.logits.iter().zip(&got.logits) {
                dev += (a as f64 - b as f64).abs();
                dev_n += 1;
            }
        }
        trial_agreement.push(agree as f64 / nominal.len().max(1) as f64);
        trial_logit_dev.push(dev / dev_n.max(1) as f64);
    }
    let mean_agreement = if trial_agreement.is_empty() {
        1.0
    } else {
        trial_agreement.iter().sum::<f64>() / trial_agreement.len() as f64
    };
    let worst_agreement = trial_agreement
        .iter()
        .cloned()
        .fold(1.0f64, f64::min);

    Ok((
        CornerReport {
            node: node.name.to_string(),
            regime: regime.short().to_string(),
            trial_temp_c,
            trial_agreement,
            trial_logit_dev,
            stuck_cells,
            mean_agreement,
            worst_agreement,
        },
        snapshot,
    ))
}

/// Run the infrastructure campaign: three synthetic lanes (healthy /
/// latency-injected / panic-injected) under a multi-threaded submit
/// storm, then assert the router's liveness invariants.
pub fn run_infra(plan: &FaultPlan, cfg: &ChaosConfig) -> Result<InfraReport> {
    Ok(run_infra_with_metrics(plan, cfg)?.0)
}

/// [`run_infra`] plus the storm router's telemetry snapshot.
pub fn run_infra_with_metrics(
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(InfraReport, MetricsSnapshot)> {
    let _span = crate::util::trace::span("chaos.infra");
    let (submitters, requests) = plan.storm().unwrap_or((2, 48));
    let sizes = [4usize, 6, 3];
    let healthy = synthetic_engine(plan.seed.wrapping_add(101), &sizes, 4)?;
    let mut slow = synthetic_engine(plan.seed.wrapping_add(102), &sizes, 4)?;
    if let Some(d) = plan.slow_delay() {
        slow = slow.with_faults(Arc::new(FaultyExec::slow(d)));
    }
    let mut panicky = synthetic_engine(plan.seed.wrapping_add(103), &sizes, 4)?;
    if let Some(k) = plan.panic_after() {
        panicky = panicky.with_faults(Arc::new(FaultyExec::panicking(k)));
    }
    let router = Router::new(
        RouterConfig {
            workers: cfg.workers.max(2),
            kernel_threads: cfg.kernel_threads,
            ..Default::default()
        },
        vec![
            ("storm".into(), healthy),
            ("slow".into(), slow),
            ("panicky".into(), panicky),
        ],
    );

    let n_lanes = 3usize;
    let reqs: Vec<crate::coordinator::RequestId> = std::thread::scope(|s| {
        let router = &router;
        let mut handles = Vec::with_capacity(submitters);
        for t in 0..submitters {
            let quota = requests / submitters + usize::from(t < requests % submitters);
            handles.push(s.spawn(move || {
                let mut mine = Vec::with_capacity(quota);
                for i in 0..quota {
                    let lane = (t + i) % n_lanes;
                    let bump = 0.0625 * ((t + i) % 7) as f32;
                    let features = vec![0.25 + bump, -0.5, 0.125, 0.75 - bump];
                    if let Ok(id) = router.submit(lane, features) {
                        mine.push(id);
                    }
                }
                mine
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("submitter thread panicked"))
            .collect()
    });
    let submitted = reqs.len();

    let t0 = Instant::now();
    let drain_res = router.drain(Duration::from_secs(DRAIN_BOUND_SECS));
    let drain_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Worker failures surface through drain() as an error too — only the
    // timeout variant is a liveness breach.
    let drained_in_bound = match &drain_res {
        Ok(()) => true,
        Err(e) => !e.to_string().contains("drain timed out"),
    };

    let (mut answered, mut failed, mut stranded, mut double_delivery) = (0, 0, 0, 0);
    for &req in &reqs {
        match router.try_take(req) {
            Ok(Some(_)) => answered += 1,
            Ok(None) => stranded += 1,
            Err(_) => failed += 1,
        }
        // the same request must never be delivered a second time
        if let Ok(Some(_)) = router.try_take(req) {
            double_delivery += 1;
        }
    }
    let panic_observed = router
        .failures()
        .iter()
        .any(|m| m.contains("panicked"));
    let snapshot = router.metrics_snapshot("chaos.infra");
    router.shutdown();

    Ok((
        InfraReport {
            submitted,
            answered,
            failed,
            stranded,
            double_delivery,
            resolved_exactly_once: stranded == 0
                && double_delivery == 0
                && answered + failed == submitted,
            drained_in_bound,
            panic_observed,
            drain_ms,
        },
        snapshot,
    ))
}

/// Replay a plan end to end: both paper corners plus the infrastructure
/// campaign, composed into one report.
pub fn run_chaos(plan: &FaultPlan, cfg: &ChaosConfig) -> Result<ChaosReport> {
    Ok(run_chaos_with_metrics(plan, cfg)?.0)
}

/// [`run_chaos`] plus one telemetry snapshot per campaign stage (two
/// corners, then infra) — the `chaos --metrics-out` surface.  The
/// snapshots carry wall-clock latencies and are *not* part of the
/// deterministic [`ChaosReport::canonical_json`] replay contract.
pub fn run_chaos_with_metrics(
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(ChaosReport, Vec<MetricsSnapshot>)> {
    let _span = crate::util::trace::span("chaos.campaign");
    let net = chaos_net();
    let mut corners = Vec::with_capacity(2);
    let mut snapshots = Vec::with_capacity(3);
    for (node, regime) in chaos_corners() {
        let (corner, snap) = run_corner_with_metrics(node, regime, &net, plan, cfg)?;
        corners.push(corner);
        snapshots.push(snap);
    }
    let (infra, infra_snap) = run_infra_with_metrics(plan, cfg)?;
    snapshots.push(infra_snap);
    Ok((
        ChaosReport {
            plan: plan.clone(),
            corners,
            infra,
        },
        snapshots,
    ))
}

// ---------------------------------------------------------------------------
// Recovery campaign: detect → quarantine → rebuild → healthy
// ---------------------------------------------------------------------------

/// The self-healing recovery campaign result (`sac chaos --recover`).
///
/// Canonical fields are booleans plus one agreement number, each a
/// deterministic function of the plan: health transitions are driven by
/// canary verdicts on fixed probe rows through deterministic engines, the
/// storm invariants are scheduling-independent, and the shed scenario
/// leaves hundreds of milliseconds of margin around every timing edge.
/// The timeline and counters *are* scheduling-dependent and are exported
/// only through [`RecoveryReport::health_json`] — the diagnostic artifact
/// the CI `chaos-recovery` job uploads when the campaign fails.
#[derive(Clone, Debug)]
pub struct RecoveryReport {
    pub plan: FaultPlan,
    /// the drifted lane left `Healthy` on a canary verdict
    pub drift_detected: bool,
    /// the drifted lane reached `Quarantined`
    pub quarantined: bool,
    /// the drifted lane returned to `Healthy` through an engine rebuild
    pub rebuilt_healthy: bool,
    /// detect → quarantine → rebuild → healthy within
    /// [`RECOVERY_BOUND_SECS`]
    pub recovered_in_bound: bool,
    /// post-rebuild label agreement with the nominal lane ∈ [0, 1]
    pub post_rebuild_agreement: f64,
    /// the nominal lane never left `Healthy` (canary zero-false-positive)
    pub no_false_positives: bool,
    /// storm across all lanes: answered + failed == submitted, no strands
    /// or double deliveries
    pub resolved_exactly_once: bool,
    /// the panic-window lane's batch was retried in place and no
    /// panic-class failure leaked to a caller
    pub transient_panic_retried: bool,
    /// every shed request was past its deadline; nothing else was shed
    pub sheds_only_overdue: bool,
    /// the in-deadline request on the shedding router was answered
    pub fresh_request_answered: bool,
    // -- diagnostics (scheduling-dependent; excluded from `to_json`) --
    pub timeline: Vec<HealthEvent>,
    pub probes: u64,
    pub probe_disagreements: u64,
    pub rebuilds: u64,
    pub retries: u64,
    pub requeues: u64,
    pub respawns: u64,
    pub shed_deadline: u64,
    pub recovery_ms: f64,
}

impl RecoveryReport {
    /// Invariant breaches (empty = pass).
    pub fn violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        if !self.drift_detected {
            v.push("recovery: canary never flagged the drifted lane".into());
        }
        if !self.quarantined {
            v.push("recovery: drifted lane was never quarantined".into());
        }
        if !self.rebuilt_healthy {
            v.push("recovery: quarantined lane never returned to healthy via rebuild".into());
        }
        if !self.recovered_in_bound {
            v.push(format!(
                "recovery: detect-to-rebuild loop exceeded the {RECOVERY_BOUND_SECS}s bound"
            ));
        }
        let floor = 1.0 - MEAN_DEGRADATION_ENVELOPE;
        if self.post_rebuild_agreement < floor {
            v.push(format!(
                "recovery: post-rebuild agreement {:.4} below envelope floor {:.2}",
                self.post_rebuild_agreement, floor
            ));
        }
        if !self.no_false_positives {
            v.push("recovery: canary false positive on the nominal lane".into());
        }
        if !self.resolved_exactly_once {
            v.push("recovery: storm requests not resolved exactly once".into());
        }
        if !self.transient_panic_retried {
            v.push("recovery: transient engine panic was not retried to success".into());
        }
        if !self.sheds_only_overdue {
            v.push("recovery: shedding hit a request that was not past its deadline".into());
        }
        if !self.fresh_request_answered {
            v.push("recovery: in-deadline request on the shedding router went unanswered".into());
        }
        v
    }

    pub fn pass(&self) -> bool {
        self.violations().is_empty()
    }

    /// Deterministic serialization — a pure function of the plan.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan", self.plan.to_json()),
            (
                "agreement_floor",
                Json::Num(1.0 - MEAN_DEGRADATION_ENVELOPE),
            ),
            ("drift_detected", Json::Bool(self.drift_detected)),
            ("quarantined", Json::Bool(self.quarantined)),
            ("rebuilt_healthy", Json::Bool(self.rebuilt_healthy)),
            ("recovered_in_bound", Json::Bool(self.recovered_in_bound)),
            (
                "post_rebuild_agreement",
                Json::Num(self.post_rebuild_agreement),
            ),
            ("no_false_positives", Json::Bool(self.no_false_positives)),
            (
                "resolved_exactly_once",
                Json::Bool(self.resolved_exactly_once),
            ),
            (
                "transient_panic_retried",
                Json::Bool(self.transient_panic_retried),
            ),
            ("sheds_only_overdue", Json::Bool(self.sheds_only_overdue)),
            (
                "fresh_request_answered",
                Json::Bool(self.fresh_request_answered),
            ),
            (
                "violations",
                Json::Arr(self.violations().into_iter().map(Json::Str).collect()),
            ),
            ("pass", Json::Bool(self.pass())),
        ])
    }

    pub fn canonical_json(&self) -> String {
        self.to_json().to_string()
    }

    /// The health-timeline diagnostic artifact (CI uploads this on
    /// failure): every state transition plus the supervision counters.
    /// Scheduling-dependent — not part of the replay contract.
    pub fn health_json(&self) -> Json {
        Json::obj(vec![
            (
                "timeline",
                Json::Arr(self.timeline.iter().map(|e| e.to_json()).collect()),
            ),
            ("probes", Json::Num(self.probes as f64)),
            (
                "probe_disagreements",
                Json::Num(self.probe_disagreements as f64),
            ),
            ("rebuilds", Json::Num(self.rebuilds as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("requeues", Json::Num(self.requeues as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            ("shed_deadline", Json::Num(self.shed_deadline as f64)),
            ("recovery_ms", Json::Num(self.recovery_ms)),
            ("report", self.to_json()),
        ])
    }
}

/// High-margin canary rows: lightly-noised scaled prototypes.  Any
/// correctly calibrated engine classifies these perfectly — the chaos
/// net's logit margins dwarf in-envelope analog perturbation — so the
/// golden probes produce zero false positives on healthy lanes and a
/// rebuilt engine re-enters `Healthy` without flapping.
pub fn recovery_probe_rows(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed).fork(0xCA9A);
    (0..CHAOS_BATCH)
        .map(|r| {
            let p = &PROTOS[r % PROTOS.len()];
            p.iter()
                .map(|&pi| (0.75 * pi + rng.uniform_in(-0.05, 0.05)) as f32)
                .collect()
        })
        .collect()
}

/// Zero-padded one-shot batch over `rows` in the compiled engine shape
/// (used to label probes through the nominal engine's circuit path).
fn manual_batch(rows: &[Vec<f32>], dim: usize, batch_size: usize) -> Batch {
    let mut data = vec![0.0f32; batch_size * dim];
    for (r, row) in rows.iter().enumerate() {
        data[r * dim..r * dim + dim].copy_from_slice(row);
    }
    Batch {
        ids: (0..rows.len() as u64).collect(),
        data,
        live: rows.len(),
    }
}

/// Run the recovery campaign: replay the plan's drift step against a lane
/// whose calibration has gone stale, and assert the self-healing loop
/// end to end — canary detection, quarantine, grid-cache invalidation +
/// rebuild at the current operating point, exactly-once delivery under a
/// storm with a transient panic, and deadline shedding that only hits
/// past-deadline requests.
pub fn run_recovery(plan: &FaultPlan, cfg: &ChaosConfig) -> Result<RecoveryReport> {
    Ok(run_recovery_with_metrics(plan, cfg)?.0)
}

/// [`run_recovery`] plus the recovery router's telemetry snapshot
/// (includes the `sac-metrics/v3` health block).
pub fn run_recovery_with_metrics(
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(RecoveryReport, MetricsSnapshot)> {
    let _span = crate::util::trace::span("chaos.recovery");
    let t0 = Instant::now();
    let grid = chaos_grid();
    let net = chaos_net();
    let act = net.activation_kind()?;
    let (node, regime) = (&CMOS180, Regime::WeakInversion);

    let (dkind, from_c, to_c_plan, steps) = plan
        .drift()
        .unwrap_or((DriftKind::Step, NOMINAL_T_C, NOMINAL_T_C + 33.0, 2));
    let to_c = *temperature_schedule(dkind, from_c, to_c_plan, steps)
        .last()
        .expect("temperature schedule is never empty");

    // Nominal lane: surrogate and multiplier both calibrated at the
    // pre-drift temperature — a healthy chip.
    let nominal_table = TableModel::calibrate(node, regime, from_c);
    let mult = Multiplier::calibrate(&nominal_table, net.splines, net.c);
    let nominal_kernel = BatchKernel::with_multiplier(
        Box::new(nominal_table.clone()),
        mult.clone(),
        act,
        net.splines,
        net.c,
        &grid,
    );
    let nominal = engine_with_kernel(&net, nominal_kernel)?;

    // Drifted lane: the physics have stepped to `to_c` but the multiplier
    // calibration is stale, mirror mismatch is amplified past the plan's
    // sigma, and a heavy stuck-cell burst kills a large slice of the
    // multiplier grid.  Far outside the paper envelope by construction —
    // the canary must trip.
    let mut rng = Rng::new(plan.seed).fork(0x4EC0);
    let mm = MismatchModel::new(node);
    let sigma = (plan.sigma_scale() * 3.0).max(3.0);
    let gains = mm.sample_mirror_gains(regime, to_c, GAIN_BRANCHES, sigma, &mut rng);
    let drifted_provider: Box<dyn HProvider + Send + Sync> = Box::new(MismatchedProvider::new(
        Box::new(TableModel::calibrate(node, regime, to_c)),
        gains,
    ));
    let mut drifted_kernel = BatchKernel::with_multiplier(
        drifted_provider,
        mult.clone(),
        act,
        net.splines,
        net.c,
        &grid,
    );
    // floor the stuck fraction at half the grid: the drifted lane must be
    // unambiguously outside the envelope so detection is deterministic
    let (stuck_frac, stuck_value) = plan.stuck().unwrap_or((0.5, 0.0));
    drifted_kernel.inject_stuck_cells(&mut rng, stuck_frac.clamp(0.5, 1.0), stuck_value);
    let drifted = engine_with_kernel(&net, drifted_kernel)?;

    // Transient-panic lane: nominal physics; the first executed batch
    // panics exactly once, so the retry path must answer it.
    let flaky = nominal
        .clone()
        .with_faults(Arc::new(FaultyExec::panicking_window(0, 1)));

    // Golden probes, labelled through the nominal engine's circuit path.
    let probe_rows = recovery_probe_rows(plan.seed);
    let probe_labels: Vec<usize> = nominal
        .run_batch(&manual_batch(&probe_rows, nominal.dim, nominal.batch_size))?
        .iter()
        .map(|&(_, pred, _)| pred)
        .collect();

    // Rebuild recipe for the quarantined lane: drop every cached grid
    // sampled from this corner (they are keyed to the stale calibration),
    // then re-derive the whole kernel from the *current* operating point —
    // fresh surrogate at `to_c`, fresh multiplier calibration, clean grid.
    let rebuild_net = net.clone();
    let stale_fragment = format!("table/{}/{}/", node.name, regime);
    let rebuild: crate::coordinator::RebuildFn = Arc::new(move || {
        crate::nn::batch::grid_cache_invalidate(&stale_fragment);
        let table = TableModel::calibrate(node, regime, to_c);
        let fresh_mult = Multiplier::calibrate(&table, rebuild_net.splines, rebuild_net.c);
        let kernel = BatchKernel::with_multiplier(
            Box::new(table),
            fresh_mult,
            act,
            rebuild_net.splines,
            rebuild_net.c,
            &grid,
        );
        engine_with_kernel(&rebuild_net, kernel)
    });

    let router = Router::with_specs(
        RouterConfig {
            workers: cfg.workers.max(2),
            kernel_threads: cfg.kernel_threads,
            canary_every: 1,
            health: HealthConfig {
                window: 1,
                patience: 1,
                ..HealthConfig::default()
            },
            ..RouterConfig::default()
        },
        vec![
            LaneSpec::new("nominal", nominal.clone())
                .with_probe(probe_rows.clone(), probe_labels.clone()),
            LaneSpec::new("drifted", drifted)
                .with_probe(probe_rows.clone(), probe_labels.clone())
                .with_rebuild(rebuild),
            LaneSpec::new("flaky", flaky).with_probe(probe_rows, probe_labels),
        ],
    );
    let feats = eval_features(plan.seed, cfg.eval_rows.max(CHAOS_BATCH));

    // Phase A — detection and recovery.  Two batches through the drifted
    // lane: the first canary verdict either collapses straight through
    // Healthy → Degraded → Quarantined or parks the lane in Degraded for
    // the second verdict to escalate (patience = 1).  The rebuild runs
    // inline on the quarantining worker, so it completes before the
    // drain returns; canaries after the swap probe the rebuilt engine
    // and stay clean, so exactly one rebuild ever happens.
    for f in feats.iter().take(2 * CHAOS_BATCH) {
        router.submit(1, f.clone())?;
    }
    router.drain(Duration::from_secs(RECOVERY_BOUND_SECS))?;
    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    let recovered_in_bound = t0.elapsed() <= Duration::from_secs(RECOVERY_BOUND_SECS);

    // Phase B — post-rebuild agreement: the same eval rows through the
    // nominal lane and the rebuilt lane.
    let mut nom_ids = Vec::with_capacity(feats.len());
    let mut reb_ids = Vec::with_capacity(feats.len());
    for f in &feats {
        nom_ids.push(router.submit(0, f.clone())?);
        reb_ids.push(router.submit(1, f.clone())?);
    }
    router.drain(Duration::from_secs(DRAIN_BOUND_SECS))?;
    let mut agree = 0usize;
    for (&n, &r) in nom_ids.iter().zip(&reb_ids) {
        let nom = router
            .try_take(n)?
            .ok_or_else(|| anyhow!("recovery: nominal request stranded after drain"))?;
        let reb = router
            .try_take(r)?
            .ok_or_else(|| anyhow!("recovery: rebuilt-lane request stranded after drain"))?;
        if nom.pred == reb.pred {
            agree += 1;
        }
    }
    let post_rebuild_agreement = agree as f64 / nom_ids.len().max(1) as f64;

    // Phase C — submit storm across all three lanes (the flaky lane's
    // first batch panics once and must be retried in place).
    let (submitters, requests) = plan.storm().unwrap_or((4, 96));
    let reqs: Vec<crate::coordinator::RequestId> = std::thread::scope(|s| {
        let router = &router;
        let feats = &feats;
        let mut handles = Vec::with_capacity(submitters);
        for t in 0..submitters {
            let quota = requests / submitters + usize::from(t < requests % submitters);
            handles.push(s.spawn(move || {
                let mut mine = Vec::with_capacity(quota);
                for i in 0..quota {
                    let lane = (t + i) % 3;
                    let row = feats[(t * 31 + i) % feats.len()].clone();
                    if let Ok(id) = router.submit(lane, row) {
                        mine.push(id);
                    }
                }
                mine
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("storm submitter panicked"))
            .collect()
    });
    let submitted = reqs.len();
    router.drain(Duration::from_secs(DRAIN_BOUND_SECS)).ok();
    let (mut answered, mut failed, mut stranded, mut double_delivery) = (0, 0, 0, 0);
    for &req in &reqs {
        match router.try_take(req) {
            Ok(Some(_)) => answered += 1,
            Ok(None) => stranded += 1,
            Err(_) => failed += 1,
        }
        if let Ok(Some(_)) = router.try_take(req) {
            double_delivery += 1;
        }
    }
    let resolved_exactly_once =
        stranded == 0 && double_delivery == 0 && answered + failed == submitted;
    let panic_leaked = router.failures().iter().any(|m| m.contains("panicked"));

    let timeline = router.health_timeline();
    let health = router.health_snapshot();
    let states = router.health_states();
    let snapshot = router.metrics_snapshot("chaos.recovery");
    router.shutdown();

    let lane_final = |name: &str| {
        states
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(HealthState::Healthy)
    };
    let drift_detected = timeline
        .iter()
        .any(|e| e.lane == "drifted" && e.from == HealthState::Healthy);
    let quarantined = timeline
        .iter()
        .any(|e| e.lane == "drifted" && e.to == HealthState::Quarantined);
    let rebuilt_healthy = timeline.iter().any(|e| {
        e.lane == "drifted"
            && e.from == HealthState::Quarantined
            && e.to == HealthState::Healthy
    }) && lane_final("drifted") == HealthState::Healthy;
    let no_false_positives = !timeline.iter().any(|e| e.lane == "nominal")
        && lane_final("nominal") == HealthState::Healthy;
    let transient_panic_retried = health.retries >= 1 && !panic_leaked;

    // Phase D — deadline shedding on a dedicated single-worker router: a
    // slow engine holds the lane for 400 ms, so requests submitted behind
    // the first batch are ~340 ms past enqueue when the worker reaches
    // them — far beyond the 250 ms deadline — while the first batch
    // enters execution at age ~0 and must be answered.
    let slow = nominal
        .clone()
        .with_faults(Arc::new(FaultyExec::slow(Duration::from_millis(400))));
    let shed_router = Router::new(
        RouterConfig {
            workers: 1,
            kernel_threads: cfg.kernel_threads,
            deadline: Some(Duration::from_millis(250)),
            ..RouterConfig::default()
        },
        vec![("shed".into(), slow)],
    );
    let first = shed_router.submit(0, feats[0].clone())?;
    std::thread::sleep(Duration::from_millis(60));
    let mut late = Vec::with_capacity(3);
    for f in feats.iter().skip(1).take(3) {
        late.push(shed_router.submit(0, f.clone())?);
    }
    let fresh_request_answered = shed_router.wait(first, Duration::from_secs(10)).is_ok();
    shed_router.drain(Duration::from_secs(DRAIN_BOUND_SECS)).ok();
    let mut sheds_only_overdue = fresh_request_answered;
    let mut sheds_seen = 0u64;
    for id in late {
        match shed_router.try_take(id) {
            Err(e) if e.to_string().contains("shed") => sheds_seen += 1,
            _ => sheds_only_overdue = false,
        }
    }
    let shed_health = shed_router.health_snapshot();
    shed_router.shutdown();
    // every shed the router recorded must correspond to an overdue
    // request from the backlog above
    if shed_health.shed_deadline != sheds_seen {
        sheds_only_overdue = false;
    }

    Ok((
        RecoveryReport {
            plan: plan.clone(),
            drift_detected,
            quarantined,
            rebuilt_healthy,
            recovered_in_bound,
            post_rebuild_agreement,
            no_false_positives,
            resolved_exactly_once,
            transient_panic_retried,
            sheds_only_overdue,
            fresh_request_answered,
            timeline,
            probes: health.probes,
            probe_disagreements: health.probe_disagreements,
            rebuilds: health.rebuilds,
            retries: health.retries,
            requeues: health.requeues,
            respawns: health.respawns,
            shed_deadline: shed_health.shed_deadline,
            recovery_ms,
        },
        snapshot,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_net_is_a_margin_heavy_prototype_detector() {
        let net = chaos_net();
        assert_eq!(net.sizes, vec![8, 6, 3]);
        assert_eq!(net.weights[0].len(), 48);
        assert_eq!(net.weights[1].len(), 18);
        assert_eq!(net.activation, "relu");
        // detector k responds to prototype k with a positive pre-activation
        // and to the other prototypes with a negative one (software math)
        for (k, b) in [(0usize, -0.5f64), (1, -0.5), (2, -0.5)] {
            for (j, p) in PROTOS.iter().enumerate() {
                let pre: f64 = (0..8)
                    .map(|i| net.weights[0][i * 6 + k] * 0.75 * p[i])
                    .sum::<f64>()
                    + b;
                if j == k {
                    assert!(pre > 0.5, "detector {k} should fire on prototype {j}");
                } else {
                    assert!(pre < -0.2, "detector {k} should stay off prototype {j}");
                }
            }
        }
    }

    #[test]
    fn eval_features_are_seeded_and_classed() {
        let a = eval_features(7, 12);
        let b = eval_features(7, 12);
        let c = eval_features(8, 12);
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|row| row.len() == 8));
        assert_eq!(a, b, "same seed must replay identical rows");
        assert_ne!(a, c, "different seeds must differ");
        // rows stay near their prototype: sign pattern matches class r % 3
        for (r, row) in a.iter().enumerate() {
            let p = &PROTOS[r % 3];
            for (i, &v) in row.iter().enumerate() {
                assert_eq!(v.signum() as f64, p[i], "row {r} feature {i}");
                assert!(v.abs() > 0.5 && v.abs() < 1.0);
            }
        }
    }

    #[test]
    fn infra_report_serializes_only_deterministic_fields() {
        let r = InfraReport {
            submitted: 96,
            answered: 60,
            failed: 36,
            stranded: 0,
            double_delivery: 0,
            resolved_exactly_once: true,
            drained_in_bound: true,
            panic_observed: true,
            drain_ms: 12.5,
        };
        let s = r.to_json().to_string();
        assert!(s.contains("\"submitted\":96"));
        assert!(s.contains("\"resolved_exactly_once\":true"));
        assert!(!s.contains("answered"), "scheduling-dependent field leaked: {s}");
        assert!(!s.contains("drain_ms"), "timing field leaked: {s}");
    }

    #[test]
    fn violations_flag_envelope_and_invariant_breaches() {
        let plan = FaultPlan::new(1);
        let good = CornerReport {
            node: "cmos180".into(),
            regime: "WI".into(),
            trial_temp_c: vec![27.0],
            trial_agreement: vec![1.0],
            trial_logit_dev: vec![0.0],
            stuck_cells: vec![0],
            mean_agreement: 1.0,
            worst_agreement: 1.0,
        };
        let infra = InfraReport {
            submitted: 10,
            answered: 10,
            failed: 0,
            stranded: 0,
            double_delivery: 0,
            resolved_exactly_once: true,
            drained_in_bound: true,
            panic_observed: false,
            drain_ms: 1.0,
        };
        let report = ChaosReport {
            plan: plan.clone(),
            corners: vec![good.clone()],
            infra: infra.clone(),
        };
        assert!(report.pass(), "clean report must pass: {:?}", report.violations());

        let mut bad_corner = good.clone();
        bad_corner.mean_agreement = 0.5;
        bad_corner.worst_agreement = 0.2;
        let mut bad_infra = infra.clone();
        bad_infra.stranded = 1;
        bad_infra.resolved_exactly_once = false;
        bad_infra.drained_in_bound = false;
        let report = ChaosReport {
            plan,
            corners: vec![bad_corner],
            infra: bad_infra,
        };
        let v = report.violations();
        assert_eq!(v.len(), 4, "expected 4 violations, got {v:?}");
        assert!(!report.pass());
        let s = report.canonical_json();
        assert!(s.contains("\"pass\":false"));
        assert!(s.contains("\"violations\":["));
    }
}
