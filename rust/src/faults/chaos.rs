//! The chaos campaign runner: replay a [`FaultPlan`] against the serving
//! stack and report accuracy degradation + router liveness invariants.
//!
//! Two campaigns compose into one [`ChaosReport`]:
//!
//! * **Analog** ([`run_corner`], once per paper corner): a fixed
//!   high-margin prototype-detector net is served through a [`Router`]
//!   with one *nominal* lane and `trials` *faulted* lanes.  Each trial
//!   lane's [`BatchKernel`] is rebuilt from the plan's analog faults —
//!   Pelgrom mirror-gain mismatch sampled through
//!   [`MismatchModel`], the temperature-drift schedule stage the trial
//!   falls in, and stuck multiplier-grid cells — while reusing the one
//!   nominal multiplier calibration (chip-calibration-then-drift).  The
//!   report is per-trial label agreement against the nominal lane.
//! * **Infrastructure** ([`run_infra`]): three synthetic-engine lanes —
//!   healthy, latency-injected, panic-injected — under a multi-threaded
//!   submit storm.  The report is the router's liveness invariants:
//!   every request resolved exactly once (answered or failed, never
//!   stranded, never delivered twice) and a bounded drain.
//!
//! Determinism contract: every field serialized by
//! [`ChaosReport::canonical_json`] is a pure function of the plan — per-row
//! analog results do not depend on worker scheduling (each row is computed
//! independently and matched back by request id), and the infra fields are
//! scheduling-independent booleans/counts.  Wall-clock timings and the
//! answered/failed split (which *does* depend on batch ordinal timing) are
//! reported on the struct but excluded from the canonical serialization, so
//! identical-seed replays are bit-identical.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cells::multiplier::Multiplier;
use crate::cells::HProvider;
use crate::coordinator::{
    synthetic_engine, Engine, MetricsSnapshot, Response, Router, RouterConfig,
};
use crate::data::TrainedNet;
use crate::device::MismatchModel;
use crate::nn::batch::{BatchKernel, GridConfig};
use crate::pdk::regime::Regime;
use crate::pdk::{ProcessNode, CMOS180, FINFET7};
use crate::runtime::{Executable, FaultyExec};
use crate::sac::TableModel;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::drift::{stage_for_progress, temperature_schedule};
use super::drift::MismatchedProvider;
use super::plan::{DriftKind, FaultPlan};

/// Acceptance envelope on the *campaign mean*: mean label agreement with
/// the nominal lane must stay ≥ `1 − MEAN_DEGRADATION_ENVELOPE`.  The
/// paper's Fig. 8 shows ≤ ~10% full-scale output deviation under combined
/// PVT + mismatch; 15% on label agreement adds margin for the stuck-cell
/// fault class the paper does not model.
pub const MEAN_DEGRADATION_ENVELOPE: f64 = 0.15;

/// Collapse guard on the *worst single trial*: no trial may fall below
/// `1 − WORST_DEGRADATION_ENVELOPE` agreement.  A single unlucky stuck
/// cell in a high-traffic grid region can systematically skew one class,
/// so this floor is intentionally loose — it catches collapse (outputs
/// decorrelated from the nominal), not ordinary degradation.
pub const WORST_DEGRADATION_ENVELOPE: f64 = 0.40;

/// Drain bound for the infrastructure campaign [s] — generous versus the
/// ~ms of injected latency, so only a genuine liveness bug trips it.
pub const DRAIN_BOUND_SECS: u64 = 30;

/// Drain bound for the analog campaign [s] (many lanes, table-backed).
const ANALOG_DRAIN_SECS: u64 = 120;

/// Compiled batch dimension of the chaos net's engines.
const CHAOS_BATCH: usize = 8;

/// Distinct mirror-gain branches sampled per trial (cycled across the
/// solver's inputs by [`MismatchedProvider`]).
const GAIN_BRANCHES: usize = 16;

/// Junction temperature when the plan carries no drift fault [°C].
const NOMINAL_T_C: f64 = 27.0;

/// Campaign knobs not carried by the plan (the plan is *what* to inject;
/// this is *how hard* to sample it).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// faulted lanes per corner
    pub trials: usize,
    /// router worker threads
    pub workers: usize,
    /// evaluation rows per lane (kept a multiple of the batch size so the
    /// analog campaign never depends on the deadline flusher)
    pub eval_rows: usize,
    /// intra-batch row parallelism for every lane engine (the CLI's
    /// `--threads`; forwarded to `RouterConfig::kernel_threads`).  `None`
    /// keeps the engine default.  Agreement numbers are unaffected — the
    /// sharded kernel is bit-identical to the serial one.
    pub kernel_threads: Option<usize>,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            trials: 12,
            workers: 4,
            eval_rows: 32,
            kernel_threads: None,
        }
    }
}

/// The paper's evaluation corners, at the regime each node's story
/// centers on (Fig. 1: weak inversion at 180 nm, moderate at 7 nm).
pub fn chaos_corners() -> [(&'static ProcessNode, Regime); 2] {
    [
        (&CMOS180, Regime::WeakInversion),
        (&FINFET7, Regime::ModerateInversion),
    ]
}

/// Grid sizing for the chaos kernels: coarse enough that a stuck cell is
/// a meaningful fraction of the table, fine enough to stay within
/// `BATCH_TOL` of the scalar path on the nominal lane.
pub fn chaos_grid() -> GridConfig {
    GridConfig {
        proto_range: 6.0,
        proto_density: 96,
        act_range: 8.0,
        act_density: 64,
    }
}

/// Orthogonal ±1 prototypes (Hadamard rows), one per class.
const PROTOS: [[f64; 8]; 3] = [
    [1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0],
    [1.0, 1.0, -1.0, -1.0, 1.0, 1.0, -1.0, -1.0],
    [1.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0, -1.0],
];

/// The fixed chaos net: a hand-constructed prototype detector
/// `[8, 6, 3]` whose nominal logit margins are far larger than any
/// in-envelope analog perturbation, so agreement loss measures fault
/// severity rather than razor-edge class boundaries.
///
/// Hidden unit `k < 3` detects prototype `k` (weights `0.22·p_k`, bias
/// −0.5 so non-matching rows stay below the ReLU knee); hidden units
/// 3..6 are low-gain spares for the same prototypes.  The output layer
/// routes each detector to its class.
pub fn chaos_net() -> TrainedNet {
    let (din, hid, kout) = (8usize, 6usize, 3usize);
    let mut w1 = vec![0.0; din * hid];
    for i in 0..din {
        for k in 0..hid {
            w1[i * hid + k] = if k < 3 {
                0.22 * PROTOS[k][i]
            } else {
                0.06 * PROTOS[k - 3][i]
            };
        }
    }
    let b1 = vec![-0.5, -0.5, -0.5, -0.15, -0.15, -0.15];
    let mut w2 = vec![0.0; hid * kout];
    for j in 0..kout {
        w2[j * kout + j] = 0.3;
        w2[(3 + j) * kout + j] = 0.1;
    }
    let b2 = vec![0.0; kout];
    TrainedNet {
        task: "chaos".into(),
        sizes: vec![din, hid, kout],
        activation: "relu".into(),
        splines: 1,
        c: 1.0,
        acc_sw: 0.0,
        acc_sac_algorithmic: 0.0,
        weights: vec![w1, w2],
        biases: vec![b1, b2],
    }
}

/// Evaluation rows: noisy prototypes, class `r % 3`, seeded off the plan.
pub fn eval_features(seed: u64, rows: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed).fork(0xFEA7);
    (0..rows)
        .map(|r| {
            let p = &PROTOS[r % PROTOS.len()];
            p.iter()
                .map(|&pi| (0.75 * pi + rng.uniform_in(-0.15, 0.15)) as f32)
                .collect()
        })
        .collect()
}

/// One corner's analog campaign result.
#[derive(Clone, Debug)]
pub struct CornerReport {
    pub node: String,
    pub regime: String,
    /// junction temperature each trial was served at [°C]
    pub trial_temp_c: Vec<f64>,
    /// per-trial label agreement with the nominal lane ∈ [0, 1]
    pub trial_agreement: Vec<f64>,
    /// per-trial mean |logit − nominal logit|
    pub trial_logit_dev: Vec<f64>,
    /// stuck multiplier-grid cells injected per trial
    pub stuck_cells: Vec<usize>,
    pub mean_agreement: f64,
    pub worst_agreement: f64,
}

impl CornerReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::Str(self.node.clone())),
            ("regime", Json::Str(self.regime.clone())),
            ("trial_temp_c", Json::from_f64_slice(&self.trial_temp_c)),
            ("trial_agreement", Json::from_f64_slice(&self.trial_agreement)),
            ("trial_logit_dev", Json::from_f64_slice(&self.trial_logit_dev)),
            (
                "stuck_cells",
                Json::Arr(self.stuck_cells.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("mean_agreement", Json::Num(self.mean_agreement)),
            ("worst_agreement", Json::Num(self.worst_agreement)),
        ])
    }
}

/// The infrastructure campaign result.  `answered`/`failed`/`drain_ms`
/// depend on worker scheduling (which batch ordinal trips the panic gate)
/// and are excluded from the canonical serialization; the invariant
/// fields are deterministic.
#[derive(Clone, Debug)]
pub struct InfraReport {
    pub submitted: usize,
    pub answered: usize,
    pub failed: usize,
    /// requests neither answered nor failed after a full drain
    pub stranded: usize,
    /// requests delivered more than once by `try_take`
    pub double_delivery: usize,
    /// `answered + failed == submitted` with no strands or doubles
    pub resolved_exactly_once: bool,
    /// drain returned (successfully or with collected worker failures)
    /// before [`DRAIN_BOUND_SECS`]
    pub drained_in_bound: bool,
    /// at least one engine panic was contained and surfaced
    pub panic_observed: bool,
    pub drain_ms: f64,
}

impl InfraReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::Num(self.submitted as f64)),
            ("stranded", Json::Num(self.stranded as f64)),
            ("double_delivery", Json::Num(self.double_delivery as f64)),
            ("resolved_exactly_once", Json::Bool(self.resolved_exactly_once)),
            ("drained_in_bound", Json::Bool(self.drained_in_bound)),
            ("panic_observed", Json::Bool(self.panic_observed)),
        ])
    }
}

/// The full campaign report for one plan.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub plan: FaultPlan,
    pub corners: Vec<CornerReport>,
    pub infra: InfraReport,
}

impl ChaosReport {
    /// Envelope / invariant breaches (empty = pass).
    pub fn violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        let mean_floor = 1.0 - MEAN_DEGRADATION_ENVELOPE;
        let worst_floor = 1.0 - WORST_DEGRADATION_ENVELOPE;
        for c in &self.corners {
            if c.mean_agreement < mean_floor {
                v.push(format!(
                    "corner {}/{}: mean agreement {:.4} below envelope floor {:.2}",
                    c.node, c.regime, c.mean_agreement, mean_floor
                ));
            }
            if c.worst_agreement < worst_floor {
                v.push(format!(
                    "corner {}/{}: worst trial agreement {:.4} below collapse floor {:.2}",
                    c.node, c.regime, c.worst_agreement, worst_floor
                ));
            }
        }
        let i = &self.infra;
        if !i.resolved_exactly_once {
            v.push(format!(
                "infra: {} submitted but {} answered + {} failed, {} stranded, {} double-delivered",
                i.submitted, i.answered, i.failed, i.stranded, i.double_delivery
            ));
        }
        if !i.drained_in_bound {
            v.push(format!("infra: drain exceeded the {DRAIN_BOUND_SECS}s bound"));
        }
        if self.plan.panic_after().is_some() && !i.panic_observed {
            v.push("infra: planned engine panic was never observed".into());
        }
        v
    }

    pub fn pass(&self) -> bool {
        self.violations().is_empty()
    }

    /// Deterministic serialization: a pure function of the plan (see the
    /// module docs for the replay contract).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan", self.plan.to_json()),
            ("mean_envelope", Json::Num(MEAN_DEGRADATION_ENVELOPE)),
            ("worst_envelope", Json::Num(WORST_DEGRADATION_ENVELOPE)),
            (
                "corners",
                Json::Arr(self.corners.iter().map(|c| c.to_json()).collect()),
            ),
            ("infra", self.infra.to_json()),
            (
                "violations",
                Json::Arr(
                    self.violations().into_iter().map(Json::Str).collect(),
                ),
            ),
            ("pass", Json::Bool(self.pass())),
        ])
    }

    pub fn canonical_json(&self) -> String {
        self.to_json().to_string()
    }
}

fn engine_with_kernel(net: &TrainedNet, kernel: BatchKernel) -> Result<Engine> {
    let exe = Executable::native_mlp_with_kernel(net, CHAOS_BATCH, Arc::new(kernel))?;
    Engine::from_parts(net.clone(), exe)
}

/// Run the analog campaign at one corner: a nominal lane plus
/// `cfg.trials` faulted lanes served through one router, reported as
/// per-trial agreement against the nominal lane.
pub fn run_corner(
    node: &'static ProcessNode,
    regime: Regime,
    net: &TrainedNet,
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<CornerReport> {
    Ok(run_corner_with_metrics(node, regime, net, plan, cfg)?.0)
}

/// [`run_corner`] plus the corner router's telemetry snapshot (captured
/// after the drain, before shutdown — see `--metrics-out` on `chaos`).
pub fn run_corner_with_metrics(
    node: &'static ProcessNode,
    regime: Regime,
    net: &TrainedNet,
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(CornerReport, MetricsSnapshot)> {
    let _span = crate::util::trace::span("chaos.corner");
    let grid = chaos_grid();
    let act = net.activation_kind()?;
    let (dkind, from_c, to_c, steps) = plan
        .drift()
        .unwrap_or((DriftKind::Ramp, NOMINAL_T_C, NOMINAL_T_C, 1));
    let temps = temperature_schedule(dkind, from_c, to_c, steps);

    // Chip-calibration-then-drift: one surrogate per schedule stage, one
    // multiplier calibration on the nominal (first-stage) corner, reused
    // by every trial kernel.
    let stage_tables: Vec<TableModel> = temps
        .iter()
        .map(|&t| TableModel::calibrate(node, regime, t))
        .collect();
    let mult = Multiplier::calibrate(&stage_tables[0], net.splines, net.c);
    let mm = MismatchModel::new(node);
    let sigma_scale = plan.sigma_scale();

    let mut lanes: Vec<(String, Engine)> = Vec::with_capacity(cfg.trials + 1);
    let nominal_provider: Box<dyn HProvider + Send + Sync> =
        Box::new(stage_tables[0].clone());
    let nominal_kernel = BatchKernel::with_multiplier(
        nominal_provider,
        mult.clone(),
        act,
        net.splines,
        net.c,
        &grid,
    );
    lanes.push(("nominal".into(), engine_with_kernel(net, nominal_kernel)?));

    let mut trial_temp_c = Vec::with_capacity(cfg.trials);
    let mut stuck_cells = Vec::with_capacity(cfg.trials);
    for t in 0..cfg.trials {
        let mut rng = Rng::new(plan.seed).fork(0x5AC0_0000 + t as u64);
        let progress = if cfg.trials <= 1 {
            0.0
        } else {
            t as f64 / (cfg.trials - 1) as f64
        };
        let stage = stage_for_progress(progress, temps.len());
        let t_c = temps[stage];
        let gains = mm.sample_mirror_gains(regime, t_c, GAIN_BRANCHES, sigma_scale, &mut rng);
        let provider: Box<dyn HProvider + Send + Sync> = Box::new(MismatchedProvider::new(
            Box::new(stage_tables[stage].clone()),
            gains,
        ));
        let mut kernel = BatchKernel::with_multiplier(
            provider,
            mult.clone(),
            act,
            net.splines,
            net.c,
            &grid,
        );
        let stuck = match plan.stuck() {
            Some((fraction, value)) => kernel.inject_stuck_cells(&mut rng, fraction, value),
            None => 0,
        };
        trial_temp_c.push(t_c);
        stuck_cells.push(stuck);
        lanes.push((format!("trial{t}"), engine_with_kernel(net, kernel)?));
    }

    let n_lanes = lanes.len();
    let router = Router::new(
        RouterConfig {
            workers: cfg.workers.max(1),
            kernel_threads: cfg.kernel_threads,
            ..Default::default()
        },
        lanes,
    );
    let feats = eval_features(plan.seed, cfg.eval_rows);
    let mut reqs = Vec::with_capacity(n_lanes);
    for lane in 0..n_lanes {
        let mut ids = Vec::with_capacity(feats.len());
        for f in &feats {
            ids.push(router.submit(lane, f.clone())?);
        }
        reqs.push(ids);
    }
    router.drain(Duration::from_secs(ANALOG_DRAIN_SECS))?;
    let mut lane_answers: Vec<Vec<Response>> = Vec::with_capacity(n_lanes);
    for ids in &reqs {
        let mut rows = Vec::with_capacity(ids.len());
        for &id in ids {
            rows.push(
                router
                    .try_take(id)?
                    .ok_or_else(|| anyhow!("analog request stranded after drain"))?,
            );
        }
        lane_answers.push(rows);
    }
    let snapshot = router.metrics_snapshot(&format!("chaos.corner.{}", node.name));
    router.shutdown();

    let nominal = &lane_answers[0];
    let mut trial_agreement = Vec::with_capacity(cfg.trials);
    let mut trial_logit_dev = Vec::with_capacity(cfg.trials);
    for rows in lane_answers.iter().skip(1) {
        let mut agree = 0usize;
        let mut dev = 0.0f64;
        let mut dev_n = 0usize;
        for (nom, got) in nominal.iter().zip(rows) {
            if nom.pred == got.pred {
                agree += 1;
            }
            for (&a, &b) in nom.logits.iter().zip(&got.logits) {
                dev += (a as f64 - b as f64).abs();
                dev_n += 1;
            }
        }
        trial_agreement.push(agree as f64 / nominal.len().max(1) as f64);
        trial_logit_dev.push(dev / dev_n.max(1) as f64);
    }
    let mean_agreement = if trial_agreement.is_empty() {
        1.0
    } else {
        trial_agreement.iter().sum::<f64>() / trial_agreement.len() as f64
    };
    let worst_agreement = trial_agreement
        .iter()
        .cloned()
        .fold(1.0f64, f64::min);

    Ok((
        CornerReport {
            node: node.name.to_string(),
            regime: regime.short().to_string(),
            trial_temp_c,
            trial_agreement,
            trial_logit_dev,
            stuck_cells,
            mean_agreement,
            worst_agreement,
        },
        snapshot,
    ))
}

/// Run the infrastructure campaign: three synthetic lanes (healthy /
/// latency-injected / panic-injected) under a multi-threaded submit
/// storm, then assert the router's liveness invariants.
pub fn run_infra(plan: &FaultPlan, cfg: &ChaosConfig) -> Result<InfraReport> {
    Ok(run_infra_with_metrics(plan, cfg)?.0)
}

/// [`run_infra`] plus the storm router's telemetry snapshot.
pub fn run_infra_with_metrics(
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(InfraReport, MetricsSnapshot)> {
    let _span = crate::util::trace::span("chaos.infra");
    let (submitters, requests) = plan.storm().unwrap_or((2, 48));
    let sizes = [4usize, 6, 3];
    let healthy = synthetic_engine(plan.seed.wrapping_add(101), &sizes, 4)?;
    let mut slow = synthetic_engine(plan.seed.wrapping_add(102), &sizes, 4)?;
    if let Some(d) = plan.slow_delay() {
        slow = slow.with_faults(Arc::new(FaultyExec::slow(d)));
    }
    let mut panicky = synthetic_engine(plan.seed.wrapping_add(103), &sizes, 4)?;
    if let Some(k) = plan.panic_after() {
        panicky = panicky.with_faults(Arc::new(FaultyExec::panicking(k)));
    }
    let router = Router::new(
        RouterConfig {
            workers: cfg.workers.max(2),
            kernel_threads: cfg.kernel_threads,
            ..Default::default()
        },
        vec![
            ("storm".into(), healthy),
            ("slow".into(), slow),
            ("panicky".into(), panicky),
        ],
    );

    let n_lanes = 3usize;
    let reqs: Vec<crate::coordinator::RequestId> = std::thread::scope(|s| {
        let router = &router;
        let mut handles = Vec::with_capacity(submitters);
        for t in 0..submitters {
            let quota = requests / submitters + usize::from(t < requests % submitters);
            handles.push(s.spawn(move || {
                let mut mine = Vec::with_capacity(quota);
                for i in 0..quota {
                    let lane = (t + i) % n_lanes;
                    let bump = 0.0625 * ((t + i) % 7) as f32;
                    let features = vec![0.25 + bump, -0.5, 0.125, 0.75 - bump];
                    if let Ok(id) = router.submit(lane, features) {
                        mine.push(id);
                    }
                }
                mine
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("submitter thread panicked"))
            .collect()
    });
    let submitted = reqs.len();

    let t0 = Instant::now();
    let drain_res = router.drain(Duration::from_secs(DRAIN_BOUND_SECS));
    let drain_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Worker failures surface through drain() as an error too — only the
    // timeout variant is a liveness breach.
    let drained_in_bound = match &drain_res {
        Ok(()) => true,
        Err(e) => !e.to_string().contains("drain timed out"),
    };

    let (mut answered, mut failed, mut stranded, mut double_delivery) = (0, 0, 0, 0);
    for &req in &reqs {
        match router.try_take(req) {
            Ok(Some(_)) => answered += 1,
            Ok(None) => stranded += 1,
            Err(_) => failed += 1,
        }
        // the same request must never be delivered a second time
        if let Ok(Some(_)) = router.try_take(req) {
            double_delivery += 1;
        }
    }
    let panic_observed = router
        .failures()
        .iter()
        .any(|m| m.contains("panicked"));
    let snapshot = router.metrics_snapshot("chaos.infra");
    router.shutdown();

    Ok((
        InfraReport {
            submitted,
            answered,
            failed,
            stranded,
            double_delivery,
            resolved_exactly_once: stranded == 0
                && double_delivery == 0
                && answered + failed == submitted,
            drained_in_bound,
            panic_observed,
            drain_ms,
        },
        snapshot,
    ))
}

/// Replay a plan end to end: both paper corners plus the infrastructure
/// campaign, composed into one report.
pub fn run_chaos(plan: &FaultPlan, cfg: &ChaosConfig) -> Result<ChaosReport> {
    Ok(run_chaos_with_metrics(plan, cfg)?.0)
}

/// [`run_chaos`] plus one telemetry snapshot per campaign stage (two
/// corners, then infra) — the `chaos --metrics-out` surface.  The
/// snapshots carry wall-clock latencies and are *not* part of the
/// deterministic [`ChaosReport::canonical_json`] replay contract.
pub fn run_chaos_with_metrics(
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> Result<(ChaosReport, Vec<MetricsSnapshot>)> {
    let _span = crate::util::trace::span("chaos.campaign");
    let net = chaos_net();
    let mut corners = Vec::with_capacity(2);
    let mut snapshots = Vec::with_capacity(3);
    for (node, regime) in chaos_corners() {
        let (corner, snap) = run_corner_with_metrics(node, regime, &net, plan, cfg)?;
        corners.push(corner);
        snapshots.push(snap);
    }
    let (infra, infra_snap) = run_infra_with_metrics(plan, cfg)?;
    snapshots.push(infra_snap);
    Ok((
        ChaosReport {
            plan: plan.clone(),
            corners,
            infra,
        },
        snapshots,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_net_is_a_margin_heavy_prototype_detector() {
        let net = chaos_net();
        assert_eq!(net.sizes, vec![8, 6, 3]);
        assert_eq!(net.weights[0].len(), 48);
        assert_eq!(net.weights[1].len(), 18);
        assert_eq!(net.activation, "relu");
        // detector k responds to prototype k with a positive pre-activation
        // and to the other prototypes with a negative one (software math)
        for (k, b) in [(0usize, -0.5f64), (1, -0.5), (2, -0.5)] {
            for (j, p) in PROTOS.iter().enumerate() {
                let pre: f64 = (0..8)
                    .map(|i| net.weights[0][i * 6 + k] * 0.75 * p[i])
                    .sum::<f64>()
                    + b;
                if j == k {
                    assert!(pre > 0.5, "detector {k} should fire on prototype {j}");
                } else {
                    assert!(pre < -0.2, "detector {k} should stay off prototype {j}");
                }
            }
        }
    }

    #[test]
    fn eval_features_are_seeded_and_classed() {
        let a = eval_features(7, 12);
        let b = eval_features(7, 12);
        let c = eval_features(8, 12);
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|row| row.len() == 8));
        assert_eq!(a, b, "same seed must replay identical rows");
        assert_ne!(a, c, "different seeds must differ");
        // rows stay near their prototype: sign pattern matches class r % 3
        for (r, row) in a.iter().enumerate() {
            let p = &PROTOS[r % 3];
            for (i, &v) in row.iter().enumerate() {
                assert_eq!(v.signum() as f64, p[i], "row {r} feature {i}");
                assert!(v.abs() > 0.5 && v.abs() < 1.0);
            }
        }
    }

    #[test]
    fn infra_report_serializes_only_deterministic_fields() {
        let r = InfraReport {
            submitted: 96,
            answered: 60,
            failed: 36,
            stranded: 0,
            double_delivery: 0,
            resolved_exactly_once: true,
            drained_in_bound: true,
            panic_observed: true,
            drain_ms: 12.5,
        };
        let s = r.to_json().to_string();
        assert!(s.contains("\"submitted\":96"));
        assert!(s.contains("\"resolved_exactly_once\":true"));
        assert!(!s.contains("answered"), "scheduling-dependent field leaked: {s}");
        assert!(!s.contains("drain_ms"), "timing field leaked: {s}");
    }

    #[test]
    fn violations_flag_envelope_and_invariant_breaches() {
        let plan = FaultPlan::new(1);
        let good = CornerReport {
            node: "cmos180".into(),
            regime: "WI".into(),
            trial_temp_c: vec![27.0],
            trial_agreement: vec![1.0],
            trial_logit_dev: vec![0.0],
            stuck_cells: vec![0],
            mean_agreement: 1.0,
            worst_agreement: 1.0,
        };
        let infra = InfraReport {
            submitted: 10,
            answered: 10,
            failed: 0,
            stranded: 0,
            double_delivery: 0,
            resolved_exactly_once: true,
            drained_in_bound: true,
            panic_observed: false,
            drain_ms: 1.0,
        };
        let report = ChaosReport {
            plan: plan.clone(),
            corners: vec![good.clone()],
            infra: infra.clone(),
        };
        assert!(report.pass(), "clean report must pass: {:?}", report.violations());

        let mut bad_corner = good.clone();
        bad_corner.mean_agreement = 0.5;
        bad_corner.worst_agreement = 0.2;
        let mut bad_infra = infra.clone();
        bad_infra.stranded = 1;
        bad_infra.resolved_exactly_once = false;
        bad_infra.drained_in_bound = false;
        let report = ChaosReport {
            plan,
            corners: vec![bad_corner],
            infra: bad_infra,
        };
        let v = report.violations();
        assert_eq!(v.len(), 4, "expected 4 violations, got {v:?}");
        assert!(!report.pass());
        let s = report.canonical_json();
        assert!(s.contains("\"pass\":false"));
        assert!(s.contains("\"violations\":["));
    }
}
