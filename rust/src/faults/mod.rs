//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded, JSON-serializable composition of
//! **analog** faults (Pelgrom mirror-gain mismatch, junction-temperature
//! drift, stuck multiplier-grid cells) and **infrastructure** faults
//! (engine panics, injected latency, submit storms).  The [`chaos`]
//! campaign runner replays a plan end to end — faulted [`crate::nn::batch::BatchKernel`]s
//! served through a [`crate::coordinator::Router`] at both paper corners,
//! plus a storm against fault-gated synthetic engines — and emits a
//! [`ChaosReport`] whose canonical serialization is a pure function of
//! the plan: identical seeds replay bit-identically.
//!
//! Layering: `faults` sits on top of `device`, `sac`, `nn`, `runtime`
//! and `coordinator`; nothing below depends on it.  The CLI `chaos`
//! subcommand and `tests/chaos.rs` are the consumers.

pub mod chaos;
pub mod drift;
pub mod plan;

pub use chaos::{
    chaos_corners, chaos_grid, chaos_net, eval_features, recovery_probe_rows, run_chaos,
    run_chaos_with_metrics, run_corner, run_corner_with_metrics, run_infra,
    run_infra_with_metrics, run_recovery, run_recovery_with_metrics, ChaosConfig, ChaosReport,
    CornerReport, EnvelopeViolation, InfraReport, RecoveryReport, DRAIN_BOUND_SECS,
    MEAN_DEGRADATION_ENVELOPE, RECOVERY_BOUND_SECS, WORST_DEGRADATION_ENVELOPE,
};
pub use drift::{
    stage_for_progress, temperature_schedule, DriftingHProvider, MismatchedProvider,
};
pub use plan::{AnalogFault, DriftKind, FaultPlan, InfraFault, PlanError};
