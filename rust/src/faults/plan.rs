//! Seeded, serializable fault plans.
//!
//! A [`FaultPlan`] is the unit of chaos replay: one seed plus a list of
//! analog faults (Pelgrom mismatch, temperature drift, stuck lookup cells)
//! and infrastructure faults (engine panics, latency injection, submit
//! storms).  Plans round-trip through `util::json` so a failing CI run can
//! upload its plan and any machine can replay it bit-identically
//! (`sac chaos --plan plan.json`).  DESIGN.md §8 documents the schema.
//!
//! The seed is stored as a JSON number; keep seeds below 2^53 so the f64
//! round-trip is lossless (the harness's defaults are small integers).

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

/// Typed fault-plan validation error.  Carried inside `anyhow` so the
/// CLI can distinguish a malformed plan (exit 2, like any IO/parse
/// error) from an envelope violation (exit 1) by downcasting.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanError(pub String);

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid fault plan: {}", self.0)
    }
}

impl std::error::Error for PlanError {}

fn plan_err<T>(msg: String) -> Result<T> {
    Err(PlanError(msg).into())
}

/// Read a non-negative integer field, rejecting NaN / infinite /
/// negative / fractional values *before* the f64 → integer cast (which
/// would silently saturate them).
fn plan_uint(j: &Json, key: &str) -> Result<u64> {
    let v = j.get(key)?.as_f64()?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v >= 9_007_199_254_740_992.0 {
        return plan_err(format!("field {key:?} must be a non-negative integer, got {v}"));
    }
    Ok(v as u64)
}

/// Temperature drift trajectory shape (Sec. VI's temperature sweeps).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftKind {
    /// linear ramp `from_c → to_c` over the run
    Ramp,
    /// abrupt step: first half at `from_c`, second half at `to_c`
    Step,
}

impl DriftKind {
    pub fn name(self) -> &'static str {
        match self {
            DriftKind::Ramp => "ramp",
            DriftKind::Step => "step",
        }
    }

    pub fn parse(s: &str) -> Result<DriftKind> {
        match s {
            "ramp" => Ok(DriftKind::Ramp),
            "step" => Ok(DriftKind::Step),
            other => bail!("unknown drift kind {other:?} (expected \"ramp\" or \"step\")"),
        }
    }
}

/// Faults acting on the analog substrate an engine computes with.
#[derive(Clone, Debug, PartialEq)]
pub enum AnalogFault {
    /// Pelgrom mismatch on the input mirrors, sigmas scaled by
    /// `sigma_scale` (1.0 = paper-calibrated A_VT / A_β).
    Mismatch { sigma_scale: f64 },
    /// Temperature drift applied over the run, quantized to `steps`
    /// stages (each stage re-solves the corner's cell tables).
    TempDrift {
        kind: DriftKind,
        from_c: f64,
        to_c: f64,
        steps: usize,
    },
    /// Stuck-at storage cells in the multiplier lookup grid: a `fraction`
    /// of samples forced to `value` (0.0 = dead cell).
    StuckCells { fraction: f64, value: f64 },
}

/// Faults acting on the serving infrastructure around the engines.
#[derive(Clone, Debug, PartialEq)]
pub enum InfraFault {
    /// One router lane's engine panics on every batch past `after_batches`.
    EnginePanic { after_batches: u64 },
    /// One router lane's engine sleeps `delay_us` before every batch.
    SlowEngine { delay_us: u64 },
    /// Concurrent submit storm: `submitters` threads pushing `requests`
    /// requests total, round-robin across all lanes.
    SubmitStorm { submitters: usize, requests: usize },
}

/// One replayable chaos scenario (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub analog: Vec<AnalogFault>,
    pub infra: Vec<InfraFault>,
}

impl AnalogFault {
    fn to_json(&self) -> Json {
        match self {
            AnalogFault::Mismatch { sigma_scale } => Json::obj(vec![
                ("kind", Json::Str("mismatch".into())),
                ("sigma_scale", Json::Num(*sigma_scale)),
            ]),
            AnalogFault::TempDrift {
                kind,
                from_c,
                to_c,
                steps,
            } => Json::obj(vec![
                ("kind", Json::Str("temp_drift".into())),
                ("drift", Json::Str(kind.name().into())),
                ("from_c", Json::Num(*from_c)),
                ("to_c", Json::Num(*to_c)),
                ("steps", Json::Num(*steps as f64)),
            ]),
            AnalogFault::StuckCells { fraction, value } => Json::obj(vec![
                ("kind", Json::Str("stuck_cells".into())),
                ("fraction", Json::Num(*fraction)),
                ("value", Json::Num(*value)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<AnalogFault> {
        match j.get("kind")?.as_str()? {
            "mismatch" => Ok(AnalogFault::Mismatch {
                sigma_scale: j.get("sigma_scale")?.as_f64()?,
            }),
            "temp_drift" => Ok(AnalogFault::TempDrift {
                kind: DriftKind::parse(j.get("drift")?.as_str()?)?,
                from_c: j.get("from_c")?.as_f64()?,
                to_c: j.get("to_c")?.as_f64()?,
                steps: plan_uint(j, "steps")? as usize,
            }),
            "stuck_cells" => Ok(AnalogFault::StuckCells {
                fraction: j.get("fraction")?.as_f64()?,
                value: j.get("value")?.as_f64()?,
            }),
            other => Err(anyhow!("unknown analog fault kind {other:?}")),
        }
    }
}

impl InfraFault {
    fn to_json(&self) -> Json {
        match self {
            InfraFault::EnginePanic { after_batches } => Json::obj(vec![
                ("kind", Json::Str("engine_panic".into())),
                ("after_batches", Json::Num(*after_batches as f64)),
            ]),
            InfraFault::SlowEngine { delay_us } => Json::obj(vec![
                ("kind", Json::Str("slow_engine".into())),
                ("delay_us", Json::Num(*delay_us as f64)),
            ]),
            InfraFault::SubmitStorm {
                submitters,
                requests,
            } => Json::obj(vec![
                ("kind", Json::Str("submit_storm".into())),
                ("submitters", Json::Num(*submitters as f64)),
                ("requests", Json::Num(*requests as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<InfraFault> {
        match j.get("kind")?.as_str()? {
            "engine_panic" => Ok(InfraFault::EnginePanic {
                after_batches: plan_uint(j, "after_batches")?,
            }),
            "slow_engine" => Ok(InfraFault::SlowEngine {
                delay_us: plan_uint(j, "delay_us")?,
            }),
            "submit_storm" => Ok(InfraFault::SubmitStorm {
                submitters: plan_uint(j, "submitters")? as usize,
                requests: plan_uint(j, "requests")? as usize,
            }),
            other => Err(anyhow!("unknown infra fault kind {other:?}")),
        }
    }
}

impl FaultPlan {
    /// Empty plan (no faults) with the given seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            analog: Vec::new(),
            infra: Vec::new(),
        }
    }

    /// The default chaos scenario the CI smoke job and the chaos suite
    /// replay: paper-calibrated mismatch, a 27→60 °C ramp in four stages,
    /// a sprinkle of dead lookup cells, plus a panicking lane, a slow
    /// lane, and a concurrent submit storm.
    pub fn default_plan(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            analog: vec![
                AnalogFault::Mismatch { sigma_scale: 1.0 },
                AnalogFault::TempDrift {
                    kind: DriftKind::Ramp,
                    from_c: 27.0,
                    to_c: 60.0,
                    steps: 4,
                },
                AnalogFault::StuckCells {
                    fraction: 0.003,
                    value: 0.0,
                },
            ],
            infra: vec![
                InfraFault::EnginePanic { after_batches: 3 },
                InfraFault::SlowEngine { delay_us: 1500 },
                InfraFault::SubmitStorm {
                    submitters: 4,
                    requests: 96,
                },
            ],
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            (
                "analog",
                Json::Arr(self.analog.iter().map(|f| f.to_json()).collect()),
            ),
            (
                "infra",
                Json::Arr(self.infra.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let plan = FaultPlan {
            seed: plan_uint(j, "seed")?,
            analog: j
                .get("analog")?
                .as_arr()?
                .iter()
                .map(AnalogFault::from_json)
                .collect::<Result<_>>()?,
            infra: j
                .get("infra")?
                .as_arr()?
                .iter()
                .map(InfraFault::from_json)
                .collect::<Result<_>>()?,
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Reject physically meaningless or replay-ambiguous plans with a
    /// typed [`PlanError`].  Runs on every load/parse; callers building
    /// plans programmatically can invoke it directly.
    pub fn validate(&self) -> Result<()> {
        let mut seen_analog = [0u32; 3];
        for f in &self.analog {
            match f {
                AnalogFault::Mismatch { sigma_scale } => {
                    seen_analog[0] += 1;
                    if !sigma_scale.is_finite() || *sigma_scale < 0.0 {
                        return plan_err(format!(
                            "mismatch sigma_scale must be finite and >= 0, got {sigma_scale}"
                        ));
                    }
                }
                AnalogFault::TempDrift {
                    from_c, to_c, steps, ..
                } => {
                    seen_analog[1] += 1;
                    if !from_c.is_finite() || !to_c.is_finite() {
                        return plan_err(format!(
                            "temp_drift temperatures must be finite, got {from_c} -> {to_c}"
                        ));
                    }
                    if *steps == 0 {
                        return plan_err("temp_drift needs at least one step".into());
                    }
                }
                AnalogFault::StuckCells { fraction, value } => {
                    seen_analog[2] += 1;
                    if !(0.0..=1.0).contains(fraction) {
                        return plan_err(format!(
                            "stuck_cells fraction must be in [0, 1], got {fraction}"
                        ));
                    }
                    if !value.is_finite() {
                        return plan_err(format!("stuck_cells value must be finite, got {value}"));
                    }
                }
            }
        }
        // The accessors (`drift()`, `sigma_scale()`, …) read the *first*
        // fault of each kind: duplicates would make the replayed schedule
        // order-ambiguous, so an out-of-order/duplicated schedule is an
        // error, not a silent pick.
        if seen_analog.iter().any(|&n| n > 1) {
            return plan_err(
                "duplicate analog faults of the same kind make the schedule ambiguous".into(),
            );
        }
        let mut seen_infra = [0u32; 3];
        for f in &self.infra {
            match f {
                InfraFault::EnginePanic { .. } => seen_infra[0] += 1,
                InfraFault::SlowEngine { .. } => seen_infra[1] += 1,
                InfraFault::SubmitStorm { submitters, .. } => {
                    seen_infra[2] += 1;
                    if *submitters == 0 {
                        return plan_err("submit_storm needs at least one submitter".into());
                    }
                }
            }
        }
        if seen_infra.iter().any(|&n| n > 1) {
            return plan_err(
                "duplicate infra faults of the same kind make the schedule ambiguous".into(),
            );
        }
        Ok(())
    }

    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        FaultPlan::from_json(&json::parse(text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<FaultPlan> {
        FaultPlan::from_json(&json::parse_file(path)?)
    }

    /// Mismatch sigma scale; 0.0 when the plan injects no mismatch.
    pub fn sigma_scale(&self) -> f64 {
        self.analog
            .iter()
            .find_map(|f| match f {
                AnalogFault::Mismatch { sigma_scale } => Some(*sigma_scale),
                _ => None,
            })
            .unwrap_or(0.0)
    }

    /// The drift trajectory, if any.
    pub fn drift(&self) -> Option<(DriftKind, f64, f64, usize)> {
        self.analog.iter().find_map(|f| match f {
            AnalogFault::TempDrift {
                kind,
                from_c,
                to_c,
                steps,
            } => Some((*kind, *from_c, *to_c, (*steps).max(1))),
            _ => None,
        })
    }

    /// Stuck-cell injection `(fraction, value)`, if any.
    pub fn stuck(&self) -> Option<(f64, f64)> {
        self.analog.iter().find_map(|f| match f {
            AnalogFault::StuckCells { fraction, value } => Some((*fraction, *value)),
            _ => None,
        })
    }

    /// Panic trigger for the panicking lane, if any.
    pub fn panic_after(&self) -> Option<u64> {
        self.infra.iter().find_map(|f| match f {
            InfraFault::EnginePanic { after_batches } => Some(*after_batches),
            _ => None,
        })
    }

    /// Latency injection for the slow lane, if any.
    pub fn slow_delay(&self) -> Option<std::time::Duration> {
        self.infra.iter().find_map(|f| match f {
            InfraFault::SlowEngine { delay_us } => {
                Some(std::time::Duration::from_micros(*delay_us))
            }
            _ => None,
        })
    }

    /// Submit-storm shape `(submitters, total requests)`, if any.
    pub fn storm(&self) -> Option<(usize, usize)> {
        self.infra.iter().find_map(|f| match f {
            InfraFault::SubmitStorm {
                submitters,
                requests,
            } => Some(((*submitters).max(1), *requests)),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_roundtrips_through_json_text() {
        let plan = FaultPlan::default_plan(20260808);
        let text = plan.to_json().to_string();
        let back = FaultPlan::parse(&text).unwrap();
        assert_eq!(back, plan);
        // canonical (BTreeMap-sorted) serialization is stable
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn accessors_reflect_faults() {
        let plan = FaultPlan::default_plan(1);
        assert_eq!(plan.sigma_scale(), 1.0);
        let (kind, from_c, to_c, steps) = plan.drift().unwrap();
        assert_eq!(kind, DriftKind::Ramp);
        assert_eq!((from_c, to_c, steps), (27.0, 60.0, 4));
        assert_eq!(plan.stuck().unwrap(), (0.003, 0.0));
        assert_eq!(plan.panic_after(), Some(3));
        assert_eq!(plan.slow_delay(), Some(std::time::Duration::from_micros(1500)));
        assert_eq!(plan.storm(), Some((4, 96)));

        let empty = FaultPlan::new(2);
        assert_eq!(empty.sigma_scale(), 0.0);
        assert!(empty.drift().is_none());
        assert!(empty.stuck().is_none());
        assert!(empty.panic_after().is_none());
        assert!(empty.slow_delay().is_none());
        assert!(empty.storm().is_none());
    }

    #[test]
    fn unknown_fault_kinds_rejected() {
        assert!(FaultPlan::parse(
            r#"{"seed": 1, "analog": [{"kind": "gamma_ray"}], "infra": []}"#
        )
        .is_err());
        assert!(FaultPlan::parse(
            r#"{"seed": 1, "analog": [], "infra": [{"kind": "meteor"}]}"#
        )
        .is_err());
        assert!(DriftKind::parse("sawtooth").is_err());
    }

    #[test]
    fn malformed_plans_are_rejected_with_a_typed_error() {
        // NaN latency duration
        let e = FaultPlan::parse(
            r#"{"seed": 1, "analog": [], "infra": [{"kind": "slow_engine", "delay_us": NaN}]}"#,
        );
        assert!(e.is_err());
        // negative latency duration
        let e = FaultPlan::parse(
            r#"{"seed": 1, "analog": [], "infra": [{"kind": "slow_engine", "delay_us": -5}]}"#,
        )
        .unwrap_err();
        assert!(
            e.downcast_ref::<PlanError>().is_some(),
            "expected a typed PlanError, got: {e:#}"
        );
        // fractional batch ordinal
        assert!(FaultPlan::parse(
            r#"{"seed": 1, "analog": [], "infra": [{"kind": "engine_panic", "after_batches": 2.5}]}"#
        )
        .is_err());
        // zero-step drift schedule
        let e = FaultPlan::parse(
            r#"{"seed": 1, "analog": [{"kind": "temp_drift", "drift": "ramp",
                 "from_c": 27.0, "to_c": 60.0, "steps": 0}], "infra": []}"#,
        )
        .unwrap_err();
        assert!(e.downcast_ref::<PlanError>().is_some(), "{e:#}");
        // out-of-order (duplicated) drift schedule
        let e = FaultPlan::parse(
            r#"{"seed": 1, "analog": [
                 {"kind": "temp_drift", "drift": "ramp", "from_c": 27.0, "to_c": 60.0, "steps": 2},
                 {"kind": "temp_drift", "drift": "step", "from_c": 60.0, "to_c": 27.0, "steps": 2}
               ], "infra": []}"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("ambiguous"), "{e:#}");
        // stuck fraction outside [0, 1]
        assert!(FaultPlan::parse(
            r#"{"seed": 1, "analog": [{"kind": "stuck_cells", "fraction": 1.5, "value": 0.0}], "infra": []}"#
        )
        .is_err());
        // negative mismatch sigma via validate() on a built plan
        let bad = FaultPlan {
            seed: 1,
            analog: vec![AnalogFault::Mismatch { sigma_scale: -1.0 }],
            infra: vec![],
        };
        let e = bad.validate().unwrap_err();
        assert!(e.downcast_ref::<PlanError>().is_some());
        assert!(e.to_string().starts_with("invalid fault plan:"));
        // the default plan is, of course, valid
        FaultPlan::default_plan(7).validate().unwrap();
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("sac_fault_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let plan = FaultPlan::default_plan(42);
        plan.save(&path).unwrap();
        assert_eq!(FaultPlan::load(&path).unwrap(), plan);
    }
}
