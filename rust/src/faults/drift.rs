//! Fault-injecting [`HProvider`] wrappers.
//!
//! Analog faults enter the serving stack *through the backend*, not by
//! perturbing logits after the fact:
//!
//! * [`MismatchedProvider`] applies per-branch input-mirror gains —
//!   sampled from the Pelgrom model via
//!   [`crate::device::MismatchModel::mirror_gain`] — before delegating to
//!   the wrapped backend, the same input-current scaling
//!   `cells::CircuitCorner` applies for its device-exact mismatch tier.
//! * [`DriftingHProvider`] swaps between per-temperature backends as the
//!   run progresses, modeling a junction-temperature ramp or step *during*
//!   serving.  Its live mode advances an atomic call counter; the chaos
//!   harness instead pins each trial to a schedule stage via
//!   [`temperature_schedule`] so concurrent scheduling cannot perturb the
//!   replayed report.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::cells::HProvider;

use super::plan::DriftKind;

/// The junction-temperature value at each schedule stage.
///
/// `Ramp` interpolates `from_c → to_c` linearly over `steps` stages;
/// `Step` holds `from_c` for the first half and `to_c` for the second.
pub fn temperature_schedule(kind: DriftKind, from_c: f64, to_c: f64, steps: usize) -> Vec<f64> {
    let steps = steps.max(1);
    match kind {
        DriftKind::Ramp => (0..steps)
            .map(|i| {
                if steps == 1 {
                    from_c
                } else {
                    from_c + (to_c - from_c) * i as f64 / (steps - 1) as f64
                }
            })
            .collect(),
        DriftKind::Step => (0..steps)
            .map(|i| if i < steps.div_ceil(2) { from_c } else { to_c })
            .collect(),
    }
}

/// Schedule stage for a trial at `progress ∈ [0, 1]` (deterministic — the
/// replay-safe alternative to the live call counter).
pub fn stage_for_progress(progress: f64, steps: usize) -> usize {
    let steps = steps.max(1);
    ((progress.clamp(0.0, 1.0) * steps as f64) as usize).min(steps - 1)
}

/// Input-mirror mismatch wrapper: input `i` is scaled by
/// `gains[i % gains.len()]` before the wrapped backend solves.  Empty
/// `gains` is an exact passthrough.
pub struct MismatchedProvider {
    inner: Box<dyn HProvider + Send + Sync>,
    gains: Vec<f64>,
}

impl MismatchedProvider {
    pub fn new(inner: Box<dyn HProvider + Send + Sync>, gains: Vec<f64>) -> MismatchedProvider {
        MismatchedProvider { inner, gains }
    }

    pub fn gains(&self) -> &[f64] {
        &self.gains
    }
}

impl HProvider for MismatchedProvider {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        if self.gains.is_empty() {
            return self.inner.h(x, c);
        }
        let xg: Vec<f64> = x
            .iter()
            .enumerate()
            .map(|(i, &v)| v * self.gains[i % self.gains.len()])
            .collect();
        self.inner.h(&xg, c)
    }

    fn label(&self) -> String {
        format!("mismatched({})", self.inner.label())
    }
}

/// Mid-run temperature drift: a sequence of per-temperature backends, the
/// active one advancing every `calls_per_stage` solver calls.
pub struct DriftingHProvider {
    stages: Vec<(f64, Box<dyn HProvider + Send + Sync>)>,
    calls_per_stage: u64,
    calls: AtomicU64,
}

impl DriftingHProvider {
    /// `stages` pairs each junction temperature with the backend solved at
    /// that temperature; the last stage holds once reached.
    pub fn new(
        stages: Vec<(f64, Box<dyn HProvider + Send + Sync>)>,
        calls_per_stage: u64,
    ) -> DriftingHProvider {
        assert!(!stages.is_empty(), "drift needs at least one stage");
        DriftingHProvider {
            stages,
            calls_per_stage: calls_per_stage.max(1),
            calls: AtomicU64::new(0),
        }
    }

    /// Temperatures in stage order.
    pub fn temperatures(&self) -> Vec<f64> {
        self.stages.iter().map(|(t, _)| *t).collect()
    }

    /// Solver calls observed so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    fn stage_at(&self, call: u64) -> usize {
        ((call / self.calls_per_stage) as usize).min(self.stages.len() - 1)
    }

    /// Stage index the *next* call will solve in.
    pub fn current_stage(&self) -> usize {
        self.stage_at(self.calls())
    }
}

impl HProvider for DriftingHProvider {
    fn h(&self, x: &[f64], c: f64) -> f64 {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        let (_, backend) = &self.stages[self.stage_at(n)];
        backend.h(x, c)
    }

    fn label(&self) -> String {
        let temps: Vec<String> = self.stages.iter().map(|(t, _)| format!("{t}")).collect();
        format!("drifting[{}]", temps.join("→"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;

    /// Trivial backend returning a constant (stage identification).
    struct Const(f64);

    impl HProvider for Const {
        fn h(&self, _x: &[f64], _c: f64) -> f64 {
            self.0
        }

        fn label(&self) -> String {
            format!("const{}", self.0)
        }
    }

    #[test]
    fn ramp_schedule_hits_endpoints_linearly() {
        let t = temperature_schedule(DriftKind::Ramp, 27.0, 60.0, 4);
        assert_eq!(t.len(), 4);
        assert_eq!(t[0], 27.0);
        assert_eq!(t[3], 60.0);
        assert!(t.windows(2).all(|w| w[1] > w[0]));
        assert_eq!(temperature_schedule(DriftKind::Ramp, 27.0, 60.0, 1), vec![27.0]);
        // degenerate steps=0 clamps to one stage
        assert_eq!(temperature_schedule(DriftKind::Ramp, 27.0, 60.0, 0), vec![27.0]);
    }

    #[test]
    fn step_schedule_splits_halves() {
        let t = temperature_schedule(DriftKind::Step, 27.0, 100.0, 4);
        assert_eq!(t, vec![27.0, 27.0, 100.0, 100.0]);
        let t5 = temperature_schedule(DriftKind::Step, 27.0, 100.0, 5);
        assert_eq!(t5, vec![27.0, 27.0, 27.0, 100.0, 100.0]);
    }

    #[test]
    fn stage_for_progress_covers_range() {
        assert_eq!(stage_for_progress(0.0, 4), 0);
        assert_eq!(stage_for_progress(0.49, 4), 1);
        assert_eq!(stage_for_progress(1.0, 4), 3);
        assert_eq!(stage_for_progress(2.0, 4), 3); // clamped
        assert_eq!(stage_for_progress(0.5, 1), 0);
    }

    #[test]
    fn unit_gains_are_exact_passthrough() {
        let inner = Algorithmic::relu();
        let wrapped = MismatchedProvider::new(Box::new(Algorithmic::relu()), vec![]);
        let unit = MismatchedProvider::new(Box::new(Algorithmic::relu()), vec![1.0; 4]);
        let x = [0.7, -0.3, 1.1];
        assert_eq!(wrapped.h(&x, 1.0), inner.h(&x, 1.0));
        assert_eq!(unit.h(&x, 1.0), inner.h(&x, 1.0));
        assert!(wrapped.label().contains("mismatched"));
    }

    #[test]
    fn nonunit_gains_perturb_the_solve() {
        let inner = Algorithmic::relu();
        let skew = MismatchedProvider::new(Box::new(Algorithmic::relu()), vec![1.05, 0.95]);
        assert_eq!(skew.gains().len(), 2);
        let x = [0.7, -0.3, 1.1];
        let nominal = inner.h(&x, 1.0);
        let shifted = skew.h(&x, 1.0);
        assert_ne!(shifted, nominal);
        // a 5% input skew moves the solution by O(percent), not wildly
        assert!((shifted - nominal).abs() < 0.2 * nominal.abs().max(1.0));
    }

    #[test]
    fn drifting_provider_switches_stage_mid_run() {
        let p = DriftingHProvider::new(
            vec![
                (27.0, Box::new(Const(1.0))),
                (60.0, Box::new(Const(2.0))),
                (100.0, Box::new(Const(3.0))),
            ],
            5,
        );
        assert_eq!(p.temperatures(), vec![27.0, 60.0, 100.0]);
        let mut seen = Vec::new();
        for _ in 0..17 {
            seen.push(p.h(&[0.0], 1.0));
        }
        assert_eq!(&seen[..5], &[1.0; 5]);
        assert_eq!(&seen[5..10], &[2.0; 5]);
        // last stage holds past the end of the schedule
        assert_eq!(&seen[10..], &[3.0; 7]);
        assert_eq!(p.calls(), 17);
        assert_eq!(p.current_stage(), 2);
        assert!(p.label().contains("27") && p.label().contains("100"));
    }
}
