//! Infrastructure fault injection for executables.
//!
//! A [`FaultyExec`] composes with any [`crate::runtime::Executable`] via
//! `Executable::with_faults`: before each batch execution it can sleep
//! (latency injection ahead of the router's deadline flusher), panic
//! (worker-pool crash path — the router's `catch_unwind` must convert it
//! into per-request failures, never a deadlock), or return an error
//! (clean engine failure).  The call counter is shared across clones
//! (`Arc` field on the executable), so a router lane's workers observe one
//! global batch count — "panic after K batches" means K *total*, not K per
//! worker.
//!
//! All triggers are deterministic functions of the batch ordinal; the only
//! scheduling dependence is which *requests* land in the failing batches,
//! which is why the chaos report's canonical form aggregates per-request
//! outcomes into order-independent invariants (see `faults::chaos`).
//!
//! The gate sits *above* the kernel, so it is orthogonal to intra-batch
//! row parallelism: a panic injected here unwinds out of the engine on
//! the router worker before any slab is dispatched, and a batch that does
//! reach the sharded kernel completes (or panics) identically at any
//! `kernel_threads` setting — the chaos suite runs both ways.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Result};

/// Deterministic per-batch fault trigger (see module docs).
#[derive(Debug, Default)]
pub struct FaultyExec {
    /// sleep this long before every batch (latency injection)
    delay: Option<Duration>,
    /// panic on batch ordinals `>= k` (0 = every batch panics)
    panic_after: Option<u64>,
    /// upper bound on the panic window: ordinals `>= u` run clean again
    /// (`None` = panic forever once triggered)
    panic_until: Option<u64>,
    /// return an error on batch ordinals `>= k`
    fail_after: Option<u64>,
    /// batches started so far (shared across executable clones)
    calls: AtomicU64,
}

impl FaultyExec {
    /// Pure latency injection: every batch sleeps `delay` first.
    pub fn slow(delay: Duration) -> FaultyExec {
        FaultyExec {
            delay: Some(delay),
            ..FaultyExec::default()
        }
    }

    /// Panic on every batch once `after` batches have run.
    pub fn panicking(after: u64) -> FaultyExec {
        FaultyExec {
            panic_after: Some(after),
            ..FaultyExec::default()
        }
    }

    /// Panic on batch ordinals in `[after, after + count)` only — a
    /// *transient* panic window.  The self-healing router's retry path is
    /// exercised with this trigger: the retry's re-run lands past the
    /// window and succeeds.
    pub fn panicking_window(after: u64, count: u64) -> FaultyExec {
        FaultyExec {
            panic_after: Some(after),
            panic_until: Some(after.saturating_add(count)),
            ..FaultyExec::default()
        }
    }

    /// Return a clean error on every batch once `after` batches have run.
    pub fn failing(after: u64) -> FaultyExec {
        FaultyExec {
            fail_after: Some(after),
            ..FaultyExec::default()
        }
    }

    /// Add latency injection to an existing trigger.
    pub fn with_delay(mut self, delay: Duration) -> FaultyExec {
        self.delay = Some(delay);
        self
    }

    /// Batches started so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    /// Fault gate, invoked by `Executable::run_f32_rows` ahead of the real
    /// execution.  Returns `Ok(())` when the batch should proceed.
    pub fn before_run(&self) -> Result<()> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if let Some(d) = self.delay {
            std::thread::sleep(d);
        }
        if let Some(k) = self.panic_after {
            if n >= k && self.panic_until.is_none_or(|u| n < u) {
                panic!("fault injection: engine panic on batch {n} (trigger: after {k})");
            }
        }
        if let Some(k) = self.fail_after {
            if n >= k {
                bail!("fault injection: engine failure on batch {n} (trigger: after {k})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_transparent() {
        let f = FaultyExec::default();
        for _ in 0..10 {
            f.before_run().unwrap();
        }
        assert_eq!(f.calls(), 10);
    }

    #[test]
    fn fail_after_triggers_on_exact_ordinal() {
        let f = FaultyExec::failing(2);
        assert!(f.before_run().is_ok());
        assert!(f.before_run().is_ok());
        assert!(f.before_run().is_err());
        assert!(f.before_run().is_err());
        assert_eq!(f.calls(), 4);
    }

    #[test]
    fn panic_after_zero_panics_immediately() {
        let f = FaultyExec::panicking(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_run()));
        assert!(r.is_err());
    }

    #[test]
    fn panic_window_is_transient() {
        let f = FaultyExec::panicking_window(2, 2);
        // ordinals 0,1 clean — 2,3 panic — 4.. clean again
        assert!(f.before_run().is_ok());
        assert!(f.before_run().is_ok());
        for _ in 0..2 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_run()));
            assert!(r.is_err(), "ordinal inside the window must panic");
        }
        assert!(f.before_run().is_ok(), "past the window runs clean");
        assert!(f.before_run().is_ok());
        assert_eq!(f.calls(), 6);
    }

    #[test]
    fn delay_injects_latency() {
        let f = FaultyExec::slow(Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        f.before_run().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }
}
