//! Artifact runtime: load the AOT-compiled artifact manifest and execute
//! its entries on the request path.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing each
//! compiled graph (parameter order, shapes, dtypes, and the S-AC metadata —
//! sizes/splines/C/activation).  This module loads that manifest and builds
//! a [`native::NativeExec`] per entry: a self-contained, dependency-free
//! interpreter that computes the same math as the lowered graph (see
//! `runtime/native.rs` and DESIGN.md §"Runtime" for the contract).  Python
//! never runs on the request path; the process is self-contained once
//! `artifacts/` exists.
//!
//! Executables can also be constructed *without* artifacts via
//! [`Executable::native_mlp`] — that is what the serving router's tests and
//! the `bench-serve` subcommand use, so the coordinator is exercisable on a
//! clean checkout.

pub mod artifact;
pub mod faulty;
pub mod native;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

pub use artifact::{EntrySpec, Manifest, ParamSpec};
pub use faulty::FaultyExec;
pub use native::{ExecMode, Graph, MlpSpec, NativeExec};

use crate::data::TrainedNet;
use crate::nn::batch::BatchKernel;
use crate::util::json::Json;

/// A loaded, ready-to-execute artifact entry.
#[derive(Clone, Debug)]
pub struct Executable {
    pub name: String,
    pub spec: EntrySpec,
    exec: NativeExec,
    /// Optional fault-injection gate, shared across clones so "after K
    /// batches" counts globally over all router workers.
    faults: Option<Arc<FaultyExec>>,
}

/// The runtime: the artifact directory plus its parsed manifest.
#[derive(Clone, Debug)]
pub struct Runtime {
    pub artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Read the artifact manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .with_context(|| "run `make artifacts` first")?;
        Ok(Runtime {
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
        })
    }

    /// Backend identifier (kept for CLI/report compatibility).
    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Build the executor for one manifest entry (scalar mode).
    pub fn load(&self, entry: &str) -> Result<Executable> {
        self.load_with_mode(entry, ExecMode::Scalar)
    }

    /// Build the executor for one manifest entry in the given execution
    /// mode (`--engine` on the CLI).  GMP-kernel entries ignore the mode.
    pub fn load_with_mode(&self, entry: &str, mode: ExecMode) -> Result<Executable> {
        let spec = self
            .manifest
            .entries
            .get(entry)
            .ok_or_else(|| anyhow!("no artifact entry {entry:?} in manifest"))?
            .clone();
        let exec = exec_from_spec(entry, &spec, mode)?;
        Ok(Executable {
            name: entry.to_string(),
            spec,
            exec,
            faults: None,
        })
    }
}

/// Derive the native executor from a manifest entry's shapes + metadata.
///
/// Cross-validates the meta `sizes` against every parameter shape so an
/// inconsistent manifest (version skew with `aot.py`, hand edits) fails
/// here with a clean error instead of panicking inside a worker later.
fn exec_from_spec(name: &str, spec: &EntrySpec, mode: ExecMode) -> Result<NativeExec> {
    if let Ok(sizes_j) = spec.meta.get("sizes") {
        // S-AC MLP graph: params are w1,b1,…,wL,bL,x (see aot.py).
        let sizes: Vec<usize> = sizes_j
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        if sizes.len() < 2 {
            return Err(anyhow!("{name}: sizes needs at least [in, out]"));
        }
        let nl = sizes.len() - 1;
        if spec.params.len() != 2 * nl + 1 {
            return Err(anyhow!(
                "{name}: {} params in manifest, but sizes {:?} implies {}",
                spec.params.len(),
                sizes,
                2 * nl + 1
            ));
        }
        for li in 0..nl {
            let w = &spec.params[2 * li];
            if w.shape != [sizes[li], sizes[li + 1]] {
                return Err(anyhow!(
                    "{name}: param {} shape {:?} != sizes-implied [{}, {}]",
                    w.name,
                    w.shape,
                    sizes[li],
                    sizes[li + 1]
                ));
            }
            let b = &spec.params[2 * li + 1];
            if b.shape != [sizes[li + 1]] {
                return Err(anyhow!(
                    "{name}: param {} shape {:?} != sizes-implied [{}]",
                    b.name,
                    b.shape,
                    sizes[li + 1]
                ));
            }
        }
        let xspec = spec.params.last().unwrap();
        if xspec.shape.len() != 2 || xspec.shape[1] != sizes[0] {
            return Err(anyhow!(
                "{name}: input param shape {:?} != [batch, {}]",
                xspec.shape,
                sizes[0]
            ));
        }
        NativeExec::mlp_with_mode(
            MlpSpec {
                sizes,
                splines: spec.meta.get("splines")?.as_usize()?,
                c: spec.meta.get("c")?.as_f64()?,
                activation: spec.meta.get("activation")?.as_str()?.to_string(),
                batch: xspec.shape[0],
            },
            mode,
        )
    } else if spec.params.len() == 1 && spec.params[0].shape.len() == 2 {
        // Batched GMP kernel: a single [B × M] input and a `c` constant.
        let c = spec.meta.get("c")?.as_f64()?;
        Ok(NativeExec::gmp(
            spec.params[0].shape[0],
            spec.params[0].shape[1],
            c,
        ))
    } else {
        Err(anyhow!("{name}: unrecognized artifact entry shape"))
    }
}

impl Executable {
    /// Build an MLP executable directly from trained weights, without any
    /// artifact directory — the in-memory path used by the router tests,
    /// `bench-serve`, and synthetic workloads (scalar mode).
    pub fn native_mlp(net: &TrainedNet, batch: usize) -> Result<Executable> {
        Executable::native_mlp_with_mode(net, batch, ExecMode::Scalar)
    }

    /// [`Executable::native_mlp`] in the given execution mode.
    pub fn native_mlp_with_mode(
        net: &TrainedNet,
        batch: usize,
        mode: ExecMode,
    ) -> Result<Executable> {
        let exec = NativeExec::mlp_with_mode(mlp_spec(net, batch), mode)?;
        Ok(Executable {
            name: format!("{}_mlp", net.task),
            spec: mlp_entry_spec(net, batch),
            exec,
            faults: None,
        })
    }

    /// [`Executable::native_mlp`] driven by a caller-supplied batched
    /// kernel (corner backends, fault-injected grids) — the fault
    /// harness's analog-corner path.
    pub fn native_mlp_with_kernel(
        net: &TrainedNet,
        batch: usize,
        kernel: Arc<BatchKernel>,
    ) -> Result<Executable> {
        let exec = NativeExec::mlp_with_kernel(mlp_spec(net, batch), kernel)?;
        Ok(Executable {
            name: format!("{}_mlp", net.task),
            spec: mlp_entry_spec(net, batch),
            exec,
            faults: None,
        })
    }

    /// Attach an infrastructure fault gate: `run_f32_rows` consults it
    /// before every batch.  The gate is `Arc`-shared, so clones (router
    /// lane workers) advance one global batch counter.
    pub fn with_faults(mut self, faults: Arc<FaultyExec>) -> Executable {
        self.faults = Some(faults);
        self
    }

    /// Raise intra-batch row parallelism (the `--threads`/`SAC_THREADS`
    /// knob).  Scalar executors fan rows out over `pool::parallel_map`;
    /// batched executors shard the columnar kernel into row slabs on the
    /// process-wide slab pool (bit-identical results at any thread
    /// count).  The router applies this per engine via
    /// `RouterConfig::kernel_threads`.
    pub fn with_par_threads(mut self, n: usize) -> Executable {
        self.exec = self.exec.with_par_threads(n);
        self
    }

    /// Which execution strategy this executable uses.
    pub fn mode(&self) -> ExecMode {
        self.exec.mode()
    }

    /// Signal-health accumulators of the underlying batched kernel
    /// (`None` in scalar mode).  Kernels are `Arc`-shared across clones,
    /// so every router worker of a lane reads the same accumulators.
    pub fn signal_health(&self) -> Option<crate::nn::batch::SignalHealthStats> {
        self.exec.signal_health()
    }

    /// Execute with f32 parameter buffers in manifest order.  Each buffer's
    /// length must match the manifest shape.  Returns the flat f32 outputs.
    pub fn run_f32(&self, params: &[&[f32]]) -> Result<Vec<f32>> {
        self.run_f32_rows(params, usize::MAX)
    }

    /// Like [`Executable::run_f32`], but computes only the first
    /// `min(rows, batch)` batch rows (the serving path passes the live row
    /// count so zero-padded tail rows cost nothing).
    pub fn run_f32_rows(&self, params: &[&[f32]], rows: usize) -> Result<Vec<f32>> {
        if params.len() != self.spec.params.len() {
            return Err(anyhow!(
                "{}: expected {} params, got {}",
                self.name,
                self.spec.params.len(),
                params.len()
            ));
        }
        for (buf, spec) in params.iter().zip(&self.spec.params) {
            let want: usize = spec.shape.iter().product();
            if buf.len() != want {
                return Err(anyhow!(
                    "{}: param {} length {} != shape {:?}",
                    self.name,
                    spec.name,
                    buf.len(),
                    spec.shape
                ));
            }
        }
        if let Some(faults) = &self.faults {
            faults.before_run()?;
        }
        self.exec.run_rows(params, rows)
    }

    /// Expected output element count (flat).
    pub fn output_len(&self) -> usize {
        self.spec
            .outputs
            .iter()
            .map(|o| o.shape.iter().product::<usize>())
            .sum()
    }
}

/// Graph spec for an in-memory MLP executable (no artifact directory).
fn mlp_spec(net: &TrainedNet, batch: usize) -> MlpSpec {
    MlpSpec {
        sizes: net.sizes.clone(),
        splines: net.splines,
        c: net.c,
        activation: net.activation.clone(),
        batch,
    }
}

/// Manifest-equivalent entry spec for an in-memory MLP executable, so the
/// artifact path and the in-memory path share one validation surface.
fn mlp_entry_spec(net: &TrainedNet, batch: usize) -> EntrySpec {
    let nl = net.n_layers();
    let mut params = Vec::with_capacity(2 * nl + 1);
    for li in 0..nl {
        params.push(ParamSpec {
            name: format!("w{}", li + 1),
            shape: vec![net.sizes[li], net.sizes[li + 1]],
            dtype: "f32".into(),
        });
        params.push(ParamSpec {
            name: format!("b{}", li + 1),
            shape: vec![net.sizes[li + 1]],
            dtype: "f32".into(),
        });
    }
    params.push(ParamSpec {
        name: "x".into(),
        shape: vec![batch, net.sizes[0]],
        dtype: "f32".into(),
    });
    let outputs = vec![ParamSpec {
        name: "logits".into(),
        shape: vec![batch, *net.sizes.last().unwrap()],
        dtype: "f32".into(),
    }];
    let meta = Json::obj(vec![
        (
            "sizes",
            Json::Arr(net.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        ("splines", Json::Num(net.splines as f64)),
        ("c", Json::Num(net.c)),
        ("activation", Json::Str(net.activation.clone())),
    ]);
    EntrySpec {
        file: String::new(),
        params,
        outputs,
        meta,
    }
}

/// Default artifact directory: `$SAC_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SAC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_net() -> TrainedNet {
        TrainedNet {
            task: "toy".into(),
            sizes: vec![2, 3, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![
                vec![0.8, -0.8, 0.5, -0.8, 0.8, 0.5],
                vec![0.9, -0.9, 0.9, -0.9, -0.9, 0.9],
            ],
            biases: vec![vec![-0.2, -0.2, -0.6], vec![0.0, 0.0]],
        }
    }

    #[test]
    fn native_mlp_spec_shapes() {
        let exe = Executable::native_mlp(&toy_net(), 4).unwrap();
        assert_eq!(exe.spec.params.len(), 5);
        assert_eq!(exe.spec.params[0].shape, vec![2, 3]);
        assert_eq!(exe.spec.params[4].shape, vec![4, 2]);
        assert_eq!(exe.output_len(), 8);
    }

    #[test]
    fn run_f32_validates_shapes() {
        let exe = Executable::native_mlp(&toy_net(), 2).unwrap();
        let bad: Vec<&[f32]> = vec![&[0.0]];
        assert!(exe.run_f32(&bad).is_err());
    }

    #[test]
    fn manifest_entry_roundtrips_to_executor() {
        // Write a minimal manifest and check Runtime::load derives the
        // right executor families from it.
        let text = r#"{
            "gmp_kernel": {
                "file": "gmp_kernel.hlo.txt",
                "params": [{"name": "x", "shape": [8, 4], "dtype": "f32"}],
                "outputs": [{"name": "h", "shape": [8], "dtype": "f32"}],
                "c": 1.0
            },
            "toy_mlp": {
                "file": "toy_mlp.hlo.txt",
                "params": [
                    {"name": "w1", "shape": [2, 2], "dtype": "f32"},
                    {"name": "b1", "shape": [2], "dtype": "f32"},
                    {"name": "x", "shape": [4, 2], "dtype": "f32"}
                ],
                "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}],
                "sizes": [2, 2], "splines": 1, "c": 1.0, "activation": "relu"
            }
        }"#;
        let dir = std::env::temp_dir().join("sac_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.platform(), "native-cpu");

        let gmp = rt.load("gmp_kernel").unwrap();
        let x = vec![0.25f32; 32];
        let bufs: Vec<&[f32]> = vec![&x];
        assert_eq!(gmp.run_f32(&bufs).unwrap().len(), 8);

        let mlp = rt.load("toy_mlp").unwrap();
        let w1 = vec![0.5f32, -0.5, 0.25, 0.75];
        let b1 = vec![0.0f32, 0.0];
        let xin = vec![0.1f32; 8];
        let bufs: Vec<&[f32]> = vec![&w1, &b1, &xin];
        assert_eq!(mlp.run_f32(&bufs).unwrap().len(), 8);

        assert!(rt.load("missing").is_err());
    }

    #[test]
    fn inconsistent_manifest_rejected_at_load() {
        // meta sizes say [2,3,2] but w1 is [2,2]: must fail at load(), not
        // panic inside a worker at run time
        let text = r#"{
            "skewed_mlp": {
                "file": "skewed_mlp.hlo.txt",
                "params": [
                    {"name": "w1", "shape": [2, 2], "dtype": "f32"},
                    {"name": "b1", "shape": [3], "dtype": "f32"},
                    {"name": "w2", "shape": [3, 2], "dtype": "f32"},
                    {"name": "b2", "shape": [2], "dtype": "f32"},
                    {"name": "x", "shape": [4, 2], "dtype": "f32"}
                ],
                "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}],
                "sizes": [2, 3, 2], "splines": 1, "c": 1.0, "activation": "relu"
            }
        }"#;
        let dir = std::env::temp_dir().join("sac_runtime_skew_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        let err = rt.load("skewed_mlp").unwrap_err();
        assert!(err.to_string().contains("w1"), "unexpected error: {err:#}");
    }

    #[test]
    fn native_mlp_mode_is_threaded_through() {
        let scalar = Executable::native_mlp(&toy_net(), 4).unwrap();
        assert_eq!(scalar.mode(), ExecMode::Scalar);
        let batched =
            Executable::native_mlp_with_mode(&toy_net(), 4, ExecMode::Batched).unwrap();
        assert_eq!(batched.mode(), ExecMode::Batched);
        // same manifest-facing spec either way
        assert_eq!(batched.spec.params.len(), scalar.spec.params.len());
        assert_eq!(batched.output_len(), scalar.output_len());
    }

    #[test]
    fn faulty_executable_gates_runs_and_shares_counter_across_clones() {
        let net = toy_net();
        let gate = Arc::new(FaultyExec::failing(2));
        let exe = Executable::native_mlp(&net, 2)
            .unwrap()
            .with_faults(gate.clone());
        let clone = exe.clone();
        let bufs: Vec<Vec<f32>> = vec![
            net.weights[0].iter().map(|&v| v as f32).collect(),
            net.biases[0].iter().map(|&v| v as f32).collect(),
            net.weights[1].iter().map(|&v| v as f32).collect(),
            net.biases[1].iter().map(|&v| v as f32).collect(),
            vec![0.1; 4],
        ];
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        // the clone's batch consumes the shared budget
        assert!(exe.run_f32(&refs).is_ok());
        assert!(clone.run_f32(&refs).is_ok());
        let err = exe.run_f32(&refs).unwrap_err();
        assert!(err.to_string().contains("fault injection"), "{err:#}");
        assert_eq!(gate.calls(), 3);
    }

    #[test]
    fn run_f32_rows_limits_output() {
        let exe = Executable::native_mlp(&toy_net(), 4).unwrap();
        let net = toy_net();
        let bufs: Vec<Vec<f32>> = vec![
            net.weights[0].iter().map(|&v| v as f32).collect(),
            net.biases[0].iter().map(|&v| v as f32).collect(),
            net.weights[1].iter().map(|&v| v as f32).collect(),
            net.biases[1].iter().map(|&v| v as f32).collect(),
            vec![0.1; 8],
        ];
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let full = exe.run_f32(&refs).unwrap();
        let one = exe.run_f32_rows(&refs, 1).unwrap();
        assert_eq!(full.len(), 8);
        assert_eq!(one.len(), 2);
        assert_eq!(&full[..2], &one[..]);
    }
}
