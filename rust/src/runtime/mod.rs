//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them on
//! the request path.  Python never runs here — `make artifacts` produced
//! HLO *text* (see python/compile/aot.py for why text, not serialized
//! protos) and this module compiles it once per process through the `xla`
//! crate's PJRT CPU client.

pub mod artifact;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

pub use artifact::{Manifest, ParamSpec};

/// A compiled, ready-to-execute artifact.
pub struct Executable {
    pub name: String,
    pub spec: artifact::EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT client and the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .with_context(|| "run `make artifacts` first")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by manifest entry name.
    pub fn load(&self, entry: &str) -> Result<Executable> {
        let spec = self
            .manifest
            .entries
            .get(entry)
            .ok_or_else(|| anyhow!("no artifact entry {entry:?} in manifest"))?
            .clone();
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {entry}: {e:?}"))?;
        Ok(Executable {
            name: entry.to_string(),
            spec,
            exe,
        })
    }
}

impl Executable {
    /// Execute with f32 parameter buffers in manifest order.  Each buffer's
    /// length must match the manifest shape.  Returns the flat f32 outputs
    /// (the AOT graphs return a 1-tuple).
    pub fn run_f32(&self, params: &[&[f32]]) -> Result<Vec<f32>> {
        if params.len() != self.spec.params.len() {
            return Err(anyhow!(
                "{}: expected {} params, got {}",
                self.name,
                self.spec.params.len(),
                params.len()
            ));
        }
        let mut lits = Vec::with_capacity(params.len());
        for (buf, spec) in params.iter().zip(&self.spec.params) {
            let want: usize = spec.shape.iter().product();
            if buf.len() != want {
                return Err(anyhow!(
                    "{}: param {} length {} != shape {:?}",
                    self.name,
                    spec.name,
                    buf.len(),
                    spec.shape
                ));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape {}: {e:?}", spec.name))?;
            lits.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {}: {e:?}", self.name))?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple {}: {e:?}", self.name))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec {}: {e:?}", self.name))
    }

    /// Expected output element count (flat).
    pub fn output_len(&self) -> usize {
        self.spec
            .outputs
            .iter()
            .map(|o| o.shape.iter().product::<usize>())
            .sum()
    }
}

/// Default artifact directory: `$SAC_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SAC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
