//! Native executor: the in-process interpreter for AOT artifact entries.
//!
//! The python build path (`python/compile/aot.py`) lowers two graph
//! families and records their parameter order / shapes / metadata in
//! `manifest.json`:
//!
//! ```text
//!   gmp_kernel   x:[B×M] ↦ h:[B]             (meta: c)
//!   <task>_mlp   w1,b1,…,wL,bL,x:[B×D] ↦ logits:[B×K]
//!                                            (meta: sizes, splines, c, activation)
//! ```
//!
//! Instead of shipping an XLA/PJRT runtime dependency, this module executes
//! those graphs natively with the crate's own S-AC math — the *same*
//! algorithms the python graphs were traced from (`kernels/gmp.py` ↔
//! [`crate::sac::gmp`], `nets.sac_forward` ↔ [`crate::nn::forward`]), so
//! the numbers agree to solver tolerance.  Cross-language parity is pinned
//! by `artifacts/goldens_gmp.json` in the integration tests.
//!
//! The executor is plain data (`Send + Sync`), so the serving router can run
//! batches of the same task concurrently on many workers without locking.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::cells::multiplier::Multiplier;
use crate::cells::Algorithmic;
use crate::data::TrainedNet;
use crate::nn;
use crate::nn::batch::{BatchKernel, GridConfig};
use crate::nn::Activation;
use crate::sac::gmp::{solve_bisect, Shape, GMP_ITERS};
use crate::util::pool;

/// Which execution strategy an MLP executor uses on the serving path.
///
/// * `Scalar`  — the per-row golden path: `nn::forward` with exact
///   four-proto-unit GMP solves per MAC.
/// * `Batched` — the columnar engine (`nn::batch`): per-corner dense
///   lookup grids evaluated over the whole batch at once, exact-cell
///   fallback outside the grids.  ≥ 5× faster on serving batches
///   (`benches/hotpath.rs`); equivalence is pinned in
///   `tests/integration.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Scalar,
    Batched,
}

impl ExecMode {
    /// Parse a `--engine` CLI value.
    pub fn parse(s: &str) -> Result<ExecMode> {
        match s {
            "scalar" => Ok(ExecMode::Scalar),
            "batched" => Ok(ExecMode::Batched),
            other => bail!("unknown engine mode {other:?} (expected \"scalar\" or \"batched\")"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Scalar => "scalar",
            ExecMode::Batched => "batched",
        }
    }
}

/// Shape/metadata of an S-AC MLP inference graph (mirror of the manifest
/// entry written by `aot.py::export_task_mlp`).
#[derive(Clone, Debug)]
pub struct MlpSpec {
    /// layer sizes, e.g. `[256, 15, 10]`
    pub sizes: Vec<usize>,
    /// spline count S of the multiplier / activation cells
    pub splines: usize,
    /// GMP constraint current C (algorithmic units)
    pub c: f64,
    /// hidden activation: `phi1` | `phi2` | `relu` | `softplus`
    pub activation: String,
    /// compiled batch dimension
    pub batch: usize,
}

/// Which graph family a [`NativeExec`] interprets.
#[derive(Clone, Debug)]
pub enum Graph {
    /// Batched GMP solve `x:[b×m] ↦ h:[b]` (the Layer-1 microkernel).
    Gmp { b: usize, m: usize, c: f64 },
    /// Full S-AC MLP inference graph.
    Mlp(MlpSpec),
}

/// A native, self-contained executor for one artifact entry.
#[derive(Clone, Debug)]
pub struct NativeExec {
    pub graph: Graph,
    /// Multiplier calibration shared by every MAC.  Weight-independent
    /// (a property of (S, C) only), so it is computed once at load time
    /// rather than per batch.
    mult: Option<Multiplier>,
    /// Hidden activation, parsed (and thereby validated) once at load
    /// time rather than per batch.
    act: Option<Activation>,
    /// Batched columnar kernel (grids sampled once at load time);
    /// `None` = scalar per-row execution.  `Arc` so cloned executors
    /// (router lanes) share the grids.
    kernel: Option<Arc<BatchKernel>>,
    /// Row-parallelism inside one batch.  Defaults to `SAC_THREADS` when
    /// set, else 1: the serving router already parallelizes across
    /// batches/tasks, and raising this should be a deliberate choice
    /// (`--threads` on the CLI, [`NativeExec::with_par_threads`]).  The
    /// scalar path fans rows out over `pool::parallel_map`; the batched
    /// kernel shards the columnar buffers into contiguous row slabs on
    /// the process-wide slab pool with bit-identical results at any
    /// thread count.
    pub par_threads: usize,
}

impl NativeExec {
    /// Executor for the batched GMP kernel.
    pub fn gmp(b: usize, m: usize, c: f64) -> NativeExec {
        NativeExec {
            graph: Graph::Gmp { b, m, c },
            mult: None,
            act: None,
            kernel: None,
            par_threads: pool::threads_from_env().unwrap_or(1),
        }
    }

    /// Executor for an S-AC MLP graph; calibrates the multiplier once
    /// (scalar mode — see [`NativeExec::mlp_with_mode`]).
    pub fn mlp(spec: MlpSpec) -> Result<NativeExec> {
        NativeExec::mlp_with_mode(spec, ExecMode::Scalar)
    }

    /// Executor for an S-AC MLP graph in the given execution mode.
    /// `Batched` additionally samples the per-corner lookup grids once.
    pub fn mlp_with_mode(spec: MlpSpec, mode: ExecMode) -> Result<NativeExec> {
        if spec.sizes.len() < 2 {
            bail!("mlp needs at least [in, out] sizes, got {:?}", spec.sizes);
        }
        let act = Activation::parse(&spec.activation)?;
        let mult = Multiplier::calibrate(&Algorithmic::relu(), spec.splines, spec.c);
        let kernel = match mode {
            ExecMode::Scalar => None,
            ExecMode::Batched => Some(Arc::new(BatchKernel::new(
                Box::new(Algorithmic::relu()),
                act,
                spec.splines,
                spec.c,
                &GridConfig::default(),
            ))),
        };
        Ok(NativeExec {
            graph: Graph::Mlp(spec),
            mult: Some(mult),
            act: Some(act),
            kernel,
            par_threads: pool::threads_from_env().unwrap_or(1),
        })
    }

    /// Executor for an S-AC MLP graph driven by a caller-supplied batched
    /// kernel (corner backends, fault-injected grids, …) instead of the
    /// default `Algorithmic` calibration.  The kernel must match the
    /// spec's `(activation, splines)`; its multiplier doubles as the
    /// scalar-fallback calibration.
    pub fn mlp_with_kernel(spec: MlpSpec, kernel: Arc<BatchKernel>) -> Result<NativeExec> {
        if spec.sizes.len() < 2 {
            bail!("mlp needs at least [in, out] sizes, got {:?}", spec.sizes);
        }
        let act = Activation::parse(&spec.activation)?;
        if kernel.activation() != act {
            bail!(
                "kernel activation {:?} != spec activation {:?}",
                kernel.activation(),
                act
            );
        }
        if kernel.splines() != spec.splines {
            bail!(
                "kernel splines {} != spec splines {}",
                kernel.splines(),
                spec.splines
            );
        }
        let mult = kernel.multiplier().clone();
        Ok(NativeExec {
            graph: Graph::Mlp(spec),
            mult: Some(mult),
            act: Some(act),
            kernel: Some(kernel),
            par_threads: pool::threads_from_env().unwrap_or(1),
        })
    }

    /// Which execution strategy this executor uses.
    pub fn mode(&self) -> ExecMode {
        if self.kernel.is_some() {
            ExecMode::Batched
        } else {
            ExecMode::Scalar
        }
    }

    /// Signal-health accumulators of the batched kernel (`None` on the
    /// scalar path, which has no grids to fall out of).
    pub fn signal_health(&self) -> Option<crate::nn::batch::SignalHealthStats> {
        self.kernel.as_ref().map(|k| k.signal_health())
    }

    /// Row-parallel variant (for the single-task CLI/bench path).
    pub fn with_par_threads(mut self, n: usize) -> NativeExec {
        self.par_threads = n.max(1);
        self
    }

    /// Number of f32 parameter buffers this executor expects.
    pub fn n_params(&self) -> usize {
        match &self.graph {
            Graph::Gmp { .. } => 1,
            Graph::Mlp(spec) => 2 * (spec.sizes.len() - 1) + 1,
        }
    }

    /// Execute with parameter buffers in manifest order; returns the flat
    /// f32 outputs for the full compiled batch.  Buffer shapes must have
    /// been validated by the caller
    /// ([`crate::runtime::Executable::run_f32`]).
    pub fn run(&self, params: &[&[f32]]) -> Result<Vec<f32>> {
        self.run_rows(params, usize::MAX)
    }

    /// Like [`NativeExec::run`], but computes only the first
    /// `min(rows, batch)` rows and returns `rows × out_dim` outputs.
    /// This is the deadline-flush fast path: a padded tail batch with one
    /// live request costs one row of GMP solves, not the whole batch.
    pub fn run_rows(&self, params: &[&[f32]], rows: usize) -> Result<Vec<f32>> {
        let _span = crate::util::trace::span("native.run");
        if params.len() != self.n_params() {
            bail!("expected {} params, got {}", self.n_params(), params.len());
        }
        match &self.graph {
            Graph::Gmp { b, m, c } => self.run_gmp(params[0], *b, *m, *c, rows.min(*b)),
            Graph::Mlp(spec) => {
                let rows = rows.min(spec.batch);
                self.run_mlp(spec, params, rows)
            }
        }
    }

    fn run_gmp(&self, x: &[f32], b: usize, m: usize, c: f64, rows: usize) -> Result<Vec<f32>> {
        if x.len() != b * m {
            bail!("gmp input length {} != {b}x{m}", x.len());
        }
        let row_h = |r: usize| -> f32 {
            let xs: Vec<f64> = x[r * m..(r + 1) * m].iter().map(|&v| v as f64).collect();
            solve_bisect(&xs, c, Shape::Relu, GMP_ITERS) as f32
        };
        if self.par_threads <= 1 {
            Ok((0..rows).map(row_h).collect())
        } else {
            Ok(pool::parallel_map(rows, self.par_threads, row_h))
        }
    }

    fn run_mlp(&self, spec: &MlpSpec, params: &[&[f32]], rows: usize) -> Result<Vec<f32>> {
        let nl = spec.sizes.len() - 1;
        // Materialize the weights into the f64 layout both engines
        // expect.  Weights arrive as f32 parameter buffers per the AOT
        // contract (the graph treats them as inputs, not constants), so
        // this f32→f64 conversion recurs per batch by design; its cost is
        // ~3 orders of magnitude below the MAC work it feeds.
        let mut weights: Vec<Vec<f64>> = Vec::with_capacity(nl);
        let mut biases: Vec<Vec<f64>> = Vec::with_capacity(nl);
        for li in 0..nl {
            weights.push(params[2 * li].iter().map(|&v| v as f64).collect());
            biases.push(params[2 * li + 1].iter().map(|&v| v as f64).collect());
        }
        let x = params[2 * nl];
        let din = spec.sizes[0];
        let k = *spec.sizes.last().unwrap();
        if x.len() != spec.batch * din {
            bail!("mlp input length {} != {}x{din}", x.len(), spec.batch);
        }
        if let Some(kernel) = &self.kernel {
            // Batched columnar path: whole-batch evaluation through the
            // precomputed grids, sharded into row slabs when par_threads
            // asks for it (bit-identical to the serial kernel).
            let out = kernel.forward_batch_threads(
                &spec.sizes,
                &weights,
                &biases,
                x,
                rows,
                self.par_threads,
            );
            return Ok(out.into_iter().map(|v| v as f32).collect());
        }
        let net = TrainedNet {
            task: String::new(),
            sizes: spec.sizes.clone(),
            activation: spec.activation.clone(),
            splines: spec.splines,
            c: spec.c,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights,
            biases,
        };
        let act = self
            .act
            .ok_or_else(|| anyhow!("mlp executor missing activation"))?;
        let mult = self
            .mult
            .as_ref()
            .ok_or_else(|| anyhow!("mlp executor missing multiplier calibration"))?;
        let provider = Algorithmic::relu();
        let row_logits = |r: usize| -> Vec<f64> {
            nn::forward_with(&net, &provider, mult, act, &x[r * din..(r + 1) * din])
        };
        let row_results: Vec<Vec<f64>> = if self.par_threads <= 1 {
            (0..rows).map(row_logits).collect()
        } else {
            pool::parallel_map(rows, self.par_threads, row_logits)
        };
        let mut out = Vec::with_capacity(rows * k);
        for row in row_results {
            debug_assert_eq!(row.len(), k);
            out.extend(row.into_iter().map(|v| v as f32));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sac::gmp::solve_exact;

    #[test]
    fn gmp_exec_matches_solver() {
        let exec = NativeExec::gmp(3, 4, 1.0);
        let x: Vec<f32> = vec![
            0.5, -0.2, 1.0, 0.1, //
            -1.0, -1.0, -1.0, -1.0, //
            2.0, 1.5, 0.0, -0.5,
        ];
        let bufs: Vec<&[f32]> = vec![&x];
        let h = exec.run(&bufs).unwrap();
        assert_eq!(h.len(), 3);
        for r in 0..3 {
            let xs: Vec<f64> = x[r * 4..(r + 1) * 4].iter().map(|&v| v as f64).collect();
            let expect = solve_exact(&xs, 1.0);
            assert!(
                (h[r] as f64 - expect).abs() < 1e-5,
                "row {r}: {} vs {expect}",
                h[r]
            );
        }
    }

    #[test]
    fn gmp_exec_parallel_agrees_with_serial() {
        let b = 16;
        let m = 5;
        let mut rng = crate::util::rng::Rng::new(7);
        let x: Vec<f32> = (0..b * m).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect();
        let serial = NativeExec::gmp(b, m, 0.8);
        let par = NativeExec::gmp(b, m, 0.8).with_par_threads(4);
        let bufs: Vec<&[f32]> = vec![&x];
        assert_eq!(serial.run(&bufs).unwrap(), par.run(&bufs).unwrap());
    }

    #[test]
    fn mlp_exec_matches_direct_forward() {
        let spec = MlpSpec {
            sizes: vec![2, 3, 2],
            splines: 3,
            c: 1.0,
            activation: "phi1".into(),
            batch: 2,
        };
        let exec = NativeExec::mlp(spec).unwrap();
        // f32-exact weights so the f32→f64 round-trip is lossless
        let w1: Vec<f32> = vec![0.5, -0.25, 0.75, -0.5, 0.25, 0.5];
        let b1: Vec<f32> = vec![-0.125, 0.0, 0.25];
        let w2: Vec<f32> = vec![0.5, -0.5, 0.25, -0.25, -0.75, 0.75];
        let b2: Vec<f32> = vec![0.0, 0.125];
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75];
        let bufs: Vec<&[f32]> = vec![&w1, &b1, &w2, &b2, &x];
        let out = exec.run(&bufs).unwrap();
        assert_eq!(out.len(), 4);

        let net = TrainedNet {
            task: "t".into(),
            sizes: vec![2, 3, 2],
            activation: "phi1".into(),
            splines: 3,
            c: 1.0,
            acc_sw: 0.0,
            acc_sac_algorithmic: 0.0,
            weights: vec![
                w1.iter().map(|&v| v as f64).collect(),
                w2.iter().map(|&v| v as f64).collect(),
            ],
            biases: vec![
                b1.iter().map(|&v| v as f64).collect(),
                b2.iter().map(|&v| v as f64).collect(),
            ],
        };
        let p = Algorithmic::relu();
        let m = Multiplier::calibrate(&p, 3, 1.0);
        for r in 0..2 {
            let logits = nn::forward(&net, &p, &m, &x[r * 2..(r + 1) * 2]);
            for (j, &l) in logits.iter().enumerate() {
                assert!(
                    (out[r * 2 + j] as f64 - l).abs() < 1e-5,
                    "row {r} logit {j}: {} vs {l}",
                    out[r * 2 + j]
                );
            }
        }
    }

    #[test]
    fn run_rows_computes_only_live_rows() {
        let exec = NativeExec::gmp(8, 3, 1.0);
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..24).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect();
        let bufs: Vec<&[f32]> = vec![&x];
        let full = exec.run(&bufs).unwrap();
        let two = exec.run_rows(&bufs, 2).unwrap();
        assert_eq!(full.len(), 8);
        assert_eq!(two.len(), 2);
        assert_eq!(&full[..2], &two[..]);
    }

    #[test]
    fn mlp_rejects_bad_activation() {
        let spec = MlpSpec {
            sizes: vec![2, 2],
            splines: 1,
            c: 1.0,
            activation: "gelu".into(),
            batch: 1,
        };
        assert!(NativeExec::mlp(spec.clone()).is_err());
        assert!(NativeExec::mlp_with_mode(spec, ExecMode::Batched).is_err());
    }

    #[test]
    fn mlp_with_kernel_matches_default_batched_and_validates() {
        let spec = MlpSpec {
            sizes: vec![2, 3, 2],
            splines: 3,
            c: 1.0,
            activation: "phi1".into(),
            batch: 4,
        };
        let kernel = Arc::new(BatchKernel::new(
            Box::new(Algorithmic::relu()),
            Activation::Phi1,
            3,
            1.0,
            &GridConfig::default(),
        ));
        let custom = NativeExec::mlp_with_kernel(spec.clone(), kernel).unwrap();
        assert_eq!(custom.mode(), ExecMode::Batched);
        let stock = NativeExec::mlp_with_mode(spec.clone(), ExecMode::Batched).unwrap();
        let w1: Vec<f32> = vec![0.5, -0.25, 0.75, -0.5, 0.25, 0.5];
        let b1: Vec<f32> = vec![-0.125, 0.0, 0.25];
        let w2: Vec<f32> = vec![0.5, -0.5, 0.25, -0.25, -0.75, 0.75];
        let b2: Vec<f32> = vec![0.0, 0.125];
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75, 0.1, 0.9, -0.8, -0.3];
        let bufs: Vec<&[f32]> = vec![&w1, &b1, &w2, &b2, &x];
        // same backend, same calibration path → bit-identical outputs
        assert_eq!(custom.run(&bufs).unwrap(), stock.run(&bufs).unwrap());

        // kernel/spec activation or spline disagreement is rejected
        let relu_kernel = Arc::new(BatchKernel::new(
            Box::new(Algorithmic::relu()),
            Activation::Relu,
            3,
            1.0,
            &GridConfig::default(),
        ));
        assert!(NativeExec::mlp_with_kernel(spec.clone(), relu_kernel).is_err());
        let s1_kernel = Arc::new(BatchKernel::new(
            Box::new(Algorithmic::relu()),
            Activation::Phi1,
            1,
            1.0,
            &GridConfig::default(),
        ));
        assert!(NativeExec::mlp_with_kernel(spec, s1_kernel).is_err());
    }

    #[test]
    fn batched_mlp_parallel_threads_bit_identical() {
        let spec = MlpSpec {
            sizes: vec![2, 3, 2],
            splines: 3,
            c: 1.0,
            activation: "phi1".into(),
            batch: 64,
        };
        let serial = NativeExec::mlp_with_mode(spec.clone(), ExecMode::Batched)
            .unwrap()
            .with_par_threads(1);
        let par = NativeExec::mlp_with_mode(spec, ExecMode::Batched)
            .unwrap()
            .with_par_threads(4);
        let w1: Vec<f32> = vec![0.5, -0.25, 0.75, -0.5, 0.25, 0.5];
        let b1: Vec<f32> = vec![-0.125, 0.0, 0.25];
        let w2: Vec<f32> = vec![0.5, -0.5, 0.25, -0.25, -0.75, 0.75];
        let b2: Vec<f32> = vec![0.0, 0.125];
        let mut rng = crate::util::rng::Rng::new(21);
        let x: Vec<f32> = (0..64 * 2).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let bufs: Vec<&[f32]> = vec![&w1, &b1, &w2, &b2, &x];
        assert_eq!(serial.run(&bufs).unwrap(), par.run(&bufs).unwrap());
        // live-row restriction too (17 rows still shards at 4 threads)
        assert_eq!(
            serial.run_rows(&bufs, 17).unwrap(),
            par.run_rows(&bufs, 17).unwrap()
        );
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        assert_eq!(ExecMode::parse("scalar").unwrap(), ExecMode::Scalar);
        assert_eq!(ExecMode::parse("batched").unwrap(), ExecMode::Batched);
        assert!(ExecMode::parse("warp").is_err());
        assert_eq!(ExecMode::Batched.name(), "batched");
    }

    #[test]
    fn batched_mlp_matches_scalar_mlp() {
        let spec = MlpSpec {
            sizes: vec![2, 3, 2],
            splines: 3,
            c: 1.0,
            activation: "phi1".into(),
            batch: 4,
        };
        let scalar = NativeExec::mlp_with_mode(spec.clone(), ExecMode::Scalar).unwrap();
        let batched = NativeExec::mlp_with_mode(spec, ExecMode::Batched).unwrap();
        assert_eq!(scalar.mode(), ExecMode::Scalar);
        assert_eq!(batched.mode(), ExecMode::Batched);
        let w1: Vec<f32> = vec![0.5, -0.25, 0.75, -0.5, 0.25, 0.5];
        let b1: Vec<f32> = vec![-0.125, 0.0, 0.25];
        let w2: Vec<f32> = vec![0.5, -0.5, 0.25, -0.25, -0.75, 0.75];
        let b2: Vec<f32> = vec![0.0, 0.125];
        let x: Vec<f32> = vec![0.5, -0.5, -0.25, 0.75, 0.1, 0.9, -0.8, -0.3];
        let bufs: Vec<&[f32]> = vec![&w1, &b1, &w2, &b2, &x];
        let a = scalar.run(&bufs).unwrap();
        let b = batched.run(&bufs).unwrap();
        assert_eq!(a.len(), b.len());
        for (j, (&sv, &bv)) in a.iter().zip(&b).enumerate() {
            assert!(
                (sv - bv).abs() < 1e-2,
                "logit {j}: scalar {sv} vs batched {bv}"
            );
        }
        // live-row restriction behaves identically in both modes
        let a2 = scalar.run_rows(&bufs, 2).unwrap();
        let b2m = batched.run_rows(&bufs, 2).unwrap();
        assert_eq!(a2.len(), 4);
        assert_eq!(b2m.len(), 4);
        assert_eq!(&b[..4], &b2m[..]);
    }
}
