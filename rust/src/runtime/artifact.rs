//! Artifact manifest (`artifacts/manifest.json`, written by aot.py):
//! parameter order, shapes and dtypes per compiled entry.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::{parse_file, Json};

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<ParamSpec>,
    /// extra metadata (sizes, activation, c, splines) as raw json
    pub meta: Json,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, EntrySpec>,
}

fn parse_param(j: &Json) -> Result<ParamSpec> {
    Ok(ParamSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let j = parse_file(path)?;
        let obj = j.as_obj()?;
        let mut entries = BTreeMap::new();
        for (name, ej) in obj {
            let Ok(file) = ej.get("file") else {
                bail!("manifest entry {name} missing file");
            };
            let params = ej
                .get("params")?
                .as_arr()?
                .iter()
                .map(parse_param)
                .collect::<Result<Vec<_>>>()?;
            let outputs = ej
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(parse_param)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec {
                    file: file.as_str()?.to_string(),
                    params,
                    outputs,
                    meta: ej.clone(),
                },
            );
        }
        Ok(Manifest { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let text = r#"{
            "gmp_kernel": {
                "file": "gmp_kernel.hlo.txt",
                "params": [{"name": "x", "shape": [4096, 8], "dtype": "f32"}],
                "outputs": [{"name": "h", "shape": [4096], "dtype": "f32"}],
                "c": 1.0
            }
        }"#;
        let dir = std::env::temp_dir().join("sac_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.json");
        std::fs::write(&p, text).unwrap();
        let m = Manifest::load(&p).unwrap();
        let e = &m.entries["gmp_kernel"];
        assert_eq!(e.file, "gmp_kernel.hlo.txt");
        assert_eq!(e.params[0].shape, vec![4096, 8]);
        assert_eq!(e.outputs[0].shape, vec![4096]);
        assert!((e.meta.get("c").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join("sac_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.json");
        std::fs::write(&p, r#"{"x": {"params": [], "outputs": []}}"#).unwrap();
        assert!(Manifest::load(&p).is_err());
    }
}
