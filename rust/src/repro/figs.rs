//! Figure regeneration (Figs. 1-15).  Each function computes the figure's
//! data series, writes `results/figN*.csv`, and returns a printable report.

use std::path::Path;

use anyhow::Result;

use crate::analysis::{dc, montecarlo as mc, power};
use crate::cells::activations::CellKind;
use crate::cells::{wta, Algorithmic, CircuitCorner, HProvider};
use crate::device::{fom, Mosfet};
use crate::pdk::{Polarity, ProcessNode, regime::Regime, CMOS180, FINFET7};
use crate::sac::splines;
use crate::util::table::{ascii_plot, write_xy_csv, Table};

/// Fig. 1: gm/Id and (gm/Id)·f_T vs overdrive across nodes.
pub fn fig1(out: &Path) -> Result<String> {
    let mut report = String::from("Fig. 1 — transconductance efficiency & FOM vs overdrive\n");
    let npts = 61;
    let mut series_gm: Vec<(String, Vec<f64>)> = Vec::new();
    let mut series_fom: Vec<(String, Vec<f64>)> = Vec::new();
    let mut vovs: Vec<f64> = Vec::new();
    for node in ProcessNode::all() {
        let pts = fom::fom_sweep(node, npts);
        if vovs.is_empty() {
            vovs = pts.iter().map(|p| p.vov).collect();
        }
        series_gm.push((node.name.to_string(), pts.iter().map(|p| p.gm_over_id).collect()));
        series_fom.push((node.name.to_string(), pts.iter().map(|p| p.fom).collect()));
        let peak = fom::fom_peak_vov(node);
        report += &format!(
            "  {}: gm/Id(WI)={:.1} 1/V, FOM peak at Vov={:+.3} V (moderate inversion)\n",
            node.name,
            pts[0].gm_over_id,
            peak
        );
    }
    let refs_gm: Vec<(&str, &[f64])> = series_gm
        .iter()
        .map(|(n, v)| (n.as_str(), v.as_slice()))
        .collect();
    write_xy_csv(&out.join("fig1_gm_over_id.csv"), "vov", &vovs, &refs_gm)?;
    let refs_fom: Vec<(&str, &[f64])> = series_fom
        .iter()
        .map(|(n, v)| (n.as_str(), v.as_slice()))
        .collect();
    write_xy_csv(&out.join("fig1_fom.csv"), "vov", &vovs, &refs_fom)?;
    report += &ascii_plot(&refs_gm, 10, 60);
    Ok(report)
}

/// Fig. 2a: spline approximation of e^x for S = 1, 3.
pub fn fig2a(out: &Path) -> Result<String> {
    let xs = dc::grid(-2.0, 1.2, 65);
    let exact: Vec<f64> = xs.iter().map(|&x| x.exp()).collect();
    let s1: Vec<f64> = xs.iter().map(|&x| splines::exp_spline_approx(x, 1)).collect();
    let s3: Vec<f64> = xs.iter().map(|&x| splines::exp_spline_approx(x, 3)).collect();
    write_xy_csv(
        &out.join("fig2a_spline_approx.csv"),
        "x",
        &xs,
        &[("exp", &exact), ("s1", &s1), ("s3", &s3)],
    )?;
    let e1 = crate::util::stats::max_abs_dev(&exact, &s1);
    let e3 = crate::util::stats::max_abs_dev(&exact, &s3);
    let mut rep = format!(
        "Fig. 2a — e^x spline approx: max err S=1 {e1:.3}, S=3 {e3:.3} (margin narrows)\n"
    );
    rep += &ascii_plot(&[("exp", &exact[..]), ("s1", &s1[..]), ("s3", &s3[..])], 10, 60);
    Ok(rep)
}

/// Fig. 3: basic S-AC proto-shapes — spline counts, nodes, regimes.
pub fn fig3(out: &Path) -> Result<String> {
    let zs = dc::grid(-2.5, 1.5, 33);
    let mut rep = String::from("Fig. 3 — proto-shape h(x)/Imax across nodes / regimes\n");
    // (a,b): S=1 and S=3 at both nodes, WI
    for s in [1usize, 3] {
        let mut series: Vec<(String, Vec<f64>)> = Vec::new();
        for node in ProcessNode::paper_pair() {
            let cc = CircuitCorner::new(node, Regime::WeakInversion);
            let ys: Vec<f64> = zs
                .iter()
                .map(|&z| crate::cells::proto_unit(&cc, z, s, 1.0))
                .collect();
            series.push((node.name.to_string(), dc::normalize(&ys)));
        }
        let refs: Vec<(&str, &[f64])> = series
            .iter()
            .map(|(n, v)| (n.as_str(), v.as_slice()))
            .collect();
        write_xy_csv(&out.join(format!("fig3_s{s}_nodes.csv")), "x", &zs, &refs)?;
        let (mx, mean) = dc::curve_deviation(&series[0].1, &series[1].1);
        rep += &format!(
            "  S={s}: 180nm vs 7nm normalized shape — max dev {:.3}, mean {:.4}\n",
            mx, mean
        );
    }
    // (c,d): regimes per node, S=3
    for node in ProcessNode::paper_pair() {
        let mut series: Vec<(String, Vec<f64>)> = Vec::new();
        for regime in Regime::all() {
            let cc = CircuitCorner::new(node, regime);
            let ys: Vec<f64> = zs
                .iter()
                .map(|&z| crate::cells::proto_unit(&cc, z, 3, 1.0))
                .collect();
            series.push((regime.short().to_string(), dc::normalize(&ys)));
        }
        let refs: Vec<(&str, &[f64])> = series
            .iter()
            .map(|(n, v)| (n.as_str(), v.as_slice()))
            .collect();
        write_xy_csv(&out.join(format!("fig3_regimes_{}.csv", node.name)), "x", &zs, &refs)?;
        let (d_wm, _) = dc::curve_deviation(&series[0].1, &series[1].1);
        let (d_ws, _) = dc::curve_deviation(&series[0].1, &series[2].1);
        rep += &format!(
            "  {}: WI↔MI max dev {:.3}, WI↔SI max dev {:.3} (margin-bounded)\n",
            node.name, d_wm, d_ws
        );
    }
    Ok(rep)
}

/// Fig. 4: temperature, Monte-Carlo mismatch and supply-variation
/// robustness of the basic shape (180 nm).
pub fn fig4(out: &Path) -> Result<String> {
    let zs = dc::grid(-2.5, 1.5, 25);
    let mut rep = String::from("Fig. 4 — shape robustness at 180nm\n");
    // (a) temperature
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for t in [-45.0, 27.0, 125.0] {
        let cc = CircuitCorner::new(&CMOS180, Regime::WeakInversion).at_temp(t);
        let ys: Vec<f64> = zs
            .iter()
            .map(|&z| crate::cells::proto_unit(&cc, z, 3, 1.0))
            .collect();
        series.push((format!("{t}C"), dc::normalize(&ys)));
    }
    let refs: Vec<(&str, &[f64])> = series
        .iter()
        .map(|(n, v)| (n.as_str(), v.as_slice()))
        .collect();
    write_xy_csv(&out.join("fig4a_temperature.csv"), "x", &zs, &refs)?;
    let (d1, _) = dc::curve_deviation(&series[0].1, &series[2].1);
    rep += &format!("  (a) -45C vs 125C normalized max dev: {:.3}\n", d1);

    // (b) Monte-Carlo mismatch on the proto shape
    let cfg = mc::McConfig {
        trials: 30,
        zs: zs.clone(),
        ..Default::default()
    };
    let r = mc::run_cell_mc(CellKind::Softplus, &CMOS180, Regime::WeakInversion, &cfg);
    rep += &format!("  (b) MC mismatch max deviation: {:.2}% (paper: ≤5%)\n", r.max_pct_dev);
    write_xy_csv(
        &out.join("fig4b_mc_std.csv"),
        "x",
        &zs,
        &[("point_std", &r.point_std[..])],
    )?;

    // (c) supply variation 0.9 → 1.8 V in WI
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for vdd in [0.9, 1.2, 1.5, 1.8] {
        let cc = CircuitCorner::new(&CMOS180, Regime::WeakInversion).with_supply(vdd);
        let ys: Vec<f64> = zs
            .iter()
            .map(|&z| crate::cells::proto_unit(&cc, z, 3, 1.0))
            .collect();
        series.push((format!("{vdd}V"), dc::normalize(&ys)));
    }
    let refs: Vec<(&str, &[f64])> = series
        .iter()
        .map(|(n, v)| (n.as_str(), v.as_slice()))
        .collect();
    write_xy_csv(&out.join("fig4c_supply.csv"), "x", &zs, &refs)?;
    let (d2, _) = dc::curve_deviation(&series[0].1, &series[3].1);
    rep += &format!("  (c) 0.9V vs 1.8V normalized max dev: {:.3}\n", d2);
    Ok(rep)
}

/// Fig. 5: deep-threshold operation (source shifting → fA currents).
pub fn fig5(out: &Path) -> Result<String> {
    let mut rep = String::from("Fig. 5 — deep-threshold (fA) operation, 180nm\n");
    // (a) Id–Vgs with and without source shift (log scale data)
    let vgs = dc::grid(0.0, 0.9, 46);
    let normal = Mosfet::square(&CMOS180, Polarity::N);
    let mut shifted = Mosfet::square(&CMOS180, Polarity::N);
    shifted.source_shift = 0.35;
    shifted.body_at_vdd = true;
    let i_norm: Vec<f64> = vgs.iter().map(|&v| normal.forward(v, 0.0)).collect();
    let i_shift: Vec<f64> = vgs.iter().map(|&v| shifted.forward(v, 0.0)).collect();
    write_xy_csv(
        &out.join("fig5a_idvgs.csv"),
        "vgs",
        &vgs,
        &[("normal", &i_norm), ("source_shifted", &i_shift)],
    )?;
    let min_i = i_shift.iter().cloned().fold(f64::INFINITY, f64::min);
    rep += &format!(
        "  (a) minimum current with source shift: {:.2} fA (paper: 1.97 fA NMOS)\n",
        min_i * 1e15
    );

    // (c) proto shape at fA bias, S = 1 and 3
    let zs = dc::grid(-2.5, 1.5, 25);
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for s in [1usize, 3] {
        let unit = crate::sac::SacUnit::new(&CMOS180, Polarity::N, Regime::WeakInversion, 1)
            .deep_threshold(0.35)
            .with_bias(5.0e-14);
        let ys: Vec<f64> = zs.iter().map(|&z| unit.proto_shape(z, s)).collect();
        series.push((format!("S={s}"), dc::normalize(&ys)));
    }
    let refs: Vec<(&str, &[f64])> = series
        .iter()
        .map(|(n, v)| (n.as_str(), v.as_slice()))
        .collect();
    write_xy_csv(&out.join("fig5c_deep_shape.csv"), "x", &zs, &refs)?;
    rep += "  (c) S-AC shape preserved at 50 fA bias (see fig5c_deep_shape.csv)\n";
    Ok(rep)
}

/// Fig. 7: all activation cells across the two nodes (+ temperature).
pub fn fig7(out: &Path) -> Result<String> {
    let zs = dc::grid(-2.0, 2.0, 29);
    let mut rep = String::from("Fig. 7 — activation standard cells, 180nm vs 7nm\n");
    let mut table = Table::new(
        "cross-node deviation (normalized)",
        &["cell", "max dev", "mean dev"],
    );
    for kind in CellKind::all() {
        let mut series: Vec<(String, Vec<f64>)> = Vec::new();
        for node in ProcessNode::paper_pair() {
            for t in [27.0, 125.0] {
                let cc = CircuitCorner::new(node, Regime::WeakInversion).at_temp(t);
                let ys = dc::sweep_cell(kind, &cc, &zs);
                series.push((format!("{}_{}C", node.name, t), dc::normalize(&ys)));
            }
        }
        let refs: Vec<(&str, &[f64])> = series
            .iter()
            .map(|(n, v)| (n.as_str(), v.as_slice()))
            .collect();
        write_xy_csv(&out.join(format!("fig7_{}.csv", kind.name())), "x", &zs, &refs)?;
        let (mx, mean) = dc::curve_deviation(&series[0].1, &series[2].1);
        table.row(vec![
            kind.name().to_string(),
            format!("{mx:.4}"),
            format!("{mean:.4}"),
        ]);
    }
    rep += &table.render();
    table.write_csv(&out.join("fig7_deviation.csv"))?;
    Ok(rep)
}

/// Fig. 8: Monte-Carlo max % deviation of ReLU / sigmoid / soft-plus at
/// both nodes (paper: 3.11 / 7.31 / 2.44 / 4.14 / 0.91 / 3.86 %).
pub fn fig8(out: &Path, trials: usize) -> Result<String> {
    let paper: &[(&str, CellKind, &ProcessNode, f64)] = &[
        ("relu@180", CellKind::Relu, &CMOS180, 3.11),
        ("sigmoid@180", CellKind::Phi2, &CMOS180, 7.31),
        ("softplus@180", CellKind::Softplus, &CMOS180, 2.44),
        ("relu@7", CellKind::Relu, &FINFET7, 4.14),
        ("sigmoid@7", CellKind::Phi2, &FINFET7, 0.91),
        ("softplus@7", CellKind::Softplus, &FINFET7, 3.86),
    ];
    let mut table = Table::new(
        "Fig. 8 — MC max % deviation (WI)",
        &["cell", "measured %", "paper %"],
    );
    let cfg = mc::McConfig {
        trials,
        zs: dc::grid(-1.8, 1.8, 13),
        ..Default::default()
    };
    for &(name, kind, node, paper_pct) in paper {
        let node_static: &'static ProcessNode = if node.name == "cmos180" {
            &CMOS180
        } else {
            &FINFET7
        };
        let r = mc::run_cell_mc(kind, node_static, Regime::WeakInversion, &cfg);
        table.row(vec![
            name.to_string(),
            format!("{:.2}", r.max_pct_dev),
            format!("{paper_pct:.2}"),
        ]);
    }
    table.write_csv(&out.join("fig8_mc_deviation.csv"))?;
    Ok(table.render())
}

/// Fig. 10: WTA / N-of-M / SoftArgMax characteristics.
pub fn fig10(out: &Path) -> Result<String> {
    let mut rep = String::from("Fig. 10 — WTA family\n");
    let alg = Algorithmic::relu();
    // (a,b): 2-input WTA outputs vs differential input (both nodes,
    // circuit tier at 180nm + algorithmic)
    let dx = dc::grid(-1.0, 1.0, 41);
    for node in ProcessNode::paper_pair() {
        let cc = CircuitCorner::new(node, Regime::WeakInversion);
        let mut o1 = Vec::new();
        let mut o2 = Vec::new();
        for &d in &dx {
            let x = [1.5 + d / 2.0, 1.5 - d / 2.0];
            let y = wta::wta_outputs(&cc, &x, 0.5);
            o1.push(y[0]);
            o2.push(y[1]);
        }
        write_xy_csv(
            &out.join(format!("fig10_wta2_{}.csv", node.name)),
            "dI",
            &dx,
            &[("iout1", &o1), ("iout2", &o2)],
        )?;
        // crossover at zero differential
        let mid = dx.len() / 2;
        rep += &format!(
            "  {}: outputs equal at ΔI=0 (|o1−o2|={:.4}); winner takes over for |ΔI|>0\n",
            node.name,
            (o1[mid] - o2[mid]).abs()
        );
    }
    // (e,f): winners vs C for x = [α..5α]
    let x5 = [1.0, 2.0, 3.0, 4.0, 5.0];
    let cs = dc::grid(0.25, 12.0, 48);
    let mut winners = Vec::new();
    let mut iout = Vec::new();
    for &c in &cs {
        winners.push(wta::winner_count(&alg, &x5, c) as f64);
        iout.push(wta::nofm_current(&alg, &x5, c));
    }
    write_xy_csv(
        &out.join("fig10ef_nofm.csv"),
        "C",
        &cs,
        &[("winners", &winners), ("iout", &iout)],
    )?;
    rep += &format!(
        "  (e,f) winners M: 1 → {} as C grows 0.25 → 12 (N-of-M selection)\n",
        winners.last().unwrap()
    );
    // (g,h): per-output currents vs C
    let mut per: Vec<Vec<f64>> = vec![Vec::new(); 5];
    for &c in &cs {
        let y = wta::wta_outputs(&alg, &x5, c);
        for (i, v) in y.iter().enumerate() {
            per[i].push(*v);
        }
    }
    let refs: Vec<(String, &[f64])> = per
        .iter()
        .enumerate()
        .map(|(i, v)| (format!("iout{}", i + 1), v.as_slice()))
        .collect();
    let refs2: Vec<(&str, &[f64])> = refs.iter().map(|(n, v)| (n.as_str(), *v)).collect();
    write_xy_csv(&out.join("fig10gh_softargmax.csv"), "C", &cs, &refs2)?;
    rep += "  (g,h) per-output activation order follows input rank (SoftArgMax)\n";
    Ok(rep)
}

/// Fig. 12: four-quadrant multiplier across nodes / regimes / temperature.
pub fn fig12(out: &Path) -> Result<String> {
    use crate::cells::multiplier::Multiplier;
    let mut rep = String::from("Fig. 12 — multiplier characteristics (S=3)\n");
    let xs = dc::grid(-1.0, 1.0, 21);
    let ws = [-1.0, -0.5, 0.5, 1.0];
    // calibrate the operating point once on the algorithmic backend — the
    // circuit tier computes the same GMP, so (a, scale) carries over, and
    // re-calibrating through the nested device solve would cost ~36k
    // circuit solves per corner for no information
    let m = Multiplier::calibrate(&Algorithmic::relu(), 3, 1.0);
    // (a) nodes + temperature at WI
    for node in ProcessNode::paper_pair() {
        for t in [27.0, 125.0] {
            let cc = CircuitCorner::new(node, Regime::WeakInversion).at_temp(t);
            let mut series: Vec<(String, Vec<f64>)> = Vec::new();
            for &w in &ws {
                series.push((
                    format!("w={w}"),
                    xs.iter().map(|&x| m.mul(&cc, x, w)).collect(),
                ));
            }
            let refs: Vec<(&str, &[f64])> = series
                .iter()
                .map(|(n, v)| (n.as_str(), v.as_slice()))
                .collect();
            write_xy_csv(
                &out.join(format!("fig12_mult_{}_{}C.csv", node.name, t)),
                "x",
                &xs,
                &refs,
            )?;
            // linearity check at w=1
            let err: f64 = xs
                .iter()
                .zip(&series[3].1)
                .map(|(&x, &y)| (y - x).abs())
                .fold(0.0, f64::max);
            rep += &format!("  {} @{}C: max |y − x·w| (w=1) = {:.3}\n", node.name, t, err);
        }
    }
    // (b,c) regimes per node
    for node in ProcessNode::paper_pair() {
        for regime in Regime::all() {
            let cc = CircuitCorner::new(node, regime);
            let ys: Vec<f64> = xs.iter().map(|&x| m.mul(&cc, x, 1.0)).collect();
            write_xy_csv(
                &out.join(format!("fig12_regime_{}_{}.csv", node.name, regime.short())),
                "x",
                &xs,
                &[("y_w1", &ys)],
            )?;
        }
    }
    rep += "  regime sweeps written (shape preserved WI → SI)\n";
    Ok(rep)
}

/// Fig. 13: power vs spline count; mismatch vs sizing.
pub fn fig13(out: &Path) -> Result<String> {
    let mut rep = String::from("Fig. 13 — power & mismatch scaling\n");
    // (a) power vs S
    let ss: Vec<f64> = (1..=6).map(|s| s as f64).collect();
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for node in ProcessNode::paper_pair() {
        for regime in Regime::all() {
            series.push((
                format!("{}_{}", node.name, regime.short()),
                power::power_vs_s(node, regime, 6),
            ));
        }
    }
    let refs: Vec<(&str, &[f64])> = series
        .iter()
        .map(|(n, v)| (n.as_str(), v.as_slice()))
        .collect();
    write_xy_csv(&out.join("fig13a_power_vs_s.csv"), "S", &ss, &refs)?;
    rep += "  (a) power grows linearly with S at fixed C (CSV written)\n";
    // (b) 7nm: σ vs fin count; (c) 180nm: σ vs area multiple
    let fins = [1.0, 2.0, 4.0, 8.0];
    let s7 = mc::sizing_sensitivity(&FINFET7, &fins, 2000, 17);
    write_xy_csv(&out.join("fig13b_fins.csv"), "fins", &fins, &[("sigma_pct", &s7[..])])?;
    let areas = [1.0, 2.0, 4.0, 8.0, 16.0];
    let s180 = mc::sizing_sensitivity(&CMOS180, &areas, 2000, 18);
    write_xy_csv(&out.join("fig13c_area.csv"), "area_mult", &areas, &[("sigma_pct", &s180[..])])?;
    rep += &format!(
        "  (b) 7nm σ: {:.1}% @1 fin → {:.1}% @8 fins; (c) 180nm σ: {:.1}% → {:.1}%\n",
        s7[0],
        s7[3],
        s180[0],
        s180[4]
    );
    Ok(rep)
}

/// Fig. 15: confusion matrix + regime census for the digits network.
pub fn fig15(out: &Path, limit: usize, threads: usize) -> Result<String> {
    use crate::cells::multiplier::Multiplier;
    use crate::nn;
    let artifacts = crate::runtime::default_artifacts_dir();
    let net = nn::load_net(&artifacts, "digits")?;
    let ds = crate::data::Dataset::load_sacd(&artifacts.join("digits_test.bin"))?;
    let tm = crate::sac::TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
    let cm = nn::evaluate(
        &net,
        || Box::new(tm.clone()),
        &ds,
        limit,
        threads,
    );
    let mut rep = format!(
        "Fig. 15a — digits confusion ({} samples, 180nm WI table tier): accuracy {:.1}%\n",
        cm.total(),
        cm.accuracy() * 100.0
    );
    let mut table = Table::new(
        "confusion (rows = truth)",
        &["t\\p", "0", "1", "2", "3", "4", "5", "6", "7", "8", "9"],
    );
    for t in 0..10 {
        let mut row = vec![t.to_string()];
        for p in 0..10 {
            row.push(cm.counts[t][p].to_string());
        }
        table.row(row);
    }
    rep += &table.render();
    table.write_csv(&out.join("fig15a_confusion.csv"))?;

    // (b): regime census over a sample of inferences
    let inner = Algorithmic::relu();
    let census_p = nn::CensusProvider {
        inner: &inner,
        log: std::cell::RefCell::new(Vec::new()),
    };
    let m = Multiplier::calibrate(&census_p, net.splines, net.c);
    for i in 0..limit.min(20) {
        let _ = nn::forward(&net, &census_p, &m, ds.row(i));
    }
    let vals = census_p.log.borrow();
    let mut table2 = Table::new("Fig. 15b — regime census", &["intended", "% shifted"]);
    for regime in Regime::all() {
        let c = nn::regime_census(&CMOS180, regime, &vals);
        table2.row(vec![
            regime.short().to_string(),
            format!("{:.1}", c.fraction_shifted * 100.0),
        ]);
    }
    rep += &table2.render();
    rep += "  (paper: ~8% of transistors shift one regime; accuracy unaffected)\n";
    table2.write_csv(&out.join("fig15b_census.csv"))?;
    Ok(rep)
}
