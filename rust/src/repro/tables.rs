//! Table regeneration (Tables I-V).  Same contract as `figs`: compute,
//! write `results/tableN.csv`, return a printable report.

use std::path::Path;

use anyhow::Result;

use crate::analysis::{dc, power};
use crate::cells::activations::CellKind;
use crate::cells::multiplier::Multiplier;
use crate::cells::{Algorithmic, CircuitCorner};
use crate::nn;
use crate::pdk::{ProcessNode, regime::Regime, CMOS180, FINFET7};
use crate::sac::TableModel;
use crate::util::table::Table;

fn eng(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1e3 || a < 1e-2 {
        format!("{x:.3e}")
    } else {
        format!("{x:.3}")
    }
}

/// Table I: operation performance parameters (S=1).
pub fn table1(out: &Path) -> Result<String> {
    let mut t = Table::new(
        "Table I — operation performance (S=1)",
        &["node", "regime", "TOPS/mm2", "TOPS/W", "pJ/MAC"],
    );
    for node in ProcessNode::paper_pair() {
        for regime in [
            Regime::StrongInversion,
            Regime::ModerateInversion,
            Regime::WeakInversion,
        ] {
            let p = power::op_perf(node, regime);
            t.row(vec![
                node.name.into(),
                regime.short().into(),
                eng(p.tops_mm2),
                eng(p.tops_w),
                eng(p.pj_mac),
            ]);
        }
    }
    t.write_csv(&out.join("table1.csv"))?;
    let mut rep = t.render();
    rep += "paper anchors: 180nm SI 5 TOPS/mm2 / WI 73 TOPS/W; 7nm SI 5100 TOPS/mm2 / WI 3.6e5 TOPS/W\n";
    Ok(rep)
}

/// Table II: multiplier error metrics + area/power savings vs S.
pub fn table2(out: &Path) -> Result<String> {
    let p = Algorithmic::relu();
    let mut t = Table::new(
        "Table II — multiplier error & savings vs spline count (N=2)",
        &["S", "max err %", "avg abs err %", "bias %", "std %", "area sav %", "power sav %"],
    );
    for s in [1usize, 2, 3] {
        let m = Multiplier::calibrate(&p, s, 1.0);
        let e = m.error_stats(&p, 41);
        let (a_sav, p_sav) = power::savings_vs_full_precision(s);
        t.row(vec![
            s.to_string(),
            format!("{:.1}", e.max * 100.0),
            format!("{:.2}", e.mean_abs * 100.0),
            format!("{:+.2}", e.bias * 100.0),
            format!("{:.2}", e.std * 100.0),
            format!("{a_sav:.1}"),
            format!("{p_sav:.1}"),
        ]);
    }
    t.write_csv(&out.join("table2.csv"))?;
    let mut rep = t.render();
    rep += "paper: max 50/33.3/11.1 %, avg 22.3/9.3/3.7 %, savings 68.7→31.3 % area, 68.4→37.2 % power\n";
    Ok(rep)
}

/// Table III: energy/operation per cell per regime per node + the Err
/// (cross-node mean-abs-deviation) column.
pub fn table3(out: &Path) -> Result<String> {
    let mut t = Table::new(
        "Table III — energy/op [fJ] and cross-node deviation",
        &["op", "Err(180vs7)", "node", "WI", "MI", "SI"],
    );
    let zs = dc::grid(-2.0, 2.0, 17);
    for kind in [
        CellKind::Cosh,
        CellKind::Sinh,
        CellKind::Relu,
        CellKind::Phi1,
        CellKind::Softplus,
    ] {
        // Err: mean-abs deviation between normalized 180nm / 7nm curves
        let c180 = CircuitCorner::new(&CMOS180, Regime::WeakInversion);
        let c7 = CircuitCorner::new(&FINFET7, Regime::WeakInversion);
        let y180 = dc::sweep_cell(kind, &c180, &zs);
        let y7 = dc::sweep_cell(kind, &c7, &zs);
        let (_, err) = dc::curve_deviation(&y180, &y7);
        for node in ProcessNode::paper_pair() {
            t.row(vec![
                kind.name().into(),
                format!("{err:.4}"),
                node.name.into(),
                eng(power::cell_energy(node, Regime::WeakInversion, kind) * 1e15),
                eng(power::cell_energy(node, Regime::ModerateInversion, kind) * 1e15),
                eng(power::cell_energy(node, Regime::StrongInversion, kind) * 1e15),
            ]);
        }
    }
    // WTA row (per input) and multiplier row
    for node in ProcessNode::paper_pair() {
        t.row(vec![
            "wta/input".into(),
            "-".into(),
            node.name.into(),
            eng(power::wta_energy_per_input(node, Regime::WeakInversion) * 1e15),
            eng(power::wta_energy_per_input(node, Regime::ModerateInversion) * 1e15),
            eng(power::wta_energy_per_input(node, Regime::StrongInversion) * 1e15),
        ]);
        t.row(vec![
            "multiply".into(),
            "-".into(),
            node.name.into(),
            eng(power::mult_energy(node, Regime::WeakInversion, 3) * 1e15),
            eng(power::mult_energy(node, Regime::ModerateInversion, 3) * 1e15),
            eng(power::mult_energy(node, Regime::StrongInversion, 3) * 1e15),
        ]);
    }
    t.write_csv(&out.join("table3.csv"))?;
    let mut rep = t.render();
    rep += "paper anchors (fJ): cosh 180nm 40.9/108/222, 7nm 0.02/0.61/23.1; Err 0.006-0.18\n";
    Ok(rep)
}

/// Table IV: classification accuracy — S/W baseline plus H/W at every
/// (node, regime) corner, on the exported test sets.
pub fn table4(out: &Path, limit: usize, threads: usize) -> Result<String> {
    let artifacts = crate::runtime::default_artifacts_dir();
    let mut t = Table::new(
        "Table IV — classification accuracy [%]",
        &["dataset", "regime", "S/W", "H/W 180nm", "H/W 7nm"],
    );
    let mut rep = String::new();
    for task in ["xor", "arem", "digits"] {
        let net = match nn::load_net(&artifacts, task) {
            Ok(n) => n,
            Err(e) => {
                rep += &format!("  !! {task}: {e} (run `make artifacts`)\n");
                continue;
            }
        };
        let ds = crate::data::Dataset::load_sacd(
            &artifacts.join(format!("{task}_test.bin")),
        )?;
        let lim = if task == "digits" { limit } else { ds.n };
        for regime in [
            Regime::StrongInversion,
            Regime::ModerateInversion,
            Regime::WeakInversion,
        ] {
            let mut row = vec![
                task.to_string(),
                regime.short().into(),
                format!("{:.1}", net.acc_sw * 100.0),
            ];
            for node in ProcessNode::paper_pair() {
                let tm = TableModel::calibrate(node, regime, 27.0);
                let cm = nn::evaluate(&net, || Box::new(tm.clone()), &ds, lim, threads);
                row.push(format!("{:.1}", cm.accuracy() * 100.0));
            }
            t.row(row);
        }
    }
    t.write_csv(&out.join("table4.csv"))?;
    rep = t.render() + &rep;
    rep += "paper: XOR 95/93-95, AReM 94/93-94, MNIST 93/92-92.5 (S/W then H/W range)\n";
    Ok(rep)
}

/// Table V: the "This Work" comparison columns.
pub fn table5(out: &Path) -> Result<String> {
    let artifacts = crate::runtime::default_artifacts_dir();
    let acc = nn::load_net(&artifacts, "digits")
        .map(|n| n.acc_sac_algorithmic * 100.0)
        .unwrap_or(f64::NAN);
    let mut t = Table::new(
        "Table V — comparison row for This Work",
        &["node", "supply V", "classifier", "feature size", "regime", "accuracy %", "energy/pixel pJ", "speed MHz"],
    );
    for node in [&FINFET7, &CMOS180] {
        for regime in [Regime::WeakInversion, Regime::StrongInversion] {
            // energy/pixel: full 256-15-10 net energy divided by 256 pixels
            let macs = (256 * 15 + 15 * 10) as f64;
            let e_net = macs * power::mult_energy(node, regime, 3);
            let e_pixel_pj = e_net / 256.0 * 1e12;
            let u = power::unit_op(node, regime, 3);
            let speed_mhz = 1.0 / (4.4 * u.tau_s) / 1e6;
            t.row(vec![
                node.name.into(),
                format!("{}", node.vdd),
                "ANN".into(),
                "256".into(),
                regime.short().into(),
                format!("{acc:.1}"),
                eng(e_pixel_pj),
                format!("{speed_mhz:.2}"),
            ]);
        }
    }
    t.write_csv(&out.join("table5.csv"))?;
    let mut rep = t.render();
    rep += "paper: 7nm WI 0.05 pJ/px @92.2%, SI 3.7 pJ/px @92.5%; 180nm WI 2.3 pJ/px, SI 97.6 pJ/px\n";
    Ok(rep)
}
