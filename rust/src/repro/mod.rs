//! The repro harness: regenerates every table and figure of the paper's
//! evaluation (`sac repro <id>` / `sac repro all`).  DESIGN.md §5 maps each
//! id to the modules that implement it; EXPERIMENTS.md records
//! paper-vs-measured.

pub mod ablations;
pub mod figs;
pub mod tables;

use std::path::PathBuf;

use anyhow::{bail, Result};

/// Options shared by the harness entry points.
#[derive(Clone, Debug)]
pub struct ReproOpts {
    pub out: PathBuf,
    /// sample limit for NN-scale experiments (digits test set is 1000)
    pub limit: usize,
    pub threads: usize,
    /// Monte-Carlo trials for Fig. 8
    pub mc_trials: usize,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            out: PathBuf::from("results"),
            limit: 1000,
            threads: crate::util::pool::default_threads(),
            mc_trials: 40,
        }
    }
}

/// All experiment ids, in paper order.
pub const ALL_IDS: &[&str] = &[
    "fig1", "fig2a", "fig3", "fig4", "fig5", "fig7", "fig8", "fig10",
    "fig12", "fig13", "fig15", "table1", "table2", "table3", "table4",
    "table5", "ablations",
];

/// Run one experiment id, returning its printable report.
pub fn run(id: &str, opts: &ReproOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out)?;
    let out = opts.out.as_path();
    match id {
        "fig1" => figs::fig1(out),
        "fig2a" => figs::fig2a(out),
        "fig3" => figs::fig3(out),
        "fig4" => figs::fig4(out),
        "fig5" => figs::fig5(out),
        "fig7" => figs::fig7(out),
        "fig8" => figs::fig8(out, opts.mc_trials),
        "fig10" => figs::fig10(out),
        "fig12" => figs::fig12(out),
        "fig13" => figs::fig13(out),
        "fig15" => figs::fig15(out, opts.limit, opts.threads),
        "table1" => tables::table1(out),
        "table2" => tables::table2(out),
        "table3" => tables::table3(out),
        "table4" => tables::table4(out, opts.limit, opts.threads),
        "table5" => tables::table5(out),
        "ablations" => ablations::run_all(out),
        other => bail!("unknown experiment id {other:?}; known: {ALL_IDS:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> ReproOpts {
        ReproOpts {
            out: std::env::temp_dir().join("sac_repro_test"),
            limit: 8,
            threads: 2,
            mc_trials: 4,
        }
    }

    #[test]
    fn fig1_and_fig2a_run() {
        let o = quick_opts();
        let r = run("fig1", &o).unwrap();
        assert!(r.contains("FOM peak"));
        let r = run("fig2a", &o).unwrap();
        assert!(r.contains("margin narrows"));
        assert!(o.out.join("fig1_fom.csv").exists());
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", &quick_opts()).is_err());
    }

    #[test]
    fn table1_and_table2_run() {
        let o = quick_opts();
        let r = run("table1", &o).unwrap();
        assert!(r.contains("TOPS"));
        let r = run("table2", &o).unwrap();
        assert!(r.contains("max err"));
    }
}
