//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!  A1 — spline count S: accuracy (multiplier error, LSE fit) vs cost
//!       (power, area) — the paper's central precision/power knob.
//!  A2 — solver iteration budget: GMP residual vs bisection depth —
//!       justifies the fixed 60-iteration kernel and the trimmed
//!       48/40 circuit solve.
//!  A3 — fidelity tier: algorithmic vs table-model vs device-exact —
//!       transfer-curve deviation and per-evaluation cost, the basis for
//!       running NN-scale experiments on the table tier.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::analysis::{dc, power};
use crate::cells::activations::CellKind;
use crate::cells::multiplier::Multiplier;
use crate::cells::{Algorithmic, CircuitCorner, HProvider};
use crate::pdk::{regime::Regime, CMOS180};
use crate::sac::gmp::{residual, solve_bisect, solve_exact, Shape};
use crate::sac::{splines, TableModel};
use crate::util::table::Table;

/// A1: spline count vs accuracy and cost.
pub fn spline_count(out: &Path) -> Result<String> {
    let p = Algorithmic::relu();
    let mut t = Table::new(
        "A1 — spline count: accuracy vs cost",
        &["S", "mult max err %", "LSE max err", "unit power µW (180nm MI)", "devices/unit"],
    );
    for s in 1..=6 {
        let m = Multiplier::calibrate(&p, s, 1.0);
        let e = m.error_stats(&p, 21);
        // LSE fit error of the 2-input unit
        let (offs, cp) = splines::schedule(s, 1.0);
        let pairs = [(0.3, -0.4), (1.0, 0.2), (-0.8, -0.1), (0.5, 0.45)];
        let lse_err = pairs
            .iter()
            .map(|&(a, b)| {
                let mut x = Vec::new();
                for &o in &offs {
                    x.push(a + o);
                    x.push(b + o);
                }
                (solve_exact(&x, cp) - (a.exp() + b.exp()).ln()).abs()
            })
            .fold(0.0, f64::max);
        let u = power::unit_op(&CMOS180, Regime::ModerateInversion, s);
        t.row(vec![
            s.to_string(),
            format!("{:.1}", e.max * 100.0),
            format!("{lse_err:.3}"),
            format!("{:.3}", u.power_w * 1e6),
            format!("{}", 2 * s + 3),
        ]);
    }
    t.write_csv(&out.join("ablation_splines.csv"))?;
    Ok(t.render()
        + "accuracy saturates by S=3 while power/area grow linearly — the paper's S=3 choice\n")
}

/// A2: solver iteration budget vs residual.
pub fn iteration_budget(out: &Path) -> Result<String> {
    let mut t = Table::new(
        "A2 — bisection depth vs GMP residual (softplus w=0.05, M=6)",
        &["iters", "max |residual|", "max |h - h_60|"],
    );
    let mut rng = crate::util::rng::Rng::new(3);
    let cases: Vec<Vec<f64>> = (0..50)
        .map(|_| (0..6).map(|_| rng.uniform_in(-3.0, 3.0)).collect())
        .collect();
    let shape = Shape::Softplus { width: 0.05 };
    let href: Vec<f64> = cases
        .iter()
        .map(|x| solve_bisect(x, 1.0, shape, 60))
        .collect();
    for iters in [10usize, 20, 30, 40, 50, 60] {
        let mut max_r = 0.0f64;
        let mut max_d = 0.0f64;
        for (x, &hr) in cases.iter().zip(&href) {
            let h = solve_bisect(x, 1.0, shape, iters);
            max_r = max_r.max(residual(x, h, 1.0, shape).abs());
            max_d = max_d.max((h - hr).abs());
        }
        t.row(vec![
            iters.to_string(),
            format!("{max_r:.2e}"),
            format!("{max_d:.2e}"),
        ]);
    }
    t.write_csv(&out.join("ablation_iters.csv"))?;
    Ok(t.render()
        + "30 halvings already sit below analog mismatch (1e-2); 60 matches f32 exactly\n")
}

/// A3: fidelity tiers — deviation and cost per evaluation.
pub fn fidelity_tiers(out: &Path) -> Result<String> {
    let zs = dc::grid(-2.0, 2.0, 21);
    let alg = Algorithmic::relu();
    let tm = TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
    let cc = CircuitCorner::new(&CMOS180, Regime::WeakInversion);
    let tiers: Vec<(&str, &dyn HProvider)> = vec![
        ("algorithmic", &alg),
        ("table-model", &tm),
        ("device-exact", &cc),
    ];
    let ref_curve = dc::normalize(&dc::sweep_cell(CellKind::Phi1, &cc, &zs));
    let mut t = Table::new(
        "A3 — fidelity tiers on φ1 (ref = device-exact)",
        &["tier", "max dev", "µs/eval"],
    );
    for (name, p) in tiers {
        let y = dc::normalize(&dc::sweep_cell(CellKind::Phi1, p, &zs));
        let (mx, _) = dc::curve_deviation(&ref_curve, &y);
        let t0 = Instant::now();
        let reps = 50;
        for _ in 0..reps {
            std::hint::black_box(CellKind::Phi1.eval(p, 0.37));
        }
        let us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
        t.row(vec![
            name.to_string(),
            format!("{mx:.4}"),
            format!("{us:.2}"),
        ]);
    }
    t.write_csv(&out.join("ablation_tiers.csv"))?;
    Ok(t.render()
        + "table tier: device-level agreement at algorithmic-level cost → used for Table IV\n")
}

pub fn run_all(out: &Path) -> Result<String> {
    Ok(spline_count(out)? + "\n" + &iteration_budget(out)? + "\n" + &fidelity_tiers(out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_run() {
        let out = std::env::temp_dir().join("sac_ablation_test");
        std::fs::create_dir_all(&out).unwrap();
        let r = spline_count(&out).unwrap();
        assert!(r.contains("S=3 choice"));
        let r = iteration_budget(&out).unwrap();
        assert!(r.contains("mismatch"));
    }
}
