//! Monte-Carlo mismatch engine (Figs. 4b, 8, 13b/c).
//!
//! Each trial samples Pelgrom mismatch onto every device of a circuit-tier
//! cell, re-sweeps the transfer curve, and reports the deviation from the
//! nominal curve.  Trials run on the scoped threadpool, one deterministic
//! RNG stream per trial.

use crate::cells::activations::CellKind;
use crate::cells::{CircuitCorner, HProvider};
use crate::device::MismatchModel;
use crate::pdk::{ProcessNode, regime::Regime};
use crate::util::{pool, rng::Rng, stats};

/// Monte-Carlo configuration.
#[derive(Clone, Debug)]
pub struct McConfig {
    pub trials: usize,
    pub seed: u64,
    pub threads: usize,
    /// sweep grid for the transfer curve
    pub zs: Vec<f64>,
}

impl Default for McConfig {
    fn default() -> Self {
        McConfig {
            trials: 60,
            seed: 1234,
            threads: pool::default_threads(),
            zs: super::dc::grid(-2.0, 2.0, 25),
        }
    }
}

impl McConfig {
    /// [`McConfig::default`] with `SAC_MC_TRIALS` / `SAC_MC_SEED`
    /// environment overrides — CI shrinks the campaign without patching
    /// call sites; explicit CLI flags still take precedence over both.
    /// Unparsable values fall back to the default (env misconfiguration
    /// must not silently change what a figure means).
    pub fn from_env() -> McConfig {
        McConfig::from_env_with(|k| std::env::var(k).ok())
    }

    /// [`McConfig::from_env`] with an injectable lookup (test seam).
    pub fn from_env_with(lookup: impl Fn(&str) -> Option<String>) -> McConfig {
        let mut cfg = McConfig::default();
        if let Some(t) = lookup("SAC_MC_TRIALS").and_then(|v| v.trim().parse::<usize>().ok()) {
            if t > 0 {
                cfg.trials = t;
            }
        }
        if let Some(s) = lookup("SAC_MC_SEED").and_then(|v| v.trim().parse::<u64>().ok()) {
            cfg.seed = s;
        }
        cfg
    }
}

/// Result of one cell's MC campaign.
#[derive(Clone, Debug)]
pub struct McResult {
    pub cell: CellKind,
    pub node_name: String,
    /// nominal normalized curve
    pub nominal: Vec<f64>,
    /// per-trial normalized curves
    pub curves: Vec<Vec<f64>>,
    /// max % deviation from nominal across all trials/points (Fig. 8's
    /// "Maximum % Deviation")
    pub max_pct_dev: f64,
    /// per-point std of the output (for Fig. 13b/c style plots)
    pub point_std: Vec<f64>,
}

/// Run mismatch MC on a cell at a circuit corner.
pub fn run_cell_mc(
    kind: CellKind,
    node: &'static ProcessNode,
    regime: Regime,
    cfg: &McConfig,
) -> McResult {
    let nominal_corner = CircuitCorner::new(node, regime);
    let nominal_raw = super::dc::sweep_cell(kind, &nominal_corner, &cfg.zs);
    let nominal = super::dc::normalize(&nominal_raw);
    let full = nominal
        .iter()
        .map(|v| v.abs())
        .fold(0.0, f64::max)
        .max(1e-30);

    let mm = MismatchModel::new(node);
    let base_rng = Rng::new(cfg.seed);
    // analog-sized matched pairs (what a designer lays out for mirrors)
    let sigma_vt = mm.sigma_vt(node.analog_w_um, node.analog_l_um);
    let sigma_beta = mm.sigma_beta(node.analog_w_um, node.analog_l_um);

    let curves: Vec<Vec<f64>> = pool::parallel_map(cfg.trials, cfg.threads, |t| {
        let mut rng = base_rng.fork(t as u64 + 1);
        // sample per-branch mismatch (enough entries for the widest unit)
        let dvt: Vec<f64> = (0..16).map(|_| rng.gauss_ms(0.0, sigma_vt)).collect();
        let dbeta: Vec<f64> = (0..16).map(|_| rng.gauss_ms(0.0, sigma_beta)).collect();
        let mut corner = CircuitCorner::new(node, regime);
        corner.dvt = dvt;
        corner.dbeta = dbeta;
        let raw = super::dc::sweep_cell(kind, &corner, &cfg.zs);
        // normalize by the *nominal* full-scale so deviation is physical
        raw.iter().map(|v| v / full_scale(&nominal_raw)).collect()
    });

    let nominal_scaled: Vec<f64> = nominal_raw
        .iter()
        .map(|v| v / full_scale(&nominal_raw))
        .collect();

    let mut max_pct = 0.0f64;
    let npts = cfg.zs.len();
    let mut point_std = vec![0.0; npts];
    for i in 0..npts {
        let vals: Vec<f64> = curves.iter().map(|c| c[i]).collect();
        let s = stats::summarize(&vals);
        point_std[i] = s.std;
        for v in &vals {
            max_pct = max_pct.max((v - nominal_scaled[i]).abs() * 100.0 / full);
        }
    }

    McResult {
        cell: kind,
        node_name: node.name.to_string(),
        nominal: nominal_scaled,
        curves,
        max_pct_dev: max_pct,
        point_std,
    }
}

fn full_scale(ys: &[f64]) -> f64 {
    ys.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1e-30)
}

/// σ(I_out) as a function of device sizing (Fig. 13b/c): sweep the device
/// area (fins at 7nm / W·L at 180nm) and the overdrive, return the output
/// std in % of nominal.
pub fn sizing_sensitivity(
    node: &'static ProcessNode,
    sizes: &[f64],
    trials: usize,
    seed: u64,
) -> Vec<f64> {
    let mm = MismatchModel::new(node);
    let base = Rng::new(seed);
    sizes
        .iter()
        .map(|&size_mult| {
            let w = node.wmin_um * size_mult;
            let l = node.lmin_um.max(node.wmin_um);
            let sigma = mm.sigma_vt(w, l);
            // propagate through the WI exponential: σI/I ≈ σVt/(n·UT)
            // measured by sampling rather than the linearized formula
            let mut rng = base.fork(size_mult.to_bits());
            let ut = ProcessNode::ut(27.0);
            let vals: Vec<f64> = (0..trials)
                .map(|_| {
                    let dvt = rng.gauss_ms(0.0, sigma);
                    ((-dvt / (node.n_slope * ut)).exp() - 1.0) * 100.0
                })
                .collect();
            stats::summarize(&vals).std
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::CMOS180;

    fn quick_cfg() -> McConfig {
        McConfig {
            trials: 8,
            seed: 7,
            threads: 2,
            zs: super::super::dc::grid(-1.5, 1.5, 7),
        }
    }

    #[test]
    fn from_env_overrides_trials_and_seed() {
        let cfg = McConfig::from_env_with(|k| match k {
            "SAC_MC_TRIALS" => Some("16".into()),
            "SAC_MC_SEED" => Some("99".into()),
            _ => None,
        });
        assert_eq!(cfg.trials, 16);
        assert_eq!(cfg.seed, 99);
        // non-overridden fields keep the defaults
        let d = McConfig::default();
        assert_eq!(cfg.threads, d.threads);
        assert_eq!(cfg.zs, d.zs);
    }

    #[test]
    fn from_env_ignores_missing_and_bad_values() {
        let cfg = McConfig::from_env_with(|_| None);
        assert_eq!(cfg.trials, McConfig::default().trials);
        assert_eq!(cfg.seed, McConfig::default().seed);
        let cfg = McConfig::from_env_with(|k| match k {
            "SAC_MC_TRIALS" => Some("zero?".into()),
            "SAC_MC_SEED" => Some("-5".into()),
            _ => None,
        });
        assert_eq!(cfg.trials, McConfig::default().trials);
        assert_eq!(cfg.seed, McConfig::default().seed);
        // zero trials would be a degenerate campaign — rejected too
        let cfg = McConfig::from_env_with(|k| match k {
            "SAC_MC_TRIALS" => Some("0".into()),
            _ => None,
        });
        assert_eq!(cfg.trials, McConfig::default().trials);
    }

    #[test]
    fn mc_deviation_small_but_nonzero() {
        let r = run_cell_mc(
            CellKind::Relu,
            &CMOS180,
            Regime::WeakInversion,
            &quick_cfg(),
        );
        assert!(r.max_pct_dev > 0.0, "mismatch must move the curve");
        assert!(r.max_pct_dev < 30.0, "deviation implausibly large: {}", r.max_pct_dev);
        assert_eq!(r.curves.len(), 8);
    }

    #[test]
    fn mc_deterministic() {
        let a = run_cell_mc(CellKind::Relu, &CMOS180, Regime::WeakInversion, &quick_cfg());
        let b = run_cell_mc(CellKind::Relu, &CMOS180, Regime::WeakInversion, &quick_cfg());
        assert_eq!(a.max_pct_dev, b.max_pct_dev);
    }

    #[test]
    fn sizing_larger_devices_less_spread() {
        let stds = sizing_sensitivity(&CMOS180, &[1.0, 4.0, 16.0], 400, 3);
        assert!(stds[0] > stds[1] && stds[1] > stds[2], "{stds:?}");
    }
}
