//! Analysis engines regenerating the paper's evaluation data:
//! DC sweeps (Figs. 3/7/12), Monte-Carlo mismatch (Figs. 4b/8/13b-c),
//! power/energy/area (Tables I/III/V, Fig. 13a), multiplier error
//! (Table II) and SNR (Sec. IV-L3).

pub mod dc;
pub mod montecarlo;
pub mod power;
pub mod snr;
