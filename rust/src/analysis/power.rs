//! Power / energy / speed / area models (Tables I, III, V; Fig. 13a).
//!
//! The paper reports SPICE-measured numbers; we derive them from explicit
//! first-order analog models so every row of every table is regenerable:
//!
//!  * static power of one S-AC unit: `P = (C_tail + I_out + S·I_br) · VDD`;
//!  * settling time: single-pole `τ = N_τ · C_node / g_m(I_bias)` with
//!    `g_m` from the EKV bias point and `C_node` from the device C_gg plus
//!    a wiring multiplier;
//!  * energy/operation = `P · τ_settle`;
//!  * area per device = `k_layout · W·L`, unit = (2S branches + tail +
//!     2 mirror) devices, multiplier = 4 units + bias network.
//!
//! Constants are calibrated so the 180 nm WI corner lands at Table III's
//! scale; the *ratios* across regimes and nodes are pure physics (bias
//! currents, supplies, capacitances) — those are what EXPERIMENTS.md
//! compares.

// Physical-unit annotations like "[V]" / "[A]" in the docs below are
// prose, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

use crate::cells::activations::CellKind;
use crate::pdk::{Polarity, ProcessNode, regime::Regime};

/// settling multiplier (number of time constants to 0.1% + phase margin)
const N_TAU: f64 = 7.0;
/// wiring capacitance multiplier on top of device C_gg
const K_WIRE: f64 = 3.0;
/// layout area overhead over raw W·L (contacts, spacing, guard rings)
const K_LAYOUT: f64 = 14.0;
/// branch standing-current fraction of C (spline overhead, Fig. 13a slope)
const K_BRANCH: f64 = 0.12;

/// Operating-point characterization of one S-AC unit.
#[derive(Clone, Copy, Debug)]
pub struct UnitOp {
    /// bias (tail) current C [A]
    pub c_bias: f64,
    /// static power [W]
    pub power_w: f64,
    /// settling time [s]
    pub tau_s: f64,
    /// silicon area [µm²]
    pub area_um2: f64,
}

/// Characterize one S-AC unit with `s` splines at (node, regime).
pub fn unit_op(node: &'static ProcessNode, regime: Regime, s: usize) -> UnitOp {
    let c = node.bias_current(regime);
    let dev = crate::device::Mosfet::square(node, Polarity::N);
    // gm at the bias current: evaluate at the regime's gate bias
    let vg = node.bias_for(regime, 27.0);
    let gm = dev.gm(vg, 0.0).max(1e-12);
    let id = dev.forward(vg, 0.0);
    // scale gm to the actual tail current (gm ∝ I in WI, ∝ sqrt(I) in SI —
    // use the EKV-consistent local ratio)
    let gm_at_c = gm * (c / id.max(1e-30));
    let cgg_f = node.cox_ff_um2 * dev.w_um * dev.l_um * 1e-15; // F
    let c_node = K_WIRE * cgg_f * (2 * s + 3) as f64; // all branch gates hang on V_B
    let tau = N_TAU * c_node / gm_at_c;
    let n_dev = 2 * s + 3; // 2S branches + tail + 2-mirror output
    let area = K_LAYOUT * dev.w_um * dev.l_um * n_dev as f64;
    let power = (2.0 * c + s as f64 * K_BRANCH * c) * node.vdd;
    UnitOp {
        c_bias: c,
        power_w: power,
        tau_s: tau,
        area_um2: area,
    }
}

/// Energy per operation of a composed cell [J] (Table III rows).
pub fn cell_energy(node: &'static ProcessNode, regime: Regime, kind: CellKind) -> f64 {
    let u = unit_op(node, regime, 3);
    kind.unit_count() as f64 * u.power_w * u.tau_s
}

/// Energy per op of the N-input WTA [J/input] (Table III's N× row).
pub fn wta_energy_per_input(node: &'static ProcessNode, regime: Regime) -> f64 {
    let u = unit_op(node, regime, 1);
    // one branch + share of tail per input
    0.6 * u.power_w * u.tau_s
}

/// Multiplier (4 proto units + bias network) energy per MAC [J].
pub fn mult_energy(node: &'static ProcessNode, regime: Regime, s: usize) -> f64 {
    let u = unit_op(node, regime, s);
    4.4 * u.power_w * u.tau_s
}

/// Multiplier area [µm²].
pub fn mult_area(node: &'static ProcessNode, s: usize) -> f64 {
    let u = unit_op(node, Regime::ModerateInversion, s);
    4.4 * u.area_um2
}

/// Table I: operation-performance parameters at S=1.
#[derive(Clone, Copy, Debug)]
pub struct OpPerf {
    /// computational density [TOPS/mm²]
    pub tops_mm2: f64,
    /// power efficiency [TOPS/W]
    pub tops_w: f64,
    /// system efficiency [pJ/MAC]
    pub pj_mac: f64,
}

pub fn op_perf(node: &'static ProcessNode, regime: Regime) -> OpPerf {
    let s = 1;
    let u = unit_op(node, regime, s);
    let e_mac = mult_energy(node, regime, s); // J
    let rate = 1.0 / (4.4 * u.tau_s); // MAC/s of one multiplier (sequential settle)
    let area_mm2 = mult_area(node, s) * 1e-6;
    OpPerf {
        tops_mm2: rate / area_mm2 * 1e-12,
        tops_w: 1e-12 / e_mac,
        pj_mac: e_mac * 1e12,
    }
}

/// Fig. 13a: average power vs spline count at fixed C.
pub fn power_vs_s(node: &'static ProcessNode, regime: Regime, smax: usize) -> Vec<f64> {
    (1..=smax)
        .map(|s| unit_op(node, regime, s).power_w)
        .collect()
}

/// Table II area/power savings of the S-spline multiplier vs a
/// full-precision Gilbert-style multiplier (paper cites [29], [30]).
/// The reference design is modeled as the S=3 S-AC multiplier's device
/// budget × the precision factor implied by the paper's 68.7% (S=1)
/// savings anchor.
pub fn savings_vs_full_precision(s: usize) -> (f64, f64) {
    // reference multiplier device/bias budget (devices, standing current
    // units) — anchored so S=1 ≈ 68.7% area / 68.4% power savings
    let ref_devices = 16.0 * K_LAYOUT;
    let ref_power_units = 7.0;
    let unit_devices = (2 * s + 3) as f64;
    let area_sac = 4.4 * K_LAYOUT * unit_devices / 1.4; // shared bias net
    let power_sac = (2.0 + s as f64 * K_BRANCH) * 4.4 / 2.0;
    let area_sav = (1.0 - area_sac / (ref_devices * 4.4 / 1.4)).max(0.0) * 100.0;
    let pow_sav = (1.0 - power_sac / ref_power_units).max(0.0) * 100.0;
    (area_sav, pow_sav)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::{CMOS180, FINFET7};

    #[test]
    fn wi_lowest_energy_si_highest() {
        // Table III: least energy in WI, worst in SI — per node
        for node in [&CMOS180, &FINFET7] {
            let e_wi = cell_energy(node, Regime::WeakInversion, CellKind::Cosh);
            let e_mi = cell_energy(node, Regime::ModerateInversion, CellKind::Cosh);
            let e_si = cell_energy(node, Regime::StrongInversion, CellKind::Cosh);
            assert!(e_wi < e_mi && e_mi < e_si, "{}: {e_wi} {e_mi} {e_si}", node.name);
        }
    }

    #[test]
    fn finfet_orders_of_magnitude_cheaper() {
        // Table III: 7nm energies are 3-4 orders below 180nm
        let e180 = cell_energy(&CMOS180, Regime::WeakInversion, CellKind::Relu);
        let e7 = cell_energy(&FINFET7, Regime::WeakInversion, CellKind::Relu);
        assert!(e180 / e7 > 100.0, "ratio={}", e180 / e7);
    }

    #[test]
    fn table1_orderings() {
        // Table I: computational density peaks in SI; power efficiency
        // peaks in WI; 7nm beats 180nm across the board.
        for node in [&CMOS180, &FINFET7] {
            let wi = op_perf(node, Regime::WeakInversion);
            let si = op_perf(node, Regime::StrongInversion);
            assert!(si.tops_mm2 > wi.tops_mm2, "{}", node.name);
            assert!(wi.tops_w > si.tops_w, "{}", node.name);
        }
        assert!(
            op_perf(&FINFET7, Regime::StrongInversion).tops_mm2
                > op_perf(&CMOS180, Regime::StrongInversion).tops_mm2 * 100.0
        );
    }

    #[test]
    fn energy_scale_matches_table3_order_of_magnitude() {
        // 180nm WI cosh: paper 40.86 fJ — ours within 30x
        let e = cell_energy(&CMOS180, Regime::WeakInversion, CellKind::Cosh) * 1e15;
        assert!(e > 1.0 && e < 1500.0, "cosh 180nm WI = {e} fJ");
    }

    #[test]
    fn power_grows_with_s_fig13a() {
        let p = power_vs_s(&CMOS180, Regime::WeakInversion, 6);
        for w in p.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn savings_decrease_with_s_table2() {
        let (a1, p1) = savings_vs_full_precision(1);
        let (a2, p2) = savings_vs_full_precision(2);
        let (a3, p3) = savings_vs_full_precision(3);
        assert!(a1 > a2 && a2 > a3, "area {a1} {a2} {a3}");
        assert!(p1 > p2 && p2 > p3, "power {p1} {p2} {p3}");
        // anchored near the paper's S=1 point
        assert!((a1 - 68.7).abs() < 10.0, "a1={a1}");
    }
}
