//! SNR analysis of parallel S-AC blocks (Sec. IV-L3, eqs. 31-36).
//!
//! The claim: N parallel blocks sum the signal coherently (×N amplitude)
//! but their circuit noise incoherently (×√N RMS), so SNR grows ∝ N —
//! "for each increase in the number of connected S-AC blocks in parallel,
//! the circuit SNR increases by twice".  Verified here both analytically
//! and by Monte-Carlo over the device noise model.

use crate::device::{noise, Mosfet};
use crate::pdk::{Polarity, ProcessNode, regime::Regime};
use crate::util::rng::Rng;

/// Analytic SNR (power ratio) of `n` parallel blocks, unit signal per
/// block, circuit RMS noise `n_ckt` per block.
pub fn snr_parallel(n: usize, signal: f64, n_ckt: f64) -> f64 {
    let s = signal * n as f64;
    let noise_power = n as f64 * n_ckt * n_ckt; // incoherent sum
    s * s / noise_power
}

/// Monte-Carlo SNR measurement: simulate `trials` samples of `n` parallel
/// blocks, each contributing signal + white device noise.
pub fn snr_measured(
    node: &'static ProcessNode,
    regime: Regime,
    n_blocks: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    let dev = Mosfet::square(node, Polarity::N);
    let vg = node.bias_for(regime, 27.0);
    let bw = 1e6; // 1 MHz measurement bandwidth
    let n_rms = noise::rms_noise(&dev, vg, 0.0, bw);
    let signal = node.bias_current(regime) * 0.5;
    let mut rng = Rng::new(seed);
    let mut acc_sig = 0.0;
    let mut acc_noise = 0.0;
    for _ in 0..trials {
        let mut tot = 0.0;
        for _ in 0..n_blocks {
            tot += signal + rng.gauss_ms(0.0, n_rms);
        }
        acc_sig += (signal * n_blocks as f64) * (signal * n_blocks as f64);
        let dev_ = tot - signal * n_blocks as f64;
        acc_noise += dev_ * dev_;
    }
    acc_sig / acc_noise.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdk::CMOS180;

    #[test]
    fn analytic_snr_doubles_per_block_doubling() {
        let s1 = snr_parallel(1, 1.0, 0.1);
        let s2 = snr_parallel(2, 1.0, 0.1);
        let s4 = snr_parallel(4, 1.0, 0.1);
        assert!((s2 / s1 - 2.0).abs() < 1e-9);
        assert!((s4 / s2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn measured_snr_tracks_analytic_scaling() {
        let s1 = snr_measured(&CMOS180, Regime::WeakInversion, 1, 40_000, 5);
        let s2 = snr_measured(&CMOS180, Regime::WeakInversion, 2, 40_000, 6);
        let ratio = s2 / s1;
        assert!((ratio - 2.0).abs() < 0.3, "ratio={ratio}");
    }
}
