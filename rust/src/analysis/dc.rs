//! DC transfer-curve sweeps of standard cells across operating corners
//! (Figs. 3, 7, 12) plus normalized cross-corner deviation metrics
//! (Table III's Err column).

use crate::cells::activations::CellKind;
use crate::cells::HProvider;
use crate::util::stats;

/// Uniform sweep grid.
pub fn grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

/// Sweep a cell's transfer curve on a backend.
pub fn sweep_cell(kind: CellKind, p: &dyn HProvider, zs: &[f64]) -> Vec<f64> {
    zs.iter().map(|&z| kind.eval(p, z)).collect()
}

/// Normalize a curve by its max |value| (the paper plots h/Imax).
pub fn normalize(ys: &[f64]) -> Vec<f64> {
    let m = ys.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1e-30);
    ys.iter().map(|v| v / m).collect()
}

/// Max (and mean) absolute deviation between two normalized curves — the
/// paper's "Err = MAX |Mean Absolute Deviation|" between 180nm and 7nm
/// (Table III footnote).
pub fn curve_deviation(a: &[f64], b: &[f64]) -> (f64, f64) {
    let na = normalize(a);
    let nb = normalize(b);
    (
        stats::max_abs_dev(&na, &nb),
        stats::mean_abs_dev(&na, &nb),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Algorithmic;

    #[test]
    fn grid_endpoints() {
        let g = grid(-1.0, 1.0, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] + 1.0).abs() < 1e-12);
        assert!((g[4] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_peak_is_one() {
        let n = normalize(&[0.5, -2.0, 1.0]);
        assert!((n[1] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn identical_curves_zero_deviation() {
        let p = Algorithmic::relu();
        let zs = grid(-2.0, 2.0, 21);
        let ys = sweep_cell(CellKind::Phi1, &p, &zs);
        let (mx, mean) = curve_deviation(&ys, &ys);
        assert_eq!(mx, 0.0);
        assert_eq!(mean, 0.0);
    }
}
