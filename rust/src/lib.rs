//! # S-AC: Shape-based Analog Computing
//!
//! Full-stack reproduction of *"Process, Bias and Temperature Scalable
//! CMOS Analog Computing Circuits for Machine Learning"* (Kumar et al.,
//! IEEE TCSI 2022).  See DESIGN.md for the architecture and EXPERIMENTS.md
//! for paper-vs-measured results.
pub mod util;
pub mod pdk;
pub mod device;
pub mod sac;
pub mod cells;
pub mod analysis;
pub mod data;
pub mod nn;
pub mod repro;
pub mod runtime;
pub mod coordinator;
