//! # S-AC: Shape-based Analog Computing
//!
//! Full-stack reproduction of *"Process, Bias and Temperature Scalable
//! CMOS Analog Computing Circuits for Machine Learning"* (Kumar et al.,
//! IEEE TCSI 2022), plus a multi-task serving layer on top of it.
//!
//! The stack, bottom to top (repo-root `DESIGN.md` has the full
//! architecture; `EXPERIMENTS.md` tracks paper-vs-measured results):
//!
//! 1. **Device** — [`pdk`] process decks and the [`device`] EKV all-region
//!    MOSFET / diode / mismatch / noise models.
//! 2. **S-AC core** — [`sac`]: the algorithmic GMP solvers, spline
//!    schedule, device-exact unit circuit and calibrated table models.
//! 3. **Cells & networks** — [`cells`] standard cells (activations,
//!    multiplier, WTA) and [`nn`] network evaluation on any fidelity tier.
//! 4. **Serving** — [`runtime`] executes the AOT-exported graphs natively;
//!    [`coordinator`] batches, routes and serves them across tasks and
//!    worker threads.
//! 5. **Chaos** — [`faults`]: deterministic fault-injection plans
//!    (mismatch, temperature drift, stuck cells, panics, storms) replayed
//!    against the serving stack with bit-identical reports per seed.
//!
//! [`analysis`] and [`repro`] regenerate the paper's figures/tables;
//! [`data`] loads the exported datasets/weights; [`util`] holds the
//! in-repo infrastructure substrates (JSON, CLI, RNG, stats, pools,
//! property testing, benchmarking — the image vendors no serde_json /
//! clap / rayon / criterion / proptest).

// Lint posture for a numeric-kernel codebase (CI runs
// `cargo clippy -- -D warnings`): index-based loops mirror the paper's
// subscripted equations and frequently index several buffers with
// derived offsets, solver/cell signatures legitimately carry many scalar
// knobs, and `.max(lo).min(hi)` chains predate `clamp` in the seed.
// Correctness/suspicious/perf lints stay fully enforced.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_clamp,
    clippy::type_complexity
)]

pub mod util;
pub mod pdk;
pub mod device;
pub mod sac;
pub mod cells;
pub mod analysis;
pub mod data;
pub mod nn;
pub mod repro;
pub mod runtime;
pub mod coordinator;
pub mod faults;
