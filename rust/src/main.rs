//! `sac` — the S-AC framework CLI.
//!
//! ```text
//! repro <id|all>        regenerate a paper table/figure (results/*.csv)
//! serve <task>          batched inference through the multi-task router
//! bench-serve           synthetic router throughput bench (no artifacts)
//! metrics               synthetic serving run + telemetry exposition
//! trace export          Chrome/Perfetto trace dump of a synthetic run
//! characterize <cell>   DC sweep of a standard cell across corners
//! mc <cell>             Monte-Carlo mismatch campaign
//! chaos                 replay a fault-injection plan against the stack
//! info                  stack/PDK/artifact status
//! ```

// Same lint posture as the library crate (see src/lib.rs).
#![allow(clippy::needless_range_loop, clippy::manual_clamp)]

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use sac::analysis::{dc, montecarlo as mc};
use sac::cells::activations::CellKind;
use sac::cells::CircuitCorner;
use sac::coordinator::{
    check_schema, metrics_file_json, scrape, synthetic_engine_with_mode, Engine, MetricsSnapshot,
    Router, RouterConfig,
};
use sac::data::Dataset;
use sac::faults::{
    run_chaos, run_chaos_with_metrics, run_recovery, run_recovery_with_metrics, ChaosConfig,
    EnvelopeViolation, FaultPlan,
};
use sac::pdk::{regime::Regime, ProcessNode};
use sac::repro::{self, ReproOpts};
use sac::runtime::{default_artifacts_dir, ExecMode, Runtime};
use sac::util::cli::Args;
use sac::util::rng::Rng;
use sac::util::table::{write_xy_csv, Table};

const USAGE: &str = "\
sac — shape-based analog computing framework (TCSI 2022 reproduction)

USAGE:
  sac repro <id|all> [--out results] [--limit N] [--threads N] [--mc-trials N]
  sac serve <task> [--artifacts DIR] [--requests N] [--workers N] [--engine scalar|batched]
                   [--threads N] [--deadline-ms MS] [--max-queue N] [--canary-every B]
                   [--metrics-out FILE] [--metrics-addr ADDR] [--hold-ms MS]
  sac bench-serve [--tasks K] [--workers N] [--submitters N] [--requests N] [--batch B]
                  [--engine scalar|batched] [--threads N] [--deadline-ms MS] [--max-queue N]
                  [--canary-every B] [--metrics-out FILE] [--metrics-addr ADDR] [--hold-ms MS]
  sac metrics [--tasks K] [--requests N] [--workers N] [--batch B] [--seed S]
              [--format prom|json|both] [--out FILE] [--validate FILE]
  sac trace export [--tasks K] [--requests N] [--workers N] [--batch B] [--threads N]
                   [--seed S] [--capacity N] [--out FILE]
  sac characterize <cell> [--node NAME] [--regime WI|MI|SI] [--temp C] [--out results]
  sac mc <cell> [--node NAME] [--trials N]
  sac chaos [--plan FILE | --seed S] [--trials N] [--workers N] [--threads N] [--out results]
            [--check] [--recover] [--metrics-out FILE]
  sac info [--artifacts DIR]

engines: batched (default; columnar lookup-grid engine) | scalar (per-row GMP solves)
env: SAC_MC_TRIALS / SAC_MC_SEED override the mc campaign defaults (flags win)
     SAC_THREADS sets the default intra-batch row parallelism (--threads wins);
     results are bit-identical at any thread count
     SAC_TRACE=1 enables span tracing (SAC_TRACE_CAPACITY sizes the ring);
     SAC_SIGNAL_HEALTH=1 enables the analog signal-health accumulators
     --metrics-out / sac metrics emit Prometheus + canonical JSON telemetry;
     --metrics-addr ADDR serves /metrics, /metrics.json and /healthz live while
     a serving command runs (--hold-ms keeps the endpoint up after the workload);
     sac metrics --validate FILE checks a metrics file against this build's schema;
     sac trace export prints a chrome://tracing / Perfetto trace of a seeded run
serving resilience (DESIGN.md §11): --deadline-ms sheds requests still unexecuted
     past their deadline, --max-queue bounds the admission queue, --canary-every B
     probes each lane's health every B batches and quarantines + rebuilds on drift
chaos exit codes: 0 pass | 1 envelope/invariant violation | 2 IO, parse or plan error;
     --recover replays the self-healing loop (detect, quarantine, rebuild, shed)

ids: fig1 fig2a fig3 fig4 fig5 fig7 fig8 fig10 fig12 fig13 fig15
     table1 table2 table3 table4 table5 | all
cells: cosh sinh relu phi1 phi2 softplus
tasks: xor arem digits
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    sac::util::trace::init_from_env();
    sac::nn::batch::signal_health_init_from_env();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        // exit-code contract for `sac chaos`: envelope / invariant
        // violations exit 1; IO, parse and invalid-plan errors exit 2
        let code = if argv[0] == "chaos" {
            if e.downcast_ref::<EnvelopeViolation>().is_some() {
                1
            } else {
                2
            }
        } else {
            1
        };
        std::process::exit(code);
    }
}

/// Intra-batch row parallelism for the serving commands: an explicit
/// `--threads` flag wins, else the `SAC_THREADS` env default, else
/// `None` (keep the engine's own setting).
fn kernel_threads_arg(args: &Args) -> Result<Option<usize>> {
    match args.get("threads") {
        Some(_) => Ok(Some(args.get_usize("threads", 1)?.max(1))),
        None => Ok(sac::util::pool::threads_from_env()),
    }
}

/// Self-healing knobs shared by `serve` and `bench-serve`
/// (`--deadline-ms`, `--max-queue`, `--canary-every`).
fn resilience_args(args: &Args, mut cfg: RouterConfig) -> Result<RouterConfig> {
    if args.get("deadline-ms").is_some() {
        let ms = args.get_usize("deadline-ms", 0)?.max(1) as u64;
        cfg.deadline = Some(Duration::from_millis(ms));
    }
    if args.get("max-queue").is_some() {
        cfg.max_queue = Some(args.get_usize("max-queue", 0)?.max(1));
    }
    cfg.canary_every = args.get_usize("canary-every", 0)? as u64;
    Ok(cfg)
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["verbose", "check", "recover"])?;
    match args.command.as_str() {
        "repro" => cmd_repro(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "metrics" => cmd_metrics(&args),
        "trace" => cmd_trace(&args),
        "characterize" => cmd_characterize(&args),
        "mc" => cmd_mc(&args),
        "chaos" => cmd_chaos(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Start the live scrape endpoint when `--metrics-addr` is given
/// (DESIGN.md §12).  Port `0` binds an ephemeral port; the resolved
/// address is printed so callers can find it.
fn scrape_endpoint_args(
    args: &Args,
    router: &Arc<Router>,
    name: &str,
) -> Result<Option<scrape::ScrapeServer>> {
    match args.get("metrics-addr") {
        Some(addr) => {
            let srv = scrape::serve(Arc::clone(router), addr, name)?;
            println!(
                "metrics endpoint: http://{}/metrics (also /metrics.json, /healthz)",
                srv.addr()
            );
            Ok(Some(srv))
        }
        None => Ok(None),
    }
}

/// `--hold-ms` keeps the scrape endpoint up after the workload drains so
/// external scrapers (the CI curl job) can hit a quiescent router.
fn hold_scrape_endpoint(args: &Args, srv: Option<scrape::ScrapeServer>) -> Result<()> {
    if let Some(mut srv) = srv {
        let hold = args.get_usize("hold-ms", 0)? as u64;
        if hold > 0 {
            println!("holding metrics endpoint for {hold} ms");
            std::thread::sleep(Duration::from_millis(hold));
        }
        srv.shutdown();
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = ReproOpts {
        out: PathBuf::from(args.get_or("out", "results")),
        limit: args.get_usize("limit", 1000)?,
        threads: args.get_usize("threads", sac::util::pool::default_threads())?,
        mc_trials: args.get_usize("mc-trials", 40)?,
    };
    let ids: Vec<&str> = if id == "all" {
        repro::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        match repro::run(id, &opts) {
            Ok(rep) => {
                println!("━━━ {id} ({:.1}s) ━━━", t0.elapsed().as_secs_f64());
                println!("{rep}");
            }
            Err(e) => println!("━━━ {id} FAILED: {e:#}"),
        }
    }
    Ok(())
}

/// Serve one task's test set through the router (single lane, shared
/// worker pool) and score it against the recorded labels.
fn cmd_serve(args: &Args) -> Result<()> {
    let task = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("digits");
    let artifacts = PathBuf::from(
        args.get_or("artifacts", default_artifacts_dir().to_str().unwrap()),
    );
    let n_req = args.get_usize("requests", 256)?;
    let workers = args.get_usize("workers", sac::util::pool::default_threads())?;
    let mode = ExecMode::parse(args.get_or("engine", "batched"))?;
    let kernel_threads = kernel_threads_arg(args)?;
    let rt = Runtime::new(&artifacts)?;
    println!("backend: {}", rt.platform());
    let engine = Engine::new_with_mode(&rt, task, mode)?;
    println!(
        "serving {task}: net {:?}, batch={} dim={} workers={workers} engine={} threads={}",
        engine.net.sizes,
        engine.batch_size,
        engine.dim,
        engine.mode().name(),
        kernel_threads.unwrap_or(1)
    );
    let ds = Dataset::load_sacd(&artifacts.join(format!("{task}_test.bin")))?;
    let n = n_req.min(ds.n);
    let cfg = resilience_args(
        args,
        RouterConfig {
            workers,
            kernel_threads,
            ..RouterConfig::default()
        },
    )?;
    let resilient =
        cfg.deadline.is_some() || cfg.max_queue.is_some() || cfg.canary_every > 0;
    let router = Arc::new(Router::new(cfg, vec![(task.to_string(), engine)]));
    let scrape_srv = scrape_endpoint_args(args, &router, "serve")?;
    let t0 = Instant::now();
    let mut reqs = Vec::with_capacity(n);
    let mut rejected = 0usize;
    for i in 0..n {
        match router.submit(0, ds.row(i).to_vec()) {
            Ok(id) => reqs.push((i, id)),
            // bounded admission queue: overload rejections are expected
            Err(e) if e.to_string().contains("shed") => rejected += 1,
            Err(e) => return Err(e),
        }
    }
    router.drain(Duration::from_secs(600))?;
    let wall = t0.elapsed().as_secs_f64();
    let (mut correct, mut answered, mut shed) = (0usize, 0usize, 0usize);
    for &(i, req) in &reqs {
        match router.try_take(req) {
            Ok(Some(r)) => {
                answered += 1;
                if r.pred == ds.y[i] as usize {
                    correct += 1;
                }
            }
            Ok(None) => bail!("request {i} unanswered"),
            Err(e) if e.to_string().contains("shed") => shed += 1,
            Err(e) => return Err(e),
        }
    }
    println!(
        "accuracy {}/{} = {:.1}%  |  {}",
        correct,
        answered,
        correct as f64 / answered.max(1) as f64 * 100.0,
        router.metrics(0).report()
    );
    if resilient {
        let h = router.health_snapshot();
        println!(
            "  resilience: {} admitted, {rejected} rejected, {shed} shed past deadline; \
             lane health {}, {} retries, {} requeues",
            reqs.len(),
            router.health_states().first().map(|(_, s)| s.name()).unwrap_or("healthy"),
            h.retries,
            h.requeues
        );
    }
    println!(
        "end-to-end: {:.2}s wall = {:.0} req/s through the router",
        wall,
        n as f64 / wall
    );
    if let Some(path) = args.get("metrics-out") {
        write_metrics_file(path, &[router.metrics_snapshot("serve")])?;
    }
    hold_scrape_endpoint(args, scrape_srv)
}

/// Write snapshots as a canonical JSON metrics file (current schema:
/// [`sac::coordinator::METRICS_SCHEMA`]), creating parent directories
/// as needed.
fn write_metrics_file(path: &str, snapshots: &[MetricsSnapshot]) -> Result<()> {
    let p = PathBuf::from(path);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&p, metrics_file_json(snapshots).to_string())?;
    println!("wrote {}", p.display());
    Ok(())
}

/// Synthetic multi-task serving benchmark: K random-weight S-AC nets, M
/// concurrent submitters, one shared worker pool.  Runs on a clean
/// checkout (no artifacts needed) — this is the router's smoke workload.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let tasks = args.get_usize("tasks", 2)?.max(1);
    let workers = args.get_usize("workers", sac::util::pool::default_threads())?;
    let submitters = args.get_usize("submitters", 4)?.max(1);
    let requests = args.get_usize("requests", 512)?;
    let batch = args.get_usize("batch", 32)?.max(1);
    let mode = ExecMode::parse(args.get_or("engine", "batched"))?;
    let kernel_threads = kernel_threads_arg(args)?;
    const DIM: usize = 16;
    println!(
        "bench-serve: {tasks} task(s) × [{DIM},12,4] S-AC nets, batch={batch}, \
         {submitters} submitter(s), {workers} worker(s), {requests} requests, \
         engine={} threads={}",
        mode.name(),
        kernel_threads.unwrap_or(1)
    );
    let engines = (0..tasks)
        .map(|t| {
            Ok((
                format!("task{t}"),
                synthetic_engine_with_mode(100 + t as u64, &[DIM, 12, 4], batch, mode)?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let cfg = resilience_args(
        args,
        RouterConfig {
            workers,
            kernel_threads,
            ..RouterConfig::default()
        },
    )?;
    let resilient =
        cfg.deadline.is_some() || cfg.max_queue.is_some() || cfg.canary_every > 0;
    let router = Arc::new(Router::new(cfg, engines));
    let scrape_srv = scrape_endpoint_args(args, &router, "bench-serve")?;
    let t0 = Instant::now();
    let admitted: usize = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(submitters);
        for s in 0..submitters {
            let router = &router;
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(900 + s as u64);
                let per = requests / submitters
                    + usize::from(s < requests % submitters);
                let mut ok = 0usize;
                for k in 0..per {
                    let task = (s + k) % tasks;
                    let feats: Vec<f32> =
                        (0..DIM).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
                    // with --max-queue, overload rejections are expected
                    if router.submit(task, feats).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("submitter thread panicked"))
            .sum()
    });
    router.drain(Duration::from_secs(600))?;
    let wall = t0.elapsed().as_secs_f64();
    for t in 0..tasks {
        println!("  task{t}: {}", router.metrics(t).report());
    }
    // written before the delivery assertion so a failing run still
    // leaves its telemetry behind (CI uploads it as an artifact)
    if let Some(path) = args.get("metrics-out") {
        write_metrics_file(path, &[router.metrics_snapshot("bench-serve")])?;
    }
    let agg = router.aggregate_metrics();
    if resilient {
        let h = router.health_snapshot();
        println!(
            "  resilience: {admitted}/{requests} admitted, {} shed past deadline, \
             {} rejected at admission, {} canary probes ({} disagreed), {} retries",
            h.shed_deadline, h.shed_queue, h.probes, h.probe_disagreements, h.retries
        );
    } else {
        ensure!(
            agg.total_requests() == requests,
            "answered {} of {requests} requests",
            agg.total_requests()
        );
    }
    println!("  aggregate: {}", agg.report());
    println!(
        "end-to-end: {requests} requests in {wall:.2}s = {:.0} req/s",
        requests as f64 / wall
    );
    hold_scrape_endpoint(args, scrape_srv)
}

/// Self-contained telemetry demo: run a deterministic synthetic serving
/// workload through the router and print its metrics in Prometheus text
/// exposition and/or canonical JSON (DESIGN.md §9).  Runs on a clean
/// checkout — the schema-stability goldens in `tests/observability.rs`
/// pin both formats.
fn cmd_metrics(args: &Args) -> Result<()> {
    // `--validate FILE`: schema-compat check only, no workload.  Unknown
    // `sac-metrics/*` versions are a typed error (exit 1), so scripts
    // that read metrics files fail loudly instead of misparsing.
    if let Some(path) = args.get("validate") {
        let doc = sac::util::json::parse_file(Path::new(path))?;
        let schema = doc.get("schema")?.as_str()?.to_string();
        check_schema(&schema)?;
        let n = doc.get("snapshots")?.as_arr()?.len();
        println!("ok: {path} is {schema} with {n} snapshot(s)");
        return Ok(());
    }
    let tasks = args.get_usize("tasks", 2)?.max(1);
    let requests = args.get_usize("requests", 128)?;
    let workers = args.get_usize("workers", 4)?.max(1);
    let batch = args.get_usize("batch", 16)?.max(1);
    let seed = args.get_usize("seed", 7)? as u64;
    let format = args.get_or("format", "both");
    const DIM: usize = 8;
    let engines = (0..tasks)
        .map(|t| {
            Ok((
                format!("task{t}"),
                synthetic_engine_with_mode(
                    seed + t as u64,
                    &[DIM, 10, 4],
                    batch,
                    ExecMode::Batched,
                )?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let router = Router::new(
        RouterConfig {
            workers,
            ..RouterConfig::default()
        },
        engines,
    );
    let mut rng = Rng::new(seed ^ 0x5AC0);
    let mut reqs = Vec::with_capacity(requests);
    for k in 0..requests {
        let feats: Vec<f32> = (0..DIM).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        reqs.push(router.submit(k % tasks, feats)?);
    }
    router.drain(Duration::from_secs(600))?;
    for &req in &reqs {
        router
            .try_take(req)?
            .ok_or_else(|| anyhow!("request {req:?} unanswered"))?;
    }
    let snap = router.metrics_snapshot("metrics");
    let json_text = metrics_file_json(std::slice::from_ref(&snap)).to_string();
    match format {
        "prom" => print!("{}", snap.prometheus()),
        "json" => println!("{json_text}"),
        "both" => {
            print!("{}", snap.prometheus());
            println!("{json_text}");
        }
        other => bail!("unknown --format {other:?} (use prom, json or both)"),
    }
    if let Some(path) = args.get("out") {
        write_metrics_file(path, std::slice::from_ref(&snap))?;
    }
    Ok(())
}

/// `sac trace export`: run a deterministic seeded synthetic workload
/// with the span ring force-enabled and print it as a Chrome
/// trace-event document (load in `chrome://tracing` or Perfetto).
/// Every span carries the originating request's trace id, so a single
/// request can be followed submit → batch → slab → deliver
/// (DESIGN.md §12).  With `--out` the JSON goes to a file; otherwise it
/// is the only thing written to stdout.
fn cmd_trace(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("export");
    if sub != "export" {
        bail!("unknown trace subcommand {sub:?} (use `sac trace export`)");
    }
    let tasks = args.get_usize("tasks", 2)?.max(1);
    let requests = args.get_usize("requests", 64)?.max(1);
    let workers = args.get_usize("workers", 2)?.max(1);
    // defaults are sized so full batches take the row-sharded kernel
    // path: 16 rows × 4 threads clears the 2×MIN_SLAB_ROWS serial
    // cutoff, so the export shows the whole submit → batch → slab →
    // deliver pipeline, not just the serial spine
    let batch = args.get_usize("batch", 16)?.max(1);
    let threads = args.get_usize("threads", 4)?.max(1);
    let seed = args.get_usize("seed", 7)? as u64;
    let capacity = args.get_usize("capacity", 4096)?.max(16);
    // force the ring on for this run, whatever SAC_TRACE says — an
    // export of zero spans helps nobody
    sac::util::trace::enable(capacity);
    const DIM: usize = 8;
    let engines = (0..tasks)
        .map(|t| {
            Ok((
                format!("task{t}"),
                synthetic_engine_with_mode(
                    seed + t as u64,
                    &[DIM, 10, 4],
                    batch,
                    ExecMode::Batched,
                )?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let router = Router::new(
        RouterConfig {
            workers,
            kernel_threads: Some(threads),
            // a generous flush deadline: submissions take microseconds,
            // so batches fill completely and the trace shows full slabs
            max_wait: Duration::from_millis(50),
            ..RouterConfig::default()
        },
        engines,
    );
    let mut rng = Rng::new(seed ^ 0x7ACE);
    let mut reqs = Vec::with_capacity(requests);
    for k in 0..requests {
        let feats: Vec<f32> = (0..DIM).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        reqs.push(router.submit(k % tasks, feats)?);
    }
    router.drain(Duration::from_secs(600))?;
    for &req in &reqs {
        router
            .try_take(req)?
            .ok_or_else(|| anyhow!("request {req:?} unanswered"))?;
    }
    let doc = sac::util::trace::export_chrome_live().to_string();
    match args.get("out") {
        Some(path) => {
            let p = PathBuf::from(path);
            if let Some(dir) = p.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(&p, &doc)?;
            println!("wrote {} ({} bytes)", p.display(), doc.len());
        }
        // bare JSON on stdout so `sac trace export | jq` just works
        None => println!("{doc}"),
    }
    Ok(())
}

fn cmd_characterize(args: &Args) -> Result<()> {
    let cell = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("relu");
    let kind = CellKind::by_name(cell)
        .ok_or_else(|| anyhow::anyhow!("unknown cell {cell:?}"))?;
    let node = ProcessNode::by_name(args.get_or("node", "180nm"))
        .ok_or_else(|| anyhow::anyhow!("unknown node"))?;
    let regime = Regime::by_name(args.get_or("regime", "WI"))
        .ok_or_else(|| anyhow::anyhow!("unknown regime"))?;
    let temp = args.get_f64("temp", 27.0)?;
    let out = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out)?;
    let cc = CircuitCorner::new(node, regime).at_temp(temp);
    let zs = dc::grid(-2.0, 2.0, 41);
    let ys = dc::sweep_cell(kind, &cc, &zs);
    let path = out.join(format!("char_{}_{}_{}.csv", cell, node.name, regime.short()));
    write_xy_csv(&path, "x", &zs, &[(cell, &ys[..])])?;
    println!(
        "{}",
        sac::util::table::ascii_plot(&[(cell, &ys[..])], 12, 64)
    );
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_mc(args: &Args) -> Result<()> {
    let cell = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("relu");
    let kind = CellKind::by_name(cell)
        .ok_or_else(|| anyhow::anyhow!("unknown cell {cell:?}"))?;
    let node = ProcessNode::by_name(args.get_or("node", "180nm"))
        .ok_or_else(|| anyhow::anyhow!("unknown node"))?;
    // SAC_MC_TRIALS / SAC_MC_SEED env overrides sit between the library
    // defaults and explicit CLI flags
    let base = mc::McConfig::from_env();
    let cfg = mc::McConfig {
        trials: args.get_usize("trials", base.trials)?,
        ..base
    };
    let r = mc::run_cell_mc(kind, node, Regime::WeakInversion, &cfg);
    println!(
        "MC {} @ {} (WI, {} trials): max deviation {:.2}% of full scale",
        cell, node.name, cfg.trials, r.max_pct_dev
    );
    Ok(())
}

/// Replay a fault-injection plan against the serving stack and enforce
/// the degradation envelope + router liveness invariants (DESIGN.md §8).
/// `--check` runs the campaign twice and insists the canonical reports
/// are bit-identical — the determinism contract CI enforces on every PR.
fn cmd_chaos(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out)?;
    let plan = match args.get("plan") {
        Some(path) => FaultPlan::load(&PathBuf::from(path))?,
        None => FaultPlan::default_plan(args.get_usize("seed", 20220508)? as u64),
    };
    let cfg = ChaosConfig {
        trials: args.get_usize("trials", 12)?.max(1),
        workers: args.get_usize("workers", 4)?.max(1),
        kernel_threads: kernel_threads_arg(args)?,
        ..Default::default()
    };
    println!(
        "chaos: seed {} — {} analog + {} infra fault(s), {} trial(s)/corner, {} worker(s)",
        plan.seed,
        plan.analog.len(),
        plan.infra.len(),
        cfg.trials,
        cfg.workers
    );
    if args.has("recover") {
        return cmd_chaos_recover(args, &plan, &cfg, &out);
    }
    let t0 = Instant::now();
    let (report, snapshots) = run_chaos_with_metrics(&plan, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    // telemetry lands before any violation bail so a failing campaign
    // still leaves its metrics behind
    if let Some(path) = args.get("metrics-out") {
        write_metrics_file(path, &snapshots)?;
    }
    for c in &report.corners {
        println!(
            "  {}/{}: mean agreement {:.4}, worst {:.4}, temps {:?}",
            c.node, c.regime, c.mean_agreement, c.worst_agreement, c.trial_temp_c
        );
    }
    let i = &report.infra;
    println!(
        "  infra: {} submitted, {} answered, {} failed, drain {:.1}ms, \
         exactly-once {}, panic observed {}",
        i.submitted, i.answered, i.failed, i.drain_ms, i.resolved_exactly_once, i.panic_observed
    );
    if args.has("check") {
        let replay = run_chaos(&plan, &cfg)?;
        ensure!(
            replay.canonical_json() == report.canonical_json(),
            "replay of seed {} diverged from the first run — determinism contract broken",
            plan.seed
        );
        println!("  replay check: bit-identical");
    }
    let plan_path = out.join("chaos_plan.json");
    plan.save(&plan_path)?;
    let report_path = out.join("chaos_report.json");
    std::fs::write(&report_path, report.canonical_json())?;
    println!("wrote {} and {}", plan_path.display(), report_path.display());
    let violations = report.violations();
    if !violations.is_empty() {
        for v in &violations {
            eprintln!("VIOLATION: {v}");
        }
        return Err(EnvelopeViolation(violations).into());
    }
    println!("chaos pass in {wall:.1}s");
    Ok(())
}

/// `sac chaos --recover`: replay the plan through the self-healing
/// router and enforce the recovery invariants end to end — canary drift
/// detection, quarantine, grid-cache invalidation + rebuild at the
/// current operating point, exactly-once delivery under a storm with a
/// transient panic, and deadline shedding that only hits past-deadline
/// requests (DESIGN.md §11).
fn cmd_chaos_recover(args: &Args, plan: &FaultPlan, cfg: &ChaosConfig, out: &Path) -> Result<()> {
    let t0 = Instant::now();
    let (report, snapshot) = run_recovery_with_metrics(plan, cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    if let Some(path) = args.get("metrics-out") {
        write_metrics_file(path, std::slice::from_ref(&snapshot))?;
    }
    println!(
        "  recovery: detected {}, quarantined {}, rebuilt-healthy {}, \
         post-rebuild agreement {:.4} ({:.0} ms, {} rebuild(s))",
        report.drift_detected,
        report.quarantined,
        report.rebuilt_healthy,
        report.post_rebuild_agreement,
        report.recovery_ms,
        report.rebuilds
    );
    println!(
        "  storm: exactly-once {}, transient panic retried {} ({} retries); \
         shed: only-overdue {}, in-deadline answered {}",
        report.resolved_exactly_once,
        report.transient_panic_retried,
        report.retries,
        report.sheds_only_overdue,
        report.fresh_request_answered
    );
    if args.has("check") {
        let replay = run_recovery(plan, cfg)?;
        ensure!(
            replay.canonical_json() == report.canonical_json(),
            "recovery replay of seed {} diverged from the first run — determinism contract broken",
            plan.seed
        );
        println!("  replay check: bit-identical");
    }
    // health-timeline diagnostic lands before any violation bail so a
    // failing campaign leaves its artifact behind (CI uploads it)
    let health_path = out.join("chaos_health.json");
    std::fs::write(&health_path, report.health_json().to_string())?;
    let report_path = out.join("chaos_recovery.json");
    std::fs::write(&report_path, report.canonical_json())?;
    println!("wrote {} and {}", report_path.display(), health_path.display());
    let violations = report.violations();
    if !violations.is_empty() {
        for v in &violations {
            eprintln!("VIOLATION: {v}");
        }
        return Err(EnvelopeViolation(violations).into());
    }
    println!("recovery pass in {wall:.1}s");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(
        args.get_or("artifacts", default_artifacts_dir().to_str().unwrap()),
    );
    let mut t = Table::new("process nodes", &["node", "vdd", "vt0", "n", "I_spec", "AVT"]);
    for n in ProcessNode::all() {
        t.row(vec![
            n.name.into(),
            format!("{}", n.vdd),
            format!("{}", n.vt0),
            format!("{}", n.n_slope),
            format!("{:.1e}", n.i_spec),
            format!("{}", n.avt_mv_um),
        ]);
    }
    println!("{}", t.render());
    match Runtime::new(&artifacts) {
        Ok(rt) => {
            println!(
                "artifacts @ {}: backend {}",
                artifacts.display(),
                rt.platform()
            );
            for (name, e) in &rt.manifest.entries {
                println!("  {name}: {} ({} params)", e.file, e.params.len());
            }
        }
        Err(e) => println!("artifacts not ready: {e:#}"),
    }
    Ok(())
}
