"""Layer-2 model, dataset and export-format tests."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.sacml import data as D
from compile.sacml import nets, ops

jax.config.update("jax_platform_name", "cpu")


# ----------------------------------------------------------------------
# Datasets
# ----------------------------------------------------------------------

def test_xor_labels_consistent():
    x, y = D.make_xor(500, seed=1, noise=0.0)
    expect = ((x[:, 0] > 0) ^ (x[:, 1] > 0)).astype(np.int64)
    assert (y == expect).mean() > 0.98  # margin band keeps noise-free exact


def test_xor_deterministic():
    a = D.make_xor(64, seed=9)
    b = D.make_xor(64, seed=9)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_digits_shapes_and_range():
    x, y = D.make_digits(50, seed=2)
    assert x.shape == (50, 256) and y.shape == (50,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))


def test_digits_classes_distinguishable():
    """Nearest-centroid on clean renders must beat 60% — the task carries
    class signal well above the 10% floor."""
    xtr, ytr = D.make_digits(800, seed=3)
    xte, yte = D.make_digits(200, seed=4)
    cents = np.stack([xtr[ytr == d].mean(0) for d in range(10)])
    pred = np.argmin(((xte[:, None, :] - cents[None]) ** 2).sum(-1), axis=1)
    assert (pred == yte).mean() > 0.6


def test_arem_features():
    x, y = D.make_arem(300, seed=5)
    assert x.shape == (300, 24)
    assert 0.1 < y.mean() < 0.8  # both classes present
    # normalized features
    assert abs(float(x.mean())) < 0.15
    assert 0.7 < float(x.std()) < 1.3


def test_sacd_roundtrip(tmp_path):
    x = np.random.RandomState(0).rand(17, 9).astype(np.float32)
    y = np.random.RandomState(1).randint(0, 10, 17).astype(np.int64)
    p = str(tmp_path / "t.bin")
    D.save_dataset(p, x, y)
    x2, y2 = D.load_dataset(p)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)


def test_sacd_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        D.load_dataset(str(p))


# ----------------------------------------------------------------------
# Networks
# ----------------------------------------------------------------------

def test_init_params_shapes():
    p = nets.init_params([256, 15, 10], seed=0)
    assert p["w1"].shape == (256, 15)
    assert p["b2"].shape == (10,)
    assert nets.n_layers(p) == 2


def test_sac_forward_shapes():
    p = nets.init_params([8, 5, 3], seed=0, scale=0.3)
    x = jnp.asarray(np.random.RandomState(0).rand(6, 8).astype(np.float32))
    logits = nets.sac_forward(p, x, s=3, c=1.0, activation="phi1")
    assert logits.shape == (6, 3)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_sac_dense_approximates_linear():
    """Small-signal: the S-AC dense layer tracks w^T x + b (eq. 40)."""
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.uniform(-0.5, 0.5, (4, 3)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-0.1, 0.1, 3).astype(np.float32))
    x = jnp.asarray(rng.uniform(-0.5, 0.5, (5, 4)).astype(np.float32))
    y_sac = nets.sac_dense(x, w, b, s=3, c=1.0)
    y_lin = x @ w + b
    assert float(jnp.abs(y_sac - y_lin).max()) < 0.15


def test_sac_forward_differentiable():
    p = nets.init_params([4, 3, 2], seed=1, scale=0.3)
    x = jnp.asarray(np.random.RandomState(2).rand(8, 4).astype(np.float32))
    y = jnp.asarray(np.random.RandomState(3).randint(0, 2, 8))

    def loss(p):
        logits = nets.sac_forward(p, x, activation="phi1")
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))

    g = jax.grad(loss)(p)
    total = sum(float(jnp.abs(v).sum()) for v in g.values())
    assert np.isfinite(total) and total > 0


def test_solver_switch_consistency():
    """exact vs bisect backends agree on a full forward pass."""
    p = nets.init_params([6, 4, 3], seed=4, scale=0.3)
    x = jnp.asarray(np.random.RandomState(5).rand(4, 6).astype(np.float32))
    ops.set_solver("exact")
    a = np.asarray(nets.sac_forward(p, x, activation="phi1"))
    ops.set_solver("bisect")
    try:
        b = np.asarray(nets.sac_forward(p, x, activation="phi1"))
    finally:
        ops.set_solver("exact")
    np.testing.assert_allclose(a, b, atol=1e-4)


# ----------------------------------------------------------------------
# Trained-artifact sanity (skipped until `make artifacts` has run)
# ----------------------------------------------------------------------

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "weights_xor.json")),
                    reason="artifacts not built")
def test_trained_xor_accuracy():
    with open(os.path.join(ART, "weights_xor.json")) as f:
        blob = json.load(f)
    assert blob["acc_sac_algorithmic"] > 0.85
    p = {k: jnp.asarray(np.asarray(v, np.float32))
         for k, v in blob["weights"].items()}
    x, y = D.load_dataset(os.path.join(ART, "xor_test.bin"))
    logits = nets.sac_forward(p, jnp.asarray(x), s=blob["splines"],
                              c=blob["c"], activation=blob["activation"])
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))
    assert acc == pytest.approx(blob["acc_sac_algorithmic"], abs=0.02)
