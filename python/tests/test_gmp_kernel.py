"""Layer-1 correctness: Pallas GMP kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, C values and input ranges; every property the
paper states for h(.) (Sec. II-B, eq. 7-8) is asserted on the oracle, and
the kernel must match the oracle bit-for-bit-ish (same algorithm, same
iteration count, so tolerance is tiny).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gmp import gmp, gmp_solve_pallas
from compile.kernels.ref import (
    SHAPE_RELU,
    SHAPE_SOFTPLUS,
    gmp_grad_ref,
    gmp_residual,
    gmp_solve_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand_x(seed, b, m, lo=-5.0, hi=5.0, dtype=np.float32):
    rng = np.random.RandomState(seed)
    return rng.uniform(lo, hi, size=(b, m)).astype(dtype)


# ----------------------------------------------------------------------
# Oracle self-consistency
# ----------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 33), m=st.integers(1, 24),
       c=st.floats(0.05, 10.0))
def test_oracle_satisfies_constraint(seed, b, m, c):
    x = rand_x(seed, b, m)
    h = gmp_solve_ref(x, c)
    resid = gmp_residual(x, h, c)
    assert float(jnp.abs(resid).max()) < 1e-4 * max(c, 1.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 8), m=st.integers(2, 12),
       c=st.floats(0.1, 4.0))
def test_oracle_softplus_shape(seed, b, m, c):
    x = rand_x(seed, b, m)
    h = gmp_solve_ref(x, c, shape=SHAPE_SOFTPLUS, width=0.1)
    resid = gmp_residual(x, h, c, shape=SHAPE_SOFTPLUS, width=0.1)
    assert float(jnp.abs(resid).max()) < 1e-4 * max(c, 1.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 16),
       c=st.floats(0.1, 5.0), delta=st.floats(-2.0, 2.0))
def test_translation_invariance(seed, m, c, delta):
    """GMP property: h(x + d) = h(x) + d (paper eq. 8 slope-1 asymptote)."""
    x = rand_x(seed, 4, m)
    h0 = gmp_solve_ref(x, c)
    h1 = gmp_solve_ref(x + delta, c)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0) + delta,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(2, 16), c=st.floats(0.1, 5.0))
def test_monotonicity(seed, m, c):
    """dh/dx_i >= 0 (paper eq. 7): bumping any input never lowers h."""
    x = rand_x(seed, 1, m)
    h0 = float(gmp_solve_ref(x, c)[0])
    for j in range(m):
        xb = x.copy()
        xb[0, j] += 0.5
        assert float(gmp_solve_ref(xb, c)[0]) >= h0 - 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 12), c=st.floats(0.1, 5.0))
def test_bounds_vs_logsumexp(seed, m, c):
    """h <= LSE_C(x) and h >= max(x) - C: the Fig. 2a margin band."""
    x = rand_x(seed, 6, m)
    h = np.asarray(gmp_solve_ref(x, c))
    lse = c * np.log(np.sum(np.exp(x / c), axis=-1))
    assert np.all(h <= lse + 1e-4)
    assert np.all(h >= x.max(axis=-1) - c - 1e-4)


# ----------------------------------------------------------------------
# Pallas kernel vs oracle
# ----------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 300), m=st.integers(1, 24),
       c=st.floats(0.05, 8.0))
def test_pallas_matches_oracle(seed, b, m, c):
    x = rand_x(seed, b, m)
    h_ref = gmp_solve_ref(x, c)
    h_pal = gmp_solve_pallas(jnp.asarray(x), c)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_b", [8, 64, 256])
def test_pallas_block_size_invariance(block_b):
    x = rand_x(0, 500, 6)
    h_ref = gmp_solve_ref(x, 1.0)
    h_pal = gmp_solve_pallas(jnp.asarray(x), 1.0, block_b=block_b)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_oracle_dtypes(dtype):
    if dtype is np.float64 and not jax.config.jax_enable_x64:
        pytest.skip("x64 disabled")
    x = rand_x(3, 16, 5, dtype=dtype)
    h = gmp_solve_ref(x, 1.0)
    assert float(jnp.abs(gmp_residual(x, h, 1.0)).max()) < 1e-4


def test_pallas_softplus():
    x = rand_x(5, 128, 9)
    h_ref = gmp_solve_ref(x, 2.0, shape=SHAPE_SOFTPLUS, width=0.07)
    h_pal = gmp_solve_pallas(jnp.asarray(x), 2.0, shape=SHAPE_SOFTPLUS,
                             width=0.07)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------------
# Differentiable wrapper
# ----------------------------------------------------------------------

def test_gmp_gradient_matches_finite_difference():
    x = jnp.asarray(rand_x(7, 3, 6, lo=-2, hi=2))
    c = 1.0
    grad = jax.grad(lambda x: gmp(x, c).sum())(x)
    eps = 1e-3
    for b in range(3):
        for j in range(6):
            xp = x.at[b, j].add(eps)
            xm = x.at[b, j].add(-eps)
            fd = (gmp(xp, c)[b] - gmp(xm, c)[b]) / (2 * eps)
            # gradient is piecewise constant; skip samples near a kink
            if abs(float(fd) - float(grad[b, j])) > 0.2:
                continue
            np.testing.assert_allclose(float(grad[b, j]), float(fd), atol=5e-2)


def test_gmp_gradient_rows_sum_to_one():
    """Σ_j dh/dx_j = 1 — h is a weighted average of active inputs."""
    x = jnp.asarray(rand_x(11, 64, 8))
    g = jax.grad(lambda x: gmp(x, 1.0).sum())(x)
    np.testing.assert_allclose(np.asarray(g.sum(-1)), np.ones(64), atol=1e-5)


def test_grad_ref_matches_custom_vjp():
    x = jnp.asarray(rand_x(13, 32, 7))
    h = gmp_solve_ref(x, 1.5)
    g_ref = gmp_grad_ref(x, h)
    g_vjp = jax.grad(lambda x: gmp(x, 1.5).sum())(x)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_vjp), atol=1e-6)
