"""Layer-2 correctness: the S-AC cell algebra (Sec. IV) and spline math
(Appendix A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import gmp_solve_ref
from compile.sacml import ops
from compile.sacml.splines import (exp_spline_approx, schedule,
                                   tangent_points, tuning_points)

jax.config.update("jax_platform_name", "cpu")


# ----------------------------------------------------------------------
# Appendix A spline schedule
# ----------------------------------------------------------------------

def test_schedule_matches_paper_s3():
    """S=3, C=1 must reproduce eq. 49-53: O = C(1±ln2), C(1−2ln2), C'=2C."""
    ln2 = np.log(2.0)
    offs, c_prime = schedule(3, 1.0)
    np.testing.assert_allclose(offs, [1 + ln2, 1 - ln2, 1 - 2 * ln2], rtol=1e-6)
    assert abs(c_prime - 2.0) < 1e-9


def test_tuning_points_paper_values():
    ln2 = np.log(2.0)
    t = tuning_points(3)
    np.testing.assert_allclose(t, [-ln2 - 1, ln2 - 1, 2 * ln2 - 1], rtol=1e-6)


def test_tangent_points_symmetric():
    for s in range(1, 8):
        q = tangent_points(s)
        np.testing.assert_allclose(q, -q[::-1], atol=1e-12)


def test_exp_approx_error_shrinks_s1_to_s3():
    """Fig. 2a: the margin narrows going from one spline to three.  (The
    dyadic schedule extends *range* beyond S=3, so only this comparison is
    monotone on a fixed window.)"""
    x = np.linspace(-1.0, 1.0, 201)
    e1 = np.abs(exp_spline_approx(x, 1) - np.exp(x)).max()
    e3 = np.abs(exp_spline_approx(x, 3) - np.exp(x)).max()
    assert e3 < e1


def test_gmp_lse_error_shrinks_with_s():
    """Multi-input GMP h approximates log-sum-exp better with more splines
    — the operative Fig. 2a claim."""
    pairs = np.array([[0.3, -0.4], [1.0, 0.2], [-0.8, -0.1], [0.5, 0.45]],
                     np.float32)
    def max_err(s):
        offs, cp = schedule(s, 1.0)
        rows = np.concatenate([pairs[:, :1] + offs, pairs[:, 1:] + offs],
                              axis=1)
        h = np.asarray(ops.gmp_exact(rows.astype(np.float32), cp))
        lse = np.log(np.exp(pairs[:, 0]) + np.exp(pairs[:, 1]))
        return np.abs(h - lse).max()
    assert max_err(3) < max_err(1)


def test_exp_approx_is_tangent():
    """The first two splines are exactly tangent to e^x at their Q points;
    later splines accumulate the PWL underestimate of the convex curve
    (relative error grows towards the top of the range)."""
    for s in (2, 3, 5):
        q = tangent_points(s)
        approx = exp_spline_approx(q, s)
        rel = np.abs(approx - np.exp(q)) / np.exp(q)
        assert rel[0] < 1e-12 and rel[1] < 1e-12
        assert np.all(rel < 0.5)
        # PWL of a convex function underestimates
        x = np.linspace(q[0], q[-1], 50)
        assert np.all(exp_spline_approx(x, s) <= np.exp(x) + 1e-9)


# ----------------------------------------------------------------------
# Exact solver vs bisection
# ----------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 40), m=st.integers(1, 20),
       c=st.floats(0.05, 8.0))
def test_exact_matches_bisection(seed, b, m, c):
    rng = np.random.RandomState(seed)
    x = rng.uniform(-4, 4, size=(b, m)).astype(np.float32)
    he = ops.gmp_exact(x, c)
    hb = gmp_solve_ref(x, c)
    np.testing.assert_allclose(np.asarray(he), np.asarray(hb),
                               rtol=1e-5, atol=1e-5)


def test_exact_gradient_rows_sum_to_one():
    x = jnp.asarray(np.random.RandomState(0).randn(32, 6).astype(np.float32))
    g = jax.grad(lambda x: ops.gmp_exact(x, 1.0).sum())(x)
    np.testing.assert_allclose(np.asarray(g.sum(-1)), np.ones(32), atol=1e-5)


# ----------------------------------------------------------------------
# Cells
# ----------------------------------------------------------------------

def test_relu_cell_limit():
    """eq. 19: as C -> 0 the cell is max(0, x)."""
    z = jnp.linspace(-2, 2, 41)
    y = ops.relu_cell(z, c=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.maximum(np.asarray(z), 0),
                               atol=2e-4)


def test_proto_unit_monotone_nonneg():
    z = jnp.linspace(-5, 3, 200)
    for s in (1, 2, 3, 4):
        h = np.asarray(ops.proto_unit(z, s, 1.0))
        assert np.all(h >= 0)
        assert np.all(np.diff(h) >= -1e-6)


def test_proto_unit_slope_saturates_at_one():
    """eq. 8: dh/dx -> 1 for large x, -> 0 for very negative x."""
    z = jnp.linspace(-8, 4, 400)
    h = np.asarray(ops.proto_unit(z, 3, 1.0))
    dz = float(z[1] - z[0])
    slope = np.diff(h) / dz
    assert slope[-1] == pytest.approx(1.0, abs=1e-3)
    assert slope[0] == pytest.approx(0.0, abs=1e-3)


def test_proto_unit_tracks_exp_in_margin():
    """Fig. 3: inside the margin the S=3 knee tracks e^z (log-domain LSE)."""
    z = np.linspace(-1.5, 0.0, 30).astype(np.float32)
    h = np.asarray(ops.proto_unit(jnp.asarray(z), 3, 1.0))
    lse = np.log1p(np.exp(z))  # 2-input LSE with ground branch, C=1
    # correlation of shape, not absolute match
    cc = np.corrcoef(h, lse)[0, 1]
    assert cc > 0.99


def test_phi1_antisymmetric_and_saturating():
    """φ1 (eq. 20): odd function, saturates at ±K (tanh-equivalent)."""
    k = 1.0
    z = jnp.linspace(-4, 4, 81)
    y = np.asarray(ops.phi1_cell(z, k=k))
    np.testing.assert_allclose(y, -y[::-1], atol=1e-5)
    assert y[-1] == pytest.approx(k, abs=1e-3)
    assert y[0] == pytest.approx(-k, abs=1e-3)
    assert np.all(np.diff(y) >= -1e-6)


def test_phi2_is_shifted_phi1():
    z = jnp.linspace(-3, 3, 31)
    np.testing.assert_allclose(
        np.asarray(ops.phi2_cell(z, k=1.0)),
        np.asarray(ops.phi1_cell(z, k=1.0)) + 1.0, atol=1e-6)


def test_cosh_sinh_symmetry():
    z = jnp.linspace(-2, 2, 41)
    ch = np.asarray(ops.cosh_cell(z))
    sh = np.asarray(ops.sinh_cell(z))
    np.testing.assert_allclose(ch, ch[::-1], atol=1e-5)   # even
    np.testing.assert_allclose(sh, -sh[::-1], atol=1e-5)  # odd
    # cosh^2 - sinh^2 structure: ch >= |sh|
    assert np.all(ch >= np.abs(sh) - 1e-5)


@pytest.mark.parametrize("s,max_err", [(1, 0.20), (3, 0.08)])
def test_multiplier_error_budget(s, max_err):
    """Table II trend: S=3 multiplier much tighter than S=1."""
    g = jnp.linspace(-1, 1, 21)
    x, w = jnp.meshgrid(g, g)
    y = ops.multiply(x, w, s=s, c=1.0)
    err = float(jnp.abs(y - x * w).max())
    assert err < max_err


def test_multiplier_four_quadrants():
    for xv, wv in [(0.5, 0.5), (-0.5, 0.5), (0.5, -0.5), (-0.5, -0.5)]:
        y = float(ops.multiply(jnp.asarray(xv), jnp.asarray(wv), 3, 1.0))
        assert y == pytest.approx(xv * wv, abs=0.06)


def test_multiplier_zero_lines():
    z = jnp.linspace(-1, 1, 11)
    y1 = np.asarray(ops.multiply(z, jnp.zeros_like(z), 3, 1.0))
    y2 = np.asarray(ops.multiply(jnp.zeros_like(z), z, 3, 1.0))
    assert np.abs(y1).max() < 0.05
    assert np.abs(y2).max() < 0.05


# ----------------------------------------------------------------------
# WTA family
# ----------------------------------------------------------------------

def test_wta_single_winner_small_c():
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    y = np.asarray(ops.wta_outputs(x, 0.5))
    assert np.argmax(y) == 4
    assert np.count_nonzero(y) == 1


def test_nofm_winner_count_grows_with_c():
    """Fig. 10e-h: larger C admits more winners (eq. 22)."""
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    counts = []
    for c in (0.5, 1.5, 3.5, 7.0, 12.0):
        counts.append(int(np.count_nonzero(np.asarray(ops.wta_outputs(x, c)))))
    assert counts == sorted(counts)
    assert counts[0] == 1 and counts[-1] >= 4


def test_nofm_current_formula():
    """eq. 22: I_out = (sum_top_M x_i - C)/M — matches wta residue mean."""
    x = np.array([1.0, 2.0, 3.0, 4.0, 5.0], np.float32)
    for c in (0.5, 2.0, 6.0):
        h = float(ops.gmp_exact(jnp.asarray(x)[None, :], c)[0])
        winners = x[x > h]
        m = len(winners)
        np.testing.assert_allclose(h, (winners.sum() - c) / m, rtol=1e-5)


def test_softargmax_normalized():
    x = jnp.asarray(np.random.RandomState(0).randn(10, 5).astype(np.float32))
    p = np.asarray(ops.softargmax(x, 1.0))
    np.testing.assert_allclose(p.sum(-1), np.ones(10), atol=1e-5)
    assert np.all(p >= 0)


def test_max_cell_approaches_max():
    x = jnp.asarray([[0.3, -1.0, 2.2, 0.9]])
    y = float(ops.max_cell(x, c=1e-4)[0])
    assert y == pytest.approx(2.2, abs=1e-3)
