"""S-AC neural networks (Sec. V): algorithm -> S-AC hardware mapping.

A dense layer is mapped per eq. 40: every MAC is the four-quadrant S-AC
multiplier (four proto-unit evaluations), the accumulation is KCL (plain
addition of currents), the bias a constant current.  The nonlinearity is an
S-AC activation cell from ``ops``.

Two forward paths:

  * ``sac_forward``   — the S-AC network (what the silicon computes).
  * ``mlp_forward``   — a vanilla float MLP with the same topology: the
                        paper's "S/W" baseline column in Table IV.

Both are pure functions of a params pytree so they can be trained with
plain JAX autodiff.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import ops

Params = Dict[str, jnp.ndarray]


def init_params(sizes: Sequence[int], seed: int = 0,
                scale: float | None = None) -> Params:
    """Glorot-ish init for an MLP with layer ``sizes`` (e.g. [256, 15, 10])."""
    rng = np.random.RandomState(seed)
    params: Params = {}
    for li in range(len(sizes) - 1):
        fan_in, fan_out = sizes[li], sizes[li + 1]
        sd = scale if scale is not None else np.sqrt(2.0 / (fan_in + fan_out))
        params[f"w{li + 1}"] = jnp.asarray(
            rng.normal(0.0, sd, size=(fan_in, fan_out)).astype(np.float32))
        params[f"b{li + 1}"] = jnp.zeros((fan_out,), jnp.float32)
    return params


def n_layers(params: Params) -> int:
    return sum(1 for k in params if k.startswith("w"))


def sac_dense(x, w, b, s: int = 3, c: float = 1.0, calib=None):
    """Eq. 40 dense layer: ``eta_k = sum_i multiply(x_i, w_ik) + b_k``.

    ``x: [B, in]``, ``w: [in, out]`` -> ``[B, out]``.  The multiply
    broadcasts to ``[B, in, out]`` (each element one 4-unit multiplier
    cell); KCL-sums over the input axis.
    """
    y = ops.multiply(x[:, :, None], w[None, :, :], s=s, c=c, calib=calib)
    return jnp.sum(y, axis=1) + b


def sac_forward(params: Params, x, s: int = 3, c: float = 1.0,
                activation: str = "phi2", act_gain: float = 4.0) -> jnp.ndarray:
    """S-AC network forward pass -> logits ``[B, n_out]``.

    ``act_gain`` maps the pre-activation current range into the activation
    cell's input range (a current-mirror ratio in the circuit).
    """
    calib = ops.calibrate_multiplier(s, c)
    nl = n_layers(params)
    h = x
    for li in range(1, nl + 1):
        h = sac_dense(h, params[f"w{li}"], params[f"b{li}"], s=s, c=c, calib=calib)
        if li < nl:
            z = h * act_gain
            if activation == "phi2":
                h = ops.phi2_cell(z, k=1.0, s=s, c=0.5) - 1.0  # recentre
            elif activation == "phi1":
                h = ops.phi1_cell(z, k=1.0, s=s, c=0.5)
            elif activation == "relu":
                h = ops.relu_cell(z, c=0.05)
            elif activation == "softplus":
                h = ops.softplus_cell(z, s=s, c=0.5)
            else:
                raise ValueError(activation)
    return h


def mlp_forward(params: Params, x, activation: str = "tanh") -> jnp.ndarray:
    """Vanilla float MLP ("S/W" baseline of Table IV)."""
    nl = n_layers(params)
    h = x
    for li in range(1, nl + 1):
        h = h @ params[f"w{li}"] + params[f"b{li}"]
        if li < nl:
            h = jnp.tanh(h) if activation == "tanh" else jax.nn.relu(h)
    return h


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, axis=-1) == labels))
