"""Training pipeline for the Table-IV case-study networks.

Trains, per task (xor / digits / arem):

  1. a vanilla float MLP  -> the "S/W" baseline accuracy column, and
  2. the S-AC network (forward through the GMP algebra, implicit-function
     gradients through the solve) with **variation-aware training** —
     multiplicative Gaussian weight noise each step, the technique the
     paper adopts from [33] so the learned weights tolerate analog
     mismatch.

Exports (consumed by the rust Layer-3 and by ``aot.py``):

  * ``artifacts/weights_<task>.json``   — trained S-AC weights + metadata
  * ``artifacts/<task>_test.bin``       — the exact test set (SACD format)

Adam is implemented inline (no optax in this environment).
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import nets

TASKS = {
    # name: (sizes, n_train, n_test, activation, steps, batch, lr)
    "xor": ([2, 4, 2], 512, 256, "phi1", 600, 64, 0.05),
    "arem": ([24, 8, 2], 2048, 512, "phi1", 500, 64, 0.03),
    "digits": ([256, 15, 10], 6000, 1000, "phi1", 900, 48, 0.03),
}

S_SPLINES = 3
C_HYPER = 1.0


def make_task(name: str, seed_off: int = 0):
    sizes, ntr, nte, act, steps, batch, lr = TASKS[name]
    gen = {"xor": D.make_xor, "digits": D.make_digits, "arem": D.make_arem}[name]
    xtr, ytr = gen(ntr, seed=100 + seed_off)
    xte, yte = gen(nte, seed=200 + seed_off)
    return (xtr, ytr, xte, yte), sizes, act, steps, batch, lr


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train_net(forward: Callable, params, xtr, ytr, steps: int, batch: int,
              lr: float, seed: int = 3, weight_noise: float = 0.0,
              log_every: int = 100, tag: str = "") -> Dict:
    """Generic minibatch Adam loop; optional variation-aware weight noise."""
    key = jax.random.PRNGKey(seed)
    n = xtr.shape[0]

    def loss_fn(p, xb, yb, k):
        if weight_noise > 0.0:
            ks = jax.random.split(k, len(p))
            noisy = {}
            for (name, val), kk in zip(sorted(p.items()), ks):
                if name.startswith("w"):
                    noisy[name] = val * (1.0 + weight_noise * jax.random.normal(kk, val.shape))
                else:
                    noisy[name] = val
            p = noisy
        return cross_entropy(forward(p, xb), yb)

    step_fn = jax.jit(lambda p, st, xb, yb, k: _step(p, st, xb, yb, k))

    def _step(p, st, xb, yb, k):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb, k)
        p2, st2 = adam_step(p, g, st, lr)
        return p2, st2, l

    state = adam_init(params)
    rng = np.random.RandomState(seed)
    t0 = time.time()
    for step in range(steps):
        idx = rng.randint(0, n, size=batch)
        key, sub = jax.random.split(key)
        params, state, loss = step_fn(params, state, xtr[idx], ytr[idx], sub)
        if log_every and (step % log_every == 0 or step == steps - 1):
            print(f"  [{tag}] step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    return params


def eval_in_batches(forward, params, x, y, batch: int = 256) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        logits = forward(params, x[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + batch]))
    return correct / len(x)


def train_task(name: str, outdir: str, quick: bool = False) -> Dict:
    """Train S/W baseline + S-AC net for one task; export artifacts."""
    (xtr, ytr, xte, yte), sizes, act, steps, batch, lr = make_task(name)
    if quick:
        steps = max(steps // 10, 20)
    print(f"== task {name}: sizes={sizes} act={act} steps={steps}")

    # S/W baseline (vanilla MLP)
    p_sw = nets.init_params(sizes, seed=1)
    fwd_sw = lambda p, x: nets.mlp_forward(p, x)
    p_sw = train_net(fwd_sw, p_sw, xtr, ytr, steps * 2, batch, 0.01,
                     tag=f"{name}/sw")
    acc_sw = eval_in_batches(fwd_sw, p_sw, xte, yte)

    # S-AC network with variation-aware training
    fwd_sac = lambda p, x: nets.sac_forward(p, x, s=S_SPLINES, c=C_HYPER,
                                            activation=act)
    p_sac = nets.init_params(sizes, seed=2, scale=0.3)
    p_sac = train_net(fwd_sac, p_sac, xtr, ytr, steps, batch, lr,
                      weight_noise=0.05, tag=f"{name}/sac")
    acc_sac = eval_in_batches(fwd_sac, p_sac, xte, yte)
    print(f"  {name}: S/W acc={acc_sw:.3f}  S-AC(algorithmic) acc={acc_sac:.3f}")

    os.makedirs(outdir, exist_ok=True)
    D.save_dataset(os.path.join(outdir, f"{name}_test.bin"), xte, yte)
    blob = {
        "task": name,
        "sizes": sizes,
        "activation": act,
        "splines": S_SPLINES,
        "c": C_HYPER,
        "acc_sw": acc_sw,
        "acc_sac_algorithmic": acc_sac,
        "weights": {k: np.asarray(v).tolist() for k, v in p_sac.items()},
    }
    with open(os.path.join(outdir, f"weights_{name}.json"), "w") as f:
        json.dump(blob, f)
    return {"task": name, "acc_sw": acc_sw, "acc_sac": acc_sac,
            "params": p_sac}


def main(outdir: str = "../artifacts", quick: bool = False) -> None:
    summary = {}
    for task in TASKS:
        r = train_task(task, outdir, quick=quick)
        summary[task] = {"acc_sw": r["acc_sw"], "acc_sac": r["acc_sac"]}
    with open(os.path.join(outdir, "training_summary.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
