"""Dataset generators for the case-study networks (Sec. V, Table IV).

Three tasks, matching the paper's evaluation:

  * **XOR** — the classic 2-D nonlinear toy; the paper reports 95%.
  * **digits** — a procedural 16x16 handwritten-digit surrogate for MNIST.
    The paper downsamples MNIST 28x28 -> 16x16 and evaluates 1000 test
    images through SPICE; we have no network access to fetch MNIST, so a
    seeded stroke-font generator with per-sample jitter (shift, thickness,
    shear, pixel noise) produces a 10-class task of comparable difficulty
    (a 256-15-10 MLP lands at the paper's ~93% S/W operating point).
    DESIGN.md §2 documents the substitution.
  * **arem** — simulated Activity-Recognition-from-RSS time series (the
    UCI AReM dataset is likewise unfetchable).  Seven activities as AR(1)
    channel processes with class-dependent statistics; binary
    one-vs-all ("bending"+"lying" positive) windowed-feature task, as the
    paper uses.

Every generator is pure-numpy and fully seeded; the exported test sets are
byte-identical between runs, so the rust evaluation (Table IV H/W columns)
scores the exact same samples as the python training pipeline.
"""

from __future__ import annotations

import struct
from typing import Tuple

import numpy as np

# --------------------------------------------------------------------------
# XOR
# --------------------------------------------------------------------------


def make_xor(n: int, seed: int = 7, noise: float = 0.15) -> Tuple[np.ndarray, np.ndarray]:
    """2-D XOR quadrant task in [-1, 1]^2 with label-preserving jitter."""
    rng = np.random.RandomState(seed)
    x = rng.uniform(-1.0, 1.0, size=(n, 2)).astype(np.float32)
    # keep a margin band away from the axes so the task is 95%-able, not 100%
    x += np.sign(x) * 0.08
    y = ((x[:, 0] > 0) ^ (x[:, 1] > 0)).astype(np.int64)
    x += rng.normal(0.0, noise, size=x.shape).astype(np.float32)
    return np.clip(x, -1.5, 1.5), y


# --------------------------------------------------------------------------
# Procedural digits (MNIST surrogate)
# --------------------------------------------------------------------------

# 7x5 stroke font, one glyph per digit.
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph(d: int) -> np.ndarray:
    return np.array([[int(ch) for ch in row] for row in _FONT[d]], dtype=np.float32)


def _render_digit(d: int, rng: np.random.RandomState, size: int = 16) -> np.ndarray:
    """Render one jittered 16x16 digit in [0, 1]."""
    g = _glyph(d)
    # upscale 7x5 -> ~12x9 with random per-sample scale
    sy = rng.uniform(1.45, 1.7)
    sx = rng.uniform(1.5, 1.8)
    h, w = int(round(7 * sy)), int(round(5 * sx))
    ys = (np.arange(h) / sy).astype(int).clip(0, 6)
    xs = (np.arange(w) / sx).astype(int).clip(0, 4)
    img = g[np.ix_(ys, xs)]
    # mild random shear (MNIST digits are roughly upright after centering)
    shear = rng.uniform(-0.12, 0.12)
    sheared = np.zeros((h, w + 2), dtype=np.float32)
    for r in range(h):
        off = int(round(shear * (r - h / 2))) + 1
        sheared[r, off:off + w] = img[r]
    img = sheared
    # random thickness: dilate with prob 1/3
    if rng.rand() < 0.33:
        pad = np.pad(img, 1)
        img = np.maximum(img, np.maximum.reduce(
            [pad[1:-1, :-2], pad[1:-1, 2:], pad[:-2, 1:-1], pad[2:, 1:-1]]))
    # paste roughly centred (MNIST is centre-of-mass normalised): +-1 px
    canvas = np.zeros((size, size), dtype=np.float32)
    ih, iw = img.shape
    cy, cx = (size - ih) // 2, (size - iw) // 2
    oy = np.clip(cy + rng.randint(-1, 2), 0, max(size - ih, 0))
    ox = np.clip(cx + rng.randint(-1, 2), 0, max(size - iw, 0))
    canvas[oy:oy + min(ih, size - oy), ox:ox + min(iw, size - ox)] = \
        img[:min(ih, size - oy), :min(iw, size - ox)]
    # intensity jitter + noise + occasional dropout pixels
    canvas *= rng.uniform(0.8, 1.0)
    canvas += rng.normal(0.0, 0.10, canvas.shape)
    drop = rng.rand(*canvas.shape) < 0.02
    canvas[drop] = 0.0
    return np.clip(canvas, 0.0, 1.0)


def make_digits(n: int, seed: int = 11) -> Tuple[np.ndarray, np.ndarray]:
    """``n`` jittered digits as flat f32 [n, 256] plus labels [n]."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, size=n)
    imgs = np.stack([_render_digit(int(d), rng) for d in labels])
    return imgs.reshape(n, -1).astype(np.float32), labels.astype(np.int64)


# --------------------------------------------------------------------------
# AReM-like simulated activity recognition
# --------------------------------------------------------------------------

_ACTIVITIES = ["bending1", "bending2", "cycling", "lying", "sitting",
               "standing", "walking"]
# per-activity (mean level, std, AR coefficient) per 6 RSS channels —
# loosely shaped after the AReM channel statistics (chest/ankle RSS bands).
_AREM_STATS = {
    "bending1": (39.2, 1.6, 0.90),
    "bending2": (38.3, 1.9, 0.88),
    "cycling":  (33.0, 4.0, 0.60),
    "lying":    (41.0, 1.5, 0.93),
    "sitting":  (40.0, 1.9, 0.87),
    "standing": (40.4, 2.1, 0.84),
    "walking":  (32.0, 5.0, 0.50),
}


def _arem_window(act: str, rng: np.random.RandomState, t: int = 48) -> np.ndarray:
    """One window of 6-channel AR(1) RSS, reduced to 24 features."""
    mu, sd, ar = _AREM_STATS[act]
    feats = []
    for ch in range(6):
        m = mu + rng.normal(0.0, 1.5) + 0.8 * ch   # per-channel offset
        s = sd * rng.uniform(0.8, 1.25)
        x = np.empty(t)
        x[0] = m + rng.normal(0.0, s)
        eps = rng.normal(0.0, s * np.sqrt(max(1.0 - ar * ar, 1e-3)), t)
        for i in range(1, t):
            x[i] = m + ar * (x[i - 1] - m) + eps[i]
        half = t // 2
        feats += [x[:half].mean(), x[:half].std(), x[half:].mean(), x[half:].std()]
    return np.asarray(feats, dtype=np.float32)


def make_arem(n: int, seed: int = 23) -> Tuple[np.ndarray, np.ndarray]:
    """``n`` windows, 24 features; label 1 = bending/lying (paper's positives)."""
    rng = np.random.RandomState(seed)
    acts = rng.randint(0, len(_ACTIVITIES), size=n)
    x = np.stack([_arem_window(_ACTIVITIES[a], rng) for a in acts])
    pos = {"bending1", "bending2", "lying"}
    y = np.array([1 if _ACTIVITIES[a] in pos else 0 for a in acts], dtype=np.int64)
    # normalize features to O(1) for the S-AC input range
    x = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-6)
    return x.astype(np.float32), y


# --------------------------------------------------------------------------
# Binary export (read by rust/src/data/loader.rs)
# --------------------------------------------------------------------------

MAGIC = b"SACD"


def save_dataset(path: str, x: np.ndarray, y: np.ndarray) -> None:
    """Write ``x: f32 [n, d]``, ``y: u16 [n]`` in the SACD binary format.

    Layout: magic ``SACD`` | u32 version=1 | u32 n | u32 d | f32 data | u16 labels
    (all little-endian).
    """
    x = np.ascontiguousarray(x, dtype="<f4")
    y = np.ascontiguousarray(y, dtype="<u2")
    n, d = x.shape
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<III", 1, n, d))
        f.write(x.tobytes())
        f.write(y.tobytes())


def load_dataset(path: str) -> Tuple[np.ndarray, np.ndarray]:
    """Read an SACD file back (round-trip tested)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r}")
        ver, n, d = struct.unpack("<III", f.read(12))
        if ver != 1:
            raise ValueError(f"unsupported version {ver}")
        x = np.frombuffer(f.read(4 * n * d), dtype="<f4").reshape(n, d)
        y = np.frombuffer(f.read(2 * n), dtype="<u2").astype(np.int64)
    return x.copy(), y
