"""S-AC computational algebra on top of the GMP primitive (Layer 2).

Every cell in the paper's standard-cell library (Sec. IV) is a composition
of the one primitive: the GMP solve ``h: sum_j [x_j - h]_+ = C`` (with the
output clamped to ``h >= 0`` — it is a current).  This module provides

  * ``gmp_exact``      — O(M log M) sort-based *exact* solve for the ReLU
                         shape (the classic MP algorithm).  Differentiable
                         through JAX's sort; used for training.
  * ``gmp_bisect``     — fixed-iteration bisection wrapper (``kernels.gmp``),
                         shape-generic; used for AOT export so the artifact
                         embeds the same algorithm as the rust runtime and
                         the Pallas kernel.
  * ``proto_unit``     — the basic S-AC proto-shape h(x) of Fig. 3 (input
                         branch + reference branch, spline expanded).
  * activation cells   — relu / soft-plus / phi1 (tanh-like) / phi2
                         (sigmoid-like) / cosh / sinh   (Fig. 6, eq. 15-21).
  * ``multiply``       — the four-quadrant multiplier (Fig. 11, eq. 24-30)
                         with its operating-point/scale calibration.
  * ``wta`` family     — winner-take-all / N-of-M / SoftArgMax / Max
                         (Fig. 9, eq. 22-23).

All functions broadcast over leading batch dimensions.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.gmp import gmp as _gmp_bisect_diff
from .splines import schedule

# ---------------------------------------------------------------------------
# GMP solves
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def gmp_exact(x, c):
    """Exact ReLU-shape GMP solve over the last axis (unclamped).

    With ``x`` sorted descending and ``S_k`` the prefix sums, the solution
    with ``k`` active branches is ``h_k = (S_k - C)/k``; the consistent ``k``
    is the largest one with ``x_(k) > h_k``.  The condition is monotone in
    ``k`` so a prefix count selects it branchlessly.

    The backward pass is the implicit-function gradient
    ``dh/dx_j = 1{x_j > h}/k`` (custom VJP — the sort is not differentiated;
    this also sidesteps a jaxlib gather-gradient incompatibility).
    """
    x = jnp.asarray(x)
    cc = jnp.asarray(c, dtype=x.dtype)
    xs = -jnp.sort(-x, axis=-1)                     # descending
    cs = jnp.cumsum(xs, axis=-1)
    m = x.shape[-1]
    ks = jnp.arange(1, m + 1, dtype=x.dtype)
    cond = xs * ks > cs - cc                         # true on a prefix
    k = jnp.sum(cond, axis=-1)                       # active count >= 1
    idx = (k - 1)[..., None]
    csk = jnp.take_along_axis(cs, idx, axis=-1)[..., 0]
    return (csk - cc) / k.astype(x.dtype)


def _gmp_exact_fwd(x, c):
    h = gmp_exact(x, c)
    return h, (x, h)


def _gmp_exact_bwd(c, res, dh):
    x, h = res
    act = (x > h[..., None]).astype(x.dtype)
    denom = jnp.maximum(jnp.sum(act, axis=-1, keepdims=True), 1.0)
    return ((act / denom) * dh[..., None],)


gmp_exact.defvjp(_gmp_exact_fwd, _gmp_exact_bwd)


def gmp_bisect(x, c, use_pallas: bool = False):
    """Bisection GMP solve (differentiable, ReLU shape) over the last axis."""
    return _gmp_bisect_diff(x, float(c), 0, 0.05, use_pallas)


# Which solver the algebra below routes through.  Training uses the exact
# solver; `aot.py` flips this to the bisection/Pallas path so the exported
# HLO contains the Layer-1 kernel's algorithm.
_SOLVER = {"fn": gmp_exact}


def set_solver(kind: str, use_pallas: bool = False) -> None:
    """Select the GMP backend: ``"exact"`` or ``"bisect"``."""
    if kind == "exact":
        _SOLVER["fn"] = gmp_exact
    elif kind == "bisect":
        _SOLVER["fn"] = functools.partial(gmp_bisect, use_pallas=use_pallas)
    else:
        raise ValueError(kind)


def gmp(x, c):
    """Active GMP solve (see ``set_solver``)."""
    return _SOLVER["fn"](x, c)


def sac_h(x, c):
    """S-AC unit output: GMP solve clamped to non-negative (it is a current)."""
    return jnp.maximum(gmp(x, c), 0.0)


# ---------------------------------------------------------------------------
# Proto-shape and activation cells
# ---------------------------------------------------------------------------


def _spline_rows(z, s: int, c: float):
    """Spline-expand scalar input ``z`` and a ground branch: ``[z+O_j, O_j]``."""
    offs, c_prime = schedule(s, c)
    offs = jnp.asarray(offs, dtype=jnp.result_type(z, jnp.float32))
    zx = z[..., None] + offs
    zr = jnp.broadcast_to(offs, zx.shape)
    return jnp.concatenate([zx, zr], axis=-1), c_prime


def proto_unit(z, s: int = 3, c: float = 1.0):
    """Basic S-AC proto-shape ``h(z)`` (Fig. 3): input + reference branch.

    For S=1 this is the two-segment MP knee; for S>=3 the knee region tracks
    ``e^z`` (log-sum-exp margin) increasingly well — the Fig. 2a story.
    """
    rows, c_prime = _spline_rows(z, s, c)
    return jnp.maximum(gmp(rows, c_prime), 0.0)


def relu_cell(z, c: float = 0.05):
    """ReLU (eq. 19): 2-input unit, ``C -> 0`` limit. ``h = [z - C]_+``."""
    rows = jnp.stack([z, jnp.zeros_like(z)], axis=-1)
    return jnp.maximum(gmp(rows, c), 0.0)


def softplus_cell(z, s: int = 3, c: float = 1.0):
    """Soft-plus (Fig. 6e): proto-unit with moderate C — a softened knee."""
    return proto_unit(z, s=s, c=c)


def phi1_cell(z, k: float = 1.0, s: int = 3, c: float = 0.5):
    """Compressive nonlinearity φ1 (eq. 20-21), tanh-equivalent.

    ``φ1(z) = h(0, z+K) − h(z, K)`` — two 2-input S-AC units.
    """
    rows_a, cp = _spline_rows_pair(jnp.zeros_like(z), z + k, s, c)
    rows_b, _ = _spline_rows_pair(z, jnp.full_like(z, k), s, c)
    ha = jnp.maximum(gmp(rows_a, cp), 0.0)
    hb = jnp.maximum(gmp(rows_b, cp), 0.0)
    return ha - hb


def phi2_cell(z, k: float = 1.0, s: int = 3, c: float = 0.5):
    """Sigmoid-equivalent φ2 (Sec. IV-E): shifted φ1 (add constant K)."""
    return phi1_cell(z, k=k, s=s, c=c) + k


def _spline_rows_pair(a, b, s: int, c: float):
    """Spline-expand a 2-input unit: rows ``[a+O_j] ++ [b+O_j]``."""
    offs, c_prime = schedule(s, c)
    offs = jnp.asarray(offs, dtype=jnp.result_type(a, jnp.float32))
    ra = a[..., None] + offs
    rb = b[..., None] + offs
    return jnp.concatenate([ra, rb], axis=-1), c_prime


def cosh_cell(z, s: int = 3, c: float = 1.0):
    """cosh (eq. 16): ``h(z) + h(−z)`` with ``h ~ e^z/2`` proto-units."""
    return proto_unit(z, s, c) + proto_unit(-z, s, c)


def sinh_cell(z, s: int = 3, c: float = 1.0):
    """sinh (eq. 18): ``h(z) − h(−z)`` (N-type minus P-type unit by KCL)."""
    return proto_unit(z, s, c) - proto_unit(-z, s, c)


# ---------------------------------------------------------------------------
# Four-quadrant multiplier (eq. 24-30)
# ---------------------------------------------------------------------------


def _gmp_exact_np(x: np.ndarray, c: float) -> np.ndarray:
    """Numpy clone of ``gmp_exact`` — used by calibration, which must run
    eagerly even when the caller is inside a jit trace."""
    xs = -np.sort(-x, axis=-1)
    cs = np.cumsum(xs, axis=-1)
    ks = np.arange(1, x.shape[-1] + 1, dtype=x.dtype)
    cond = xs * ks > cs - c
    k = cond.sum(axis=-1)
    csk = np.take_along_axis(cs, (k - 1)[..., None], axis=-1)[..., 0]
    return (csk - c) / k.astype(x.dtype)


def _proto_unit_np(z: np.ndarray, s: int, c: float) -> np.ndarray:
    offs, c_prime = schedule(s, c)
    rows = np.concatenate(
        [z[..., None] + offs, np.broadcast_to(offs, z.shape + (s,))], axis=-1)
    return np.maximum(_gmp_exact_np(rows.astype(np.float32), c_prime), 0.0)


@functools.lru_cache(maxsize=None)
def calibrate_multiplier(s: int, c: float, lo: float = -1.0, hi: float = 1.0,
                         grid: int = 33) -> Tuple[float, float]:
    """Calibrate the multiplier's operating point ``a`` and output scale.

    Eq. 24 leaves the bias point implicit ("C is a hyperparameter"); the
    circuit tunes it with the offset currents.  We pick ``(a, scale)``
    minimizing max |scale*y − x·w| over the input square — the same
    calibration a designer does on the silicon (Sec. IV-K's 4h''(0) factor).
    Pure numpy so it can be triggered from inside a jit trace.
    """
    g = np.linspace(lo, hi, grid, dtype=np.float32)
    xg, wg = np.meshgrid(g, g)
    target = (xg * wg).ravel()

    def mult_at(a: float) -> np.ndarray:
        args = np.stack([a + wg + xg, a + wg - xg, a - wg - xg, a - wg + xg])
        h = _proto_unit_np(args.reshape(4, -1).astype(np.float32), s, c)
        return h[0] - h[1] + h[2] - h[3]

    best = None
    for a in np.linspace(-1.5, 1.5, 31):
        y = mult_at(float(a))
        den = float(y @ y)
        if den < 1e-12:
            continue
        scale = float(y @ target) / den
        err = float(np.abs(scale * y - target).max())
        if best is None or err < best[0]:
            best = (err, float(a), scale)
    assert best is not None, "multiplier calibration degenerate"
    return best[1], best[2]


def multiply(x, w, s: int = 3, c: float = 1.0, calib: Tuple[float, float] | None = None):
    """Four-quadrant S-AC multiply ``y ~ x*w`` (eq. 24, Fig. 11).

    ``x`` and ``w`` broadcast; returns the calibrated product estimate.
    """
    if calib is None:
        calib = calibrate_multiplier(s, c)
    a, scale = calib
    y = (proto_unit(a + w + x, s, c) - proto_unit(a + w - x, s, c)
         + proto_unit(a - w - x, s, c) - proto_unit(a - w + x, s, c))
    return scale * y


# ---------------------------------------------------------------------------
# WTA family (Fig. 9, eq. 22-23)
# ---------------------------------------------------------------------------


def wta_outputs(x, c):
    """Per-input WTA/SoftArgMax outputs ``I_out_i = [x_i − h]_+`` (eq. 23).

    ``h`` is the shared GMP node; with small ``C`` only the winner stays
    above ``h`` (WTA / Max), larger ``C`` admits more winners (N-of-M).
    """
    h = gmp(x, c)
    return jnp.maximum(x - h[..., None], 0.0)


def nofm_current(x, c):
    """Composite N-of-M output current (eq. 22): sum of winner residues."""
    return jnp.sum(wta_outputs(x, c), axis=-1)


def max_cell(x, c: float = 1e-3):
    """Max selector: ``C -> 0`` limit of the WTA (Sec. IV-J)."""
    return gmp(x, c) + c / 1.0  # h -> max(x) - C/k, k=1 winner


def softargmax(x, c):
    """Normalized winner weights — differentiable argmax (Sec. IV-I)."""
    y = wta_outputs(x, c)
    return y / jnp.maximum(jnp.sum(y, axis=-1, keepdims=True), 1e-30)
