"""Appendix-A spline schedule for the Generalized Margin Propagation.

The paper approximates ``e^x`` with ``S`` linear splines tangent at points
``Q_1..Q_S`` (eq. 43-48).  With the dyadic choice ``e^{Q_{j+1}} = 2 e^{Q_j}``
all spline *increments* are equal (eq. 52's uniform 1/2 coefficients), so the
multi-spline expansion reduces to unit-slope ReLU branches with per-spline
offsets ``O_j`` — exactly one extra transistor branch per spline in the
circuit (Fig. 2b).

Schedule (matches the paper's S=3 worked example, eq. 49-53):

    Q_j = (j - (S+1)/2) * ln 2                       (symmetric dyadic)
    T_1 = Q_1 - 1                                    (tangent x-intercept)
    T_j = 2 Q_j - Q_{j-1} - 1        for j > 1       (eq. 46 with dyadic Q)
    O_j = -C * T_j                                   (eq. 53)
    C'  = C / e^{Q_1}                                (unit-slope rescale)

For S=3, C=1 this reproduces O = C(1+ln2), C(1-ln2), C(1-2ln2) and C' = 2C.
"""

from __future__ import annotations

import math
from typing import Tuple

import numpy as np

LN2 = math.log(2.0)


def tangent_points(s: int) -> np.ndarray:
    """Dyadic tangent points ``Q_1..Q_S`` (symmetric about 0)."""
    if s < 1:
        raise ValueError("spline count must be >= 1")
    return np.array([(j - (s + 1) / 2.0) * LN2 for j in range(1, s + 1)])


def tuning_points(s: int) -> np.ndarray:
    """Tuning (break) points ``T_1..T_S`` per Appendix A eq. 46/49-51."""
    q = tangent_points(s)
    t = np.empty(s)
    t[0] = q[0] - 1.0
    for j in range(1, s):
        t[j] = 2.0 * q[j] - q[j - 1] - 1.0
    return t


def schedule(s: int, c: float) -> Tuple[np.ndarray, float]:
    """Return ``(offsets O_j, rescaled constraint C')`` for an S-spline unit."""
    t = tuning_points(s)
    offsets = -c * t
    c_prime = c / math.exp(tangent_points(s)[0])
    return offsets.astype(np.float32), float(c_prime)


def exp_spline_approx(x: np.ndarray, s: int) -> np.ndarray:
    """Open-loop S-spline approximation of ``e^x`` (paper eq. 48, Fig. 2a).

    Used by the Fig. 2a repro harness and as a sanity anchor for the unit
    tests: the approximation error must shrink monotonically with ``S``.
    """
    q = tangent_points(s)
    t = tuning_points(s)
    eq = np.exp(q)
    coef = np.empty(s)
    for j in range(s):
        coef[j] = eq[j] - eq[:j].sum()
    x = np.asarray(x)
    out = np.zeros_like(x, dtype=np.float64)
    for j in range(s):
        out += coef[j] * np.maximum(x - t[j], 0.0)
    return out
