"""AOT export: lower the S-AC graphs to HLO *text* for the rust runtime.

This is the only bridge between the python build path and the rust request
path.  Interchange is HLO **text**, never ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--outdir`` (default ``../artifacts``):

  * ``gmp_kernel.hlo.txt``    — the Layer-1 Pallas GMP kernel alone
                                (B x M batched solve), the rust hot path's
                                microkernel and the runtime smoke test.
  * ``<task>_mlp.hlo.txt``    — full S-AC inference graphs (weights are
                                *parameters*, so rust feeds the trained
                                weights from ``weights_<task>.json``).
  * ``goldens_gmp.json``      — deterministic input/output vectors consumed
                                by rust unit tests (cross-language parity).
  * ``manifest.json``         — shapes/dtypes/parameter order per artifact.

Python never runs at serving time: ``make artifacts`` is a no-op when
outputs are newer than their inputs (Makefile dependency).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.gmp import gmp_solve_pallas
from .kernels.ref import gmp_solve_ref
from .sacml import nets, ops

# Batch sizes baked into the AOT executables (one compiled variant each).
GMP_B, GMP_M = 4096, 8
TASK_BATCH = {"xor": 64, "arem": 64, "digits": 64}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_gmp_kernel(outdir: str, manifest: dict) -> None:
    """Lower the Pallas GMP kernel (interpret=True -> plain HLO)."""
    c = 1.0

    def fn(x):
        return (gmp_solve_pallas(x, c),)

    spec = jax.ShapeDtypeStruct((GMP_B, GMP_M), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    path = os.path.join(outdir, "gmp_kernel.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["gmp_kernel"] = {
        "file": "gmp_kernel.hlo.txt",
        "params": [{"name": "x", "shape": [GMP_B, GMP_M], "dtype": "f32"}],
        "outputs": [{"name": "h", "shape": [GMP_B], "dtype": "f32"}],
        "c": c,
    }
    print(f"  gmp_kernel.hlo.txt  ({len(text)} chars)")


def export_task_mlp(task: str, outdir: str, manifest: dict) -> bool:
    """Lower one task's S-AC inference graph. Weights are parameters."""
    wpath = os.path.join(outdir, f"weights_{task}.json")
    if not os.path.exists(wpath):
        print(f"  !! weights_{task}.json missing — run training first; skipped")
        return False
    with open(wpath) as f:
        blob = json.load(f)
    sizes = blob["sizes"]
    s, c = blob["splines"], blob["c"]
    act = blob["activation"]
    batch = TASK_BATCH[task]

    # Inference routes through the bisection solver — the same algorithm the
    # Pallas kernel and the rust solver implement (DESIGN.md §6 tier chain).
    def fn(*args):
        nl = len(sizes) - 1
        params = {}
        for li in range(nl):
            params[f"w{li + 1}"] = args[2 * li]
            params[f"b{li + 1}"] = args[2 * li + 1]
        x = args[-1]
        ops.set_solver("bisect")
        try:
            logits = nets.sac_forward(params, x, s=s, c=c, activation=act)
        finally:
            ops.set_solver("exact")
        return (logits,)

    specs = []
    pspec = []
    for li in range(len(sizes) - 1):
        specs.append(jax.ShapeDtypeStruct((sizes[li], sizes[li + 1]), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((sizes[li + 1],), jnp.float32))
        pspec.append({"name": f"w{li + 1}", "shape": [sizes[li], sizes[li + 1]], "dtype": "f32"})
        pspec.append({"name": f"b{li + 1}", "shape": [sizes[li + 1]], "dtype": "f32"})
    specs.append(jax.ShapeDtypeStruct((batch, sizes[0]), jnp.float32))
    pspec.append({"name": "x", "shape": [batch, sizes[0]], "dtype": "f32"})

    text = to_hlo_text(jax.jit(fn).lower(*specs))
    fname = f"{task}_mlp.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    manifest[f"{task}_mlp"] = {
        "file": fname,
        "params": pspec,
        "outputs": [{"name": "logits", "shape": [batch, sizes[-1]], "dtype": "f32"}],
        "sizes": sizes, "splines": s, "c": c, "activation": act,
    }
    print(f"  {fname}  ({len(text)} chars)")
    return True


def export_goldens(outdir: str) -> None:
    """Deterministic GMP + cell golden vectors for rust cross-checks."""
    rng = np.random.RandomState(42)
    cases = []
    for (b, m, c) in [(4, 3, 1.0), (8, 6, 2.0), (2, 12, 0.25), (16, 8, 5.0)]:
        x = rng.uniform(-3.0, 3.0, size=(b, m)).astype(np.float32)
        h = np.asarray(gmp_solve_ref(x, c))
        cases.append({"c": c, "x": x.tolist(), "h": h.tolist()})
    z = np.linspace(-3.0, 1.5, 19).astype(np.float32)
    cells = {
        "proto_s1": np.asarray(ops.proto_unit(jnp.asarray(z), 1, 1.0)).tolist(),
        "proto_s3": np.asarray(ops.proto_unit(jnp.asarray(z), 3, 1.0)).tolist(),
        "relu": np.asarray(ops.relu_cell(jnp.asarray(z), 0.05)).tolist(),
        "phi1": np.asarray(ops.phi1_cell(jnp.asarray(z))).tolist(),
        "cosh": np.asarray(ops.cosh_cell(jnp.asarray(z))).tolist(),
        "sinh": np.asarray(ops.sinh_cell(jnp.asarray(z))).tolist(),
    }
    a, sc = ops.calibrate_multiplier(3, 1.0)
    with open(os.path.join(outdir, "goldens_gmp.json"), "w") as f:
        json.dump({"gmp": cases, "z": z.tolist(), "cells": cells,
                   "mult_calib_s3_c1": {"a": a, "scale": sc}}, f)
    print("  goldens_gmp.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--skip-mlp", action="store_true",
                    help="only export the GMP kernel + goldens")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest: dict = {}
    print("AOT export:")
    export_gmp_kernel(args.outdir, manifest)
    export_goldens(args.outdir)
    if not args.skip_mlp:
        for task in TASK_BATCH:
            export_task_mlp(task, args.outdir, manifest)
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("  manifest.json")


if __name__ == "__main__":
    main()
