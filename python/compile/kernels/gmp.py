"""Layer-1 Pallas kernel: batched Generalized Margin Propagation solve.

The compute hot-spot of the whole S-AC stack is the implicit solve

    find h[b]  s.t.  sum_j g(X[b, j] - h[b]) = C        (paper eq. 9)

evaluated for millions of rows per network forward pass (every synapse of
the S-AC multiplier and every activation cell is one such solve).  This
kernel maps it onto the TPU as a *branchless fixed-iteration bisection*:

  * grid over batch blocks; each program instance owns a ``(BLOCK_B, M)``
    VMEM tile of ``X`` plus three ``(BLOCK_B, 1)`` vectors (lo/hi/mid);
  * every iteration is one masked reduce + two selects over the tile —
    pure VPU work with data-independent control flow (``fori_loop`` with a
    static trip count), which is exactly what the TPU wants;
  * no HBM traffic inside the loop: the tile is streamed HBM->VMEM once by
    the BlockSpec pipeline and all 60 iterations run on-chip.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
"hardware" is an analog transistor array that solves eq. 9 by KCL in one
shot.  On a digital tensor core the same fixed point is reached by
bisection; 60 halvings of the bracket localize ``h`` to ~2^-60 of the
bracket width, far below analog mismatch noise (Fig. 4b: ~5%).

Run with ``interpret=True`` everywhere in this repo: the CPU PJRT client
cannot execute Mosaic custom-calls, and interpret-mode lowers the kernel
to plain HLO so the *same* artifact runs under the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GMP_ITERS, SHAPE_RELU, SHAPE_SOFTPLUS

# Default batch-block size.  VMEM budget (v4: ~16 MiB/core): a tile of
# f32[BLOCK_B, M] with M <= 64 plus three f32[BLOCK_B] vectors is
# 256*64*4 B = 64 KiB << VMEM, leaving room for double buffering of the
# next tile while this one iterates.
BLOCK_B = 256


def _gmp_kernel(x_ref, o_ref, *, c: float, shape: int, width: float,
                iters: int):
    """Kernel body: one batch block, full solve in VMEM."""
    x = x_ref[...]  # (block_b, M)
    hi = jnp.max(x, axis=-1)
    pad = 4.0 * width if shape != SHAPE_RELU else 0.0
    lo = hi - c - pad

    def g(z):
        if shape == SHAPE_RELU:
            return jnp.maximum(z, 0.0)
        w = jnp.float32(width)
        return w * jnp.logaddexp(jnp.zeros_like(z), z / w)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        s = jnp.sum(g(x - mid[:, None]), axis=-1)
        gt = s > c
        return jnp.where(gt, mid, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    o_ref[...] = 0.5 * (lo + hi)


def gmp_solve_pallas(x, c: float, shape: int = SHAPE_RELU,
                     width: float = 0.05, iters: int = GMP_ITERS,
                     block_b: int = BLOCK_B, interpret: bool = True):
    """Batched GMP solve as a Pallas kernel.

    Args:
      x:        ``[B, M]`` f32 spline-expanded inputs.
      c:        normalization constant (static python float).
      shape:    ``SHAPE_RELU`` / ``SHAPE_SOFTPLUS``.
      width:    knee width for the soft shape.
      iters:    bisection iterations (static).
      block_b:  batch tile size (grid = ceil(B / block_b)).
      interpret: keep True on CPU (Mosaic custom-calls don't run on the
        CPU PJRT plugin); structure is identical either way.

    Returns:
      ``h`` of shape ``[B]``.
    """
    b, m = x.shape
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    kern = functools.partial(_gmp_kernel, c=float(c), shape=shape,
                             width=float(width), iters=iters)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# Differentiable wrapper: implicit-function VJP so the S-AC network can be
# trained through the solve (bisection itself is not differentiated).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def gmp(x, c: float, shape: int = SHAPE_RELU, width: float = 0.05,
        use_pallas: bool = False):
    """Differentiable GMP solve over the last axis of ``x``.

    ``use_pallas=True`` routes the forward pass through the Pallas kernel
    (2-D inputs only); otherwise the pure-jnp oracle is used.  Both are the
    same math; the flag exists so the AOT export can embed the kernel while
    training uses the cheaper-to-trace oracle.
    """
    from .ref import gmp_solve_ref
    if use_pallas and x.ndim == 2:
        return gmp_solve_pallas(x, c, shape=shape, width=width)
    return gmp_solve_ref(x, c, shape=shape, width=width)


def _gmp_fwd(x, c, shape, width, use_pallas):
    h = gmp(x, c, shape, width, use_pallas)
    return h, (x, h)


def _gmp_bwd(c, shape, width, use_pallas, res, dh):
    from .ref import gmp_grad_ref
    x, h = res
    grad = gmp_grad_ref(x, h, shape=shape, width=width)
    return (grad * dh[..., None],)


gmp.defvjp(_gmp_fwd, _gmp_bwd)
